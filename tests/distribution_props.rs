//! Property-based tests for the §3 distribution strategies, using the
//! in-repo mini property-testing framework (proptest is unavailable
//! offline). Invariants:
//!
//! * completeness — every written element is assigned exactly once
//!   (all strategies, any input);
//! * binpacking — no reader exceeds 2x the ideal volume;
//! * hyperslabs — per-reader volume within one row of ideal;
//! * round-robin — slices are exactly the written chunks;
//! * by-hostname — co-scheduled layouts yield 100% locality.

use openpmd_stream::distribution::{
    by_name, metrics, verify_complete, Binpacking, ByHostname, ChunkTable,
    Hyperslabs, LoadBalanced, ReaderLayout, RoundRobin, Strategy,
};
use openpmd_stream::openpmd::chunk::{Chunk, WrittenChunkInfo};
use openpmd_stream::prop_assert;
use openpmd_stream::testing::{check_with, Config, Gen};
use openpmd_stream::util::rng::Rng;

/// A random distribution problem: chunk table + reader layout.
#[derive(Clone, Debug)]
struct Problem {
    table: ChunkTable,
    readers: ReaderLayout,
    /// True when writers and readers share hostnames node-for-node.
    co_scheduled: bool,
}

struct ProblemGen {
    max_nodes: usize,
    max_writers_per_node: usize,
    max_chunk: u64,
}

impl Gen for ProblemGen {
    type Value = Problem;

    fn generate(&self, rng: &mut Rng) -> Problem {
        let nodes = rng.range(1, self.max_nodes + 1);
        let writers_per_node = rng.range(1, self.max_writers_per_node + 1);
        let co_scheduled = rng.chance(0.5);
        let readers_per_node = rng.range(1, 4);

        let mut chunks = Vec::new();
        let mut off = 0u64;
        for node in 0..nodes {
            for w in 0..writers_per_node {
                // Some writers contribute several chunks, some none.
                let n_chunks = rng.range(0, 3);
                for _ in 0..n_chunks {
                    let size = rng.below(self.max_chunk) + 1;
                    chunks.push(WrittenChunkInfo::new(
                        Chunk::new(vec![off], vec![size]),
                        node * writers_per_node + w,
                        format!("node{node:04}"),
                    ));
                    off += size;
                }
            }
        }
        let readers = if co_scheduled {
            ReaderLayout::nodes(nodes, readers_per_node).unwrap()
        } else {
            // Readers on a disjoint or partially overlapping node set.
            let reader_nodes = rng.range(1, nodes + 2);
            let mut l =
                ReaderLayout::nodes(reader_nodes, readers_per_node)
                    .unwrap();
            if rng.chance(0.5) {
                for r in l.ranks.iter_mut() {
                    r.hostname = format!("other-{}", r.hostname);
                }
            }
            l
        };
        Problem {
            table: ChunkTable { dataset_extent: vec![off], chunks },
            readers,
            co_scheduled,
        }
    }

    fn shrink(&self, p: &Problem) -> Vec<Problem> {
        let mut out = Vec::new();
        // Fewer chunks.
        if !p.table.chunks.is_empty() {
            for cut in [p.table.chunks.len() / 2, p.table.chunks.len() - 1] {
                let mut q = p.clone();
                q.table.chunks.truncate(cut);
                q.table.dataset_extent = vec![q
                    .table
                    .chunks
                    .iter()
                    .map(|c| c.chunk.offset[0] + c.chunk.extent[0])
                    .max()
                    .unwrap_or(0)];
                out.push(q);
            }
        }
        // Fewer readers.
        if p.readers.ranks.len() > 1 {
            let mut q = p.clone();
            q.readers.ranks.truncate(p.readers.ranks.len() / 2);
            out.push(q);
        }
        out
    }
}

fn cfg(cases: usize) -> Config {
    Config { cases, seed: 0x5EED_2021, shrink_steps: 500 }
}

fn gen() -> ProblemGen {
    ProblemGen { max_nodes: 6, max_writers_per_node: 4, max_chunk: 1000 }
}

fn all_strategies() -> Vec<Box<dyn Strategy>> {
    vec![
        Box::new(RoundRobin),
        Box::new(Hyperslabs),
        Box::new(Binpacking),
        Box::new(LoadBalanced),
        Box::new(ByHostname::paper_default()),
        by_name("hostname:roundrobin:hyperslabs").unwrap(),
        by_name("hostname:loadbalanced:loadbalanced").unwrap(),
    ]
}

#[test]
fn all_strategies_are_complete() {
    check_with(cfg(150), &gen(), |p| {
        for strat in all_strategies() {
            let a = strat.distribute(&p.table, &p.readers);
            if let Err(e) = verify_complete(&p.table, &a) {
                return Err(format!("{}: {e}", strat.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn binpacking_never_exceeds_double_ideal() {
    check_with(cfg(150), &gen(), |p| {
        if p.readers.is_empty() || p.table.chunks.is_empty() {
            return Ok(());
        }
        let a = Binpacking.distribute(&p.table, &p.readers);
        let ideal = p
            .table
            .total_elements()
            .div_ceil(p.readers.len() as u64);
        for r in &p.readers.ranks {
            let load = a.elements_for(r.rank);
            prop_assert!(
                load <= 2 * ideal,
                "reader {} got {load}, ideal {ideal}",
                r.rank
            );
        }
        Ok(())
    });
}

#[test]
fn hyperslabs_balance_within_one_row_equivalent() {
    check_with(cfg(150), &gen(), |p| {
        if p.readers.is_empty() {
            return Ok(());
        }
        let a = Hyperslabs.distribute(&p.table, &p.readers);
        let rows = p.table.dataset_extent[0];
        let n = p.readers.len() as u64;
        // Every reader's *slab* is balanced; its assigned volume is the
        // slab intersected with written chunks, which here tile the slab
        // fully, so volumes differ by at most one row-equivalent.
        let max = p
            .readers
            .ranks
            .iter()
            .map(|r| a.elements_for(r.rank))
            .max()
            .unwrap();
        let min = p
            .readers
            .ranks
            .iter()
            .map(|r| a.elements_for(r.rank))
            .min()
            .unwrap();
        let row_equiv = rows.div_ceil(n.max(1)) + 1;
        prop_assert!(
            max - min <= row_equiv,
            "imbalance {max}-{min} > {row_equiv}"
        );
        Ok(())
    });
}

#[test]
fn round_robin_preserves_written_chunks_exactly() {
    check_with(cfg(150), &gen(), |p| {
        if p.readers.is_empty() {
            return Ok(());
        }
        let a = RoundRobin.distribute(&p.table, &p.readers);
        let assigned = a.total_slices();
        prop_assert!(
            assigned == p.table.chunks.len(),
            "{assigned} slices for {} chunks",
            p.table.chunks.len()
        );
        for slices in a.per_reader.values() {
            for s in slices {
                prop_assert!(
                    p.table.chunks.iter().any(|c| c.chunk == s.chunk
                        && c.source_rank == s.source_rank),
                    "slice {:?} is not a written chunk",
                    s.chunk
                );
            }
        }
        Ok(())
    });
}

#[test]
fn by_hostname_is_fully_local_when_co_scheduled() {
    check_with(cfg(150), &gen(), |p| {
        if !p.co_scheduled || p.table.chunks.is_empty() {
            return Ok(());
        }
        let a = ByHostname::paper_default().distribute(&p.table, &p.readers);
        let q = metrics::quality(&p.table, &p.readers, &a);
        prop_assert!(
            (q.locality_fraction - 1.0).abs() < 1e-12,
            "locality {} < 1 on co-scheduled layout",
            q.locality_fraction
        );
        Ok(())
    });
}

#[test]
fn slices_stay_within_their_source_chunks() {
    // No strategy may fabricate data: every slice must be contained in a
    // written chunk of the same source rank.
    check_with(cfg(100), &gen(), |p| {
        for strat in all_strategies() {
            let a = strat.distribute(&p.table, &p.readers);
            for slices in a.per_reader.values() {
                for s in slices {
                    let ok = p.table.chunks.iter().any(|c| {
                        c.source_rank == s.source_rank
                            && c.chunk.contains(&s.chunk)
                    });
                    prop_assert!(
                        ok,
                        "{}: slice {:?} (rank {}) outside written chunks",
                        strat.name(),
                        s.chunk,
                        s.source_rank
                    );
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// LoadBalanced (LPT) properties
// ---------------------------------------------------------------------

/// A randomly *skewed* table: one straggler chunk at least as large as
/// all other chunks combined (the load-imbalanced-producer shape), in
/// a shuffled position, with announced byte costs on a coin flip.
#[derive(Clone, Debug)]
struct SkewedProblem {
    table: ChunkTable,
    readers: ReaderLayout,
}

struct SkewedGen {
    max_small: u64,
    max_small_count: usize,
}

impl Gen for SkewedGen {
    type Value = SkewedProblem;

    fn generate(&self, rng: &mut Rng) -> SkewedProblem {
        let n_small = rng.range(1, self.max_small_count + 1);
        let mut sizes: Vec<u64> = (0..n_small)
            .map(|_| rng.below(self.max_small) + 1)
            .collect();
        let small_sum: u64 = sizes.iter().sum();
        // The straggler dominates: >= the sum of everything else.
        sizes.push(small_sum + rng.below(small_sum + 1));
        rng.shuffle(&mut sizes);
        let announce_bytes = rng.chance(0.5);
        let mut chunks = Vec::new();
        let mut off = 0u64;
        for (i, &size) in sizes.iter().enumerate() {
            let mut info = WrittenChunkInfo::new(
                Chunk::new(vec![off], vec![size]),
                i,
                format!("node{:04}", i % 3),
            );
            if announce_bytes {
                // Byte costs proportional to elements (f32 payloads):
                // dominance carries over to the byte scale.
                info = info.with_encoded_bytes(size * 4);
            }
            chunks.push(info);
            off += size;
        }
        SkewedProblem {
            table: ChunkTable { dataset_extent: vec![off], chunks },
            readers: ReaderLayout::local(rng.range(1, 9)).unwrap(),
        }
    }
}

/// On straggler-dominated tables the LPT bound is exact: the straggler
/// IS the makespan, so LoadBalanced's max-rank byte load can never
/// exceed RoundRobin's (which may deal extra chunks onto the
/// straggler's rank). This is the PR's acceptance property.
#[test]
fn loadbalanced_max_load_never_exceeds_round_robin_on_skewed_tables() {
    let gen = SkewedGen { max_small: 800, max_small_count: 12 };
    check_with(cfg(200), &gen, |p| {
        let lb = LoadBalanced.distribute(&p.table, &p.readers);
        let rr = RoundRobin.distribute(&p.table, &p.readers);
        if let Err(e) = verify_complete(&p.table, &lb) {
            return Err(format!("loadbalanced incomplete: {e}"));
        }
        let (lb_max, rr_max) =
            (lb.max_cost(&p.readers), rr.max_cost(&p.readers));
        prop_assert!(
            lb_max <= rr_max,
            "LPT max load {lb_max} > RoundRobin {rr_max} on a \
             straggler-dominated table"
        );
        Ok(())
    });
}

/// On *arbitrary* random tables RoundRobin can get lucky, so the
/// provable relation is Graham's LPT guarantee transferred through
/// OPT <= RR: 3 * LPT_max <= 4 * RR_max, always.
#[test]
fn loadbalanced_within_graham_bound_of_round_robin() {
    check_with(cfg(150), &gen(), |p| {
        if p.readers.is_empty() {
            return Ok(());
        }
        let lb = LoadBalanced.distribute(&p.table, &p.readers);
        let rr = RoundRobin.distribute(&p.table, &p.readers);
        let (lb_max, rr_max) =
            (lb.max_cost(&p.readers), rr.max_cost(&p.readers));
        prop_assert!(
            3 * (lb_max as u128) <= 4 * (rr_max as u128),
            "LPT max {lb_max} beyond 4/3 of RoundRobin {rr_max}"
        );
        Ok(())
    });
}

/// Cost-awareness: when announced byte sizes disagree with element
/// counts, LoadBalanced balances the bytes. Equal-element chunks where
/// one compressed 8x worse must see the heavy chunk isolated.
#[test]
fn loadbalanced_balances_announced_bytes() {
    let mk = |off: u64, rank: usize, bytes: u64| {
        WrittenChunkInfo::new(Chunk::new(vec![off], vec![100]), rank, "h")
            .with_encoded_bytes(bytes)
    };
    let table = ChunkTable {
        dataset_extent: vec![500],
        chunks: vec![
            mk(0, 0, 8000),
            mk(100, 1, 1000),
            mk(200, 2, 1000),
            mk(300, 3, 1000),
            mk(400, 4, 1000),
        ],
    };
    let readers = ReaderLayout::local(2).unwrap();
    let a = LoadBalanced.distribute(&table, &readers);
    verify_complete(&table, &a).unwrap();
    // Elements say 300 vs 200; bytes say 8000 vs 4000 — the byte view
    // must win: the heavy chunk alone on one rank.
    assert_eq!(a.max_cost(&readers), 8000);
}

#[test]
fn assignments_are_deterministic() {
    check_with(cfg(50), &gen(), |p| {
        for strat in all_strategies() {
            let a = strat.distribute(&p.table, &p.readers);
            let b = strat.distribute(&p.table, &p.readers);
            for r in &p.readers.ranks {
                prop_assert!(
                    a.slices(r.rank) == b.slices(r.rank),
                    "{} is nondeterministic",
                    strat.name()
                );
            }
        }
        Ok(())
    });
}
