//! Integration tests for the two-path `openpmd-pipe`: parallel pipe
//! instances over one source, staged-vs-serial identity at several
//! depths, and staged error propagation (no deadlock).

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use openpmd_stream::adios::bp::{BpReader, BpWriter, WriterCtx};
use openpmd_stream::adios::engine::{cast, Engine, StepStatus, VarDecl};
use openpmd_stream::distribution::{ReaderLayout, RoundRobin};
use openpmd_stream::openpmd::chunk::Chunk;
use openpmd_stream::openpmd::types::Datatype;
use openpmd_stream::pipeline::pipe::{run, run_pipe, PipeOptions};
use openpmd_stream::testing::engines::{
    InjectedEngine, INJECTED_STORE_FAULT,
};
use openpmd_stream::testing::fixtures;

const VAR: &str = "/data/x";
const EXTENT: u64 = 16;
const CHUNKS: u64 = 4;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("opmd-staged-{name}-{}", std::process::id()))
}

/// A BP source whose steps each carry one `[16]` f32 variable written
/// as four chunks — element at global index `g` of step `s` holds
/// `s * 100 + g` (the shared fixture formula).
fn make_chunked_bp(path: &PathBuf, steps: u64) {
    fixtures::write_chunked_bp(path, steps, EXTENT, CHUNKS);
}

#[test]
fn two_round_robin_instances_forward_disjoint_complete_union() {
    let steps = 3u64;
    let src = tmp("par-src.bp");
    make_chunked_bp(&src, steps);

    // Two pipe instances over the same source, RoundRobin assignment.
    let mut outs = Vec::new();
    for rank in 0..2usize {
        let dst = tmp(&format!("par-dst{rank}.bp"));
        let mut input = BpReader::open(&src).unwrap();
        let mut output =
            BpWriter::create(&dst, WriterCtx::default()).unwrap();
        let opts = PipeOptions {
            rank,
            instances: 2,
            strategy: std::sync::Arc::new(RoundRobin),
            layout: ReaderLayout::local(2).unwrap(),
            max_steps: None,
            idle_timeout: Duration::from_secs(10),
            depth: 0,
            operators: None,
            metrics_sink: None,
        };
        let report = run_pipe(&mut input, &mut output, opts).unwrap();
        assert_eq!(report.steps, steps);
        assert!(report.chunks > 0, "instance {rank} forwarded nothing");
        outs.push(dst);
    }

    // Per step, the union of the two outputs' chunks must cover every
    // element exactly once (complete AND disjoint), with right values.
    let mut readers: Vec<BpReader> =
        outs.iter().map(|p| BpReader::open(p).unwrap()).collect();
    for s in 0..steps {
        let mut covered: BTreeSet<u64> = BTreeSet::new();
        for (rank, reader) in readers.iter_mut().enumerate() {
            assert_eq!(reader.begin_step().unwrap(), StepStatus::Ok);
            for info in reader.available_chunks(VAR) {
                let data =
                    reader.get(VAR, info.chunk.clone()).unwrap();
                let xs = cast::bytes_to_f32(&data).unwrap();
                let off = info.chunk.offset[0];
                for (i, &x) in xs.iter().enumerate() {
                    let g = off + i as u64;
                    assert_eq!(x, (s * 100 + g) as f32,
                               "step {s} rank {rank} elem {g}");
                    assert!(covered.insert(g),
                            "step {s}: element {g} forwarded twice");
                }
            }
            reader.end_step().unwrap();
        }
        assert_eq!(covered.len() as u64, EXTENT,
                   "step {s}: union incomplete ({covered:?})");
    }
    std::fs::remove_file(&src).ok();
    for p in outs {
        std::fs::remove_file(&p).ok();
    }
}

#[test]
fn staged_output_is_byte_identical_to_serial() {
    let steps = 5u64;
    let src = tmp("ident-src.bp");
    make_chunked_bp(&src, steps);

    let run_with_depth = |depth: usize, dst: &PathBuf| {
        let mut input = BpReader::open(&src).unwrap();
        let mut output =
            BpWriter::create(dst, WriterCtx::default()).unwrap();
        let mut opts = PipeOptions::solo();
        opts.depth = depth;
        run(&mut input, &mut output, opts).unwrap()
    };

    let d_serial = tmp("ident-serial.bp");
    let d_two = tmp("ident-depth2.bp");
    let d_four = tmp("ident-depth4.bp");
    let serial = run_with_depth(0, &d_serial);
    let two = run_with_depth(2, &d_two);
    let four = run_with_depth(4, &d_four);
    for r in [&serial, &two, &four] {
        assert_eq!(r.steps, steps);
        assert_eq!(r.dropped_steps, 0);
        assert_eq!(r.bytes_out, steps * EXTENT * 4);
        assert_eq!(r.chunks, steps * CHUNKS);
    }

    let want = std::fs::read(&d_serial).unwrap();
    assert_eq!(want, std::fs::read(&d_two).unwrap(),
               "depth-2 output differs from serial");
    assert_eq!(want, std::fs::read(&d_four).unwrap(),
               "depth-4 output differs from serial");

    std::fs::remove_file(&src).ok();
    for p in [d_serial, d_two, d_four] {
        std::fs::remove_file(&p).ok();
    }
}

#[test]
fn staged_store_failure_propagates_and_joins_without_deadlock() {
    let src = tmp("fail-src.bp");
    make_chunked_bp(&src, 8);
    let dst = tmp("fail-dst.bp");

    let mut input = BpReader::open(&src).unwrap();
    let inner = BpWriter::create(&dst, WriterCtx::default()).unwrap();
    // Steps 0 and 1 store fine; step 2's batch execution dies while the
    // fetch thread is several steps ahead (depth 3) — the failure must
    // unwind the fetch stage through the dropped queue, not deadlock it.
    let mut output = InjectedEngine::failing(inner, 2);
    let mut opts = PipeOptions::solo();
    opts.depth = 3;

    let started = Instant::now();
    let err = run(&mut input, &mut output, opts).unwrap_err();
    assert!(format!("{err:#}").contains(INJECTED_STORE_FAULT), "{err:#}");
    // Generous bound: a deadlocked join would hang until the harness
    // timeout, a clean shutdown returns in milliseconds.
    assert!(started.elapsed() < Duration::from_secs(30));

    std::fs::remove_file(&src).ok();
    std::fs::remove_file(&dst).ok();
}

#[test]
fn staged_reports_match_serial_reports() {
    // Same accounting code on both paths: counters must agree exactly.
    let src = tmp("acct-src.bp");
    make_chunked_bp(&src, 4);
    let totals = |depth: usize| {
        let dst = tmp(&format!("acct-dst{depth}.bp"));
        let mut input = BpReader::open(&src).unwrap();
        let mut output =
            BpWriter::create(&dst, WriterCtx::default()).unwrap();
        let mut opts = PipeOptions::solo();
        opts.depth = depth;
        opts.max_steps = Some(3);
        let r = run(&mut input, &mut output, opts).unwrap();
        std::fs::remove_file(&dst).ok();
        (r.steps, r.dropped_steps, r.bytes_in, r.bytes_out, r.chunks)
    };
    assert_eq!(totals(0), totals(2));
    std::fs::remove_file(&src).ok();
}

#[test]
fn staged_max_steps_over_quiet_stream_returns_promptly() {
    use openpmd_stream::adios::sst::{
        QueueConfig, QueueFullPolicy, SstReader, SstReaderOptions,
        SstWriter, SstWriterOptions,
    };

    // Publish 3 steps, then leave the writer OPEN: the stream goes
    // quiet but does not end.
    let mut writer = SstWriter::open(SstWriterOptions {
        listen: format!("staged-quiet-{}", std::process::id()),
        transport: "inproc".into(),
        rank: 0,
        hostname: "n0".into(),
        queue: QueueConfig { policy: QueueFullPolicy::Block, limit: 8 },
        group: None,
        ..Default::default()
    })
    .unwrap();
    let addr = writer.address();
    let var = VarDecl::new("/x", Datatype::F32, vec![4]);
    for s in 0..3 {
        writer.begin_step().unwrap();
        writer
            .put(&var, Chunk::whole(vec![4]),
                 cast::f32_to_bytes(&[s as f32; 4]))
            .unwrap();
        writer.end_step().unwrap();
    }

    let mut input = SstReader::open(SstReaderOptions {
        writers: vec![addr],
        transport: "inproc".into(),
        rank: 0,
        hostname: "n0".into(),
        begin_step_timeout: Duration::from_millis(50),
        codecs: None,
    })
    .unwrap();
    let dst = tmp("quiet-dst.bp");
    let mut output = BpWriter::create(&dst, WriterCtx::default()).unwrap();
    let mut opts = PipeOptions::solo();
    opts.depth = 2;
    opts.max_steps = Some(3);
    opts.idle_timeout = Duration::from_secs(30);

    let started = Instant::now();
    let report = run(&mut input, &mut output, opts).unwrap();
    assert_eq!(report.steps, 3);
    // After the 3rd forward the fetch stage was polling a quiet-but-
    // open stream; the stop flag must wind it down promptly — waiting
    // out the 30 s idle timeout (or failing the run with "pipe idle")
    // would regress the max_steps contract.
    assert!(started.elapsed() < Duration::from_secs(10),
            "staged pipe wound down too slowly: {:?}", started.elapsed());

    writer.close().unwrap();
    std::fs::remove_file(&dst).ok();
}
