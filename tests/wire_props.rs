//! Wire-frame property tests: random payloads round-trip through
//! `adios::wire` encode/decode, and corrupted length fields are decode
//! errors — never panics, never unbounded allocations.
//!
//! The generators are seeded with the repo's deterministic RNG so a
//! failure reproduces bit-for-bit.

use std::collections::BTreeMap;
use std::sync::Arc;

use openpmd_stream::adios::ops::OpChain;
use openpmd_stream::adios::wire::{
    decode_msg, encode_msg, GetItem, GetReply, Msg, StepMeta, VarMeta,
};
use openpmd_stream::openpmd::chunk::{Chunk, WrittenChunkInfo};
use openpmd_stream::openpmd::types::Datatype;
use openpmd_stream::openpmd::Attribute;
use openpmd_stream::util::rng::Rng;

fn random_payload(rng: &mut Rng, max: usize) -> Vec<u8> {
    let len = rng.below(max as u64 + 1) as usize;
    (0..len).map(|_| rng.next_u64() as u8).collect()
}

fn random_chunk(rng: &mut Rng) -> Chunk {
    let rank = rng.range(1, 4);
    let offset: Vec<u64> = (0..rank).map(|_| rng.below(100)).collect();
    let extent: Vec<u64> =
        (0..rank).map(|_| rng.below(100) + 1).collect();
    Chunk { offset, extent }
}

fn random_reply_msg(rng: &mut Rng) -> Msg {
    let n = rng.below(6) as usize;
    let items = (0..n)
        .map(|_| match rng.below(3) {
            // Includes 0-byte payloads (max bound inclusive of 0).
            0 => GetReply::Data(Arc::new(random_payload(rng, 300))),
            1 => GetReply::Encoded(Arc::new(random_payload(rng, 300))),
            _ => GetReply::Error(format!("err-{}", rng.below(1000))),
        })
        .collect();
    Msg::GetBatchReply { req_id: rng.next_u64(), items }
}

fn random_announce_msg(rng: &mut Rng) -> Msg {
    let mut attributes = BTreeMap::new();
    for i in 0..rng.below(4) {
        attributes.insert(format!("/a/{i}"),
                          Attribute::F64(rng.f64()));
    }
    let chains = ["", "shuffle", "shuffle|rle", "zfp:9|shuffle", "delta"];
    let vars = (0..rng.below(4))
        .map(|i| VarMeta {
            name: format!("/data/0/v{i}"),
            dtype: Datatype::F32,
            shape: vec![rng.below(1000) + 1],
            ops: OpChain::parse(chains[rng.range(0, chains.len())])
                .unwrap(),
            chunks: (0..rng.below(4))
                .map(|_| {
                    let info = WrittenChunkInfo::new(
                        random_chunk(rng),
                        rng.below(8) as usize,
                        "propnode",
                    );
                    // Exercise both the announced-size and the
                    // unknown-size (sentinel) encodings.
                    if rng.chance(0.5) {
                        info.with_encoded_bytes(rng.below(1 << 20))
                    } else {
                        info
                    }
                })
                .collect(),
        })
        .collect();
    Msg::StepAnnounce {
        step: rng.below(1 << 40),
        meta: StepMeta { attributes, vars },
    }
}

fn random_batch_msg(rng: &mut Rng) -> Msg {
    let items = (0..rng.below(6))
        .map(|i| GetItem {
            var: format!("/data/0/v{i}"),
            sel: random_chunk(rng),
        })
        .collect();
    Msg::GetBatch {
        req_id: rng.next_u64(),
        step: rng.below(1 << 30),
        items,
    }
}

fn random_msg(rng: &mut Rng) -> Msg {
    match rng.below(4) {
        0 => random_reply_msg(rng),
        1 => random_announce_msg(rng),
        2 => random_batch_msg(rng),
        _ => Msg::Hello {
            reader_rank: rng.below(64) as usize,
            hostname: format!("h{}", rng.below(100)),
            codecs: (0..rng.below(5))
                .map(|i| format!("codec{i}"))
                .collect(),
        },
    }
}

/// Semantic equality good enough for the property: re-encoding the
/// decoded message must reproduce the original bytes exactly.
#[test]
fn random_messages_round_trip_byte_exactly() {
    let mut rng = Rng::new(0xC0DEC);
    for trial in 0..300 {
        let msg = random_msg(&mut rng);
        let encoded = encode_msg(&msg);
        let decoded = decode_msg(&encoded)
            .unwrap_or_else(|e| panic!("trial {trial}: {e:#}"));
        let re = encode_msg(&decoded);
        assert_eq!(re, encoded, "trial {trial} not byte-stable");
    }
}

#[test]
fn zero_byte_and_empty_shapes_round_trip() {
    let msg = Msg::GetBatchReply {
        req_id: 1,
        items: vec![
            GetReply::Data(Arc::new(Vec::new())),
            GetReply::Encoded(Arc::new(Vec::new())),
            GetReply::Error(String::new()),
        ],
    };
    let encoded = encode_msg(&msg);
    assert_eq!(encode_msg(&decode_msg(&encoded).unwrap()), encoded);
    let empty_announce = Msg::StepAnnounce {
        step: 0,
        meta: StepMeta::default(),
    };
    let encoded = encode_msg(&empty_announce);
    assert_eq!(encode_msg(&decode_msg(&encoded).unwrap()), encoded);
}

/// Corrupted length fields — including ones far beyond the frame bound
/// (`u64::MAX`, which would wrap a naive `pos + n` check) — must be
/// rejected as errors, not panic or pre-allocate gigabytes.
#[test]
fn corrupted_length_fields_are_errors_not_panics() {
    let mut rng = Rng::new(0xBADF00D);
    for trial in 0..200 {
        let msg = random_msg(&mut rng);
        let encoded = encode_msg(&msg);
        if encoded.len() < 9 {
            continue;
        }
        // Overwrite a random 8-byte window with an implausible length.
        let at = rng.range(1, encoded.len() - 7);
        let mut corrupt = encoded.clone();
        let huge: u64 = match rng.below(3) {
            0 => u64::MAX,
            1 => u64::MAX / 2,
            _ => (1 << 40) + rng.below(1 << 20),
        };
        corrupt[at..at + 8].copy_from_slice(&huge.to_le_bytes());
        // Must return (Ok or Err), never panic — the assert is that we
        // get here at all; decode success is allowed when the window
        // happened to land inside payload bytes.
        let _ = decode_msg(&corrupt);
        let _ = trial;
    }
}

/// Random single-byte mutations never panic the decoder.
#[test]
fn random_mutations_never_panic_the_decoder() {
    let mut rng = Rng::new(7777);
    for _ in 0..300 {
        let msg = random_msg(&mut rng);
        let mut encoded = encode_msg(&msg);
        if encoded.is_empty() {
            continue;
        }
        for _ in 0..8 {
            let at = rng.range(0, encoded.len());
            encoded[at] = rng.next_u64() as u8;
        }
        let _ = decode_msg(&encoded);
    }
}

/// Truncation at every prefix length is an error or a valid shorter
/// message — never a panic (frame-bounded validation).
#[test]
fn every_truncation_is_handled() {
    let mut rng = Rng::new(31337);
    let msg = random_announce_msg(&mut rng);
    let encoded = encode_msg(&msg);
    for cut in 0..encoded.len() {
        let _ = decode_msg(&encoded[..cut]);
    }
}
