//! Endpoint-spec property tests: randomly composed `SourceSpec` /
//! `SinkSpec` strings round-trip through `parse` ↔ `Display`
//! bit-for-bit, and every degenerate form the grammar documents is a
//! *typed* [`SpecError`] — never a panic, never an accepted garbage
//! spec.
//!
//! The generators are seeded with the repo's deterministic RNG so a
//! failure reproduces bit-for-bit.

use openpmd_stream::adios::spec::{
    ReaderSlot, SinkSpec, SourceSpec, SpecError,
};
use openpmd_stream::util::rng::Rng;

/// A path-ish token with no reserved prefix or separator characters.
fn random_path(rng: &mut Rng) -> String {
    let stems = ["run", "dump", "series", "out", "steps"];
    let exts = ["bp", "h5bp", "data"];
    format!(
        "{}{}.{}",
        stems[rng.range(0, stems.len())],
        rng.below(1000),
        exts[rng.range(0, exts.len())],
    )
}

fn random_addr(rng: &mut Rng, tcp: bool) -> String {
    if tcp {
        format!("tcp://node{}:{}", rng.below(64), 1024 + rng.below(60000))
    } else {
        format!("hub-{}", rng.below(1000))
    }
}

/// Any parseable source form, including nested merge lists.
fn random_source(rng: &mut Rng, allow_compound: bool) -> SourceSpec {
    let top = if allow_compound { 5 } else { 2 };
    match rng.below(top) {
        0 => SourceSpec::Series { path: random_path(rng) },
        1 => SourceSpec::Shards {
            index: format!("{}.index.json", random_path(rng)),
        },
        2 => {
            let tcp = rng.chance(0.5);
            let n = rng.range(1, 4);
            SourceSpec::Sst {
                writers: (0..n).map(|_| random_addr(rng, tcp)).collect(),
            }
        }
        3 => {
            let tcp = rng.chance(0.5);
            SourceSpec::Serve { addr: random_addr(rng, tcp) }
        }
        _ => {
            let n = rng.range(1, 4);
            SourceSpec::Merge {
                children: (0..n)
                    .map(|_| random_source(rng, false))
                    .collect(),
            }
        }
    }
}

fn random_sink(rng: &mut Rng) -> SinkSpec {
    match rng.below(4) {
        0 => SinkSpec::Bp { path: random_path(rng) },
        1 => SinkSpec::Json { path: random_path(rng) },
        2 => {
            let tcp = rng.chance(0.5);
            SinkSpec::Sst { listen: random_addr(rng, tcp) }
        }
        _ => {
            let tcp = rng.chance(0.5);
            SinkSpec::Serve { listen: random_addr(rng, tcp) }
        }
    }
}

#[test]
fn source_specs_round_trip_parse_display() {
    let mut rng = Rng::new(0x5bec);
    for _ in 0..2000 {
        let spec = random_source(&mut rng, true);
        let rendered = spec.to_string();
        let reparsed = SourceSpec::parse(&rendered)
            .unwrap_or_else(|e| panic!("reparsing {rendered:?}: {e}"));
        assert_eq!(reparsed, spec, "round trip of {rendered:?}");
        // Display is canonical: a second round trip is a fixed point.
        assert_eq!(reparsed.to_string(), rendered);
    }
}

#[test]
fn sink_specs_round_trip_parse_display() {
    let mut rng = Rng::new(0x51a0);
    for _ in 0..2000 {
        let spec = random_sink(&mut rng);
        let rendered = spec.to_string();
        let reparsed = SinkSpec::parse(&rendered)
            .unwrap_or_else(|e| panic!("reparsing {rendered:?}: {e}"));
        assert_eq!(reparsed, spec, "round trip of {rendered:?}");
        assert_eq!(reparsed.to_string(), rendered);
    }
}

#[test]
fn legacy_flag_pairs_agree_with_parsed_specs() {
    let mut rng = Rng::new(0x1e6acf);
    for _ in 0..500 {
        let path = random_path(&mut rng);
        assert_eq!(
            SinkSpec::from_parts("bp", &path).unwrap(),
            SinkSpec::parse(&path).unwrap(),
        );
        assert_eq!(
            SinkSpec::from_parts("json", &path).unwrap(),
            SinkSpec::parse(&format!("json:{path}")).unwrap(),
        );
        let host = random_addr(&mut rng, false);
        // sst:tcp normalizes to the tcp:// form, so the resulting
        // spec round-trips through parse like any other.
        let tcp = SinkSpec::from_parts("sst:tcp", &host).unwrap();
        assert_eq!(tcp.transport(), "tcp");
        assert_eq!(SinkSpec::parse(&tcp.to_string()).unwrap(), tcp);
    }
}

#[test]
fn degenerate_specs_are_typed_errors_not_panics() {
    // Every documented grammar violation, plus fuzzed separators.
    assert!(matches!(SourceSpec::parse(""),
                     Err(SpecError::Empty { .. })));
    assert!(matches!(SourceSpec::parse("   "),
                     Err(SpecError::Empty { .. })));
    assert!(matches!(SourceSpec::parse("sst+"),
                     Err(SpecError::Empty { .. })));
    assert!(matches!(SourceSpec::parse("sst+a,,b"),
                     Err(SpecError::Empty { .. })));
    assert!(matches!(
        SourceSpec::parse("sst+tcp://h:1,plainname"),
        Err(SpecError::MixedTransports { tcp: 1, total: 2 })
    ));
    assert!(matches!(SourceSpec::parse("serve+a,b"),
                     Err(SpecError::ServeIsOneEndpoint { got: 2 })));
    assert!(matches!(SourceSpec::parse("serve+"),
                     Err(SpecError::Empty { .. })));
    assert!(matches!(SourceSpec::parse("shards:"),
                     Err(SpecError::MissingShardIndex)));
    assert!(matches!(SourceSpec::parse("merge:"),
                     Err(SpecError::Empty { .. })));
    assert!(matches!(SourceSpec::parse("merge:a,merge:b"),
                     Err(SpecError::NestedMerge)));
    assert!(matches!(SourceSpec::parse("merge:a,sst+w"),
                     Err(SpecError::StreamInMerge { .. })));
    assert!(matches!(SourceSpec::parse("merge:serve+hub,a"),
                     Err(SpecError::StreamInMerge { .. })));
    assert!(matches!(SinkSpec::parse(""),
                     Err(SpecError::Empty { .. })));
    assert!(matches!(SinkSpec::parse("bp:"),
                     Err(SpecError::Empty { .. })));
    assert!(matches!(SinkSpec::parse("json:"),
                     Err(SpecError::Empty { .. })));
    assert!(matches!(SinkSpec::parse("sst+"),
                     Err(SpecError::Empty { .. })));
    assert!(matches!(SinkSpec::from_parts("hdf5", "x"),
                     Err(SpecError::UnknownSinkEngine { .. })));
    assert!(matches!(SinkSpec::from_parts("bp", ""),
                     Err(SpecError::Empty { .. })));
}

#[test]
fn fuzzed_strings_never_panic_the_parsers() {
    let mut rng = Rng::new(0xf022);
    let alphabet: Vec<char> =
        "abz019+:,/.| sst merge shards serve".chars().collect();
    for _ in 0..5000 {
        let len = rng.range(0, 40);
        let s: String = (0..len)
            .map(|_| alphabet[rng.range(0, alphabet.len())])
            .collect();
        // Outcome is irrelevant; absence of panics (and of unbounded
        // recursion via merge nesting) is the property.
        let _ = SourceSpec::parse(&s);
        let _ = SinkSpec::parse(&s);
    }
}

#[test]
fn slots_validate_and_expose_their_coordinates() {
    let mut rng = Rng::new(0x510d);
    for _ in 0..500 {
        let readers = rng.range(1, 32);
        let rank = rng.range(0, readers);
        let slot = ReaderSlot::of(rank, readers).unwrap();
        assert_eq!(slot.rank(), rank);
        assert_eq!(slot.readers(), readers);
        assert!(matches!(
            ReaderSlot::of(readers, readers),
            Err(SpecError::BadSlot { .. })
        ));
    }
    assert_eq!(ReaderSlot::solo().rank(), 0);
    assert_eq!(ReaderSlot::solo().readers(), 1);
}
