//! Integration tests for the SST staging engine: writer/reader pairs over
//! both transports, queue policies, multi-writer streams, openPMD series
//! round trips, and failure injection.

use std::sync::Arc;
use std::time::Duration;

use openpmd_stream::adios::engine::{cast, Engine, StepStatus, VarDecl};
use openpmd_stream::adios::sst::{
    QueueConfig, QueueFullPolicy, SstReader, SstReaderOptions, SstWriter,
    SstWriterOptions, WriterGroup,
};
use openpmd_stream::openpmd::chunk::Chunk;
use openpmd_stream::openpmd::types::Datatype;
use openpmd_stream::openpmd::Attribute;

fn writer_opts(transport: &str, rank: usize, host: &str)
    -> SstWriterOptions
{
    SstWriterOptions {
        listen: String::new(), // auto
        transport: transport.into(),
        rank,
        hostname: host.into(),
        queue: QueueConfig { policy: QueueFullPolicy::Block, limit: 4 },
        group: None,
        ..Default::default()
    }
}

fn reader_opts(transport: &str, writers: Vec<String>) -> SstReaderOptions {
    SstReaderOptions {
        writers,
        transport: transport.into(),
        rank: 0,
        hostname: "localhost".into(),
        begin_step_timeout: Duration::from_secs(20),
        codecs: None,
    }
}

/// One writer, one reader, N steps with data verification.
fn single_pair_round_trip(transport: &str) {
    let mut opts = writer_opts(transport, 0, "nodeA");
    opts.listen = if transport == "inproc" {
        format!("pair-rt-{}", std::process::id())
    } else {
        String::new()
    };
    let mut writer = SstWriter::open(opts).unwrap();
    let addr = writer.address();
    let transport_owned = transport.to_string();

    let reader_thread = std::thread::spawn(move || {
        let mut reader =
            SstReader::open(reader_opts(&transport_owned, vec![addr]))
                .unwrap();
        let mut sums = Vec::new();
        loop {
            match reader.begin_step().unwrap() {
                StepStatus::Ok => {}
                StepStatus::EndOfStream => break,
                StepStatus::NotReady => continue,
                other => panic!("unexpected {other:?}"),
            }
            let vars = reader.available_variables();
            assert_eq!(vars.len(), 1);
            assert_eq!(
                reader.attribute("/series/author").unwrap().as_str(),
                Some("tester")
            );
            let chunks = reader.available_chunks(&vars[0].name);
            assert_eq!(chunks.len(), 1);
            assert_eq!(chunks[0].hostname, "nodeA");
            let data = reader
                .get(&vars[0].name, Chunk::whole(vars[0].shape.clone()))
                .unwrap();
            sums.push(cast::bytes_to_f32(&data).unwrap().iter().sum::<f32>());
            reader.end_step().unwrap();
        }
        reader.close().unwrap();
        sums
    });

    let var = VarDecl::new("/data/x", Datatype::F32, vec![64]);
    let mut want = Vec::new();
    for step in 0..5 {
        assert_eq!(writer.begin_step().unwrap(), StepStatus::Ok);
        writer
            .put_attribute("/series/author", Attribute::Str("tester".into()))
            .unwrap();
        let xs: Vec<f32> = (0..64).map(|i| (step * 64 + i) as f32).collect();
        want.push(xs.iter().sum::<f32>());
        writer
            .put(&var, Chunk::whole(vec![64]), cast::f32_to_bytes(&xs))
            .unwrap();
        writer.end_step().unwrap();
    }
    writer.close().unwrap();
    let got = reader_thread.join().unwrap();
    assert_eq!(got, want);
}

#[test]
fn inproc_round_trip() {
    single_pair_round_trip("inproc");
}

#[test]
fn tcp_round_trip() {
    single_pair_round_trip("tcp");
}

#[test]
fn discard_policy_drops_steps_when_reader_lags() {
    let mut opts = writer_opts("inproc", 0, "n0");
    opts.listen = format!("discard-{}", std::process::id());
    opts.queue = QueueConfig { policy: QueueFullPolicy::Discard, limit: 1 };
    let mut writer = SstWriter::open(opts).unwrap();
    let addr = writer.address();

    let reader_thread = std::thread::spawn(move || {
        let mut reader =
            SstReader::open(reader_opts("inproc", vec![addr])).unwrap();
        let mut consumed = Vec::new();
        loop {
            match reader.begin_step().unwrap() {
                StepStatus::Ok => {}
                StepStatus::EndOfStream => break,
                _ => continue,
            }
            // Slow reader: writer will fill its queue and discard.
            std::thread::sleep(Duration::from_millis(60));
            let v = reader.available_variables();
            let data =
                reader.get(&v[0].name, Chunk::whole(v[0].shape.clone()))
                    .unwrap();
            consumed.push(cast::bytes_to_f32(&data).unwrap()[0]);
            reader.end_step().unwrap();
        }
        consumed
    });

    // Give the reader a moment to subscribe, then produce fast.
    std::thread::sleep(Duration::from_millis(100));
    let var = VarDecl::new("/x", Datatype::F32, vec![4]);
    let total_steps = 30u64;
    for step in 0..total_steps {
        match writer.begin_step().unwrap() {
            StepStatus::Ok => {
                let xs = vec![step as f32; 4];
                writer
                    .put(&var, Chunk::whole(vec![4]), cast::f32_to_bytes(&xs))
                    .unwrap();
                writer.end_step().unwrap();
            }
            StepStatus::Discarded => {}
            other => panic!("unexpected {other:?}"),
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = writer.stats().unwrap();
    writer.close().unwrap();
    let consumed = reader_thread.join().unwrap();

    assert!(stats.steps_discarded > 0,
            "expected discards, got {stats:?}");
    assert_eq!(
        stats.steps_published + stats.steps_discarded,
        total_steps
    );
    // The reader saw exactly the published steps, in order.
    assert_eq!(consumed.len() as u64, stats.steps_published);
    let mut sorted = consumed.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert_eq!(consumed, sorted, "steps out of order: {consumed:?}");
}

#[test]
fn block_policy_never_discards() {
    let mut opts = writer_opts("inproc", 0, "n0");
    opts.listen = format!("block-{}", std::process::id());
    opts.queue = QueueConfig { policy: QueueFullPolicy::Block, limit: 1 };
    let mut writer = SstWriter::open(opts).unwrap();
    let addr = writer.address();

    let reader_thread = std::thread::spawn(move || {
        let mut reader =
            SstReader::open(reader_opts("inproc", vec![addr])).unwrap();
        let mut n = 0;
        loop {
            match reader.begin_step().unwrap() {
                StepStatus::Ok => {}
                StepStatus::EndOfStream => break,
                _ => continue,
            }
            std::thread::sleep(Duration::from_millis(20));
            reader.end_step().unwrap();
            n += 1;
        }
        n
    });

    std::thread::sleep(Duration::from_millis(50));
    let var = VarDecl::new("/x", Datatype::F32, vec![2]);
    for step in 0..10 {
        assert_eq!(writer.begin_step().unwrap(), StepStatus::Ok,
                   "blocked writer must not discard (step {step})");
        writer
            .put(&var, Chunk::whole(vec![2]),
                 cast::f32_to_bytes(&[step as f32, 0.0]))
            .unwrap();
        writer.end_step().unwrap();
    }
    let stats = writer.stats().unwrap();
    writer.close().unwrap();
    let n = reader_thread.join().unwrap();
    assert_eq!(stats.steps_discarded, 0);
    assert_eq!(n, 10);
}

/// Three writers (one "application") with a shared WriterGroup, two
/// readers using hyperslab-style selections.
#[test]
fn multi_writer_multi_reader_hyperslabs() {
    let group = WriterGroup::new();
    let n_writers = 3usize;
    let per_writer = 32u64;
    let total = n_writers as u64 * per_writer;

    let mut writers = Vec::new();
    let mut addrs = Vec::new();
    for rank in 0..n_writers {
        let mut opts = writer_opts("inproc", rank, &format!("host{rank}"));
        opts.listen =
            format!("mwmr-{}-{}", rank, std::process::id());
        opts.group = Some(group.clone());
        let w = SstWriter::open(opts).unwrap();
        addrs.push(w.address());
        writers.push(w);
    }

    let mut reader_threads = Vec::new();
    for r in 0..2usize {
        let addrs = addrs.clone();
        reader_threads.push(std::thread::spawn(move || {
            let mut opts = reader_opts("inproc", addrs);
            opts.rank = r;
            let mut reader = SstReader::open(opts).unwrap();
            let mut seen = Vec::new();
            loop {
                match reader.begin_step().unwrap() {
                    StepStatus::Ok => {}
                    StepStatus::EndOfStream => break,
                    _ => continue,
                }
                // Reader r loads its half of the dataset (spans writers).
                let half = total / 2;
                let sel = Chunk::new(vec![r as u64 * half], vec![half]);
                let data = reader.get("/data/0/x", sel).unwrap();
                seen.push(cast::bytes_to_f32(&data).unwrap());
                reader.end_step().unwrap();
            }
            reader.close().unwrap();
            seen
        }));
    }

    // Each writer rank writes its contiguous part [rank*32, (rank+1)*32).
    let var = VarDecl::new("/data/0/x", Datatype::F32, vec![total]);
    for step in 0..3 {
        for (rank, w) in writers.iter_mut().enumerate() {
            assert_eq!(w.begin_step().unwrap(), StepStatus::Ok);
            let off = rank as u64 * per_writer;
            let xs: Vec<f32> = (0..per_writer)
                .map(|i| (step * 1000 + off + i) as f32)
                .collect();
            w.put(&var, Chunk::new(vec![off], vec![per_writer]),
                  cast::f32_to_bytes(&xs))
                .unwrap();
            w.end_step().unwrap();
        }
    }
    for w in writers.iter_mut() {
        w.close().unwrap();
    }

    for (r, t) in reader_threads.into_iter().enumerate() {
        let seen = t.join().unwrap();
        assert_eq!(seen.len(), 3, "reader {r} missed steps");
        for (step, data) in seen.iter().enumerate() {
            let half = (total / 2) as usize;
            assert_eq!(data.len(), half);
            for (i, &x) in data.iter().enumerate() {
                let global = r * half + i;
                assert_eq!(x, (step * 1000 + global) as f32,
                           "reader {r} step {step} elem {i}");
            }
        }
    }
}

#[test]
fn late_joining_reader_sees_staged_steps() {
    let mut opts = writer_opts("inproc", 0, "n0");
    opts.listen = format!("late-{}", std::process::id());
    opts.queue = QueueConfig { policy: QueueFullPolicy::Block, limit: 8 };
    let mut writer = SstWriter::open(opts).unwrap();
    let addr = writer.address();

    // Publish 3 steps before any reader exists.
    let var = VarDecl::new("/x", Datatype::F32, vec![2]);
    for step in 0..3 {
        writer.begin_step().unwrap();
        writer
            .put(&var, Chunk::whole(vec![2]),
                 cast::f32_to_bytes(&[step as f32, 1.0]))
            .unwrap();
        writer.end_step().unwrap();
    }

    // Now subscribe: the backlog must be announced.
    let mut reader =
        SstReader::open(reader_opts("inproc", vec![addr])).unwrap();
    let mut got = Vec::new();
    for _ in 0..3 {
        assert_eq!(reader.begin_step().unwrap(), StepStatus::Ok);
        let data = reader.get("/x", Chunk::whole(vec![2])).unwrap();
        got.push(cast::bytes_to_f32(&data).unwrap()[0]);
        reader.end_step().unwrap();
    }
    assert_eq!(got, vec![0.0, 1.0, 2.0]);
    reader.close().unwrap();
    writer.close().unwrap();
}

#[test]
fn reader_crash_does_not_wedge_writer() {
    let mut opts = writer_opts("inproc", 0, "n0");
    opts.listen = format!("crash-{}", std::process::id());
    opts.queue = QueueConfig { policy: QueueFullPolicy::Discard, limit: 2 };
    // The leaked reader never drains; keep the close linger short so the
    // test (and real crashed-reader scenarios) cannot hang.
    opts.close_linger = Duration::from_millis(300);
    let mut writer = SstWriter::open(opts).unwrap();
    let addr = writer.address();

    // Reader connects, consumes one step, then vanishes without Bye.
    {
        let mut reader =
            SstReader::open(reader_opts("inproc", vec![addr])).unwrap();
        let var = VarDecl::new("/x", Datatype::F32, vec![1]);
        writer.begin_step().unwrap();
        writer
            .put(&var, Chunk::whole(vec![1]), cast::f32_to_bytes(&[7.0]))
            .unwrap();
        writer.end_step().unwrap();
        assert_eq!(reader.begin_step().unwrap(), StepStatus::Ok);
        std::mem::forget(reader); // simulated crash: no Bye, no end_step
    }
    // Writer keeps going; close() must not hang forever.
    let var = VarDecl::new("/x", Datatype::F32, vec![1]);
    for _ in 0..4 {
        if writer.begin_step().unwrap() == StepStatus::Ok {
            writer
                .put(&var, Chunk::whole(vec![1]),
                     cast::f32_to_bytes(&[0.0]))
                .unwrap();
            writer.end_step().unwrap();
        }
    }
    // NOTE: the leaked in-proc reader keeps its channel alive, so the
    // writer sees an unresponsive (not dead) peer — exactly the lagging-
    // reader case, which Discard handles by dropping steps.
    let stats = writer.stats().unwrap();
    assert!(stats.steps_published >= 1);
}

#[test]
fn get_error_for_unknown_variable() {
    let mut opts = writer_opts("inproc", 0, "n0");
    opts.listen = format!("unkvar-{}", std::process::id());
    let mut writer = SstWriter::open(opts).unwrap();
    let addr = writer.address();
    let mut reader =
        SstReader::open(reader_opts("inproc", vec![addr])).unwrap();
    let var = VarDecl::new("/x", Datatype::F32, vec![2]);
    writer.begin_step().unwrap();
    writer
        .put(&var, Chunk::whole(vec![2]), cast::f32_to_bytes(&[1.0, 2.0]))
        .unwrap();
    writer.end_step().unwrap();
    assert_eq!(reader.begin_step().unwrap(), StepStatus::Ok);
    assert!(reader.get("/nope", Chunk::whole(vec![2])).is_err());
    // The engine is still usable afterwards.
    let ok = reader.get("/x", Chunk::whole(vec![2])).unwrap();
    assert_eq!(cast::bytes_to_f32(&ok).unwrap(), vec![1.0, 2.0]);
    reader.end_step().unwrap();
    reader.close().unwrap();
    writer.close().unwrap();
}

/// The two-phase contract on the wire: a deferred batch of many
/// selections costs ONE GetBatch/GetBatchReply round trip per writer per
/// step, not one message per chunk.
#[test]
fn deferred_batch_is_one_wire_message_per_step() {
    let mut opts = writer_opts("inproc", 0, "n0");
    opts.listen = format!("batch1msg-{}", std::process::id());
    let mut writer = SstWriter::open(opts).unwrap();
    let addr = writer.address();
    let mut reader =
        SstReader::open(reader_opts("inproc", vec![addr])).unwrap();

    // One step, two variables, two chunks each.
    let var_a = VarDecl::new("/a", Datatype::F32, vec![8]);
    let var_b = VarDecl::new("/b", Datatype::F32, vec![8]);
    writer.begin_step().unwrap();
    for (var, base) in [(&var_a, 0.0f32), (&var_b, 100.0)] {
        let h = writer.define_variable(var).unwrap();
        writer
            .put_deferred(&h, Chunk::new(vec![0], vec![4]),
                          cast::f32_to_bytes(&[base; 4]))
            .unwrap();
        writer
            .put_deferred(&h, Chunk::new(vec![4], vec![4]),
                          cast::f32_to_bytes(&[base + 1.0; 4]))
            .unwrap();
    }
    writer.end_step().unwrap();

    assert_eq!(reader.begin_step().unwrap(), StepStatus::Ok);
    // Defer 4 selections (one per written chunk) + 1 spanning selection.
    let mut handles = Vec::new();
    for var in ["/a", "/b"] {
        handles.push(
            reader.get_deferred(var, Chunk::new(vec![0], vec![4])).unwrap());
        handles.push(
            reader.get_deferred(var, Chunk::new(vec![4], vec![4])).unwrap());
    }
    handles.push(
        reader.get_deferred("/a", Chunk::new(vec![2], vec![4])).unwrap());
    reader.perform_gets().unwrap();
    for h in handles {
        assert!(!reader.take_get(h).unwrap().is_empty());
    }

    let stats = reader.stats();
    assert_eq!(stats.batch_requests, 1,
               "whole deferred batch must be one request: {stats:?}");
    assert_eq!(stats.data_messages, 1,
               "whole deferred batch must be one data reply: {stats:?}");
    // 4 aligned selections (1 part each) + 1 spanning (2 parts) = 6.
    assert_eq!(stats.chunk_requests, 6);

    reader.end_step().unwrap();
    reader.close().unwrap();
    writer.close().unwrap();
}

/// A writer-side batch failure mid-`perform_gets` must poison the
/// drained handles: `take_get` then reports the batch error instead of
/// a baffling "unknown handle". Uses a wire-level fake writer so the
/// error path is actually exercised (a real `SstWriter` never errors a
/// validated batch).
#[test]
fn failed_batch_poisons_handles_with_the_batch_error() {
    use openpmd_stream::adios::transport;
    use openpmd_stream::adios::wire::{
        GetReply, Msg, StepMeta, VarMeta,
    };
    use openpmd_stream::openpmd::chunk::WrittenChunkInfo;
    use openpmd_stream::adios::transport::Recv;

    let t = transport::by_name("inproc").unwrap();
    let mut listener = t
        .listen(&format!("poison-{}", std::process::id()))
        .unwrap();
    let addr = listener.address();

    // Fake writer: handshake, announce one step with /x f32 [4], then
    // answer the batched get with per-item errors.
    let fake = std::thread::spawn(move || {
        let mut conn = listener
            .accept_timeout(Duration::from_secs(10))
            .unwrap()
            .expect("reader never dialed");
        match conn.recv().unwrap() {
            Recv::Msg(Msg::Hello { .. }) => {}
            _ => panic!("expected Hello"),
        }
        conn.send(Msg::HelloAck { writer_rank: 0, hostname: "fake".into() })
            .unwrap();
        let meta = StepMeta {
            attributes: Default::default(),
            vars: vec![VarMeta {
                name: "/x".into(),
                dtype: Datatype::F32,
                shape: vec![4],
                ops: Default::default(),
                chunks: vec![WrittenChunkInfo::new(
                    Chunk::whole(vec![4]), 0, "fake")],
            }],
        };
        conn.send(Msg::StepAnnounce { step: 0, meta }).unwrap();
        loop {
            match conn.recv().unwrap() {
                Recv::Msg(Msg::GetBatch { req_id, items, .. }) => {
                    conn.send(Msg::GetBatchReply {
                        req_id,
                        items: items
                            .iter()
                            .map(|_| {
                                GetReply::Error("injected fault".into())
                            })
                            .collect(),
                    })
                    .unwrap();
                }
                Recv::Msg(Msg::ReaderBye) | Recv::Closed => break,
                _ => {}
            }
        }
    });

    let mut reader =
        SstReader::open(reader_opts("inproc", vec![addr])).unwrap();
    assert_eq!(reader.begin_step().unwrap(), StepStatus::Ok);
    let h1 = reader
        .get_deferred("/x", Chunk::new(vec![0], vec![2]))
        .unwrap();
    let h2 = reader
        .get_deferred("/x", Chunk::new(vec![2], vec![2]))
        .unwrap();
    let perform_err = reader.perform_gets().unwrap_err();
    assert!(format!("{perform_err:#}").contains("injected fault"),
            "{perform_err:#}");
    // Both handles were drained before the failure; they must surface
    // the batch error, not "unknown handle".
    for h in [h1, h2] {
        let err = format!("{}", reader.take_get(h).unwrap_err());
        assert!(err.contains("injected fault"), "{err}");
        assert!(!err.contains("unknown"), "{err}");
    }
    // The engine stays usable for step lifecycle calls.
    reader.end_step().unwrap();
    reader.close().unwrap();
    fake.join().unwrap();
}

#[test]
fn zero_copy_on_aligned_inproc_reads() {
    // An exact-chunk read over inproc must return the writer's buffer
    // (same allocation), not a copy — the RDMA-analog property.
    let mut opts = writer_opts("inproc", 0, "n0");
    opts.listen = format!("zc-{}", std::process::id());
    let mut writer = SstWriter::open(opts).unwrap();
    let addr = writer.address();
    let mut reader =
        SstReader::open(reader_opts("inproc", vec![addr])).unwrap();

    let var = VarDecl::new("/x", Datatype::F32, vec![8]);
    let payload = cast::f32_to_bytes(&[0.0; 8]);
    let payload_ptr = payload.as_ptr();
    writer.begin_step().unwrap();
    writer.put(&var, Chunk::whole(vec![8]), payload).unwrap();
    writer.end_step().unwrap();

    assert_eq!(reader.begin_step().unwrap(), StepStatus::Ok);
    let got = reader.get("/x", Chunk::whole(vec![8])).unwrap();
    assert!(Arc::ptr_eq(&got, &Arc::new(Vec::new())) == false);
    assert_eq!(got.as_ptr(), payload_ptr,
               "aligned inproc read copied the payload");
    reader.end_step().unwrap();
    reader.close().unwrap();
    writer.close().unwrap();
}
