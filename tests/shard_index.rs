//! Shard-index schema conformance: the `<out>.index.json` a fleet
//! publishes must round-trip exactly (write → parse → identical shard
//! list and ordering) for any fleet width and step count, and every
//! way a family can be inconsistent — missing shards on disk,
//! duplicate ranks, width mismatches — must surface as the typed
//! [`ShardIndexError`] it is, never as silent truncation.

use openpmd_stream::openpmd::series::{
    open_shard_family, parse_shard_index, shard_path, write_shard_index,
    ShardIndexError,
};
use openpmd_stream::testing::{check, Pair, UsizeRange};

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("opmd-idx-{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Property: for any (readers, steps), writing the index and parsing
/// it back yields exactly the declared width, step count, and the
/// shard names in rank order.
#[test]
fn index_round_trips_for_any_width_and_step_count() {
    let dir = tmp_dir("prop");
    let base = dir.join("fam.bp");
    check(
        &Pair(UsizeRange(1, 32), UsizeRange(0, 1000)),
        |&(readers, steps)| {
            let path = write_shard_index(&base, readers, steps as u64)
                .map_err(|e| format!("write: {e:#}"))?;
            let text = std::fs::read_to_string(&path)
                .map_err(|e| format!("read: {e}"))?;
            let parsed = parse_shard_index(&text)
                .map_err(|e| format!("parse: {e}"))?;
            if parsed.readers != readers {
                return Err(format!(
                    "readers {} != {readers}",
                    parsed.readers
                ));
            }
            if parsed.steps != steps as u64 {
                return Err(format!("steps {} != {steps}", parsed.steps));
            }
            let want: Vec<String> = (0..readers)
                .map(|r| {
                    shard_path(&base, r, readers)
                        .file_name()
                        .unwrap()
                        .to_string_lossy()
                        .into_owned()
                })
                .collect();
            if parsed.shards != want {
                return Err(format!(
                    "shard list {:?} != {want:?}",
                    parsed.shards
                ));
            }
            Ok(())
        },
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn duplicate_ranks_are_a_typed_error() {
    let doc = r#"{"series": "f.bp", "readers": 3, "steps": 2,
        "shards": ["f.r0of3.bp", "f.r1of3.bp", "f.r1of3.bp"]}"#;
    assert_eq!(
        parse_shard_index(doc).unwrap_err(),
        ShardIndexError::DuplicateRank { rank: 1 }
    );
}

#[test]
fn width_mismatches_are_typed_errors() {
    // Declared M vs listed count.
    let count = r#"{"series": "f.bp", "readers": 4, "steps": 2,
        "shards": ["f.r0of4.bp"]}"#;
    assert_eq!(
        parse_shard_index(count).unwrap_err(),
        ShardIndexError::CountMismatch { declared: 4, listed: 1 }
    );
    // Declared M vs a shard's own r<i>ofM marker.
    let marker = r#"{"series": "f.bp", "readers": 2, "steps": 2,
        "shards": ["f.r0of2.bp", "f.r1of8.bp"]}"#;
    assert_eq!(
        parse_shard_index(marker).unwrap_err(),
        ShardIndexError::WidthMismatch {
            name: "f.r1of8.bp".into(),
            marker: 8,
            declared: 2,
        }
    );
}

#[test]
fn missing_shard_files_are_typed_errors() {
    let dir = tmp_dir("missing");
    let base = dir.join("ghost.bp");
    let index = write_shard_index(&base, 2, 1).unwrap();
    // The index exists; the shards were never written. The error is
    // the typed MissingShard, naming the first absent shard.
    let err = format!("{:#}", open_shard_family(&index).unwrap_err());
    let typed = format!(
        "{}",
        ShardIndexError::MissingShard {
            path: dir.join("ghost.r0of2.bp"),
        }
    );
    assert!(err.contains(&typed), "{err:?} lacks {typed:?}");
    std::fs::remove_dir_all(&dir).ok();
}
