//! Observability conformance: the tracing layer must be a pure
//! observer. Enabling it cannot change a single output byte of a pipe
//! run, the drained spans must render as a well-formed balanced Chrome
//! trace, and the counter registry must attribute the run's traffic to
//! the backends that actually moved it.

use std::path::PathBuf;

use openpmd_stream::adios::bp::{BpReader, BpWriter, WriterCtx};
use openpmd_stream::obs::metrics::snapshot_metrics;
use openpmd_stream::obs::{export, trace};
use openpmd_stream::pipeline::pipe::{run, PipeOptions};
use openpmd_stream::testing::fixtures;
use openpmd_stream::util::json;

const EXTENT: u64 = 16;
const CHUNKS: u64 = 4;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("opmd-obs-{name}-{}", std::process::id()))
}

fn pipe_once(src: &PathBuf, dst: &PathBuf) {
    let mut input = BpReader::open(src).unwrap();
    let mut output = BpWriter::create(dst, WriterCtx::default()).unwrap();
    run(&mut input, &mut output, PipeOptions::solo()).unwrap();
}

/// The whole enable/disable lifecycle lives in ONE test: the trace
/// switch is process-global, so splitting it across `#[test]` fns
/// would race under the parallel test harness.
#[test]
fn tracing_is_a_pure_observer_and_exports_well_formed() {
    let steps = 4u64;
    let src = tmp("src.bp");
    fixtures::write_chunked_bp(&src, steps, EXTENT, CHUNKS);

    // Reference run, tracing off (the default).
    assert!(!trace::enabled());
    let d_off = tmp("off.bp");
    pipe_once(&src, &d_off);

    // Instrumented run: identical inputs, tracing on.
    trace::drain(); // discard anything earlier tests of this binary left
    trace::enable();
    let d_on = tmp("on.bp");
    pipe_once(&src, &d_on);
    trace::disable();
    let dumps = trace::drain();

    // 1. Byte-identical output: tracing observed, never altered.
    let want = std::fs::read(&d_off).unwrap();
    let got = std::fs::read(&d_on).unwrap();
    assert_eq!(want, got, "tracing changed the pipe's output bytes");

    // 2. The drain actually saw the run: per-step pipe spans with
    //    sane self-consistent timestamps.
    let events: Vec<_> =
        dumps.iter().flat_map(|d| d.events.iter()).collect();
    assert!(!events.is_empty(), "enabled run recorded no spans");
    let pipe_steps =
        events.iter().filter(|e| e.name == "pipe.step").count() as u64;
    // `>=`, not `==`: the sibling counter test may pipe concurrently
    // while the global switch is on, and its spans land here too.
    assert!(pipe_steps >= steps,
            "expected >= {steps} pipe.step spans, saw {pipe_steps}");
    for e in &events {
        assert!(e.start_us.checked_add(e.dur_us).is_some(),
                "span {} has degenerate timing", e.name);
    }
    let dropped: u64 = dumps.iter().map(|d| d.dropped).sum();
    assert_eq!(dropped, 0, "tiny run must not overflow span buffers");

    // 3. Chrome export is well-formed: parseable JSON, balanced by
    //    construction (every span is one complete "ph":"X" event), and
    //    it round-trips through our own parser.
    let doc = export::chrome_trace(&dumps);
    let parsed = json::parse(&doc.to_string()).unwrap();
    let tev = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    let mut span_events = 0;
    for ev in tev {
        let ph = ev.get("ph").unwrap().as_str().unwrap();
        assert!(ph == "X" || ph == "M", "unexpected phase {ph:?}");
        assert!(ev.get("pid").is_some() && ev.get("tid").is_some());
        if ph == "X" {
            span_events += 1;
            assert!(ev.get("ts").is_some() && ev.get("dur").is_some(),
                    "complete event missing ts/dur");
        }
    }
    assert_eq!(span_events, events.len(), "chrome export lost spans");

    // 4. The JSON-lines export parses line by line.
    let lines = export::trace_json_lines(&dumps);
    assert_eq!(lines.lines().count(), events.len());
    for line in lines.lines() {
        let o = json::parse(line).unwrap();
        assert!(o.get("name").is_some() && o.get("dur_us").is_some(),
                "bad trace line: {line}");
    }

    std::fs::remove_file(&src).ok();
    std::fs::remove_file(&d_off).ok();
    std::fs::remove_file(&d_on).ok();
}

/// Counters run unconditionally (no enable switch), so this test is
/// safe against the global trace flag: a BP->BP pipe must show up in
/// the bp.* counters, and the snapshot delta must isolate this run
/// even with other tests of this binary running concurrently... which
/// it cannot quite (counters are process-wide), so assert growth, not
/// exact values.
#[test]
fn pipe_run_advances_backend_counters_and_metrics_line_parses() {
    let src = tmp("ctr-src.bp");
    fixtures::write_chunked_bp(&src, 3, EXTENT, CHUNKS);
    let dst = tmp("ctr-dst.bp");

    let before = snapshot_metrics();
    pipe_once(&src, &dst);
    let after = snapshot_metrics();
    let delta = after.delta(&before);

    assert!(delta.counter("bp.get_sweeps") >= 3,
            "reader sweeps not counted");
    assert!(delta.counter("bp.put_chunks") >= 3 * CHUNKS,
            "writer chunks not counted");
    assert!(delta.counter("bp.put_bytes") >= 3 * EXTENT * 4,
            "writer bytes not counted");
    assert!(delta.counter("bp.get_bytes") >= 3 * EXTENT * 4,
            "reader bytes not counted");

    // The periodic --metrics emission must be one parseable JSON line.
    let line = export::metrics_line(Some(2), &delta);
    assert!(!line.contains('\n'));
    let o = json::parse(&line).unwrap();
    assert_eq!(o.get("step").unwrap().as_u64(), Some(2));
    assert!(o.get("counters").is_some(), "line lacks counters: {line}");

    std::fs::remove_file(&src).ok();
    std::fs::remove_file(&dst).ok();
}
