//! Buffer-pool conformance: pooling is a pure allocator optimization.
//! Enabling it cannot change a single output byte on any backend, and
//! error/unwind paths must hand buffers back instead of leaking pool
//! budget.
//!
//! The pooling switch is process-global, so the whole on/off lifecycle
//! lives in ONE `#[test]` (the `tests/obs_conformance.rs` pattern):
//! splitting it across test fns would race under the parallel harness.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use openpmd_stream::adios::bp::{BpReader, BpWriter, WriterCtx};
use openpmd_stream::adios::engine::{cast, Engine, StepStatus, VarDecl};
use openpmd_stream::adios::json::JsonWriter;
use openpmd_stream::adios::ops::OpChain;
use openpmd_stream::adios::sst::{
    QueueConfig, QueueFullPolicy, SstReader, SstReaderOptions, SstWriter,
    SstWriterOptions,
};
use openpmd_stream::openpmd::chunk::Chunk;
use openpmd_stream::openpmd::types::Datatype;
use openpmd_stream::pipeline::pipe::{run, PipeOptions};
use openpmd_stream::testing::engines::InjectedEngine;
use openpmd_stream::testing::fixtures;
use openpmd_stream::util::pool;

const EXTENT: u64 = 16;
const CHUNKS: u64 = 4;
const STEPS: u64 = 4;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("opmd-pool-{name}-{}", std::process::id()))
}

fn pipe_bp(src: &PathBuf, dst: &PathBuf) {
    let mut input = BpReader::open(src).unwrap();
    let mut output = BpWriter::create(dst, WriterCtx {
        rank: 0,
        hostname: "pool".into(),
    })
    .unwrap();
    run(&mut input, &mut output, PipeOptions::solo()).unwrap();
}

fn pipe_json(src: &PathBuf, dst: &PathBuf) {
    let mut input = BpReader::open(src).unwrap();
    let mut output = JsonWriter::create(dst, 0, "pool").unwrap();
    run(&mut input, &mut output, PipeOptions::solo()).unwrap();
}

/// Read every file of a flat directory (the JSON engine's
/// `step-N.json` layout) for byte-level comparison.
fn dir_bytes(dir: &PathBuf) -> BTreeMap<String, Vec<u8>> {
    let mut out = BTreeMap::new();
    for entry in std::fs::read_dir(dir).unwrap() {
        let entry = entry.unwrap();
        out.insert(
            entry.file_name().to_string_lossy().into_owned(),
            std::fs::read(entry.path()).unwrap(),
        );
    }
    out
}

/// SST writer -> reader roundtrip over `transport` with an operator
/// chain (so codec encode/decode scratch is exercised); returns every
/// byte the reader got, in step order.
fn sst_roundtrip(transport: &str, tag: &str) -> Vec<u8> {
    let chain = OpChain::parse("shuffle|rle").unwrap();
    let mut writer = SstWriter::open(SstWriterOptions {
        listen: if transport == "inproc" {
            format!("pool-{tag}-{transport}-{}", std::process::id())
        } else {
            String::new()
        },
        transport: transport.into(),
        rank: 0,
        hostname: "pool".into(),
        queue: QueueConfig { policy: QueueFullPolicy::Block, limit: 8 },
        ..Default::default()
    })
    .unwrap();
    let decl = VarDecl::new("/data/x", Datatype::F32, vec![EXTENT])
        .with_ops(chain);
    let h = writer.define_variable(&decl).unwrap();
    let per_chunk = EXTENT / CHUNKS;
    for s in 0..2u64 {
        assert_eq!(writer.begin_step().unwrap(), StepStatus::Ok);
        for c in 0..CHUNKS {
            let off = c * per_chunk;
            let xs: Vec<f32> = (0..per_chunk)
                .map(|i| (s * 100 + off + i) as f32)
                .collect();
            writer
                .put_deferred(&h,
                              Chunk::new(vec![off], vec![per_chunk]),
                              cast::f32_to_bytes(&xs))
                .unwrap();
        }
        writer.end_step().unwrap();
    }
    let addr = writer.address();
    let mut reader = SstReader::open(SstReaderOptions {
        writers: vec![addr],
        transport: transport.into(),
        rank: 0,
        hostname: "pool".into(),
        begin_step_timeout: Duration::from_secs(30),
        codecs: None,
    })
    .unwrap();
    let close_thread = std::thread::spawn(move || writer.close());
    let mut out = Vec::new();
    loop {
        match reader.begin_step().unwrap() {
            StepStatus::Ok => {
                let whole = reader
                    .get("/data/x", Chunk::whole(vec![EXTENT]))
                    .unwrap();
                out.extend_from_slice(&whole);
                reader.end_step().unwrap();
            }
            StepStatus::NotReady => {
                std::thread::sleep(Duration::from_millis(2))
            }
            StepStatus::EndOfStream => break,
            other => panic!("unexpected step status {other:?}"),
        }
    }
    reader.close().unwrap();
    close_thread.join().unwrap().unwrap();
    out
}

#[test]
fn pooling_is_invisible_in_output_and_bounded_under_errors() {
    let src = tmp("src.bp");
    fixtures::write_chunked_bp(&src, STEPS, EXTENT, CHUNKS);

    // ----------------------------------------------------------------
    // 1. Byte identity, all four backends: a pooled run and a
    //    pool-bypassed run of the same input produce identical bytes.
    // ----------------------------------------------------------------
    assert!(pool::pooling_enabled(), "pool must default to on");

    let bp_on = tmp("bp-on.bp");
    let bp_off = tmp("bp-off.bp");
    let json_on = tmp("json-on");
    let json_off = tmp("json-off");
    std::fs::remove_dir_all(&json_on).ok();
    std::fs::remove_dir_all(&json_off).ok();

    pipe_bp(&src, &bp_on);
    pipe_json(&src, &json_on);
    let sst_inproc_on = sst_roundtrip("inproc", "on");
    let sst_tcp_on = sst_roundtrip("tcp", "on");

    pool::set_pooling_enabled(false);
    pipe_bp(&src, &bp_off);
    pipe_json(&src, &json_off);
    let sst_inproc_off = sst_roundtrip("inproc", "off");
    let sst_tcp_off = sst_roundtrip("tcp", "off");
    pool::set_pooling_enabled(true);

    assert_eq!(std::fs::read(&bp_on).unwrap(),
               std::fs::read(&bp_off).unwrap(),
               "pooling changed BP output bytes");
    assert_eq!(dir_bytes(&json_on), dir_bytes(&json_off),
               "pooling changed JSON output bytes");
    assert_eq!(sst_inproc_on, sst_inproc_off,
               "pooling changed SST/inproc roundtrip bytes");
    assert_eq!(sst_tcp_on, sst_tcp_off,
               "pooling changed SST/tcp roundtrip bytes");
    // And the streamed bytes match the fixture formula regardless.
    let xs = cast::bytes_to_f32(&sst_inproc_on).unwrap();
    assert_eq!(xs.len(), 2 * EXTENT as usize);
    for (g, &x) in xs.iter().enumerate() {
        let (s, i) = (g as u64 / EXTENT, g as u64 % EXTENT);
        assert_eq!(x, (s * 100 + i) as f32);
    }

    // ----------------------------------------------------------------
    // 2. Error paths do not leak pool budget.
    //
    // 2a. perform_gets failure: a BP file whose final payload is
    //     truncated passes begin_step (the index seeks past EOF) and
    //     then fails the actual payload read — after the fetch scratch
    //     was already checked out. The RAII handle must shelve it.
    // ----------------------------------------------------------------
    let trunc = tmp("trunc.bp");
    let whole = std::fs::read(&src).unwrap();
    std::fs::write(&trunc, &whole[..whole.len() - 9]).unwrap();
    for _ in 0..20 {
        let mut r = BpReader::open(&trunc).unwrap();
        let mut saw_error = false;
        loop {
            match r.begin_step() {
                Ok(StepStatus::Ok) => {
                    match r.get("/data/x", Chunk::whole(vec![EXTENT])) {
                        Ok(_) => r.end_step().unwrap(),
                        Err(_) => {
                            saw_error = true;
                            break;
                        }
                    }
                }
                _ => break,
            }
        }
        assert!(saw_error, "truncated BP read should fail a get");
        assert!(pool::retained_bytes() <= pool::pool_budget(),
                "retained bytes exceeded budget on the error path");
    }

    // 2b. Store-side failure mid-pipe (InjectedEngine): the pipe run
    //     unwinds with payload buffers in flight; repeated failing runs
    //     must keep retained bytes bounded, not ratchet upward.
    for i in 0..10 {
        let dst = tmp(&format!("fail-{i}.bp"));
        let mut input = BpReader::open(&src).unwrap();
        let inner = BpWriter::create(&dst, WriterCtx {
            rank: 0,
            hostname: "pool".into(),
        })
        .unwrap();
        let mut output = InjectedEngine::failing(inner, 1);
        let err = run(&mut input, &mut output, PipeOptions::solo());
        assert!(err.is_err(), "injected store fault must surface");
        assert!(pool::retained_bytes() <= pool::pool_budget(),
                "retained bytes exceeded budget under injected faults");
        std::fs::remove_file(&dst).ok();
    }

    std::fs::remove_file(&src).ok();
    std::fs::remove_file(&trunc).ok();
    std::fs::remove_file(&bp_on).ok();
    std::fs::remove_file(&bp_off).ok();
    std::fs::remove_dir_all(&json_on).ok();
    std::fs::remove_dir_all(&json_off).ok();
}
