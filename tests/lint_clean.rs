//! Tier-1 gate: the crate passes its own static-analysis lint.
//!
//! Runs the full `pallas-lint` pipeline (all rule families, the
//! format-fingerprint manifest, and the waiver-budget ledger) over this
//! repository and asserts zero unwaived findings. This is the same
//! check CI runs through the `pallas-lint` binary; having it in the
//! test suite means a plain `cargo test` catches a hardened-zone
//! regression before any workflow does.

use openpmd_stream::analysis::lint::{self, LintOptions};

#[test]
fn repository_is_lint_clean() {
    let root = env!("CARGO_MANIFEST_DIR");
    let report =
        lint::run(&LintOptions::at(root)).expect("lint run succeeds");
    assert!(report.files_scanned > 0, "no sources scanned");

    let unwaived: Vec<String> = report
        .unwaived()
        .map(|f| {
            format!("{}:{} [{}] {}", f.file, f.line, f.rule, f.message)
        })
        .collect();
    assert!(
        unwaived.is_empty(),
        "unwaived lint findings:\n  {}",
        unwaived.join("\n  ")
    );
}

#[test]
fn waived_findings_fit_the_committed_ledger() {
    // The ledger equality check runs inside lint::run (any imbalance is
    // itself an unwaived `waiver-ledger` finding, caught above). This
    // test pins the current waiver total so a diff shows up in review
    // when it moves.
    let root = env!("CARGO_MANIFEST_DIR");
    let report =
        lint::run(&LintOptions::at(root)).expect("lint run succeeds");
    assert_eq!(
        report.waived_count(),
        2,
        "waiver set changed — update this pin and the ledger together"
    );
}
