//! Fleet conformance across the (strategy × M) matrix: the union of a
//! fleet's output shards must be byte-identical to the serial single
//! pipe over the same N=2-writer skewed SST stream — complete,
//! disjoint, and value-exact (the PR's acceptance bar asserts the
//! RoundRobin / BinPacking / LoadBalanced cells at M ∈ {1, 2, 4}).
//!
//! The serial reference is independent of (strategy, M), so each test
//! builds it once (already validated against the writers' formula by
//! `serial_reference`) and sweeps the widths against it.

use openpmd_stream::testing::fleet_conformance::{
    assert_fleet_matches, fleet_union, serial_reference,
};

fn sweep(tag: &str, strategy: &str) {
    let serial = serial_reference(tag)
        .unwrap_or_else(|e| panic!("serial reference: {e:#}"));
    for readers in [1usize, 2, 4] {
        assert_fleet_matches(&serial, tag, strategy, readers)
            .unwrap_or_else(|e| panic!("M={readers}: {e:#}"));
    }
}

/// The acceptance-bar strategies, every fleet width.
#[test]
fn fleet_union_matches_serial_pipe_roundrobin() {
    sweep("rr", "roundrobin");
}

#[test]
fn fleet_union_matches_serial_pipe_binpacking() {
    sweep("bin", "binpacking");
}

#[test]
fn fleet_union_matches_serial_pipe_loadbalanced() {
    sweep("lb", "loadbalanced");
}

/// The slicing strategies cut chunks (slice-subset fetches per writer,
/// partial-selection service on the writer side): same contract.
#[test]
fn fleet_union_matches_serial_pipe_hyperslabs() {
    sweep("hs", "hyperslabs");
}

#[test]
fn fleet_union_matches_serial_pipe_hostname() {
    // Readers all on "localhost" while writers live on node0000/0001:
    // by-hostname degrades entirely to its fallback, which must still
    // be complete + disjoint.
    sweep("host", "hostname");
}

/// A union check alone (no serial reference) at a width that exceeds
/// the chunk count for some strategies — idle ranks must still
/// publish empty steps rather than desynchronize the shard family.
#[test]
fn fleet_wider_than_the_chunk_table_stays_complete() {
    let merged = fleet_union("wide", "binpacking", 6).unwrap();
    assert_eq!(merged.len(), 3);
}
