//! Fixture corpus for the interprocedural concurrency pass.
//!
//! Each fixture under `tests/lint_fixtures/` is a source snippet with
//! a known-good or known-bad locking shape (see the README there).
//! The fixtures are parsed under a *virtual* lock-zone path and run
//! through `concurrency::analyze` together with `registry.rs` (parsed
//! as `rust/src/util/sync.rs`, where the pass expects the lock-class
//! table). Positives assert the expected rule fires; negatives assert
//! the pass stays silent — regressions in either direction fail here
//! before they reach the repo-wide gate in `tests/lint_clean.rs`.

use std::path::PathBuf;

use openpmd_stream::analysis::lint::concurrency::{analyze, LockGraph};
use openpmd_stream::analysis::lint::{Finding, SourceFile};

fn fixture(name: &str) -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Analyze one fixture beside the registry fixture, both under
/// virtual paths: the registry where the pass looks for the class
/// table, the case inside a lock zone.
fn analyze_fixture(name: &str) -> (Vec<Finding>, LockGraph) {
    let sources = vec![
        SourceFile::parse("rust/src/util/sync.rs", &fixture("registry.rs")),
        SourceFile::parse("rust/src/adios/sst/fixture.rs", &fixture(name)),
    ];
    let mut findings = Vec::new();
    let graph = analyze(&sources, &mut findings);
    (findings, graph)
}

/// Sorted rule names of all findings.
fn rules(findings: &[Finding]) -> Vec<&'static str> {
    let mut v: Vec<&'static str> = findings.iter().map(|f| f.rule).collect();
    v.sort_unstable();
    v
}

fn edge_kind<'g>(
    graph: &'g LockGraph,
    from: &str,
    to: &str,
) -> Option<&'g str> {
    graph
        .edges
        .get(&(from.to_string(), to.to_string()))
        .map(|e| e.kind.as_str())
}

#[test]
fn registry_fixture_parses_standalone() {
    let sources = vec![SourceFile::parse(
        "rust/src/util/sync.rs",
        &fixture("registry.rs"),
    )];
    let mut findings = Vec::new();
    let graph = analyze(&sources, &mut findings);
    assert_eq!(rules(&findings), Vec::<&str>::new());
    assert_eq!(graph.classes.len(), 3);
    assert_eq!(graph.classes.get("ALPHA"), Some(&10));
    assert_eq!(graph.classes.get("BETA"), Some(&20));
    assert_eq!(graph.classes.get("GAMMA"), Some(&30));
    assert!(graph.edges.is_empty());
}

#[test]
fn inversion_cycle_flagged() {
    let (findings, graph) = analyze_fixture("inversion_cycle.rs");
    let r = rules(&findings);
    assert!(r.contains(&"lock-order"), "{r:?}");
    assert!(r.contains(&"lock-cycle"), "{r:?}");
    assert_eq!(edge_kind(&graph, "ALPHA", "BETA"), Some("direct"));
    assert_eq!(edge_kind(&graph, "BETA", "ALPHA"), Some("direct"));
}

#[test]
fn inversion_consistent_order_clean() {
    let (findings, graph) = analyze_fixture("inversion_ok.rs");
    assert_eq!(rules(&findings), Vec::<&str>::new());
    assert_eq!(graph.edges.len(), 1);
    assert_eq!(edge_kind(&graph, "ALPHA", "BETA"), Some("direct"));
}

#[test]
fn guard_across_call_flagged() {
    let (findings, graph) = analyze_fixture("guard_across_call.rs");
    let r = rules(&findings);
    assert!(r.contains(&"lock-across-call"), "{r:?}");
    assert_eq!(edge_kind(&graph, "BETA", "ALPHA"), Some("call"));
    let f = findings.iter().find(|f| f.rule == "lock-across-call").unwrap();
    assert!(
        f.message.contains("helper") || f.message.contains("ALPHA"),
        "{}",
        f.message
    );
}

#[test]
fn guard_across_higher_rank_call_clean() {
    let (findings, graph) = analyze_fixture("guard_across_call_ok.rs");
    assert_eq!(rules(&findings), Vec::<&str>::new());
    assert_eq!(graph.edges.len(), 1);
    assert_eq!(edge_kind(&graph, "ALPHA", "BETA"), Some("call"));
}

#[test]
fn condvar_wrong_class_flagged() {
    let (findings, _) = analyze_fixture("condvar_wrong_class.rs");
    assert_eq!(rules(&findings), ["condvar-class"]);
    assert!(
        findings[0].message.contains("wrong lock"),
        "{}",
        findings[0].message
    );
}

#[test]
fn condvar_matching_class_clean() {
    let (findings, _) = analyze_fixture("condvar_ok.rs");
    assert_eq!(rules(&findings), Vec::<&str>::new());
}

#[test]
fn unregistered_raw_mutex_flagged() {
    let (findings, _) = analyze_fixture("unregistered_lock.rs");
    assert_eq!(rules(&findings), ["unregistered-lock", "unregistered-lock"]);
}

#[test]
fn registered_ordered_mutex_clean() {
    let (findings, _) = analyze_fixture("registered_lock.rs");
    assert_eq!(rules(&findings), Vec::<&str>::new());
}
