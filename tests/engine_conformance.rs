//! Drives the engine-conformance suite (two-phase deferred API) against
//! every backend: BP file, JSON, SST over inproc and TCP — plus the
//! SST-specific contracts: a Discarded step drops its deferred queue
//! before any data movement, and a deferred batch travels as ONE wire
//! data message per writer per step.

use std::path::PathBuf;
use std::time::Duration;

use openpmd_stream::adios::bp::{BpReader, BpWriter, WriterCtx};
use openpmd_stream::adios::engine::{cast, Engine, StepStatus, VarDecl};
use openpmd_stream::adios::json::{JsonReader, JsonWriter};
use openpmd_stream::adios::sst::{
    QueueConfig, QueueFullPolicy, SstReader, SstReaderOptions, SstWriter,
    SstWriterOptions,
};
use openpmd_stream::openpmd::chunk::Chunk;
use openpmd_stream::openpmd::types::Datatype;
use openpmd_stream::testing::engine_conformance::{
    run_conformance, ConformancePair,
};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("opmd-conf-{name}-{}", std::process::id()))
}

#[test]
fn bp_engine_conforms() {
    let path = tmp("bp");
    let path2 = path.clone();
    run_conformance("bp", move || {
        let writer = BpWriter::create(&path2, WriterCtx {
            rank: 0,
            hostname: "conf".into(),
        })?;
        let rpath = path2.clone();
        Ok(ConformancePair {
            writer: Box::new(writer),
            open_reader: Box::new(move || {
                Ok(Box::new(BpReader::open(&rpath)?) as Box<dyn Engine>)
            }),
        })
    })
    .unwrap();
    std::fs::remove_file(&path).ok();
}

#[test]
fn json_engine_conforms() {
    let dir = tmp("json");
    std::fs::remove_dir_all(&dir).ok();
    let dir2 = dir.clone();
    run_conformance("json", move || {
        let writer = JsonWriter::create(&dir2, 0, "conf")?;
        let rdir = dir2.clone();
        Ok(ConformancePair {
            writer: Box::new(writer),
            open_reader: Box::new(move || {
                Ok(Box::new(JsonReader::open(&rdir)?) as Box<dyn Engine>)
            }),
        })
    })
    .unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

fn sst_conformance(transport: &str) {
    let transport_owned = transport.to_string();
    run_conformance(&format!("sst:{transport}"), move || {
        let writer = SstWriter::open(SstWriterOptions {
            listen: if transport_owned == "inproc" {
                format!("conf-{transport_owned}-{}", std::process::id())
            } else {
                String::new()
            },
            transport: transport_owned.clone(),
            rank: 0,
            hostname: "conf".into(),
            // Block + roomy queue: both conformance steps stay staged
            // until the (late-joining) reader drains them.
            queue: QueueConfig { policy: QueueFullPolicy::Block, limit: 8 },
            ..Default::default()
        })?;
        let addr = writer.address();
        let transport = transport_owned.clone();
        Ok(ConformancePair {
            writer: Box::new(writer),
            open_reader: Box::new(move || {
                Ok(Box::new(SstReader::open(SstReaderOptions {
                    writers: vec![addr],
                    transport,
                    rank: 0,
                    hostname: "conf".into(),
                    begin_step_timeout: Duration::from_secs(30),
                })?) as Box<dyn Engine>)
            }),
        })
    })
    .unwrap();
}

#[test]
fn sst_inproc_engine_conforms() {
    sst_conformance("inproc");
}

#[test]
fn sst_tcp_engine_conforms() {
    sst_conformance("tcp");
}

/// SST Discard policy: a discarded step's deferred queue is dropped
/// wholesale — no bytes staged, no step published, the producer never
/// blocked.
#[test]
fn sst_discard_drops_deferred_queue() {
    let mut writer = SstWriter::open(SstWriterOptions {
        listen: format!("conf-discard-{}", std::process::id()),
        transport: "inproc".into(),
        rank: 0,
        hostname: "conf".into(),
        queue: QueueConfig { policy: QueueFullPolicy::Discard, limit: 1 },
        close_linger: Duration::from_millis(200),
        ..Default::default()
    })
    .unwrap();

    let decl = VarDecl::new("/x", Datatype::F32, vec![4]);
    let handle = writer.define_variable(&decl).unwrap();
    let payload = cast::f32_to_bytes(&[1.0, 2.0, 3.0, 4.0]);

    // Step 0 fills the queue (no reader ever retires it).
    assert_eq!(writer.begin_step().unwrap(), StepStatus::Ok);
    writer
        .put_deferred(&handle, Chunk::whole(vec![4]), payload.clone())
        .unwrap();
    writer.end_step().unwrap();
    let after_first = writer.stats();
    assert_eq!(after_first.steps_published, 1);
    assert_eq!(after_first.bytes_put, 16);

    // Step 1 is discarded; its deferred puts (and span) must be dropped
    // with zero data movement, and the producer continues unblocked.
    assert_eq!(writer.begin_step().unwrap(), StepStatus::Discarded);
    writer
        .put_deferred(&handle, Chunk::whole(vec![4]), payload.clone())
        .unwrap();
    {
        let span = writer
            .put_span(&handle, Chunk::whole(vec![4]))
            .unwrap();
        span.fill(0xAB);
    }
    writer.perform_puts().unwrap(); // no-op on a discarded step
    writer.end_step().unwrap();

    let stats = writer.stats();
    assert_eq!(stats.steps_published, 1, "discarded step was published");
    assert_eq!(stats.steps_discarded, 1);
    assert_eq!(stats.bytes_put, 16,
               "discarded step moved data: {stats:?}");
    writer.close().unwrap();
}
