//! Drives the engine-conformance suite (two-phase deferred API) against
//! every backend: BP file, JSON, SST over inproc and TCP — plus the
//! SST-specific contracts: a Discarded step drops its deferred queue
//! before any data movement, and a deferred batch travels as ONE wire
//! data message per writer per step.

use std::path::PathBuf;
use std::time::Duration;

use openpmd_stream::adios::bp::{BpReader, BpWriter, WriterCtx};
use openpmd_stream::adios::engine::{cast, Engine, StepStatus, VarDecl};
use openpmd_stream::adios::json::{JsonReader, JsonWriter};
use openpmd_stream::adios::ops::OpChain;
use openpmd_stream::adios::sst::{
    QueueConfig, QueueFullPolicy, SstReader, SstReaderOptions, SstWriter,
    SstWriterOptions,
};
use openpmd_stream::openpmd::chunk::Chunk;
use openpmd_stream::openpmd::types::Datatype;
use openpmd_stream::testing::engine_conformance::{
    run_conformance, run_operator_conformance, ConformancePair,
};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("opmd-conf-{name}-{}", std::process::id()))
}

/// Every codec chain the operator axis runs against every backend:
/// the lossless set must be byte-identical to the identity chain, the
/// zfp-lite set within tolerance, and the delta set runs on u64 data.
const OPS_CHAINS: [&str; 7] = [
    "shuffle",
    "rle",
    "shuffle|rle",
    "zfp:16",
    "zfp:16|shuffle|rle",
    "delta",
    "delta|rle",
];

#[test]
fn bp_engine_conforms() {
    let path = tmp("bp");
    let path2 = path.clone();
    run_conformance("bp", move || {
        let writer = BpWriter::create(&path2, WriterCtx {
            rank: 0,
            hostname: "conf".into(),
        })?;
        let rpath = path2.clone();
        Ok(ConformancePair {
            writer: Box::new(writer),
            open_reader: Box::new(move || {
                Ok(Box::new(BpReader::open(&rpath)?) as Box<dyn Engine>)
            }),
        })
    })
    .unwrap();
    std::fs::remove_file(&path).ok();
}

#[test]
fn json_engine_conforms() {
    let dir = tmp("json");
    std::fs::remove_dir_all(&dir).ok();
    let dir2 = dir.clone();
    run_conformance("json", move || {
        let writer = JsonWriter::create(&dir2, 0, "conf")?;
        let rdir = dir2.clone();
        Ok(ConformancePair {
            writer: Box::new(writer),
            open_reader: Box::new(move || {
                Ok(Box::new(JsonReader::open(&rdir)?) as Box<dyn Engine>)
            }),
        })
    })
    .unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

fn sst_conformance(transport: &str) {
    let transport_owned = transport.to_string();
    run_conformance(&format!("sst:{transport}"), move || {
        let writer = SstWriter::open(SstWriterOptions {
            listen: if transport_owned == "inproc" {
                format!("conf-{transport_owned}-{}", std::process::id())
            } else {
                String::new()
            },
            transport: transport_owned.clone(),
            rank: 0,
            hostname: "conf".into(),
            // Block + roomy queue: both conformance steps stay staged
            // until the (late-joining) reader drains them.
            queue: QueueConfig { policy: QueueFullPolicy::Block, limit: 8 },
            ..Default::default()
        })?;
        let addr = writer.address();
        let transport = transport_owned.clone();
        Ok(ConformancePair {
            writer: Box::new(writer),
            open_reader: Box::new(move || {
                Ok(Box::new(SstReader::open(SstReaderOptions {
                    writers: vec![addr],
                    transport,
                    rank: 0,
                    hostname: "conf".into(),
                    begin_step_timeout: Duration::from_secs(30),
                    codecs: None,
                })?) as Box<dyn Engine>)
            }),
        })
    })
    .unwrap();
}

#[test]
fn sst_inproc_engine_conforms() {
    sst_conformance("inproc");
}

#[test]
fn sst_tcp_engine_conforms() {
    sst_conformance("tcp");
}

// =====================================================================
// Operator axis: every chain × every backend
// =====================================================================

#[test]
fn bp_engine_operator_conformance() {
    for (i, spec) in OPS_CHAINS.iter().enumerate() {
        let path = tmp(&format!("bp-ops-{i}"));
        let path2 = path.clone();
        run_operator_conformance("bp", spec, move || {
            let writer = BpWriter::create(&path2, WriterCtx {
                rank: 0,
                hostname: "conf".into(),
            })?;
            let rpath = path2.clone();
            Ok(ConformancePair {
                writer: Box::new(writer),
                open_reader: Box::new(move || {
                    Ok(Box::new(BpReader::open(&rpath)?)
                        as Box<dyn Engine>)
                }),
            })
        })
        .unwrap();
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn json_engine_operator_conformance() {
    for (i, spec) in OPS_CHAINS.iter().enumerate() {
        let dir = tmp(&format!("json-ops-{i}"));
        std::fs::remove_dir_all(&dir).ok();
        let dir2 = dir.clone();
        run_operator_conformance("json", spec, move || {
            let writer = JsonWriter::create(&dir2, 0, "conf")?;
            let rdir = dir2.clone();
            Ok(ConformancePair {
                writer: Box::new(writer),
                open_reader: Box::new(move || {
                    Ok(Box::new(JsonReader::open(&rdir)?)
                        as Box<dyn Engine>)
                }),
            })
        })
        .unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}

fn sst_operator_conformance(transport: &str) {
    for (i, spec) in OPS_CHAINS.iter().enumerate() {
        let transport_owned = transport.to_string();
        run_operator_conformance(
            &format!("sst:{transport}"),
            spec,
            move || {
                let writer = SstWriter::open(SstWriterOptions {
                    listen: if transport_owned == "inproc" {
                        format!("confops-{transport_owned}-{i}-{}",
                                std::process::id())
                    } else {
                        String::new()
                    },
                    transport: transport_owned.clone(),
                    rank: 0,
                    hostname: "conf".into(),
                    queue: QueueConfig {
                        policy: QueueFullPolicy::Block,
                        limit: 8,
                    },
                    ..Default::default()
                })?;
                let addr = writer.address();
                let transport = transport_owned.clone();
                Ok(ConformancePair {
                    writer: Box::new(writer),
                    open_reader: Box::new(move || {
                        Ok(Box::new(SstReader::open(SstReaderOptions {
                            writers: vec![addr],
                            transport,
                            rank: 0,
                            hostname: "conf".into(),
                            begin_step_timeout: Duration::from_secs(30),
                            ..Default::default()
                        })?) as Box<dyn Engine>)
                    }),
                })
            },
        )
        .unwrap();
    }
}

#[test]
fn sst_inproc_operator_conformance() {
    sst_operator_conformance("inproc");
}

#[test]
fn sst_tcp_operator_conformance() {
    sst_operator_conformance("tcp");
}

/// Operator negotiation: a reader that advertises NO codecs still reads
/// an operated stream correctly — the writer decodes on its side and
/// serves raw bytes instead of failing the stream.
#[test]
fn sst_codec_less_reader_gets_raw_fallback() {
    let chain = OpChain::parse("shuffle|rle").unwrap();
    let mut writer = SstWriter::open(SstWriterOptions {
        listen: format!("conf-nego-{}", std::process::id()),
        transport: "inproc".into(),
        rank: 0,
        hostname: "conf".into(),
        queue: QueueConfig { policy: QueueFullPolicy::Block, limit: 8 },
        ..Default::default()
    })
    .unwrap();
    let decl = VarDecl::new("/data/0/x", Datatype::F32, vec![16])
        .with_ops(chain);
    let h = writer.define_variable(&decl).unwrap();
    let xs: Vec<f32> = (0..16).map(|i| i as f32 * 0.5).collect();
    assert_eq!(writer.begin_step().unwrap(), StepStatus::Ok);
    writer
        .put_deferred(&h, Chunk::whole(vec![16]), cast::f32_to_bytes(&xs))
        .unwrap();
    writer.end_step().unwrap();

    let addr = writer.address();
    let mut reader = SstReader::open(SstReaderOptions {
        writers: vec![addr],
        transport: "inproc".into(),
        rank: 0,
        hostname: "conf".into(),
        begin_step_timeout: Duration::from_secs(30),
        codecs: Some(Vec::new()), // understands no codecs at all
    })
    .unwrap();
    let close_thread = std::thread::spawn(move || writer.close());
    loop {
        match reader.begin_step().unwrap() {
            StepStatus::Ok => break,
            StepStatus::NotReady => {
                std::thread::sleep(Duration::from_millis(5))
            }
            other => panic!("expected a step, got {other:?}"),
        }
    }
    let whole = reader.get("/data/0/x", Chunk::whole(vec![16])).unwrap();
    assert_eq!(cast::bytes_to_f32(&whole).unwrap(), xs);
    // The fallback means the reader decoded nothing itself.
    assert_eq!(reader.ops_report().chunks_decoded, 0);
    reader.end_step().unwrap();
    reader.close().unwrap();
    close_thread.join().unwrap().unwrap();
}

/// SST Discard policy: a discarded step's deferred queue is dropped
/// wholesale — no bytes staged, no step published, the producer never
/// blocked.
#[test]
fn sst_discard_drops_deferred_queue() {
    let mut writer = SstWriter::open(SstWriterOptions {
        listen: format!("conf-discard-{}", std::process::id()),
        transport: "inproc".into(),
        rank: 0,
        hostname: "conf".into(),
        queue: QueueConfig { policy: QueueFullPolicy::Discard, limit: 1 },
        close_linger: Duration::from_millis(200),
        ..Default::default()
    })
    .unwrap();

    let decl = VarDecl::new("/x", Datatype::F32, vec![4]);
    let handle = writer.define_variable(&decl).unwrap();
    let payload = cast::f32_to_bytes(&[1.0, 2.0, 3.0, 4.0]);

    // Step 0 fills the queue (no reader ever retires it).
    assert_eq!(writer.begin_step().unwrap(), StepStatus::Ok);
    writer
        .put_deferred(&handle, Chunk::whole(vec![4]), payload.clone())
        .unwrap();
    writer.end_step().unwrap();
    let after_first = writer.stats().unwrap();
    assert_eq!(after_first.steps_published, 1);
    assert_eq!(after_first.bytes_put, 16);

    // Step 1 is discarded; its deferred puts (and span) must be dropped
    // with zero data movement, and the producer continues unblocked.
    assert_eq!(writer.begin_step().unwrap(), StepStatus::Discarded);
    writer
        .put_deferred(&handle, Chunk::whole(vec![4]), payload.clone())
        .unwrap();
    {
        let span = writer
            .put_span(&handle, Chunk::whole(vec![4]))
            .unwrap();
        span.fill(0xAB);
    }
    writer.perform_puts().unwrap(); // no-op on a discarded step
    writer.end_step().unwrap();

    let stats = writer.stats().unwrap();
    assert_eq!(stats.steps_published, 1, "discarded step was published");
    assert_eq!(stats.steps_discarded, 1);
    assert_eq!(stats.bytes_put, 16,
               "discarded step moved data: {stats:?}");
    writer.close().unwrap();
}
