//! Serve-daemon conformance: for any upstream, `produce → serve →
//! pipe` must land byte-identical BP output to the direct
//! `produce → pipe` — at every fan-out width (1/2/4 subscribers) and
//! for a late joiner that connects mid-stream and replays the cache
//! tail. This is the PR's acceptance bar for the fan-out mode: the
//! daemon is a transparent step multiplier, never a transform.
//!
//! Everything resolves through the typed spec layer (`SourceSpec` /
//! `SinkSpec`), exercising the same path the CLI's `serve` and `pipe`
//! subcommands take.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use openpmd_stream::adios::engine::{cast, Engine, StepStatus, VarDecl};
use openpmd_stream::adios::spec::{ReaderSlot, SinkSpec, SourceSpec};
use openpmd_stream::adios::sst::{
    QueueConfig, QueueFullPolicy, SstWriter, SstWriterOptions,
};
use openpmd_stream::openpmd::chunk::Chunk;
use openpmd_stream::openpmd::types::Datatype;
use openpmd_stream::openpmd::Attribute;
use openpmd_stream::pipeline::pipe::{run, PipeOptions};
use openpmd_stream::pipeline::serve::{
    LagPolicy, ServeDaemon, ServeOptions,
};
use openpmd_stream::testing::fixtures;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("opmd-serveconf-{name}-{}", std::process::id()))
}

/// Pipe `input_spec` into a fresh BP file at `out` (via the typed
/// spec layer, exactly like `cmd_pipe`) and return the file's bytes.
fn pipe_to_bp(input_spec: &str, out: &PathBuf) -> Vec<u8> {
    let mut input = SourceSpec::parse(input_spec)
        .unwrap()
        .open(ReaderSlot::solo())
        .unwrap();
    let mut output = SinkSpec::parse(out.to_str().unwrap())
        .unwrap()
        .open_writer(ReaderSlot::solo())
        .unwrap();
    run(input.as_mut(), output.as_mut(), PipeOptions::solo()).unwrap();
    std::fs::read(out).unwrap()
}

fn wait_for_subscribers(daemon: &ServeDaemon, n: usize) {
    let deadline = Instant::now() + Duration::from_secs(20);
    while daemon.subscribers() < n {
        assert!(
            Instant::now() < deadline,
            "only {}/{n} subscribers registered in time",
            daemon.subscribers()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn serve_opts(tag: &str, cache_steps: usize) -> ServeOptions {
    ServeOptions {
        listen: format!("serve-conf-{tag}-{}", std::process::id()),
        transport: "inproc".into(),
        cache_steps,
        lag: LagPolicy::Block,
        ..Default::default()
    }
}

/// N subscribers join before the pump starts; each pipes the served
/// stream to its own BP file. Every output must equal the direct
/// pipe's, and the daemon must account one full announce per
/// subscriber with zero drops (Block policy never sheds).
fn fan_out_matches_direct(tag: &str, subs: usize) {
    const STEPS: u64 = 5;
    let src = tmp(&format!("{tag}-src.bp"));
    fixtures::write_chunked_bp(&src, STEPS, 16, 4);
    let base = tmp(&format!("{tag}-base.bp"));
    let want = pipe_to_bp(src.to_str().unwrap(), &base);

    let mut upstream = SourceSpec::parse(src.to_str().unwrap())
        .unwrap()
        .open(ReaderSlot::solo())
        .unwrap();
    let mut daemon = ServeDaemon::start(serve_opts(tag, 16)).unwrap();
    let addr = daemon.address();

    let mut joins = Vec::new();
    for i in 0..subs {
        let spec = format!("serve+{addr}");
        let out = tmp(&format!("{tag}-sub{i}.bp"));
        joins.push(std::thread::spawn(move || {
            (out.clone(), pipe_to_bp(&spec, &out))
        }));
    }
    wait_for_subscribers(&daemon, subs);

    let report = daemon.pump(upstream.as_mut()).unwrap();
    upstream.close().unwrap();
    assert_eq!(report.steps_in, STEPS);
    assert_eq!(report.subscribers.len(), subs);
    for s in &report.subscribers {
        assert_eq!(s.announced_steps, STEPS);
        assert_eq!(s.dropped_steps, 0);
    }

    for j in joins {
        let (out, got) = j.join().unwrap();
        assert!(
            got == want,
            "{} diverged from the direct pipe's output",
            out.display()
        );
    }
}

#[test]
fn one_subscriber_matches_direct_pipe() {
    fan_out_matches_direct("fan1", 1);
}

#[test]
fn two_subscribers_match_direct_pipe() {
    fan_out_matches_direct("fan2", 2);
}

#[test]
fn four_subscribers_match_direct_pipe() {
    fan_out_matches_direct("fan4", 4);
}

/// A deterministic SST producer that sleeps `pace` between steps, so
/// a test can land a subscriber mid-stream. Identical data each call:
/// two runs give byte-identical downstream BP output.
fn paced_sst_producer(
    tag: &str,
    steps: u64,
    pace: Duration,
) -> (String, std::thread::JoinHandle<()>) {
    let mut writer = SstWriter::open(SstWriterOptions {
        listen: format!("serve-conf-{tag}-up-{}", std::process::id()),
        transport: "inproc".into(),
        rank: 0,
        hostname: "producer".into(),
        // Block (not the Discard default): shedding steps here would
        // make the two legs diverge for reasons unrelated to serve.
        queue: QueueConfig { policy: QueueFullPolicy::Block, limit: 4 },
        ..Default::default()
    })
    .unwrap();
    let addr = writer.address();
    let handle = std::thread::spawn(move || {
        let var = VarDecl::new("/data/x", Datatype::F32, vec![32]);
        for step in 0..steps {
            assert_eq!(writer.begin_step().unwrap(), StepStatus::Ok);
            writer
                .put_attribute("/data/time", Attribute::F64(step as f64))
                .unwrap();
            let xs: Vec<f32> =
                (0..32).map(|i| (step * 32 + i) as f32).collect();
            writer
                .put(&var, Chunk::whole(vec![32]), cast::f32_to_bytes(&xs))
                .unwrap();
            writer.end_step().unwrap();
            std::thread::sleep(pace);
        }
        writer.close().unwrap();
    });
    (addr, handle)
}

/// A subscriber that joins mid-stream must replay the cache tail and
/// still produce byte-identical output: with `LagPolicy::Block` and
/// `cache_steps >= steps` the whole stream stays addressable, so
/// lateness costs latency, never data.
#[test]
fn late_joiner_catches_up_from_the_cache_tail() {
    const STEPS: u64 = 6;

    // Direct leg: same producer, no pacing, straight through a pipe.
    let (up_addr, producer) =
        paced_sst_producer("late-base", STEPS, Duration::ZERO);
    let base = tmp("late-base.bp");
    let want = pipe_to_bp(&format!("sst+{up_addr}"), &base);
    producer.join().unwrap();

    // Served leg: paced producer so the pump outlives the joiner's
    // delay, one early subscriber, one joining ~2-3 steps in.
    let (up_addr, producer) = paced_sst_producer(
        "late-serve",
        STEPS,
        Duration::from_millis(120),
    );
    let mut upstream = SourceSpec::parse(&format!("sst+{up_addr}"))
        .unwrap()
        .open(ReaderSlot::solo())
        .unwrap();
    let mut daemon =
        ServeDaemon::start(serve_opts("late", STEPS as usize + 2))
            .unwrap();
    let addr = daemon.address();

    let early_spec = format!("serve+{addr}");
    let early_out = tmp("late-sub-early.bp");
    let early_dst = early_out.clone();
    let early =
        std::thread::spawn(move || pipe_to_bp(&early_spec, &early_dst));
    wait_for_subscribers(&daemon, 1);

    let late_spec = format!("serve+{addr}");
    let late_out = tmp("late-sub-late.bp");
    let late_dst = late_out.clone();
    let late = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(300));
        pipe_to_bp(&late_spec, &late_dst)
    });

    let report = daemon.pump(upstream.as_mut()).unwrap();
    upstream.close().unwrap();
    producer.join().unwrap();

    assert_eq!(report.steps_in, STEPS);
    assert_eq!(report.subscribers.len(), 2);
    for s in &report.subscribers {
        assert_eq!(s.announced_steps, STEPS);
        assert_eq!(s.dropped_steps, 0);
    }
    assert!(
        early.join().unwrap() == want,
        "{} diverged from the direct pipe's output",
        early_out.display()
    );
    assert!(
        late.join().unwrap() == want,
        "{} diverged from the direct pipe's output",
        late_out.display()
    );
}
