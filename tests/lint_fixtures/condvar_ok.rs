// Negative: the guard passed to the wait belongs to the same class
// the condvar was registered with, and nothing else is held across
// the wait — the sanctioned shape.
struct S {
    b: OrderedMutex<u32>,
    cv: OrderedCondvar,
}

fn build() -> S {
    S {
        b: OrderedMutex::new(&classes::BETA, 0),
        cv: OrderedCondvar::new(&classes::BETA),
    }
}

fn fine(s: &S) {
    let gb = s.b.lock();
    let r = s.cv.wait_timeout(gb, timeout);
}
