// Positive: a raw `std::sync::Mutex` inside a lock zone. Both the
// construction site and the unresolvable `.lock()` acquisition are
// `unregistered-lock` findings — zone code must use `OrderedMutex`
// with a class from `util::sync::classes`.
fn f() {
    let m = Mutex::new(0);
    let g = m.lock();
}
