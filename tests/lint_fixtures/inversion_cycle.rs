// Positive: two functions acquire ALPHA (rank 10) and BETA (rank 20)
// in opposite orders — the classic deadlock inversion. The
// rank-decreasing acquisition in `backward` is a `lock-order`
// finding, and the resulting A->B->A edge pair is a `lock-cycle`.
struct S {
    a: OrderedMutex<u32>,
    b: OrderedMutex<u32>,
}

fn build() -> S {
    S {
        a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0),
    }
}

fn forward(s: &S) {
    let ga = s.a.lock();
    let gb = s.b.lock();
}

fn backward(s: &S) {
    let gb = s.b.lock();
    let ga = s.a.lock();
}
