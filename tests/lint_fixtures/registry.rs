// Lock-class registry fixture, parsed under the virtual path
// `rust/src/util/sync.rs`. The grammar must match what
// `concurrency::class_defs` extracts from the real registry:
// `static NAME: LockClass = LockClass { .., rank: N };`.
pub struct LockClass {
    pub name: &'static str,
    pub rank: u32,
}

pub mod classes {
    use super::LockClass;

    pub static ALPHA: LockClass = LockClass { name: "alpha", rank: 10 };
    pub static BETA: LockClass = LockClass { name: "beta", rank: 20 };
    pub static GAMMA: LockClass = LockClass { name: "gamma", rank: 30 };
}
