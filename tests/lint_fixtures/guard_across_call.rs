// Positive: `helper` acquires ALPHA (rank 10); `caller` invokes it
// while holding BETA (rank 20). The interprocedural may-acquire set
// of `helper` contains a class at or below the held rank, so the call
// is a `lock-across-call` finding (and the implied BETA->ALPHA edge
// inverts the rank order).
struct S {
    a: OrderedMutex<u32>,
    b: OrderedMutex<u32>,
}

fn build() -> S {
    S {
        a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0),
    }
}

fn helper(s: &S) {
    let ga = s.a.lock();
}

fn caller(s: &S) {
    let gb = s.b.lock();
    helper(s);
}
