// Positive: the condvar is registered under BETA but the wait hands
// it a guard of the ALPHA mutex — the wakeup protocol and the guarded
// state disagree, so the wait is a `condvar-class` finding.
struct S {
    a: OrderedMutex<u32>,
    b: OrderedMutex<u32>,
    cv: OrderedCondvar,
}

fn build() -> S {
    S {
        a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0),
        cv: OrderedCondvar::new(&classes::BETA),
    }
}

fn wrong(s: &S) {
    let ga = s.a.lock();
    let r = s.cv.wait_timeout(ga, timeout);
}
