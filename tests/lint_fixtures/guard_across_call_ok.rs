// Negative: the callee acquires a strictly higher rank than anything
// the caller holds, which is the sanctioned nesting direction. The
// graph records an ALPHA->BETA `call` edge and nothing is flagged.
struct S {
    a: OrderedMutex<u32>,
    b: OrderedMutex<u32>,
}

fn build() -> S {
    S {
        a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0),
    }
}

fn helper(s: &S) {
    let gb = s.b.lock();
}

fn caller(s: &S) {
    let ga = s.a.lock();
    helper(s);
}
