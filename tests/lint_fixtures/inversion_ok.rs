// Negative: both functions take ALPHA before BETA, matching the rank
// order. The graph gets a single ALPHA->BETA edge and no findings.
struct S {
    a: OrderedMutex<u32>,
    b: OrderedMutex<u32>,
}

fn build() -> S {
    S {
        a: OrderedMutex::new(&classes::ALPHA, 0),
        b: OrderedMutex::new(&classes::BETA, 0),
    }
}

fn forward(s: &S) {
    let ga = s.a.lock();
    let gb = s.b.lock();
}

fn also_forward(s: &S) {
    let ga = s.a.lock();
    let gb = s.b.lock();
}
