// Negative: the same shape through the ordered wrapper with a
// registered class resolves cleanly and produces no findings.
fn f() {
    let m = OrderedMutex::new(&classes::ALPHA, 0);
    let g = m.lock();
}
