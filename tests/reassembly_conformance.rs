//! Reassembly conformance: the closed loop the multiplex layer exists
//! for. For every strategy × fleet width M, the chain
//!
//! ```text
//!   produce → fleet(M) → shards + index → reassemble → pipe
//! ```
//!
//! must be byte-identical to the plain `produce → pipe` chain — the
//! fleet's shard family, opened through its merged `<out>.index.json`
//! as ONE multiplexed logical series, is indistinguishable from the
//! pre-fleet serial stream to any downstream consumer. Also covered:
//! per-worker staged read-ahead (`depth = 2`), a mixed-backend
//! `merge:` composition (bp + json children), and the CLI end to end
//! (`openpmd-pipe` consuming `shards:<index.json>` as `--in`).

use std::path::{Path, PathBuf};

use openpmd_stream::adios::engine::{Engine, StepStatus};
use openpmd_stream::adios::spec::{ReaderSlot, SourceSpec};
use openpmd_stream::openpmd::chunk::Chunk;
use openpmd_stream::testing::fleet_conformance::{
    assert_reassembly_matches, compare_step_payloads,
    fleet_union_at_depth, serial_reference,
};

fn sweep(tag: &str, strategy: &str) {
    let serial = serial_reference(tag)
        .unwrap_or_else(|e| panic!("serial reference: {e:#}"));
    for readers in [1usize, 2, 4] {
        assert_reassembly_matches(&serial, tag, strategy, readers, 0)
            .unwrap_or_else(|e| panic!("M={readers}: {e:#}"));
    }
}

/// The acceptance-bar matrix: every strategy, every fleet width.
#[test]
fn reassembled_family_matches_serial_pipe_roundrobin() {
    sweep("rr", "roundrobin");
}

#[test]
fn reassembled_family_matches_serial_pipe_binpacking() {
    sweep("bin", "binpacking");
}

#[test]
fn reassembled_family_matches_serial_pipe_loadbalanced() {
    sweep("lb", "loadbalanced");
}

#[test]
fn reassembled_family_matches_serial_pipe_hyperslabs() {
    sweep("hs", "hyperslabs");
}

#[test]
fn reassembled_family_matches_serial_pipe_hostname() {
    sweep("host", "hostname");
}

/// Fleet workers with staged read-ahead (`--pipeline-depth 2`): the
/// shard union AND the full reassembled chain stay conformant when
/// every worker fetches through its own read-ahead thread.
#[test]
fn staged_fleet_workers_at_depth_2_stay_conformant() {
    let serial = serial_reference("depth2")
        .unwrap_or_else(|e| panic!("serial reference: {e:#}"));
    let staged = fleet_union_at_depth("depth2", "loadbalanced", 2, 2)
        .unwrap_or_else(|e| panic!("staged fleet: {e:#}"));
    compare_step_payloads(&staged, &serial, "loadbalanced M=2 depth=2")
        .unwrap_or_else(|e| panic!("{e:#}"));
    assert_reassembly_matches(&serial, "depth2", "roundrobin", 2, 2)
        .unwrap_or_else(|e| panic!("reassembled depth=2: {e:#}"));
}

// ---------------------------------------------------------------------
// Mixed-backend merge
// ---------------------------------------------------------------------

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("opmd-reasm-{name}-{}", std::process::id()))
}

/// `merge:bp,json` — two sources on different backends, each holding
/// half of every step, consumed through the pipe as one logical
/// series.
#[test]
fn mixed_backend_merge_pipes_as_one_series() {
    use openpmd_stream::adios::bp::{BpReader, BpWriter, WriterCtx};
    use openpmd_stream::adios::engine::{cast, VarDecl};
    use openpmd_stream::adios::json::JsonWriter;
    use openpmd_stream::openpmd::types::Datatype;
    use openpmd_stream::pipeline::pipe::{run_pipe, PipeOptions};

    const TOTAL: u64 = 16;
    const STEPS: u64 = 3;
    let write_half = |engine: &mut dyn Engine, offset: u64, n: u64| {
        let decl =
            VarDecl::new("/data/0/x", Datatype::F32, vec![TOTAL]);
        for step in 0..STEPS {
            assert_eq!(engine.begin_step().unwrap(), StepStatus::Ok);
            let h = engine.define_variable(&decl).unwrap();
            let xs: Vec<f32> = (0..n)
                .map(|i| (step * 1000 + offset + i) as f32)
                .collect();
            engine
                .put_deferred(&h, Chunk::new(vec![offset], vec![n]),
                              cast::f32_to_bytes(&xs))
                .unwrap();
            engine.end_step().unwrap();
        }
        engine.close().unwrap();
    };

    let bp_half = tmp("merge-half.bp");
    let json_half = tmp("merge-half-json");
    let mut wa = BpWriter::create(&bp_half, WriterCtx::default()).unwrap();
    write_half(&mut wa, 0, TOTAL / 2);
    let mut wb = JsonWriter::create(&json_half, 1, "h").unwrap();
    write_half(&mut wb, TOTAL / 2, TOTAL / 2);

    // Consume the merged composition through the pipe, exactly as the
    // CLI would with --in merge:a,b.
    let spec = format!(
        "merge:{},{}",
        bp_half.display(),
        json_half.display()
    );
    let mut input = SourceSpec::parse(&spec)
        .unwrap()
        .open(ReaderSlot::solo())
        .unwrap();
    let dst = tmp("merge-out.bp");
    let mut output = BpWriter::create(&dst, WriterCtx::default()).unwrap();
    let report = run_pipe(input.as_mut(), &mut output,
                          PipeOptions::solo())
        .unwrap();
    assert_eq!(report.steps, STEPS);
    assert_eq!(report.bytes_in, STEPS * TOTAL * 4);

    let mut check = BpReader::open(&dst).unwrap();
    for step in 0..STEPS {
        assert_eq!(check.begin_step().unwrap(), StepStatus::Ok);
        let data = check
            .get("/data/0/x", Chunk::whole(vec![TOTAL]))
            .unwrap();
        let xs = cast::bytes_to_f32(&data).unwrap();
        for (g, &x) in xs.iter().enumerate() {
            assert_eq!(x, (step * 1000 + g as u64) as f32,
                       "step {step} element {g}");
        }
        check.end_step().unwrap();
    }
    assert_eq!(check.begin_step().unwrap(), StepStatus::EndOfStream);
    std::fs::remove_file(&bp_half).ok();
    std::fs::remove_dir_all(&json_half).ok();
    std::fs::remove_file(&dst).ok();
}

// ---------------------------------------------------------------------
// CLI end to end
// ---------------------------------------------------------------------

fn run_cli(args: &[&str]) {
    let out = std::process::Command::new(env!(
        "CARGO_BIN_EXE_openpmd-stream"
    ))
    .args(args)
    .output()
    .expect("spawning openpmd-stream");
    assert!(
        out.status.success(),
        "openpmd-stream {:?} failed\nstdout:\n{}\nstderr:\n{}",
        args,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}

/// One step's logical content: rendered attributes plus every
/// variable's fully-assembled payload.
type StepSnapshot = (Vec<(String, String)>, Vec<(String, Vec<u8>)>);

/// Logical snapshot of a BP series: per step, its attributes plus
/// every variable's fully-assembled payload. Chunk *boundaries* may
/// legitimately differ between a direct and a reassembled copy (the
/// fleet splits chunks per its strategy); the logical content must
/// not.
fn snapshot(path: &Path) -> Vec<StepSnapshot> {
    use openpmd_stream::adios::bp::BpReader;
    let mut reader = BpReader::open(path).expect("open snapshot source");
    let mut steps = Vec::new();
    while reader.begin_step().expect("begin_step") == StepStatus::Ok {
        let attrs: Vec<(String, String)> = reader
            .attribute_names()
            .into_iter()
            .filter_map(|name| {
                reader
                    .attribute(&name)
                    .map(|v| (name, format!("{v:?}")))
            })
            .collect();
        let mut vars = Vec::new();
        for v in reader.available_variables() {
            let data = reader
                .get(&v.name, Chunk::whole(v.shape.clone()))
                .unwrap_or_else(|e| panic!("get {}: {e:#}", v.name));
            vars.push((v.name.clone(), data.to_vec()));
        }
        vars.sort();
        steps.push((attrs, vars));
        reader.end_step().expect("end_step");
    }
    steps
}

/// The acceptance bar's CLI leg: `openpmd-pipe` (the `pipe`
/// subcommand) accepts `shards:<index.json>` as an input engine spec,
/// end to end — produce, fleet into shards, reassemble through the
/// CLI, and compare against the direct serial pipe of the same
/// source.
#[test]
fn cli_pipe_consumes_a_shard_family_via_shards_spec() {
    let dir = tmp("cli");
    std::fs::create_dir_all(&dir).unwrap();
    let src = dir.join("src.bp");
    let serial_out = dir.join("serial.bp");
    let fleet_out = dir.join("fleet.bp");
    let final_out = dir.join("reassembled.bp");

    run_cli(&[
        "produce", "--out", src.to_str().unwrap(), "--engine", "bp",
        "--steps", "3", "--particles", "512", "--period", "2",
        "--no-runtime",
    ]);
    run_cli(&[
        "pipe", "--in", src.to_str().unwrap(),
        "--out", serial_out.to_str().unwrap(),
    ]);
    run_cli(&[
        "pipe", "--in", src.to_str().unwrap(),
        "--out", fleet_out.to_str().unwrap(),
        "--readers", "2", "--strategy", "binpacking",
    ]);
    let index = dir.join("fleet.bp.index.json");
    assert!(index.exists(), "fleet run must publish the shard index");
    let shards_spec = format!("shards:{}", index.display());
    run_cli(&[
        "pipe", "--in", &shards_spec,
        "--out", final_out.to_str().unwrap(),
    ]);

    let direct = snapshot(&serial_out);
    let reassembled = snapshot(&final_out);
    assert_eq!(direct.len(), 3, "serial pipe lost steps");
    assert_eq!(
        reassembled, direct,
        "reassembled CLI chain differs from the direct serial pipe"
    );
    std::fs::remove_dir_all(&dir).ok();
}
