//! Property tests for the lint lexer.
//!
//! The lexer's contract (see `analysis::lint::lexer`) is: never
//! panic, degrade by skipping bytes it does not recognize, and emit
//! tokens whose byte offsets are strictly increasing (the concurrency
//! pass orders items within a file by `Token::pos`). A deterministic
//! LCG assembles "token soup" from fragments chosen to hit the nasty
//! lexer states — raw strings with varying hash counts, nested block
//! comments, the lifetime-vs-char-literal ambiguity, unterminated
//! literals, multi-byte UTF-8 — and every soup must uphold the
//! contract. Deterministic seeds keep failures reproducible.

use openpmd_stream::analysis::lint::lexer;

/// Minimal deterministic generator (Knuth MMIX constants); no
/// external crates, stable across runs.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    fn pick<'a>(&mut self, items: &[&'a str]) -> &'a str {
        items[(self.next() as usize) % items.len()]
    }
}

/// Fragments biased toward lexer edge cases. Several are deliberately
/// ill-formed (unterminated string, lone quote, stray backslash):
/// the lexer must absorb them without panicking.
const PIECES: &[&str] = &[
    "fn", "let", "struct", "unsafe", "ident_a", "x9", "_",
    "0x1f", "3.5", "1u64", "0b10", "12_000", "9.",
    "'a", "'static", "'x'", "'\\n'", "'\\''",
    "\"plain\"", "\"esc\\\"aped\"", "\"\\u{41}\"", "\"multi\nline\"",
    "r\"raw\"", "r#\"one hash\"#", "r##\"two \"# hashes\"##",
    "b\"bytes\"", "b'\\0'", "br#\"raw bytes\"#",
    "// line comment\n", "//\n", "/* block */",
    "/* nested /* deeper */ still */",
    "{", "}", "(", ")", "[", "]", ";", ":", "::", ".", ",",
    "->", "=>", "&", "|", "#", "!", "=", "<", ">", "?",
    " ", "\t", "\n", "\r\n",
    "émile", "日本語", "→",
    "\"unterminated", "r#\"never closed", "/* never closed",
    "'", "\\",
];

fn soup(seed: u64) -> String {
    let mut rng = Lcg(seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1));
    let n = 40 + (rng.next() % 160) as usize;
    let mut src = String::new();
    for _ in 0..n {
        src.push_str(rng.pick(PIECES));
        if rng.next() % 4 == 0 {
            src.push(' ');
        }
    }
    src
}

/// The core contract over one input: lexing terminates without
/// panicking, offsets are in-bounds, on char boundaries, and strictly
/// increasing, and line numbers start at 1 and never decrease.
fn check_contract(src: &str, what: &str) {
    let lexed = lexer::lex(src);
    let mut prev: Option<usize> = None;
    let mut prev_line = 1u32;
    for t in &lexed.tokens {
        assert!(
            t.pos < src.len(),
            "{what}: token pos {} out of bounds ({} bytes)",
            t.pos,
            src.len()
        );
        assert!(
            src.is_char_boundary(t.pos),
            "{what}: token pos {} splits a UTF-8 sequence",
            t.pos
        );
        if let Some(p) = prev {
            assert!(
                t.pos > p,
                "{what}: byte offsets not strictly increasing \
                 ({p} then {})",
                t.pos
            );
        }
        prev = Some(t.pos);
        assert!(t.line >= 1, "{what}: zero line number");
        assert!(
            t.line >= prev_line,
            "{what}: line numbers went backwards ({prev_line} then {})",
            t.line
        );
        prev_line = t.line;
    }
    for c in &lexed.comments {
        assert!(c.line >= 1, "{what}: zero comment line");
    }
}

#[test]
fn token_soup_never_panics_and_offsets_are_monotone() {
    for seed in 0..128u64 {
        let src = soup(seed);
        check_contract(&src, &format!("seed {seed}"));
    }
}

#[test]
fn every_individual_piece_upholds_the_contract() {
    for (i, p) in PIECES.iter().enumerate() {
        check_contract(p, &format!("piece {i} ({p:?})"));
        // And doubled, so terminator/start interactions are covered.
        let doubled = format!("{p}{p}");
        check_contract(&doubled, &format!("doubled piece {i}"));
    }
}

#[test]
fn fixture_corpus_lexes_cleanly() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures");
    let mut n = 0;
    for entry in std::fs::read_dir(&dir).expect("fixture dir") {
        let path = entry.expect("dir entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("rs") {
            continue;
        }
        let src = std::fs::read_to_string(&path).expect("fixture");
        check_contract(&src, &path.display().to_string());
        n += 1;
    }
    assert!(n >= 9, "expected the fixture corpus, found {n} files");
}
