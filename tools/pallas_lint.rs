//! `pallas-lint` — the repository's static-analysis gate.
//!
//! Scans `rust/src/` and `tools/` with the hand-rolled lexer-level
//! rules in `openpmd_stream::analysis::lint` (panic-freedom zones,
//! lock discipline, the interprocedural concurrency pass,
//! engine-contract conformance, format-fingerprint hygiene), prints
//! `file:line` findings, optionally writes the machine-readable JSON
//! report CI uploads as an artifact, and exits nonzero on any unwaived
//! finding:
//!
//! ```text
//! pallas-lint [--root DIR] [--json FILE] [--bless] [--changed]
//!             [--since REV]
//! ```
//!
//! `--bless` regenerates `tools/lint/format.fingerprint.json` — and
//! refuses when a serialized layout changed while its version string
//! (`MAGIC` / `WIRE_FORMAT`) did not — and `tools/lint/lock.graph.json`
//! from the current lock-order graph.
//!
//! `--changed` restricts the *reported* findings (and the exit status)
//! to files that differ from the merge base with `main`/`master`, plus
//! untracked files; `--since REV` picks the base explicitly. Repo-wide
//! findings (`waiver-ledger`, `format-fingerprint`, `lock-graph`) are
//! always kept: a ledger or manifest drift must fail even a
//! one-file diff. The analysis itself still runs over the whole crate
//! — the concurrency pass is interprocedural, so a "changed-only"
//! scan would miss cross-file lock edges.
//!
//! Exit status: 0 clean (waived-only), 1 unwaived finding(s),
//! 2 usage/IO error.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use openpmd_stream::analysis::lint;
use openpmd_stream::util::cli::{render_help, Args, OptSpec};

/// Rules whose findings describe repo-wide state, not a single source
/// file — never hidden by `--changed`.
const REPO_WIDE_RULES: &[&str] =
    &["waiver-ledger", "format-fingerprint", "lock-graph"];

fn help() -> String {
    render_help(
        "pallas-lint",
        "dependency-free static-analysis gate (panic-freedom, lock \
         discipline, lock-order graph, engine contract, format \
         fingerprint)",
        "pallas-lint [--root DIR] [--json FILE] [--bless] [--changed] \
         [--since REV]",
        &[
            OptSpec {
                name: "root",
                value_name: Some("DIR"),
                default: Some("."),
                help: "repository root to scan",
            },
            OptSpec {
                name: "json",
                value_name: Some("FILE"),
                default: None,
                help: "write the machine-readable findings report",
            },
            OptSpec {
                name: "bless",
                value_name: None,
                default: None,
                help: "regenerate the format-fingerprint and \
                       lock-graph manifests",
            },
            OptSpec {
                name: "changed",
                value_name: None,
                default: None,
                help: "report only findings in files changed since \
                       the merge base with main/master (plus \
                       repo-wide findings)",
            },
            OptSpec {
                name: "since",
                value_name: Some("REV"),
                default: None,
                help: "like --changed, with an explicit base revision",
            },
            OptSpec {
                name: "help",
                value_name: None,
                default: None,
                help: "show this help",
            },
        ],
    )
}

/// Run `git -C root args..`, returning trimmed stdout.
fn git(root: &Path, args: &[&str]) -> Result<String, String> {
    let out = std::process::Command::new("git")
        .arg("-C")
        .arg(root)
        .args(args)
        .output()
        .map_err(|e| format!("running git: {e}"))?;
    if !out.status.success() {
        return Err(format!(
            "git {} failed: {}",
            args.join(" "),
            String::from_utf8_lossy(&out.stderr).trim()
        ));
    }
    Ok(String::from_utf8_lossy(&out.stdout).trim().to_string())
}

/// Repo-relative paths (as the lint reports them) that differ from
/// `base` (or from the merge base with main/master), plus untracked
/// files.
fn changed_files(
    root: &Path,
    since: Option<&str>,
) -> Result<BTreeSet<String>, String> {
    let base = match since {
        Some(rev) => rev.to_string(),
        None => git(root, &["merge-base", "HEAD", "main"])
            .or_else(|_| git(root, &["merge-base", "HEAD", "master"]))
            .map_err(|e| {
                format!(
                    "--changed: no merge base with main or master \
                     (pass --since REV): {e}"
                )
            })?,
    };
    let mut files = BTreeSet::new();
    for list in [
        git(root, &["diff", "--name-only", &base, "--"])?,
        git(root, &["ls-files", "--others", "--exclude-standard"])?,
    ] {
        files.extend(
            list.lines()
                .map(str::trim)
                .filter(|l| !l.is_empty())
                .map(str::to_string),
        );
    }
    Ok(files)
}

fn run() -> Result<bool, String> {
    let args = Args::from_env(false).map_err(|e| e.to_string())?;
    if args.flag("help") {
        print!("{}", help());
        return Ok(true);
    }
    let known = ["root", "json", "bless", "changed", "since", "help"];
    args.reject_unknown(&known).map_err(|e| e.to_string())?;
    let root = PathBuf::from(args.get_or("root", "."));
    if !root.join("Cargo.toml").is_file() {
        return Err(format!(
            "{} does not look like the repository root (no \
             Cargo.toml); pass --root",
            root.display()
        ));
    }
    let opts = lint::LintOptions::at(&root);

    if args.flag("bless") {
        let manifest = opts
            .manifest
            .as_deref()
            .expect("LintOptions::at always sets a manifest path");
        let fp = lint::fingerprint::bless(&root, manifest);
        println!("{}", fp.map_err(|e| format!("{e:#}"))?);
        let lg = lint::bless_lock_graph(&opts);
        println!("{}", lg.map_err(|e| format!("{e:#}"))?);
    }

    let mut report = lint::run(&opts).map_err(|e| format!("{e:#}"))?;

    // --changed / --since: the full-crate analysis already ran (the
    // concurrency pass needs every file); only the report is narrowed.
    let mut hidden = 0usize;
    if args.flag("changed") || args.get("since").is_some() {
        let changed = changed_files(&root, args.get("since"))?;
        let before = report.findings.len();
        report.findings.retain(|f| {
            REPO_WIDE_RULES.contains(&f.rule) || changed.contains(&f.file)
        });
        hidden = before - report.findings.len();
    }

    if let Some(json_path) = args.get("json") {
        let mut body = report.to_json().to_string_pretty();
        body.push('\n');
        std::fs::write(json_path, body)
            .map_err(|e| format!("writing {json_path}: {e}"))?;
    }

    for f in &report.findings {
        match &f.waived {
            Some(reason) => println!(
                "{}:{}: [{}] waived: {} ({})",
                f.file, f.line, f.rule, f.message, reason
            ),
            None => println!(
                "{}:{}: [{}] {}",
                f.file, f.line, f.rule, f.message
            ),
        }
    }
    let unwaived = report.unwaived_count();
    print!(
        "pallas-lint: {} file(s), {} finding(s) ({} waived, {} \
         unwaived)",
        report.files_scanned,
        report.findings.len(),
        report.waived_count(),
        unwaived,
    );
    if hidden > 0 {
        print!(", {hidden} in unchanged files not shown");
    }
    println!();
    Ok(unwaived == 0)
}

fn main() {
    match run() {
        Ok(true) => {}
        Ok(false) => std::process::exit(1),
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            std::process::exit(2);
        }
    }
}
