//! `pallas-lint` — the repository's static-analysis gate.
//!
//! Scans `rust/src/` and `tools/` with the hand-rolled lexer-level
//! rules in `openpmd_stream::analysis::lint` (panic-freedom zones,
//! lock discipline, engine-contract conformance, format-fingerprint
//! hygiene), prints `file:line` findings, optionally writes the
//! machine-readable JSON report CI uploads as an artifact, and exits
//! nonzero on any unwaived finding:
//!
//! ```text
//! pallas-lint [--root DIR] [--json FILE] [--bless]
//! ```
//!
//! `--bless` regenerates `tools/lint/format.fingerprint.json` — and
//! refuses when a serialized layout changed while its version string
//! (`MAGIC` / `WIRE_FORMAT`) did not.
//!
//! Exit status: 0 clean (waived-only), 1 unwaived finding(s),
//! 2 usage/IO error.

use std::path::PathBuf;

use openpmd_stream::analysis::lint;
use openpmd_stream::util::cli::{render_help, Args, OptSpec};

fn help() -> String {
    render_help(
        "pallas-lint",
        "dependency-free static-analysis gate (panic-freedom, lock \
         discipline, engine contract, format fingerprint)",
        "pallas-lint [--root DIR] [--json FILE] [--bless]",
        &[
            OptSpec {
                name: "root",
                value_name: Some("DIR"),
                default: Some("."),
                help: "repository root to scan",
            },
            OptSpec {
                name: "json",
                value_name: Some("FILE"),
                default: None,
                help: "write the machine-readable findings report",
            },
            OptSpec {
                name: "bless",
                value_name: None,
                default: None,
                help: "regenerate the format-fingerprint manifest",
            },
            OptSpec {
                name: "help",
                value_name: None,
                default: None,
                help: "show this help",
            },
        ],
    )
}

fn run() -> Result<bool, String> {
    let args = Args::from_env(false).map_err(|e| e.to_string())?;
    if args.flag("help") {
        print!("{}", help());
        return Ok(true);
    }
    args.reject_unknown(&["root", "json", "bless", "help"])
        .map_err(|e| e.to_string())?;
    let root = PathBuf::from(args.get_or("root", "."));
    if !root.join("Cargo.toml").is_file() {
        return Err(format!(
            "{} does not look like the repository root (no \
             Cargo.toml); pass --root",
            root.display()
        ));
    }
    let opts = lint::LintOptions::at(&root);

    if args.flag("bless") {
        let manifest = opts
            .manifest
            .as_deref()
            .expect("LintOptions::at always sets a manifest path");
        let msg = lint::fingerprint::bless(&root, manifest)
            .map_err(|e| format!("{e:#}"))?;
        println!("{msg}");
    }

    let report = lint::run(&opts).map_err(|e| format!("{e:#}"))?;

    if let Some(json_path) = args.get("json") {
        let mut body = report.to_json().to_string_pretty();
        body.push('\n');
        std::fs::write(json_path, body).map_err(|e| {
            format!("writing {json_path}: {e}")
        })?;
    }

    for f in &report.findings {
        match &f.waived {
            Some(reason) => println!(
                "{}:{}: [{}] waived: {} ({})",
                f.file, f.line, f.rule, f.message, reason
            ),
            None => println!(
                "{}:{}: [{}] {}",
                f.file, f.line, f.rule, f.message
            ),
        }
    }
    let unwaived = report.unwaived_count();
    println!(
        "pallas-lint: {} file(s), {} finding(s) ({} waived, {} \
         unwaived)",
        report.files_scanned,
        report.findings.len(),
        report.waived_count(),
        unwaived,
    );
    Ok(unwaived == 0)
}

fn main() {
    match run() {
        Ok(true) => {}
        Ok(false) => std::process::exit(1),
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            std::process::exit(2);
        }
    }
}
