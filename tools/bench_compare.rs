//! `bench-compare` — the CI perf-regression gate.
//!
//! Diffs the `BENCH_*.json` documents a CI run emitted (shared
//! [`BenchJson`] format from `bench::table`) against the committed
//! baselines:
//!
//! ```text
//! bench-compare --baseline bench/baseline --current bench-results \
//!               [--tolerance 0.30]
//! ```
//!
//! For every baseline document the current run must contain a
//! counterpart, and every **gated** metric must not regress beyond the
//! tolerance: a higher-is-better metric fails when
//! `current < baseline * (1 - tol)`, a lower-is-better one when
//! `current > baseline * (1 + tol)`. Ungated metrics (absolute
//! throughput on shared runners) are printed for the artifact trail
//! but never fail the job. New metrics in the current run are reported
//! as additions — commit a refreshed baseline to start gating them.
//!
//! Exit status: 0 clean, 1 regression(s), 2 usage/IO error.

use std::path::{Path, PathBuf};

use openpmd_stream::bench::{BenchJson, Table};
use openpmd_stream::util::cli::Args;
use openpmd_stream::util::json;

fn load_dir(dir: &Path) -> Result<Vec<BenchJson>, String> {
    let mut out = Vec::new();
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("reading {}: {e}", dir.display()))?;
    for entry in entries {
        let path = entry.map_err(|e| e.to_string())?.path();
        let name = path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or_default();
        if !name.starts_with("BENCH_") || !name.ends_with(".json") {
            continue;
        }
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let doc = json::parse(&text)
            .map_err(|e| format!("parsing {}: {e}", path.display()))?;
        out.push(
            BenchJson::from_json(&doc)
                .map_err(|e| format!("{}: {e}", path.display()))?,
        );
    }
    out.sort_by(|a, b| a.name.cmp(&b.name));
    Ok(out)
}

fn run() -> Result<bool, String> {
    let args = Args::from_env(false).map_err(|e| e.to_string())?;
    args.reject_unknown(&["baseline", "current", "tolerance"])
        .map_err(|e| e.to_string())?;
    let baseline_dir =
        PathBuf::from(args.get_or("baseline", "bench/baseline"));
    let current_dir =
        PathBuf::from(args.get_or("current", "bench-results"));
    let tolerance: f64 = args
        .get_parse_or("tolerance", 0.30)
        .map_err(|e| e.to_string())?;
    if !(0.0..1.0).contains(&tolerance) {
        return Err(format!(
            "--tolerance must be in [0, 1), got {tolerance}"
        ));
    }

    let baselines = load_dir(&baseline_dir)?;
    if baselines.is_empty() {
        return Err(format!(
            "no BENCH_*.json baselines under {}",
            baseline_dir.display()
        ));
    }
    let currents = load_dir(&current_dir)?;

    let mut t = Table::new(
        &format!(
            "bench-compare: {} vs {} (tolerance {:.0}%)",
            current_dir.display(),
            baseline_dir.display(),
            tolerance * 100.0
        ),
        &["bench", "metric", "baseline", "current", "delta", "verdict"],
    );
    let mut regressions = 0usize;
    for base in &baselines {
        let Some(cur) = currents.iter().find(|c| c.name == base.name)
        else {
            t.row(vec![
                base.name.clone(),
                "(document)".into(),
                "present".into(),
                "MISSING".into(),
                "-".into(),
                "REGRESSION".into(),
            ]);
            regressions += 1;
            continue;
        };
        for (key, bm) in &base.metrics {
            let Some(cm) = cur.metrics.get(key) else {
                t.row(vec![
                    base.name.clone(),
                    key.clone(),
                    format!("{:.4}", bm.value),
                    "MISSING".into(),
                    "-".into(),
                    if bm.gate { "REGRESSION" } else { "gone" }.into(),
                ]);
                if bm.gate {
                    regressions += 1;
                }
                continue;
            };
            let delta = if bm.value.abs() > f64::EPSILON {
                (cm.value - bm.value) / bm.value * 100.0
            } else {
                0.0
            };
            let regressed = bm.gate
                && if bm.higher_is_better {
                    cm.value < bm.value * (1.0 - tolerance)
                } else {
                    cm.value > bm.value * (1.0 + tolerance)
                };
            if regressed {
                regressions += 1;
            }
            t.row(vec![
                base.name.clone(),
                key.clone(),
                format!("{:.4}", bm.value),
                format!("{:.4}", cm.value),
                format!("{delta:+.1}%"),
                if regressed {
                    "REGRESSION".into()
                } else if bm.gate {
                    "ok".into()
                } else {
                    "info".into()
                },
            ]);
        }
        // Metrics the current run added (not yet in the baseline).
        for key in cur.metrics.keys() {
            if !base.metrics.contains_key(key) {
                t.row(vec![
                    base.name.clone(),
                    key.clone(),
                    "-".into(),
                    format!("{:.4}", cur.metrics[key].value),
                    "-".into(),
                    "new".into(),
                ]);
            }
        }
    }
    print!("{}", t.render());
    if regressions > 0 {
        println!(
            "\n{regressions} regression(s) beyond {:.0}% — refresh \
             bench/baseline/*.json only with an explanation in the PR.",
            tolerance * 100.0
        );
    } else {
        println!("\nno gated regressions.");
    }
    Ok(regressions == 0)
}

fn main() {
    match run() {
        Ok(true) => {}
        Ok(false) => std::process::exit(1),
        Err(e) => {
            eprintln!("bench-compare: {e}");
            std::process::exit(2);
        }
    }
}
