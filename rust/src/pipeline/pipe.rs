//! `openpmd-pipe` (§4.1): the generic stream adaptor.
//!
//! "An openPMD-api based script that redirects any openPMD data from
//! source to sink" — the identity transformation that turns streaming
//! into asynchronous, node-aggregated file IO (SST+BP), converts between
//! backends, or multiplexes a stream. This is the paper's POSIX-`tee`/
//! `pipe` analogy, and the basis of its first benchmark.
//!
//! The pipe is engine-agnostic on both sides: any read-mode [`Engine`]
//! in, any write-mode [`Engine`] out. Chunks pass through as written
//! (perfect *alignment*); with multiple pipe instances, a distribution
//! strategy decides which instance forwards which chunk.
//!
//! Every step moves through the same core regardless of how the pipe
//! executes:
//!
//! * [`open_step`] — probe the input for its next step (cheap,
//!   metadata only);
//! * [`load_open_step`] — plan this instance's share of the chunk
//!   table, execute the whole batch as ONE `perform_gets` (over SST:
//!   one wire request per writer per step), and detach the result into
//!   a [`StepPayload`];
//! * [`store_into_open_step`] — write a payload into an open output
//!   step as one batched `perform_puts` + `end_step` publish.
//!
//! [`run_pipe`] composes them serially on the calling thread, probing
//! the *output* between open and load so a step the output discards
//! under backpressure is consumed without moving any data. The staged
//! path in [`super::staged`] instead runs fetch ([`fetch_step`]) and
//! store ([`store_step`]) on separate threads with a bounded
//! read-ahead queue, so load and store latencies overlap instead of
//! adding. Because both paths share this core and its accounting,
//! they produce identical output bytes for identical inputs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};
use once_cell::sync::Lazy;

use crate::adios::engine::{
    Bytes, Engine, GetHandle, StepStatus, VarDecl, VarInfo,
};
use crate::adios::ops::{OpChain, OpsReport};
use crate::distribution::{ChunkTable, ReaderLayout, Strategy};
use crate::obs::metrics::{counter, histogram, Counter, Histogram};
use crate::obs::trace;
use crate::openpmd::chunk::Chunk;
use crate::openpmd::Attribute;

use super::metrics::{OpKind, OverlapReport, PerceivedThroughput};

// Interned obs handles; the closures run once at first deref, so the
// registry lock is touched once per site and never inside the loop.
static STEPS_FORWARDED: Lazy<&'static Counter> =
    Lazy::new(|| counter("pipe.steps_forwarded"));
static STEPS_DROPPED: Lazy<&'static Counter> =
    Lazy::new(|| counter("pipe.steps_dropped"));
static NOTREADY_POLLS: Lazy<&'static Counter> =
    Lazy::new(|| counter("pipe.notready_polls"));
static BACKOFF_US: Lazy<&'static Histogram> =
    Lazy::new(|| histogram("pipe.backoff_us"));

/// Pipe configuration.
pub struct PipeOptions {
    /// This pipe instance's rank and the total instance count (a pipe
    /// may be parallel, like any other stage).
    pub rank: usize,
    pub instances: usize,
    /// Distribution strategy for selecting chunks when parallel
    /// (ignored for a single instance, which forwards everything).
    /// Shared (`Arc`) so a fleet of workers can plan with one strategy
    /// instance.
    pub strategy: Arc<dyn Strategy>,
    /// Reader layout of the pipe stage (for topology-aware strategies).
    pub layout: ReaderLayout,
    /// Stop after this many *forwarded* steps (None = until end of
    /// stream). Downstream-discarded steps do not count.
    pub max_steps: Option<u64>,
    /// Give up if no step arrives for this long. An input-side
    /// discarded step counts as stream activity and resets the clock.
    pub idle_timeout: Duration,
    /// Staged read-ahead depth: how many steps the fetch stage may run
    /// ahead of the store stage. `0` = serial (fetch and store strictly
    /// alternate on the calling thread); `>= 1` = staged (a dedicated
    /// fetch thread feeds a bounded queue, so the store of step N
    /// overlaps the load of step N+1; 2 is classic double buffering).
    pub depth: usize,
    /// Operator-chain handling. `None` (default) forwards each input
    /// variable's announced chain to the output unchanged, so a
    /// compressed stream stays compressed end to end. `Some(chain)`
    /// overrides: every forwarded variable is re-declared with `chain`
    /// on the output (the pipe as a transcoder — e.g. raw SST in,
    /// `shuffle|rle` BP out).
    pub operators: Option<OpChain>,
    /// Periodic metric emission (the CLI's `--metrics` /
    /// `--metrics-interval`): JSON lines of registry deltas since the
    /// pipe started, one per interval plus a final summary line.
    pub metrics_sink: Option<MetricsSink>,
}

/// Where and how often the pipe emits metric snapshots.
#[derive(Clone, Debug)]
pub struct MetricsSink {
    /// JSON-lines output file (truncated at pipe start).
    pub path: std::path::PathBuf,
    /// Emit a line every N forwarded steps (`0` = only the final
    /// summary line, which is always written).
    pub every: u64,
}

impl PipeOptions {
    /// Single-instance serial pipe forwarding everything.
    pub fn solo() -> PipeOptions {
        PipeOptions {
            rank: 0,
            instances: 1,
            strategy: Arc::new(crate::distribution::RoundRobin),
            layout: ReaderLayout::local(1)
                // lint:allow(panic-site): local(1) is statically non-empty
                .expect("a one-reader layout is never empty"),
            max_steps: None,
            idle_timeout: Duration::from_secs(60),
            depth: 0,
            operators: None,
            metrics_sink: None,
        }
    }
}

/// Writes [`MetricsSink`] lines: registry deltas relative to the
/// baseline taken when the pipe started, so process-global counters
/// read as per-run numbers. File IO is best-effort — a full disk
/// degrades the metrics file, never the pipe.
pub(crate) struct MetricsEmitter {
    sink: MetricsSink,
    base: crate::obs::metrics::Snapshot,
}

impl MetricsEmitter {
    /// Baseline the registry and truncate the sink file. (Named
    /// uniquely — not `new` — because the lint pass links call edges
    /// by bare name and this constructor may acquire the obs class.)
    pub(crate) fn for_sink(sink: Option<&MetricsSink>)
        -> Option<MetricsEmitter>
    {
        let sink = sink?.clone();
        let _ = std::fs::write(&sink.path, "");
        Some(MetricsEmitter {
            sink,
            base: crate::obs::snapshot_metrics(),
        })
    }

    fn append_line(&self, line: &str) {
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .append(true)
            .open(&self.sink.path)
        {
            let _ = writeln!(f, "{line}");
        }
    }

    /// Called after each forwarded step; emits on interval boundaries.
    pub(crate) fn emit_step_line(&self, steps: u64) {
        if self.sink.every == 0 || steps % self.sink.every != 0 {
            return;
        }
        let d = crate::obs::snapshot_metrics().delta(&self.base);
        self.append_line(&crate::obs::export::metrics_line(
            Some(steps),
            &d,
        ));
    }

    /// The final `step: null` summary line.
    pub(crate) fn emit_final_line(&self) {
        let d = crate::obs::snapshot_metrics().delta(&self.base);
        self.append_line(&crate::obs::export::metrics_line(None, &d));
    }
}

/// What the pipe did.
#[derive(Debug, Default)]
pub struct PipeReport {
    /// Steps forwarded to the output.
    pub steps: u64,
    /// Steps consumed from the input but dropped because the output
    /// discarded them (queue-full backpressure). Not counted in
    /// `steps` and not counted against `PipeOptions::max_steps`.
    pub dropped_steps: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub chunks: u64,
    /// Load/store timing samples (perceived throughput accounting).
    pub metrics: PerceivedThroughput,
    /// Wall-clock overlap accounting. Filled by both paths; a serial
    /// run shows ~zero hidden time, a staged run shows how much of the
    /// store (or load) latency the read-ahead hid.
    pub overlap: OverlapReport,
    /// Merged operator accounting of both engines (decode on the input
    /// side, encode on the output side).
    pub ops: OpsReport,
}

// ======================================================================
// The shared step-forwarding core
// ======================================================================

/// Bounded backoff between `NotReady` polls, replacing the former
/// hot-spin `continue` that burned a full core until the idle timeout.
struct PollBackoff {
    next: Duration,
}

impl PollBackoff {
    const FLOOR: Duration = Duration::from_micros(200);
    const CEIL: Duration = Duration::from_millis(20);

    fn new() -> PollBackoff {
        PollBackoff { next: Self::FLOOR }
    }

    /// Sleep the current backoff and double it (bounded), so an idle
    /// stream is polled a handful of times per second instead of
    /// millions.
    fn wait(&mut self) {
        std::thread::sleep(self.next);
        self.next = (self.next * 2).min(Self::CEIL);
    }

    /// A step arrived: poll eagerly again next time.
    fn reset(&mut self) {
        self.next = Self::FLOOR;
    }
}

/// The `NotReady`/`Discarded` polling policy shared by the serial loop
/// and the staged fetch stage, so the two cannot drift: bounded backoff
/// between polls, and the idle timeout measured against the last
/// stream activity.
pub(crate) struct StepPoller {
    backoff: PollBackoff,
    idle_since: Instant,
    idle_timeout: Duration,
}

impl StepPoller {
    pub(crate) fn new(idle_timeout: Duration) -> StepPoller {
        StepPoller {
            backoff: PollBackoff::new(),
            idle_since: Instant::now(),
            idle_timeout,
        }
    }

    /// A `NotReady` poll: fail once the idle timeout has elapsed with
    /// no intervening activity, otherwise sleep the growing (bounded)
    /// backoff and let the caller poll again.
    pub(crate) fn not_ready(&mut self) -> Result<()> {
        if self.idle_since.elapsed() > self.idle_timeout {
            bail!("pipe idle for {:?}, giving up", self.idle_timeout);
        }
        NOTREADY_POLLS.inc();
        BACKOFF_US.record(self.backoff.next.as_micros() as u64);
        self.backoff.wait();
        Ok(())
    }

    /// Stream activity: a step was fully handled, or the input
    /// discarded one — an active-but-discarding stream is not idle.
    /// Resets the idle clock and the backoff. Callers stamp this
    /// AFTER processing a step (load/store, or the staged hand-off),
    /// so time spent working or blocked on backpressure never eats
    /// into the idle budget.
    pub(crate) fn activity(&mut self) {
        self.idle_since = Instant::now();
        self.backoff.reset();
    }
}

/// The slice filter: decides which chunks of each variable's table THIS
/// instance fetches for a given input step. The serial/staged pipe uses
/// a [`LocalPlan`] (each instance plans independently from its own
/// [`PipeOptions`]); the parallel fleet substitutes a shared planner
/// that computes one step-wide [`crate::distribution::Assignment`] and
/// hands every worker its disjoint share.
pub trait StepPlan: Send {
    /// Chunks of `var` this instance must fetch for input step `step`
    /// (`table` is the step's merged chunk table for that variable).
    fn slices_for(
        &mut self,
        step: u64,
        var: &VarInfo,
        table: &ChunkTable,
    ) -> Result<Vec<Chunk>>;
}

/// The per-instance default plan: forward everything when solo,
/// otherwise distribute the table locally and keep this rank's share.
/// (With every instance running the same deterministic strategy over
/// the same announced table, the local plans agree — the pre-fleet
/// multi-instance behavior, preserved verbatim.)
pub(crate) struct LocalPlan<'a> {
    opts: &'a PipeOptions,
}

impl<'a> LocalPlan<'a> {
    pub(crate) fn new(opts: &'a PipeOptions) -> LocalPlan<'a> {
        LocalPlan { opts }
    }
}

impl StepPlan for LocalPlan<'_> {
    fn slices_for(
        &mut self,
        _step: u64,
        _var: &VarInfo,
        table: &ChunkTable,
    ) -> Result<Vec<Chunk>> {
        Ok(if self.opts.instances <= 1 {
            table.chunks.iter().map(|c| c.chunk.clone()).collect()
        } else {
            let assignment =
                self.opts.strategy.distribute(table, &self.opts.layout);
            assignment
                .slices(self.opts.rank)
                .iter()
                .map(|s| s.chunk.clone())
                .collect()
        })
    }
}

/// One fetched step, detached from the input engine — everything the
/// store stage needs to reproduce the step on any output engine, safe
/// to hand across threads (payloads travel as `Arc`s).
pub(crate) struct StepPayload {
    /// Index of this step in fetch order (0-based, counting every
    /// input step this instance consumed).
    pub step: u64,
    pub attributes: Vec<(String, Attribute)>,
    /// Per variable: the declaration plus this instance's assigned
    /// `(chunk, payload)` pairs, in deterministic (variable, chunk)
    /// order. A variable with no assigned chunks keeps an empty list,
    /// so the store side still calls `define_variable` for it exactly
    /// as the pre-split serial loop did (registering it in the output
    /// engine's variable registry; step *metadata* is built from puts,
    /// so an undeclared-vs-declared-empty variable is not visible in
    /// the output bytes).
    pub vars: Vec<(VarDecl, Vec<(Chunk, Bytes)>)>,
    /// Total payload bytes.
    pub bytes: u64,
    /// Seconds the fetch stage spent executing this step's batch.
    pub load_seconds: f64,
}

impl StepPayload {
    pub(crate) fn chunk_count(&self) -> usize {
        self.vars.iter().map(|(_, chunks)| chunks.len()).sum()
    }
}

/// Return a retired payload's chunk buffers to `util::pool` — the
/// step's end of life in the serial loop, the staged store side, and
/// serve's cache eviction. A chunk still shared with a downstream
/// holder (SST staging, serve cache, a subscriber) is skipped by the
/// reclaim's refcount check and reclaimed by whoever drops it last.
pub(crate) fn reclaim_payload(payload: StepPayload) {
    for (_, chunks) in payload.vars {
        for (_, data) in chunks {
            crate::util::pool::reclaim_bytes(data);
        }
    }
}

/// Outcome of probing the input for its next step (no data movement).
pub(crate) enum StepAvailability {
    /// A step is open on the input; follow with [`load_open_step`].
    Open,
    /// No step available yet — poll again (with backoff).
    NotReady,
    /// The input discarded a step non-collectively; the stream is alive.
    Discarded,
    EndOfStream,
}

/// Probe the input for its next step. Cheap: metadata only, no gets.
pub(crate) fn open_step(input: &mut dyn Engine)
    -> Result<StepAvailability>
{
    Ok(match input.begin_step()? {
        StepStatus::Ok => StepAvailability::Open,
        StepStatus::NotReady => StepAvailability::NotReady,
        StepStatus::Discarded => StepAvailability::Discarded,
        StepStatus::EndOfStream => StepAvailability::EndOfStream,
    })
}

/// Load the already-open input step: ask `plan` for this instance's
/// share of every variable's chunk table, defer all gets, execute them
/// as one batched perform, and close the input step.
pub(crate) fn load_open_step(
    input: &mut dyn Engine,
    opts: &PipeOptions,
    plan: &mut dyn StepPlan,
    step: u64,
) -> Result<StepPayload> {
    let mut sp = trace::span("pipe.fetch").with("step", step);
    let attributes: Vec<(String, Attribute)> = input
        .attribute_names()
        .into_iter()
        .filter_map(|name| input.attribute(&name).map(|v| (name, v)))
        .collect();

    // Two-phase forwarding: defer a get for every assigned chunk of
    // every variable, then execute the step's whole chunk table as
    // ONE perform — over SST that is one batched request per writer
    // per step, the exchange the paper hides behind compute.
    let mut staged: Vec<(VarDecl, Vec<(Chunk, GetHandle)>)> = Vec::new();
    for var in input.available_variables() {
        let chunks = input.available_chunks(&var.name);
        let table = ChunkTable {
            dataset_extent: var.shape.clone(),
            chunks,
        };
        // Forward the writer's operator chain (or the configured
        // override) so the output re-encodes what the input decoded —
        // the chain survives the pipe end to end.
        let fwd_ops = match &opts.operators {
            Some(chain) => chain.clone(),
            None => var.ops.clone(),
        };
        let decl =
            VarDecl::new(var.name.clone(), var.dtype, var.shape.clone())
                .with_ops(fwd_ops);
        let mine: Vec<Chunk> = plan.slices_for(step, &var, &table)?;
        let mut gets = Vec::with_capacity(mine.len());
        for chunk in mine {
            let get = input.get_deferred(&var.name, chunk.clone())?;
            gets.push((chunk, get));
        }
        // Keep variables even with no assigned chunks, so the store
        // side still registers their declarations with the output
        // engine — the pre-split serial loop called define_variable
        // for every input variable, and this preserves that call
        // pattern (and its validation side effects) verbatim.
        staged.push((decl, gets));
    }

    let started = Instant::now();
    input.perform_gets()?;
    let mut bytes = 0u64;
    let mut vars = Vec::with_capacity(staged.len());
    for (decl, gets) in staged {
        let mut chunks = Vec::with_capacity(gets.len());
        for (chunk, get) in gets {
            let data = input.take_get(get)?;
            bytes += data.len() as u64;
            chunks.push((chunk, data));
        }
        vars.push((decl, chunks));
    }
    let load_seconds = started.elapsed().as_secs_f64().max(1e-9);
    sp.set("bytes", bytes);
    input.end_step()?;
    Ok(StepPayload {
        step,
        attributes,
        vars,
        bytes,
        load_seconds,
    })
}

/// Outcome of one [`fetch_step`] attempt (the staged fetch stage,
/// which cannot probe the output first, fetches unconditionally).
pub(crate) enum Fetched {
    Step(StepPayload),
    NotReady,
    Discarded,
    EndOfStream,
}

/// Probe-and-load in one call: the staged fetch stage's unit of work.
pub(crate) fn fetch_step(
    input: &mut dyn Engine,
    opts: &PipeOptions,
    plan: &mut dyn StepPlan,
    step: u64,
) -> Result<Fetched> {
    match open_step(input)? {
        StepAvailability::Open => {}
        StepAvailability::NotReady => return Ok(Fetched::NotReady),
        StepAvailability::Discarded => return Ok(Fetched::Discarded),
        StepAvailability::EndOfStream => return Ok(Fetched::EndOfStream),
    }
    Ok(Fetched::Step(load_open_step(input, opts, plan, step)?))
}

/// Outcome of offering a payload to the output engine.
pub(crate) enum Stored {
    /// Step published; seconds the store stage spent on it.
    Written { seconds: f64 },
    /// The output discarded the step (queue-full backpressure) and the
    /// read-ahead payload is dropped. Only the staged path reaches
    /// this: the serial loop probes the output *before* loading, so a
    /// discarded step moves no data at all.
    Discarded,
}

/// Write one payload into an ALREADY-OPEN output step: attributes,
/// one batched perform, then the `end_step` publish. Returns the
/// store-stage seconds (the whole-step Store sample, so file engines'
/// write cost is visible).
pub(crate) fn store_into_open_step(
    output: &mut dyn Engine,
    payload: &StepPayload,
) -> Result<f64> {
    let _sp = trace::span("pipe.store")
        .with("step", payload.step)
        .with("bytes", payload.bytes);
    for (name, value) in &payload.attributes {
        output.put_attribute(name, value.clone())?;
    }
    let started = Instant::now();
    for (decl, chunks) in &payload.vars {
        let var = output.define_variable(decl)?;
        for (chunk, data) in chunks {
            output.put_deferred(&var, chunk.clone(), data.clone())?;
        }
    }
    output.perform_puts()?;
    output.end_step()?;
    Ok(started.elapsed().as_secs_f64().max(1e-9))
}

/// Open an output step and write one payload into it (or drop the
/// payload if the output discards the step).
pub(crate) fn store_step(
    output: &mut dyn Engine,
    payload: &StepPayload,
) -> Result<Stored> {
    match output.begin_step()? {
        StepStatus::Ok => {}
        StepStatus::Discarded => return Ok(Stored::Discarded),
        other => bail!("output engine refused step: {other:?}"),
    }
    Ok(Stored::Written {
        seconds: store_into_open_step(output, payload)?,
    })
}

/// Account a fetched payload. Shared by the serial and staged paths so
/// their metrics cannot drift apart.
pub(crate) fn account_load(
    report: &mut PipeReport,
    payload: &StepPayload,
    rank: usize,
) {
    report.bytes_in += payload.bytes;
    report.metrics.record_sim(
        OpKind::Load,
        payload.bytes,
        payload.load_seconds,
        payload.step,
        rank,
    );
    report.overlap.load_busy_seconds += payload.load_seconds;
}

/// Account a stored payload (the counterpart of [`account_load`]).
pub(crate) fn account_store(
    report: &mut PipeReport,
    payload: &StepPayload,
    seconds: f64,
    rank: usize,
) {
    report.metrics.record_sim(
        OpKind::Store,
        payload.bytes,
        seconds,
        payload.step,
        rank,
    );
    report.overlap.store_busy_seconds += seconds;
    report.bytes_out += payload.bytes;
    report.chunks += payload.chunk_count() as u64;
    report.steps += 1;
    STEPS_FORWARDED.inc();
}

/// The staged store stage's unit of work: offer one read-ahead payload
/// to the output and account the outcome.
pub(crate) fn forward_payload(
    output: &mut dyn Engine,
    payload: &StepPayload,
    report: &mut PipeReport,
    rank: usize,
) -> Result<()> {
    account_load(report, payload, rank);
    match store_step(output, payload)? {
        Stored::Written { seconds } => {
            account_store(report, payload, seconds, rank);
        }
        Stored::Discarded => {
            report.dropped_steps += 1;
            STEPS_DROPPED.inc();
        }
    }
    Ok(())
}

// ======================================================================
// Entry points
// ======================================================================

/// Run the pipe with the configured execution mode: `opts.depth == 0`
/// is the serial loop ([`run_pipe`]), anything else the staged
/// overlapped pipe ([`super::staged::run_staged`]).
pub fn run(
    input: &mut dyn Engine,
    output: &mut dyn Engine,
    opts: PipeOptions,
) -> Result<PipeReport> {
    if opts.depth == 0 {
        run_pipe(input, output, opts)
    } else {
        super::staged::run_staged(input, output, opts)
    }
}

/// Run the pipe serially until end-of-stream (or `max_steps`): fetch
/// and store strictly alternate on the calling thread, so per-step
/// cost is load + store. The heart of the paper's first benchmark:
/// `input` is typically an SST reader fed by the producers on this
/// node; `output` a BP writer — giving streaming-based asynchronous IO
/// with node-level aggregation "for free".
pub fn run_pipe(
    input: &mut dyn Engine,
    output: &mut dyn Engine,
    opts: PipeOptions,
) -> Result<PipeReport> {
    let mut plan = LocalPlan::new(&opts);
    run_pipe_with_plan(input, output, &opts, &mut plan)
}

/// [`run_pipe`] with an explicit slice filter — the fleet's per-worker
/// loop, where `plan` is the shared step planner instead of a local
/// per-instance one.
pub(crate) fn run_pipe_with_plan(
    input: &mut dyn Engine,
    output: &mut dyn Engine,
    opts: &PipeOptions,
    plan: &mut dyn StepPlan,
) -> Result<PipeReport> {
    let mut report = PipeReport::default();
    let wall = Instant::now();
    let mut poller = StepPoller::new(opts.idle_timeout);
    let emitter = MetricsEmitter::for_sink(opts.metrics_sink.as_ref());

    loop {
        if let Some(max) = opts.max_steps {
            if report.steps >= max {
                break;
            }
        }
        match open_step(input)? {
            StepAvailability::Open => {}
            StepAvailability::NotReady => {
                poller.not_ready()?;
                continue;
            }
            StepAvailability::Discarded => {
                poller.activity();
                continue;
            }
            StepAvailability::EndOfStream => break,
        }
        // Probe the output BEFORE any data moves: under queue-full
        // backpressure a discarded step is consumed with begin/end
        // only — no gets, no wire traffic (SST's discard-before-
        // data-movement contract, preserved through the pipe).
        match output.begin_step()? {
            StepStatus::Ok => {}
            StepStatus::Discarded => {
                input.end_step()?;
                report.dropped_steps += 1;
                STEPS_DROPPED.inc();
                poller.activity();
                continue;
            }
            other => bail!("output engine refused step: {other:?}"),
        }
        let fetch_index = report.steps + report.dropped_steps;
        let _step_span =
            trace::span("pipe.step").with("step", fetch_index);
        let payload = load_open_step(input, opts, plan, fetch_index)?;
        account_load(&mut report, &payload, opts.rank);
        let seconds = store_into_open_step(output, &payload)?;
        account_store(&mut report, &payload, seconds, opts.rank);
        reclaim_payload(payload);
        if let Some(e) = &emitter {
            e.emit_step_line(report.steps);
        }
        // Activity is stamped after the step was fully handled: a
        // step whose load+store exceeds the idle timeout must not
        // trip a spurious "idle" abort on the next poll.
        poller.activity();
    }
    output.close()?;
    input.close()?;
    report.overlap.wall_seconds = wall.elapsed().as_secs_f64().max(1e-9);
    report.overlap.steps = report.steps;
    report.ops.absorb(input.ops_report());
    report.ops.absorb(output.ops_report());
    if let Some(e) = &emitter {
        e.emit_final_line();
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::bp::{BpReader, BpWriter, WriterCtx};
    use crate::adios::engine::{
        cast, GetHandle, Mode, VarHandle, VarInfo,
    };
    use crate::adios::json::JsonWriter;
    use crate::openpmd::chunk::WrittenChunkInfo;
    use crate::openpmd::types::Datatype;
    use crate::openpmd::Attribute;
    use crate::testing::engines::InjectedEngine;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("opmd-pipe-{name}-{}", std::process::id()))
    }

    fn make_bp(path: &PathBuf, steps: u64) {
        let mut w = BpWriter::create(path, WriterCtx {
            rank: 1,
            hostname: "src".into(),
        })
        .unwrap();
        let var = VarDecl::new("/data/0/particles/e/weighting",
                               Datatype::F32, vec![8]);
        for s in 0..steps {
            w.begin_step().unwrap();
            w.put_attribute("/data/0/time", Attribute::F64(s as f64))
                .unwrap();
            let xs: Vec<f32> = (0..8).map(|i| (s * 8 + i) as f32).collect();
            w.put(&var, Chunk::whole(vec![8]), cast::f32_to_bytes(&xs))
                .unwrap();
            w.end_step().unwrap();
        }
        w.close().unwrap();
    }

    #[test]
    fn bp_to_bp_identity() {
        let src = tmp("src.bp");
        let dst = tmp("dst.bp");
        make_bp(&src, 3);
        let mut input = BpReader::open(&src).unwrap();
        let mut output =
            BpWriter::create(&dst, WriterCtx::default()).unwrap();
        let report =
            run_pipe(&mut input, &mut output, PipeOptions::solo()).unwrap();
        assert_eq!(report.steps, 3);
        assert_eq!(report.dropped_steps, 0);
        assert_eq!(report.bytes_in, 3 * 8 * 4);
        assert_eq!(report.bytes_in, report.bytes_out);

        // Verify the copy's content.
        let mut check = BpReader::open(&dst).unwrap();
        for s in 0..3u64 {
            assert_eq!(check.begin_step().unwrap(), StepStatus::Ok);
            assert_eq!(
                check.attribute("/data/0/time").unwrap().as_f64(),
                Some(s as f64)
            );
            let data = check
                .get("/data/0/particles/e/weighting", Chunk::whole(vec![8]))
                .unwrap();
            assert_eq!(cast::bytes_to_f32(&data).unwrap()[0],
                       (s * 8) as f32);
            check.end_step().unwrap();
        }
        assert_eq!(check.begin_step().unwrap(), StepStatus::EndOfStream);
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&dst).ok();
    }

    #[test]
    fn bp_to_json_backend_conversion() {
        // The pipe as a format converter (one of the §4.1 enabled
        // workflows).
        let src = tmp("conv.bp");
        let dstdir = tmp("conv-json");
        make_bp(&src, 2);
        let mut input = BpReader::open(&src).unwrap();
        let mut output = JsonWriter::create(&dstdir, 0, "h").unwrap();
        let report =
            run_pipe(&mut input, &mut output, PipeOptions::solo()).unwrap();
        assert_eq!(report.steps, 2);
        assert!(dstdir.join("step-0.json").exists());
        assert!(dstdir.join("step-1.json").exists());
        std::fs::remove_file(&src).ok();
        std::fs::remove_dir_all(&dstdir).ok();
    }

    #[test]
    fn max_steps_truncates() {
        let src = tmp("trunc.bp");
        let dst = tmp("trunc-out.bp");
        make_bp(&src, 5);
        let mut input = BpReader::open(&src).unwrap();
        let mut output =
            BpWriter::create(&dst, WriterCtx::default()).unwrap();
        let mut opts = PipeOptions::solo();
        opts.max_steps = Some(2);
        let report = run_pipe(&mut input, &mut output, opts).unwrap();
        assert_eq!(report.steps, 2);
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&dst).ok();
    }

    #[test]
    fn metrics_capture_loads_and_stores() {
        let src = tmp("metrics.bp");
        let dst = tmp("metrics-out.bp");
        make_bp(&src, 4);
        let mut input = BpReader::open(&src).unwrap();
        let mut output =
            BpWriter::create(&dst, WriterCtx::default()).unwrap();
        let report =
            run_pipe(&mut input, &mut output, PipeOptions::solo()).unwrap();
        let loads = report.metrics.report(OpKind::Load, 1);
        assert_eq!(loads.ops, 4);
        assert_eq!(loads.total_bytes, 4 * 32);
        assert!(loads.mean_instance_rate > 0.0);
        // A serial run fills the overlap accounting with ~zero hidden
        // time: wall covers both stages end to end.
        assert_eq!(report.overlap.steps, 4);
        assert!(report.overlap.wall_seconds
                >= report.overlap.load_busy_seconds);
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&dst).ok();
    }

    #[test]
    fn downstream_discards_do_not_eat_max_steps() {
        // The output discards the first two steps (queue-full
        // backpressure). With `max_steps = 3` the pipe must still
        // forward THREE steps — drops are counted separately, not
        // against the budget (the former accounting terminated after
        // forwarding only one).
        let src = tmp("drop-acct.bp");
        let dst = tmp("drop-acct-out.bp");
        make_bp(&src, 5);
        let mut input = BpReader::open(&src).unwrap();
        let inner = BpWriter::create(&dst, WriterCtx::default()).unwrap();
        let mut output = InjectedEngine::discarding(inner, 2);
        let mut opts = PipeOptions::solo();
        opts.max_steps = Some(3);
        let report = run_pipe(&mut input, &mut output, opts).unwrap();
        assert_eq!(report.steps, 3);
        assert_eq!(report.dropped_steps, 2);
        // The serial loop probes the output before loading: discarded
        // steps are consumed without any gets, so no bytes moved for
        // them and no Load samples were taken.
        assert_eq!(report.bytes_in, 3 * 32);
        assert_eq!(report.bytes_out, 3 * 32);
        assert_eq!(report.metrics.report(OpKind::Load, 1).ops, 3);

        // The output holds the three non-dropped source steps (2, 3, 4).
        let mut check = BpReader::open(&dst).unwrap();
        for s in 2..5u64 {
            assert_eq!(check.begin_step().unwrap(), StepStatus::Ok);
            assert_eq!(
                check.attribute("/data/0/time").unwrap().as_f64(),
                Some(s as f64)
            );
            check.end_step().unwrap();
        }
        assert_eq!(check.begin_step().unwrap(), StepStatus::EndOfStream);
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&dst).ok();
    }

    /// Minimal scripted read engine for loop-behavior tests: plays a
    /// fixed sequence of `begin_step` statuses (steps carry no data).
    struct ScriptedInput {
        script: Vec<StepStatus>,
        cursor: usize,
        begin_calls: u64,
        /// Artificial latency per `begin_step` (models a polling wait).
        delay: Duration,
    }

    impl ScriptedInput {
        fn new(script: Vec<StepStatus>, delay: Duration) -> ScriptedInput {
            ScriptedInput { script, cursor: 0, begin_calls: 0, delay }
        }
    }

    impl Engine for ScriptedInput {
        fn engine_type(&self) -> &'static str {
            "scripted"
        }

        fn mode(&self) -> Mode {
            Mode::Read
        }

        fn begin_step(&mut self) -> Result<StepStatus> {
            self.begin_calls += 1;
            std::thread::sleep(self.delay);
            let status = self
                .script
                .get(self.cursor)
                .copied()
                .unwrap_or(StepStatus::EndOfStream);
            if self.cursor < self.script.len() {
                self.cursor += 1;
            }
            Ok(status)
        }

        fn define_variable(&mut self, _decl: &VarDecl) -> Result<VarHandle> {
            bail!("read-mode")
        }

        fn put_deferred(&mut self, _var: &VarHandle, _chunk: Chunk,
                        _data: Bytes) -> Result<()> {
            bail!("read-mode")
        }

        fn put_span(&mut self, _var: &VarHandle, _chunk: Chunk)
            -> Result<&mut [u8]>
        {
            bail!("read-mode")
        }

        fn perform_puts(&mut self) -> Result<()> {
            bail!("read-mode")
        }

        fn put_attribute(&mut self, _name: &str, _value: Attribute)
            -> Result<()>
        {
            bail!("read-mode")
        }

        fn available_variables(&self) -> Vec<VarInfo> {
            Vec::new()
        }

        fn available_chunks(&self, _var: &str) -> Vec<WrittenChunkInfo> {
            Vec::new()
        }

        fn attribute(&self, _name: &str) -> Option<Attribute> {
            None
        }

        fn attribute_names(&self) -> Vec<String> {
            Vec::new()
        }

        fn get_deferred(&mut self, _var: &str, _selection: Chunk)
            -> Result<GetHandle>
        {
            bail!("scripted input has no data")
        }

        fn perform_gets(&mut self) -> Result<()> {
            Ok(())
        }

        fn take_get(&mut self, _handle: GetHandle) -> Result<Bytes> {
            bail!("scripted input has no data")
        }

        fn end_step(&mut self) -> Result<()> {
            Ok(())
        }

        fn close(&mut self) -> Result<()> {
            Ok(())
        }
    }

    #[test]
    fn not_ready_polls_back_off_instead_of_spinning() {
        // A never-ready input must trip the idle timeout after a
        // bounded number of polls — the former hot loop called
        // begin_step millions of times while burning a full core.
        // 4096 NotReady polls vastly exceed what a backed-off loop can
        // consume in 120 ms (a spinning loop would exhaust them in
        // microseconds and sail past the idle check to EndOfStream,
        // failing the unwrap_err below).
        let mut input = ScriptedInput::new(
            vec![StepStatus::NotReady; 4096],
            Duration::ZERO,
        );
        let dst = tmp("backoff-out.bp");
        let mut output =
            BpWriter::create(&dst, WriterCtx::default()).unwrap();
        let mut opts = PipeOptions::solo();
        opts.idle_timeout = Duration::from_millis(120);
        let err = run_pipe(&mut input, &mut output, opts).unwrap_err();
        assert!(format!("{err}").contains("idle"), "{err}");
        // 120 ms of polling with a 200 µs..20 ms backoff is a few dozen
        // calls at most; a busy-wait would be several orders beyond.
        assert!(input.begin_calls < 650,
                "busy-wait: {} polls", input.begin_calls);
        std::fs::remove_file(&dst).ok();
    }

    #[test]
    fn input_discards_reset_the_idle_clock() {
        // 6 discarded steps spaced 30 ms apart exceed the 100 ms idle
        // timeout in total, but each one is stream activity: the pipe
        // must ride them out and end cleanly instead of bailing idle.
        let mut script = vec![StepStatus::Discarded; 6];
        script.push(StepStatus::EndOfStream);
        let mut input =
            ScriptedInput::new(script, Duration::from_millis(30));
        let dst = tmp("discard-idle-out.bp");
        let mut output =
            BpWriter::create(&dst, WriterCtx::default()).unwrap();
        let mut opts = PipeOptions::solo();
        opts.idle_timeout = Duration::from_millis(100);
        let report = run_pipe(&mut input, &mut output, opts).unwrap();
        assert_eq!(report.steps, 0);
        assert_eq!(report.dropped_steps, 0); // input-side, not downstream
        std::fs::remove_file(&dst).ok();
    }
}
