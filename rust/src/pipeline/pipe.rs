//! `openpmd-pipe` (§4.1): the generic stream adaptor.
//!
//! "An openPMD-api based script that redirects any openPMD data from
//! source to sink" — the identity transformation that turns streaming
//! into asynchronous, node-aggregated file IO (SST+BP), converts between
//! backends, or multiplexes a stream. This is the paper's POSIX-`tee`/
//! `pipe` analogy, and the basis of its first benchmark.
//!
//! The pipe is engine-agnostic on both sides: any read-mode [`Engine`]
//! in, any write-mode [`Engine`] out. Chunks pass through as written
//! (perfect *alignment*); with multiple pipe instances, a distribution
//! strategy decides which instance forwards which chunk.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::adios::engine::{Engine, StepStatus, VarDecl};
use crate::distribution::{ChunkTable, ReaderLayout, Strategy};
use crate::openpmd::chunk::Chunk;

use super::metrics::{OpKind, PerceivedThroughput};

/// Pipe configuration.
pub struct PipeOptions {
    /// This pipe instance's rank and the total instance count (a pipe
    /// may be parallel, like any other stage).
    pub rank: usize,
    pub instances: usize,
    /// Distribution strategy for selecting chunks when parallel
    /// (ignored for a single instance, which forwards everything).
    pub strategy: Box<dyn Strategy>,
    /// Reader layout of the pipe stage (for topology-aware strategies).
    pub layout: ReaderLayout,
    /// Stop after this many steps (None = until end of stream).
    pub max_steps: Option<u64>,
    /// Give up if no step arrives for this long.
    pub idle_timeout: Duration,
}

impl PipeOptions {
    /// Single-instance pipe forwarding everything.
    pub fn solo() -> PipeOptions {
        PipeOptions {
            rank: 0,
            instances: 1,
            strategy: Box::new(crate::distribution::RoundRobin),
            layout: ReaderLayout::local(1),
            max_steps: None,
            idle_timeout: Duration::from_secs(60),
        }
    }
}

/// What the pipe did.
#[derive(Debug, Default)]
pub struct PipeReport {
    pub steps: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub chunks: u64,
    /// Load/store timing samples (perceived throughput accounting).
    pub metrics: PerceivedThroughput,
}

/// Run the pipe until end-of-stream (or `max_steps`). The heart of the
/// paper's first benchmark: `input` is typically an SST reader fed by
/// the producers on this node; `output` a BP writer — giving streaming-
/// based asynchronous IO with node-level aggregation "for free".
pub fn run_pipe(
    input: &mut dyn Engine,
    output: &mut dyn Engine,
    opts: PipeOptions,
) -> Result<PipeReport> {
    let mut report = PipeReport::default();
    let deadline_budget = opts.idle_timeout;
    let mut idle_since = std::time::Instant::now();

    loop {
        if let Some(max) = opts.max_steps {
            if report.steps >= max {
                break;
            }
        }
        match input.begin_step()? {
            StepStatus::Ok => {}
            StepStatus::NotReady => {
                if idle_since.elapsed() > deadline_budget {
                    bail!("pipe idle for {deadline_budget:?}, giving up");
                }
                continue;
            }
            StepStatus::EndOfStream => break,
            StepStatus::Discarded => continue,
        }
        idle_since = std::time::Instant::now();

        let step = report.steps;
        let out_status = output.begin_step()?;
        if out_status == StepStatus::Discarded {
            // Downstream backpressure: consume & drop this step.
            input.end_step()?;
            report.steps += 1;
            continue;
        }

        // Forward attributes.
        for name in input.attribute_names() {
            if let Some(v) = input.attribute(&name) {
                output.put_attribute(&name, v)?;
            }
        }

        // Two-phase forwarding: defer a get for every assigned chunk of
        // every variable, then execute the step's whole chunk table as
        // ONE perform — over SST that is one batched request per writer
        // per step, the exchange the paper hides behind compute.
        let mut staged = Vec::new();
        for var in input.available_variables() {
            let chunks = input.available_chunks(&var.name);
            let table = ChunkTable {
                dataset_extent: var.shape.clone(),
                chunks,
            };
            let decl =
                VarDecl::new(var.name.clone(), var.dtype, var.shape.clone());
            let out_var = output.define_variable(&decl)?;
            let mine: Vec<Chunk> = if opts.instances <= 1 {
                table.chunks.iter().map(|c| c.chunk.clone()).collect()
            } else {
                let assignment =
                    opts.strategy.distribute(&table, &opts.layout);
                assignment
                    .slices(opts.rank)
                    .iter()
                    .map(|s| s.chunk.clone())
                    .collect()
            };
            for chunk in mine {
                let get = input.get_deferred(&var.name, chunk.clone())?;
                staged.push((out_var.clone(), chunk, get));
            }
        }

        let t = report.metrics.start(OpKind::Load, step, opts.rank);
        input.perform_gets()?;
        let mut step_bytes = 0u64;
        for (out_var, chunk, get) in staged {
            let data = input.take_get(get)?;
            step_bytes += data.len() as u64;
            output.put_deferred(&out_var, chunk, data)?;
            report.chunks += 1;
        }
        report.metrics.finish(t, step_bytes);
        report.bytes_in += step_bytes;
        report.bytes_out += step_bytes;

        input.end_step()?;
        // `put_deferred` above only buffers; the batch executes and the
        // step publishes here, charged to a whole-step Store sample so
        // file engines' write cost is visible.
        let t = report.metrics.start(OpKind::Store, step, opts.rank);
        output.perform_puts()?;
        output.end_step()?;
        report.metrics.finish(t, step_bytes);
        report.steps += 1;
    }
    output.close()?;
    input.close()?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::bp::{BpReader, BpWriter, WriterCtx};
    use crate::adios::engine::cast;
    use crate::adios::json::JsonWriter;
    use crate::openpmd::types::Datatype;
    use crate::openpmd::Attribute;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("opmd-pipe-{name}-{}", std::process::id()))
    }

    fn make_bp(path: &PathBuf, steps: u64) {
        let mut w = BpWriter::create(path, WriterCtx {
            rank: 1,
            hostname: "src".into(),
        })
        .unwrap();
        let var = VarDecl::new("/data/0/particles/e/weighting",
                               Datatype::F32, vec![8]);
        for s in 0..steps {
            w.begin_step().unwrap();
            w.put_attribute("/data/0/time", Attribute::F64(s as f64))
                .unwrap();
            let xs: Vec<f32> = (0..8).map(|i| (s * 8 + i) as f32).collect();
            w.put(&var, Chunk::whole(vec![8]), cast::f32_to_bytes(&xs))
                .unwrap();
            w.end_step().unwrap();
        }
        w.close().unwrap();
    }

    #[test]
    fn bp_to_bp_identity() {
        let src = tmp("src.bp");
        let dst = tmp("dst.bp");
        make_bp(&src, 3);
        let mut input = BpReader::open(&src).unwrap();
        let mut output =
            BpWriter::create(&dst, WriterCtx::default()).unwrap();
        let report =
            run_pipe(&mut input, &mut output, PipeOptions::solo()).unwrap();
        assert_eq!(report.steps, 3);
        assert_eq!(report.bytes_in, 3 * 8 * 4);
        assert_eq!(report.bytes_in, report.bytes_out);

        // Verify the copy's content.
        let mut check = BpReader::open(&dst).unwrap();
        for s in 0..3u64 {
            assert_eq!(check.begin_step().unwrap(), StepStatus::Ok);
            assert_eq!(
                check.attribute("/data/0/time").unwrap().as_f64(),
                Some(s as f64)
            );
            let data = check
                .get("/data/0/particles/e/weighting", Chunk::whole(vec![8]))
                .unwrap();
            assert_eq!(cast::bytes_to_f32(&data).unwrap()[0],
                       (s * 8) as f32);
            check.end_step().unwrap();
        }
        assert_eq!(check.begin_step().unwrap(), StepStatus::EndOfStream);
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&dst).ok();
    }

    #[test]
    fn bp_to_json_backend_conversion() {
        // The pipe as a format converter (one of the §4.1 enabled
        // workflows).
        let src = tmp("conv.bp");
        let dstdir = tmp("conv-json");
        make_bp(&src, 2);
        let mut input = BpReader::open(&src).unwrap();
        let mut output = JsonWriter::create(&dstdir, 0, "h").unwrap();
        let report =
            run_pipe(&mut input, &mut output, PipeOptions::solo()).unwrap();
        assert_eq!(report.steps, 2);
        assert!(dstdir.join("step-0.json").exists());
        assert!(dstdir.join("step-1.json").exists());
        std::fs::remove_file(&src).ok();
        std::fs::remove_dir_all(&dstdir).ok();
    }

    #[test]
    fn max_steps_truncates() {
        let src = tmp("trunc.bp");
        let dst = tmp("trunc-out.bp");
        make_bp(&src, 5);
        let mut input = BpReader::open(&src).unwrap();
        let mut output =
            BpWriter::create(&dst, WriterCtx::default()).unwrap();
        let mut opts = PipeOptions::solo();
        opts.max_steps = Some(2);
        let report = run_pipe(&mut input, &mut output, opts).unwrap();
        assert_eq!(report.steps, 2);
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&dst).ok();
    }

    #[test]
    fn metrics_capture_loads_and_stores() {
        let src = tmp("metrics.bp");
        let dst = tmp("metrics-out.bp");
        make_bp(&src, 4);
        let mut input = BpReader::open(&src).unwrap();
        let mut output =
            BpWriter::create(&dst, WriterCtx::default()).unwrap();
        let report =
            run_pipe(&mut input, &mut output, PipeOptions::solo()).unwrap();
        let loads = report.metrics.report(OpKind::Load, 1);
        assert_eq!(loads.ops, 4);
        assert_eq!(loads.total_bytes, 4 * 32);
        assert!(loads.mean_instance_rate > 0.0);
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&dst).ok();
    }
}
