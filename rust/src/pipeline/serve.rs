//! serve — the streaming fan-out daemon (S12): subscribe once,
//! serve N.
//!
//! The third CLI mode. A pipe couples one upstream to one downstream;
//! attaching N analyses to one producer either multiplies the
//! producer's cost (N direct SST subscriptions mean N announce/fetch
//! cycles against its staging queue) or is impossible for file inputs
//! already being consumed. `serve` sits in between:
//!
//! ```text
//!   producer ──(any SourceSpec)──▶ serve ──▶ SST client 1
//!                                       ├──▶ SST client 2
//!                                       └──▶ ... client N
//! ```
//!
//! * **Subscribe once.** The daemon consumes its upstream through the
//!   same [`fetch_step`] path as the pipe — any input spec works
//!   (`sst+tcp://…`, `shards:`, `merge:`, bp, json).
//! * **Encode once, serve N times.** Each fetched step is staged as a
//!   [`StagedStep`] with its operator chains applied exactly once
//!   ([`serve_encode_step`]); every subscriber's `GetBatch` is then
//!   answered from the shared staged frames through the same
//!   [`serve_request`] resolution the SST writer uses, so a chunk
//!   travels to N subscribers as N `Arc` clones of ONE buffer over
//!   the in-process transport. Writer-side work is independent of N.
//! * **Step cache.** The last `cache_steps` staged steps stay
//!   addressable. A late joiner starts at the cache tail (it is
//!   announced every step still cached); a slow subscriber is handled
//!   per [`LagPolicy`] — the per-subscriber generalization of the
//!   pipe's upstream `Discarded` accounting.
//!
//! Locking: the hub (cache + subscriber registry) and each
//! subscriber's outbox are disjoint by construction — announces are
//! queued as step *numbers* into per-subscriber outboxes and resolved
//! against the cache at send time by the owning sender thread, so the
//! two locks are never held together and no blocking call runs under
//! either. The lock classes ([`classes::SERVE_HUB`],
//! [`classes::SERVE_SUBSCRIBER`], [`classes::SERVE_SERVICE_THREADS`])
//! therefore add zero edges to the lock-order graph.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};
use once_cell::sync::Lazy;

use crate::adios::engine::{Bytes, Engine};
use crate::adios::ops::{self, OpChain, OpCtx, OpsReport};
use crate::adios::sst::{serve_request, StagedStep};
use crate::adios::transport::{self, Conn, ConnRx, ConnTx, Recv};
use crate::adios::wire::{GetReply, Msg, VarMeta};
use crate::obs::metrics::{counter, gauge, Counter, Gauge};
use crate::obs::trace;
use crate::openpmd::chunk::WrittenChunkInfo;
use crate::util::pool;
use crate::util::sync::{
    classes, OrderedCondvar, OrderedGuard, OrderedMutex,
};

use super::pipe::{
    fetch_step, Fetched, LocalPlan, MetricsEmitter, MetricsSink,
    PipeOptions, StepPayload, StepPoller,
};

static INGRESS_STEPS: Lazy<&'static Counter> =
    Lazy::new(|| counter("serve.ingress_steps"));
static INGRESS_BYTES: Lazy<&'static Counter> =
    Lazy::new(|| counter("serve.ingress_bytes"));
static ENCODE_OPS: Lazy<&'static Counter> =
    Lazy::new(|| counter("serve.encode_ops"));
static EGRESS_BYTES: Lazy<&'static Counter> =
    Lazy::new(|| counter("serve.egress_bytes"));
static EGRESS_BATCHES: Lazy<&'static Counter> =
    Lazy::new(|| counter("serve.egress_batches"));
static ANNOUNCES: Lazy<&'static Counter> =
    Lazy::new(|| counter("serve.announce_msgs"));
static SUB_DROPS: Lazy<&'static Counter> =
    Lazy::new(|| counter("serve.sub_dropped_steps"));
static SUBSCRIBERS: Lazy<&'static Gauge> =
    Lazy::new(|| gauge("serve.subscribers"));

/// What to do when evicting the oldest cached step would drop it from
/// under a subscriber still behind it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LagPolicy {
    /// Evict anyway: the laggard simply never sees that step (its
    /// queued announce resolves to a cache miss and is counted in
    /// [`SubscriberReport::dropped_steps`]). A subscriber stalled
    /// *mid-fetch* on the evictee gets [`ServeOptions::stall_grace`]
    /// to finish, then is disconnected. The producer is never
    /// blocked — the serve-side analog of SST's `Discard`.
    DropOldest,
    /// Apply backpressure: hold the publish until every live
    /// subscriber has finished (`StepDone`) the evictee. With no
    /// subscriber ever connected this blocks until the first one
    /// joins — same contract as SST's `Block` with no reader.
    Block,
}

impl LagPolicy {
    pub fn parse(s: &str) -> Result<LagPolicy> {
        match s {
            "drop" | "drop-oldest" => Ok(LagPolicy::DropOldest),
            "block" => Ok(LagPolicy::Block),
            other => bail!(
                "unknown lag policy {other:?} (expected drop | block)"
            ),
        }
    }
}

impl std::fmt::Display for LagPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LagPolicy::DropOldest => write!(f, "drop"),
            LagPolicy::Block => write!(f, "block"),
        }
    }
}

/// Configuration for [`ServeDaemon`].
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Listen hint handed to the transport (e.g. `"127.0.0.1:0"` for
    /// tcp, a name for inproc). The bound address is reported by
    /// [`ServeDaemon::address`].
    pub listen: String,
    /// Transport name (`"tcp"` or `"inproc"`).
    pub transport: String,
    /// How many staged steps stay addressable (the cache depth K).
    /// Must be at least 1.
    pub cache_steps: usize,
    /// Slow-subscriber policy at eviction time.
    pub lag: LagPolicy,
    /// Stop after this many upstream steps (None = until end of
    /// stream).
    pub max_steps: Option<u64>,
    /// Give up if the upstream produces nothing for this long.
    pub idle_timeout: Duration,
    /// Override the operator chain applied to staged chunks (None =
    /// keep each variable's own chain).
    pub operators: Option<OpChain>,
    /// Optional JSON-lines metrics sink (same format as the pipe's).
    pub metrics_sink: Option<MetricsSink>,
    /// Rank announced to subscribers in `HelloAck`.
    pub rank: usize,
    /// Hostname announced to subscribers and stamped on chunk info.
    pub hostname: String,
    /// How long `pump` waits at end of stream for subscribers to
    /// drain their remaining announces before tearing down — and,
    /// when none ever connected, for a first subscriber to dial in
    /// (a finite file upstream pumps in milliseconds; without the
    /// grace window no consumer could ever reach it).
    pub close_linger: Duration,
    /// [`LagPolicy::DropOldest`] only: how long an eviction waits for
    /// a subscriber stalled mid-fetch on the evictee before
    /// disconnecting it.
    pub stall_grace: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            listen: String::new(),
            transport: "tcp".into(),
            cache_steps: 4,
            lag: LagPolicy::DropOldest,
            max_steps: None,
            idle_timeout: Duration::from_secs(60),
            operators: None,
            metrics_sink: None,
            rank: 0,
            hostname: "localhost".into(),
            close_linger: Duration::from_secs(10),
            stall_grace: Duration::from_secs(5),
        }
    }
}

/// Per-subscriber accounting in the final [`ServeReport`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SubscriberReport {
    pub rank: usize,
    /// Steps announced to this subscriber.
    pub announced_steps: u64,
    /// Queued steps evicted before this subscriber was ready for
    /// them (its share of cache-pressure loss).
    pub dropped_steps: u64,
    /// Payload bytes served to this subscriber.
    pub egress_bytes: u64,
}

/// What the daemon did.
#[derive(Debug, Default)]
pub struct ServeReport {
    /// Steps consumed from the upstream and staged.
    pub steps_in: u64,
    /// Upstream steps the *source* discarded before us.
    pub steps_discarded_upstream: u64,
    /// Staged steps evicted from the cache.
    pub steps_evicted: u64,
    /// Raw payload bytes fetched from the upstream.
    pub bytes_in: u64,
    /// Payload bytes served to all subscribers combined.
    pub egress_bytes: u64,
    /// Every subscriber that ever connected, in join order.
    pub subscribers: Vec<SubscriberReport>,
    /// Operator work: staging encodes plus per-request re-encodes.
    pub ops: OpsReport,
    pub wall_seconds: f64,
}

impl ServeReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "served {} steps ({} bytes in) to {} subscriber(s), \
             {} bytes out, {} evicted, {:.2}s",
            self.steps_in,
            self.bytes_in,
            self.subscribers.len(),
            self.egress_bytes,
            self.steps_evicted,
            self.wall_seconds,
        )
    }
}

/// Outbound work queued for one subscriber, drained by its sender
/// thread. Announces are step *numbers* in an ordered set: the sender
/// pops the minimum, so delivery is in step order no matter how
/// enqueues interleave, and the backlog snapshot taken at
/// registration can race with concurrent publishes without
/// duplicating or reordering anything.
#[derive(Default)]
struct Outbox {
    /// Ready wire replies (FIFO, sent before any announce).
    replies: VecDeque<Msg>,
    /// Steps to announce, resolved against the cache at send time.
    announces: BTreeSet<u64>,
    /// End of stream: send `CloseStream` once everything drains.
    closing: bool,
    /// Set once registration has seeded the cache backlog; the
    /// sender must not announce before this.
    primed: bool,
}

/// One connected subscriber. The sender thread owns the connection's
/// tx half exclusively; the receiver thread owns the rx half; all
/// shared coordination is the outbox plus lock-free atomics.
struct Subscriber {
    rank: usize,
    codecs: Vec<String>,
    out: OrderedMutex<Outbox>,
    out_cv: OrderedCondvar,
    /// Step currently announced but not yet `StepDone`d, stored as
    /// `step + 1` (0 = none). Pins that step against eviction checks.
    inflight: AtomicU64,
    /// High-water `StepDone` mark, stored as `step + 1` (0 = none).
    done: AtomicU64,
    /// Cleared when either thread loses the connection.
    alive: AtomicBool,
    /// Set once `CloseStream` was delivered (clean drain).
    finished: AtomicBool,
    announced: AtomicU64,
    dropped: AtomicU64,
    egress: AtomicU64,
}

/// Shared hub state: the step cache plus the subscriber registry.
#[derive(Default)]
struct HubState {
    cache: BTreeMap<u64, Arc<StagedStep>>,
    peers: Vec<Arc<Subscriber>>,
    /// Operator work done on behalf of subscribers (per-request
    /// decode/re-encode inside [`serve_request`]).
    ops: OpsReport,
    steps_evicted: u64,
    /// Whether any subscriber ever connected ([`LagPolicy::Block`]
    /// with zero subscribers waits for the first join, but drains
    /// freely once everyone left).
    ever_had_subscriber: bool,
    /// Upstream exhausted: new joiners get `closing` outboxes.
    closed: bool,
}

struct Hub {
    state: OrderedMutex<HubState>,
    /// Signaled on `StepDone`, subscriber death, and drain progress.
    hub_cv: OrderedCondvar,
}

/// The fan-out daemon: accept loop + per-subscriber thread pairs
/// around a shared step cache. Construct with [`ServeDaemon::start`],
/// feed with [`ServeDaemon::pump`].
pub struct ServeDaemon {
    opts: ServeOptions,
    address: String,
    hub: Arc<Hub>,
    accept_thread: Option<JoinHandle<()>>,
    serve_threads: Arc<OrderedMutex<Vec<JoinHandle<()>>>>,
    stop: Arc<AtomicBool>,
}

/// Service-thread lock helper, same contract as the SST writer's:
/// threads with no `Result` channel back to the daemon log the
/// poison and bow out instead of re-panicking. (The name is the
/// lint's sanctioned acquisition-helper idiom.)
fn lock_or_warn<T>(m: &OrderedMutex<T>) -> Option<OrderedGuard<'_, T>> {
    match m.lock() {
        Ok(g) => Some(g),
        Err(e) => {
            crate::warn_log!("serve", "{e}; stopping service thread");
            None
        }
    }
}

impl ServeDaemon {
    /// Bind the listener and start the accept loop. No upstream IO
    /// happens until [`pump`](ServeDaemon::pump).
    pub fn start(opts: ServeOptions) -> Result<ServeDaemon> {
        if opts.cache_steps == 0 {
            bail!("serve cache must hold at least one step");
        }
        let tp = transport::by_name(&opts.transport)?;
        let mut listener = tp.listen(&opts.listen)?;
        let address = listener.address();
        let hub = Arc::new(Hub {
            state: OrderedMutex::new(
                &classes::SERVE_HUB,
                HubState::default(),
            ),
            hub_cv: OrderedCondvar::new(&classes::SERVE_HUB),
        });
        let serve_threads = Arc::new(OrderedMutex::new(
            &classes::SERVE_SERVICE_THREADS,
            Vec::new(),
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let accept_thread = {
            let hub = Arc::clone(&hub);
            let serve_threads = Arc::clone(&serve_threads);
            let stop = Arc::clone(&stop);
            let rank = opts.rank;
            let hostname = opts.hostname.clone();
            std::thread::Builder::new()
                .name("serve-accept".into())
                .spawn(move || {
                    trace::set_thread_identity(rank, "serve-accept");
                    while !stop.load(Ordering::Relaxed) {
                        match listener
                            .accept_timeout(Duration::from_millis(50))
                        {
                            Ok(Some(conn)) => {
                                if let Err(e) = serve_register_subscriber(
                                    conn, &hub, &serve_threads, &stop,
                                    rank, &hostname,
                                ) {
                                    crate::warn_log!(
                                        "serve",
                                        "subscriber handshake failed: {e:#}"
                                    );
                                }
                            }
                            Ok(None) => {}
                            Err(e) => {
                                crate::warn_log!(
                                    "serve",
                                    "accept error: {e:#}; \
                                     no longer accepting subscribers"
                                );
                                break;
                            }
                        }
                    }
                })?
        };
        Ok(ServeDaemon {
            opts,
            address,
            hub,
            accept_thread: Some(accept_thread),
            serve_threads,
            stop,
        })
    }

    /// The bound listen address (resolved port for tcp); subscribers
    /// dial this with an ordinary `sst+<transport>://` source spec.
    pub fn address(&self) -> String {
        self.address.clone()
    }

    /// How many subscribers are currently registered and live — lets
    /// a launcher (or a conformance test) wait for an expected fan-out
    /// before pumping a finite upstream through.
    pub fn subscribers(&self) -> usize {
        match self.hub.state.lock() {
            Ok(st) => st
                .peers
                .iter()
                .filter(|p| p.alive.load(Ordering::Relaxed))
                .count(),
            Err(_) => 0,
        }
    }

    /// Consume the upstream to exhaustion (or `max_steps`), staging
    /// and fanning out every step, then drain subscribers and tear
    /// the daemon down. The upstream is subscribed to exactly once
    /// regardless of how many subscribers connect.
    pub fn pump(&mut self, input: &mut dyn Engine) -> Result<ServeReport> {
        let started = Instant::now();
        let popts = serve_pipe_options(&self.opts);
        let mut plan = LocalPlan::new(&popts);
        let emitter =
            MetricsEmitter::for_sink(self.opts.metrics_sink.as_ref());
        let mut poller = StepPoller::new(self.opts.idle_timeout);
        let mut report = ServeReport::default();
        let mut step = 0u64;
        loop {
            if let Some(max) = self.opts.max_steps {
                if report.steps_in >= max {
                    break;
                }
            }
            match fetch_step(input, &popts, &mut plan, step)? {
                Fetched::Step(payload) => {
                    let mut sp =
                        trace::span("serve.ingest").with("step", step);
                    let (staged, local_ops) = serve_encode_step(
                        &payload,
                        self.opts.rank,
                        &self.opts.hostname,
                    )?;
                    sp.set("bytes", payload.bytes);
                    INGRESS_STEPS.inc();
                    INGRESS_BYTES.add(payload.bytes);
                    report.steps_in += 1;
                    report.bytes_in += payload.bytes;
                    report.ops.absorb(local_ops);
                    serve_publish_step(
                        &self.hub,
                        &self.opts,
                        step,
                        Arc::new(staged),
                    )?;
                    step += 1;
                    poller.activity();
                    if let Some(e) = &emitter {
                        e.emit_step_line(report.steps_in);
                    }
                }
                Fetched::NotReady => poller.not_ready()?,
                Fetched::Discarded => {
                    report.steps_discarded_upstream += 1;
                    poller.activity();
                }
                Fetched::EndOfStream => break,
            }
        }
        self.serve_drain(&mut report)?;
        if let Some(e) = &emitter {
            e.emit_final_line();
        }
        report.wall_seconds = started.elapsed().as_secs_f64();
        Ok(report)
    }

    /// End of stream: flag every outbox `closing`, linger while
    /// subscribers drain, then stop and join all threads and collect
    /// the per-subscriber accounting.
    fn serve_drain(&mut self, report: &mut ServeReport) -> Result<()> {
        let peers: Vec<Arc<Subscriber>> = {
            let mut st = self.hub.state.lock()?;
            st.closed = true;
            st.peers.clone()
        };
        for p in &peers {
            let mut out = p.out.lock()?;
            out.closing = true;
            drop(out);
            p.out_cv.notify_all();
        }
        let deadline = Instant::now() + self.opts.close_linger;
        loop {
            let st = self.hub.state.lock()?;
            // Same linger contract as the SST writer's close: wait for
            // connected subscribers to drain, AND give a first
            // subscriber the whole window to show up when none ever
            // connected — a daemon serving a finite (file) upstream
            // would otherwise tear down before any consumer could
            // dial it. Late registrations replay the full cache.
            let pending = st.peers.iter().any(|p| {
                p.alive.load(Ordering::Relaxed)
                    && !p.finished.load(Ordering::Relaxed)
            }) || !st.ever_had_subscriber;
            if !pending {
                break;
            }
            if Instant::now() > deadline {
                crate::warn_log!(
                    "serve",
                    "close linger expired with {}; tearing down",
                    if st.ever_had_subscriber {
                        "subscribers still draining"
                    } else {
                        "no subscriber ever connecting"
                    }
                );
                break;
            }
            let (guard, _) = self
                .hub
                .hub_cv
                .wait_timeout(st, Duration::from_millis(50))?;
            drop(guard);
        }
        self.serve_halt();
        let mut st = self.hub.state.lock()?;
        report.ops.absorb(st.ops);
        report.steps_evicted = st.steps_evicted;
        for p in &st.peers {
            let egress = p.egress.load(Ordering::Relaxed);
            report.egress_bytes += egress;
            report.subscribers.push(SubscriberReport {
                rank: p.rank,
                announced_steps: p.announced.load(Ordering::Relaxed),
                dropped_steps: p.dropped.load(Ordering::Relaxed),
                egress_bytes: egress,
            });
        }
        st.peers.clear();
        st.cache.clear();
        Ok(())
    }

    /// Stop and join every thread. Idempotent.
    fn serve_halt(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // Swap the handles out under the registry lock, join outside
        // it.
        let mut drained: Vec<JoinHandle<()>> = Vec::new();
        match self.serve_threads.lock() {
            Ok(mut g) => std::mem::swap(&mut drained, &mut *g),
            Err(e) => {
                crate::warn_log!("serve", "{e}; leaking service threads");
            }
        }
        for t in drained {
            let _ = t.join();
        }
        SUBSCRIBERS.set(0);
    }
}

impl Drop for ServeDaemon {
    fn drop(&mut self) {
        self.serve_halt();
    }
}

/// Open `input` once and serve it to any number of SST subscribers
/// on `opts.listen` until the upstream is exhausted. The one-call
/// form of [`ServeDaemon::start`] + [`ServeDaemon::pump`].
pub fn run_serve(
    input: &mut dyn Engine,
    opts: ServeOptions,
) -> Result<ServeReport> {
    let mut daemon = ServeDaemon::start(opts)?;
    let report = daemon.pump(input)?;
    input.close()?;
    Ok(report)
}

/// The upstream fetch reuses the pipe's solo path: one instance,
/// forward everything, with serve's idle/operator knobs applied.
fn serve_pipe_options(opts: &ServeOptions) -> PipeOptions {
    let mut p = PipeOptions::solo();
    p.idle_timeout = opts.idle_timeout;
    p.operators = opts.operators.clone();
    p
}

/// Stage one fetched step: apply each variable's operator chain
/// exactly once and build the announce metadata, mirroring what
/// `SstWriter::perform_puts` does at put time so [`serve_request`]
/// resolves subscriber selections identically. Identity chains pass
/// the payload `Arc` through untouched — staging N subscribers deep
/// still holds ONE copy of the bytes.
fn serve_encode_step(
    payload: &StepPayload,
    rank: usize,
    hostname: &str,
) -> Result<(StagedStep, OpsReport)> {
    let mut staged = StagedStep::default();
    let mut report = OpsReport::default();
    for (name, value) in &payload.attributes {
        staged.meta.attributes.insert(name.clone(), value.clone());
    }
    for (decl, chunks) in &payload.vars {
        let mut infos = Vec::with_capacity(chunks.len());
        let mut data = Vec::with_capacity(chunks.len());
        for (chunk, raw) in chunks {
            let framed: Bytes = if decl.ops.is_identity() {
                Arc::clone(raw)
            } else {
                ENCODE_OPS.inc();
                let octx = OpCtx {
                    dtype: decl.dtype,
                    extent: &chunk.extent,
                };
                ops::encode_bytes(
                    &decl.ops,
                    &octx,
                    raw.as_slice(),
                    &mut report,
                )
                .map_err(|e| {
                    anyhow::anyhow!(
                        "{}: operator encode: {e}",
                        decl.name
                    )
                })?
            };
            infos.push(
                WrittenChunkInfo::new(
                    chunk.clone(),
                    rank,
                    hostname.to_string(),
                )
                .with_encoded_bytes(framed.len() as u64),
            );
            data.push((chunk.clone(), framed));
        }
        // Declared-but-empty variables keep their VarMeta entry, so a
        // subscriber sees the same variable registry a direct pipe
        // consumer would.
        staged.meta.vars.push(VarMeta {
            name: decl.name.clone(),
            dtype: decl.dtype,
            shape: decl.shape.clone(),
            ops: decl.ops.clone(),
            chunks: infos,
        });
        staged.data.insert(decl.name.clone(), data);
    }
    Ok((staged, report))
}

/// Insert a staged step into the cache, evict per the lag policy, and
/// queue its announce at every live subscriber. The hub lock is
/// dropped before any outbox lock is taken — the two classes never
/// nest.
fn serve_publish_step(
    hub: &Hub,
    opts: &ServeOptions,
    step: u64,
    staged: Arc<StagedStep>,
) -> Result<()> {
    // Evictees are only collected under the hub lock; their buffers go
    // back to the pool after the guard drops so no hub -> buf-pool lock
    // edge ever exists.
    let mut evicted: Vec<Arc<StagedStep>> = Vec::new();
    let peers: Vec<Arc<Subscriber>> = {
        let mut st = hub.state.lock()?;
        st.cache.insert(step, staged);
        while st.cache.len() > opts.cache_steps {
            let Some(&oldest) = st.cache.keys().next() else {
                break;
            };
            st = serve_wait_evictable(hub, st, opts, oldest)?;
            if let Some(ss) = st.cache.remove(&oldest) {
                evicted.push(ss);
            }
            st.steps_evicted += 1;
        }
        st.peers
            .iter()
            .filter(|p| p.alive.load(Ordering::Relaxed))
            .cloned()
            .collect()
    };
    for ss in evicted {
        // An eviction is the step's end of life on the serve side. If
        // no subscriber still holds a pinned reference, the chunk
        // payloads are uniquely ours and recycle through the buffer
        // pool; otherwise `try_unwrap` declines and the last reader
        // frees them normally.
        if let Ok(ss) = Arc::try_unwrap(ss) {
            for (_, chunks) in ss.data {
                for (_, bytes) in chunks {
                    pool::reclaim_bytes(bytes);
                }
            }
        }
    }
    for p in &peers {
        let mut out = p.out.lock()?;
        out.announces.insert(step);
        drop(out);
        p.out_cv.notify_all();
    }
    Ok(())
}

/// Hold the hub lock (parking on the hub condvar) until `oldest` may
/// be evicted under the configured lag policy.
fn serve_wait_evictable<'a>(
    hub: &'a Hub,
    mut st: OrderedGuard<'a, HubState>,
    opts: &ServeOptions,
    oldest: u64,
) -> Result<OrderedGuard<'a, HubState>, crate::util::sync::PoisonedLock>
{
    let grace_deadline = Instant::now() + opts.stall_grace;
    loop {
        let evictable = {
            let live: Vec<&Arc<Subscriber>> = st
                .peers
                .iter()
                .filter(|p| p.alive.load(Ordering::Relaxed))
                .collect();
            match opts.lag {
                LagPolicy::Block => {
                    if live.is_empty() {
                        // Block with no subscriber: wait for the
                        // first join unless everyone already came
                        // and went.
                        st.ever_had_subscriber
                    } else {
                        live.iter().all(|p| {
                            p.done.load(Ordering::Relaxed) > oldest
                        })
                    }
                }
                LagPolicy::DropOldest => {
                    let pinned: Vec<&Arc<Subscriber>> = live
                        .iter()
                        .filter(|p| {
                            p.inflight.load(Ordering::Relaxed)
                                == oldest + 1
                        })
                        .copied()
                        .collect();
                    if pinned.is_empty() {
                        true
                    } else if Instant::now() > grace_deadline {
                        // Stalled mid-fetch past the grace window:
                        // a dead-slow subscriber must not pin the
                        // cache (and thus the producer) forever.
                        for p in &pinned {
                            crate::warn_log!(
                                "serve",
                                "subscriber {} stalled on step \
                                 {oldest} past stall grace; \
                                 disconnecting it",
                                p.rank
                            );
                            p.alive.store(false, Ordering::Relaxed);
                            p.out_cv.notify_all();
                        }
                        true
                    } else {
                        false
                    }
                }
            }
        };
        if evictable {
            return Ok(st);
        }
        let (guard, _) =
            hub.hub_cv.wait_timeout(st, Duration::from_millis(100))?;
        st = guard;
    }
}

/// Accept-thread half of a subscription: handshake, register with
/// the hub, seed the cache backlog (late joiners start at the cache
/// tail), and spawn the sender/receiver pair.
fn serve_register_subscriber(
    mut conn: Box<dyn Conn>,
    hub: &Arc<Hub>,
    serve_threads: &Arc<OrderedMutex<Vec<JoinHandle<()>>>>,
    stop: &Arc<AtomicBool>,
    daemon_rank: usize,
    hostname: &str,
) -> Result<()> {
    let (sub_rank, codecs) =
        match conn.recv_timeout(Duration::from_secs(10))? {
            Recv::Msg(Msg::Hello { reader_rank, codecs, .. }) => {
                (reader_rank, codecs)
            }
            Recv::Msg(_) => bail!("expected Hello as first message"),
            Recv::TimedOut => bail!("subscriber handshake timed out"),
            Recv::Closed => bail!("subscriber closed before Hello"),
        };
    conn.send(Msg::HelloAck {
        writer_rank: daemon_rank,
        hostname: hostname.to_string(),
    })?;
    let (tx, rx) = conn.split()?;
    let sub = Arc::new(Subscriber {
        rank: sub_rank,
        codecs,
        out: OrderedMutex::new(
            &classes::SERVE_SUBSCRIBER,
            Outbox::default(),
        ),
        out_cv: OrderedCondvar::new(&classes::SERVE_SUBSCRIBER),
        inflight: AtomicU64::new(0),
        done: AtomicU64::new(0),
        alive: AtomicBool::new(true),
        finished: AtomicBool::new(false),
        announced: AtomicU64::new(0),
        dropped: AtomicU64::new(0),
        egress: AtomicU64::new(0),
    });

    // Register and snapshot the backlog in ONE hub section (a step
    // published in between would reach neither the snapshot nor the
    // registered peer), but seed the outbox OUTSIDE it: the ordered
    // announce set plus the `primed` latch make enqueue interleaving
    // harmless, and hub/outbox locks are never held together.
    let (backlog, closed, live) = {
        let mut st = hub.state.lock()?;
        st.peers.push(Arc::clone(&sub));
        st.ever_had_subscriber = true;
        let live = st
            .peers
            .iter()
            .filter(|p| p.alive.load(Ordering::Relaxed))
            .count();
        (
            st.cache.keys().copied().collect::<Vec<u64>>(),
            st.closed,
            live,
        )
    };
    SUBSCRIBERS.set(live as u64);
    {
        let mut out = sub.out.lock()?;
        out.announces.extend(backlog);
        out.closing = closed;
        out.primed = true;
    }
    sub.out_cv.notify_all();
    hub.hub_cv.notify_all();

    let tx_handle = {
        let sub = Arc::clone(&sub);
        let hub = Arc::clone(hub);
        let stop = Arc::clone(stop);
        std::thread::Builder::new()
            .name(format!("serve-tx-r{sub_rank}"))
            .spawn(move || {
                trace::set_thread_identity(sub.rank, "serve-tx");
                serve_sender_loop(&sub, &hub, tx, &stop);
            })?
    };
    let rx_handle = {
        let sub = Arc::clone(&sub);
        let hub = Arc::clone(hub);
        let stop = Arc::clone(stop);
        std::thread::Builder::new()
            .name(format!("serve-rx-r{sub_rank}"))
            .spawn(move || {
                trace::set_thread_identity(sub.rank, "serve-rx");
                let mut rx = rx;
                serve_receiver_loop(&sub, &hub, rx.as_mut(), &stop);
                let live = {
                    let Some(st) = lock_or_warn(&hub.state) else {
                        return;
                    };
                    st.peers
                        .iter()
                        .filter(|p| p.alive.load(Ordering::Relaxed))
                        .count()
                };
                SUBSCRIBERS.set(live as u64);
            })?
    };
    let mut t = serve_threads.lock()?;
    t.push(tx_handle);
    t.push(rx_handle);
    Ok(())
}

/// What the sender thread decided to do next, computed under the
/// outbox lock and executed after it is released.
enum SenderWork {
    Reply(Msg),
    Announce(u64),
    Close,
    Idle,
    Quit,
}

fn serve_sender_decide(sub: &Subscriber) -> SenderWork {
    let Some(mut out) = lock_or_warn(&sub.out) else {
        return SenderWork::Quit;
    };
    if let Some(m) = out.replies.pop_front() {
        return SenderWork::Reply(m);
    }
    // One announce in flight at a time, in step order: the SST
    // reader protocol finishes a step (`StepDone`) before the next
    // announce matters, and the single pin keeps eviction exact.
    if out.primed && sub.inflight.load(Ordering::Relaxed) == 0 {
        if let Some(&s) = out.announces.iter().next() {
            out.announces.remove(&s);
            return SenderWork::Announce(s);
        }
        if out.closing {
            return SenderWork::Close;
        }
    }
    // Nothing to do: park briefly (bounded, so stop/death flags are
    // rechecked even if a notify is missed).
    match sub.out_cv.wait_timeout(out, Duration::from_millis(50)) {
        Ok((guard, _)) => drop(guard),
        Err(e) => {
            crate::warn_log!("serve", "{e}; shutting down sender");
            return SenderWork::Quit;
        }
    }
    SenderWork::Idle
}

/// Owns the connection's tx half: drains the outbox, resolving each
/// queued announce against the cache at send time. Every `send` runs
/// with no lock held.
fn serve_sender_loop(
    sub: &Arc<Subscriber>,
    hub: &Arc<Hub>,
    mut tx: Box<dyn ConnTx>,
    stop: &Arc<AtomicBool>,
) {
    while !stop.load(Ordering::Relaxed)
        && sub.alive.load(Ordering::Relaxed)
    {
        match serve_sender_decide(sub) {
            SenderWork::Reply(msg) => {
                if tx.send(msg).is_err() {
                    break;
                }
            }
            SenderWork::Announce(step) => {
                // Resolve against the cache and pin in the SAME hub
                // section eviction scans under: the step is either
                // already gone (this subscriber's drop — the per-peer
                // generalization of the pipe's Discarded accounting)
                // or safely pinned until StepDone.
                let staged = {
                    let Some(st) = lock_or_warn(&hub.state) else {
                        break;
                    };
                    match st.cache.get(&step) {
                        Some(s) => {
                            sub.inflight
                                .store(step + 1, Ordering::Relaxed);
                            Some(Arc::clone(s))
                        }
                        None => None,
                    }
                };
                match staged {
                    Some(staged) => {
                        let _sp = trace::span("serve.announce")
                            .with("step", step)
                            .with("subscriber", sub.rank);
                        ANNOUNCES.inc();
                        sub.announced.fetch_add(1, Ordering::Relaxed);
                        if tx
                            .send(Msg::StepAnnounce {
                                step,
                                meta: staged.meta.clone(),
                            })
                            .is_err()
                        {
                            break;
                        }
                    }
                    None => {
                        SUB_DROPS.inc();
                        sub.dropped.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            SenderWork::Close => {
                let _ = tx.send(Msg::CloseStream);
                sub.finished.store(true, Ordering::Relaxed);
                hub.hub_cv.notify_all();
                break;
            }
            SenderWork::Idle => {}
            SenderWork::Quit => break,
        }
    }
    sub.alive.store(false, Ordering::Relaxed);
    hub.hub_cv.notify_all();
    sub.out_cv.notify_all();
}

/// Owns the connection's rx half: answers `GetBatch` from the staged
/// cache via [`serve_request`] (outside all locks) and turns
/// `StepDone` into pin release + drain progress.
fn serve_receiver_loop(
    sub: &Arc<Subscriber>,
    hub: &Arc<Hub>,
    rx: &mut dyn ConnRx,
    stop: &Arc<AtomicBool>,
) {
    while !stop.load(Ordering::Relaxed)
        && sub.alive.load(Ordering::Relaxed)
    {
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok(Recv::Msg(Msg::GetBatch { req_id, step, items })) => {
                let mut sp = trace::span("serve.batch")
                    .with("step", step)
                    .with("subscriber", sub.rank)
                    .with("items", items.len());
                let staged = {
                    let Some(st) = lock_or_warn(&hub.state) else {
                        break;
                    };
                    st.cache.get(&step).cloned()
                };
                let mut local_ops = OpsReport::default();
                let mut served = 0u64;
                let mut replies = Vec::with_capacity(items.len());
                for item in &items {
                    let reply = match &staged {
                        Some(staged) => serve_request(
                            staged,
                            &item.var,
                            &item.sel,
                            &sub.codecs,
                            &mut local_ops,
                        ),
                        None => Err(anyhow::anyhow!(
                            "step {step} not cached (evicted?)"
                        )),
                    };
                    match reply {
                        Ok(r) => {
                            served += match &r {
                                GetReply::Data(d) => d.len() as u64,
                                GetReply::Encoded(d) => {
                                    d.len() as u64
                                }
                                GetReply::Error(_) => 0,
                            };
                            replies.push(r);
                        }
                        Err(e) => replies
                            .push(GetReply::Error(format!("{e:#}"))),
                    }
                }
                EGRESS_BATCHES.inc();
                EGRESS_BYTES.add(served);
                sp.set("bytes", served);
                sub.egress.fetch_add(served, Ordering::Relaxed);
                if !local_ops.is_empty() {
                    let Some(mut st) = lock_or_warn(&hub.state)
                    else {
                        break;
                    };
                    st.ops.absorb(local_ops);
                }
                let Some(mut out) = lock_or_warn(&sub.out) else {
                    break;
                };
                out.replies
                    .push_back(Msg::GetBatchReply { req_id, items: replies });
                drop(out);
                sub.out_cv.notify_all();
            }
            Ok(Recv::Msg(Msg::StepDone { step })) => {
                let _ = sub.inflight.compare_exchange(
                    step + 1,
                    0,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                );
                sub.done.fetch_max(step + 1, Ordering::Relaxed);
                hub.hub_cv.notify_all();
                sub.out_cv.notify_all();
            }
            Ok(Recv::Msg(Msg::ReaderBye)) | Ok(Recv::Closed) => break,
            Ok(Recv::TimedOut) => {}
            Ok(Recv::Msg(other)) => {
                crate::warn_log!(
                    "serve",
                    "unexpected message from subscriber {}: {other:?}",
                    sub.rank
                );
            }
            Err(e) => {
                crate::warn_log!(
                    "serve",
                    "subscriber {} receive error: {e:#}",
                    sub.rank
                );
                break;
            }
        }
    }
    sub.alive.store(false, Ordering::Relaxed);
    hub.hub_cv.notify_all();
    sub.out_cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::engine::VarDecl;
    use crate::openpmd::chunk::Chunk;
    use crate::openpmd::types::Datatype;

    fn test_hub() -> Hub {
        Hub {
            state: OrderedMutex::new(
                &classes::SERVE_HUB,
                HubState::default(),
            ),
            hub_cv: OrderedCondvar::new(&classes::SERVE_HUB),
        }
    }

    #[test]
    fn lag_policy_parses_and_displays() {
        assert_eq!(LagPolicy::parse("drop").unwrap(),
                   LagPolicy::DropOldest);
        assert_eq!(LagPolicy::parse("drop-oldest").unwrap(),
                   LagPolicy::DropOldest);
        assert_eq!(LagPolicy::parse("block").unwrap(),
                   LagPolicy::Block);
        assert!(LagPolicy::parse("nope").is_err());
        assert_eq!(LagPolicy::DropOldest.to_string(), "drop");
        assert_eq!(LagPolicy::Block.to_string(), "block");
    }

    /// DropOldest with no subscribers: the cache is a pure ring of
    /// depth K; older steps are evicted and counted.
    #[test]
    fn publish_evicts_beyond_cache_depth() {
        let hub = test_hub();
        let opts = ServeOptions {
            cache_steps: 2,
            ..ServeOptions::default()
        };
        for step in 0..5u64 {
            serve_publish_step(
                &hub,
                &opts,
                step,
                Arc::new(StagedStep::default()),
            )
            .unwrap();
        }
        let st = hub.state.lock().unwrap();
        assert_eq!(st.steps_evicted, 3);
        let kept: Vec<u64> = st.cache.keys().copied().collect();
        assert_eq!(kept, vec![3, 4]);
    }

    /// Identity chains stage the payload Arc itself — no copy — and
    /// stamp the announced encoded size.
    #[test]
    fn encode_step_is_zero_copy_for_identity_chains() {
        let raw: Bytes = Arc::new(vec![1u8, 2, 3, 4]);
        let decl = VarDecl::new("/data/x", Datatype::U8, vec![4]);
        let payload = StepPayload {
            step: 0,
            attributes: vec![],
            vars: vec![(
                decl,
                vec![(Chunk::whole(vec![4]), Arc::clone(&raw))],
            )],
            bytes: 4,
            load_seconds: 0.0,
        };
        let (staged, report) =
            serve_encode_step(&payload, 0, "h").unwrap();
        assert_eq!(report.chunks_encoded, 0, "identity must not encode");
        let data = staged.data.get("/data/x").unwrap();
        assert!(Arc::ptr_eq(&data[0].1, &raw), "must stage the same Arc");
        let vm = &staged.meta.vars[0];
        assert_eq!(vm.chunks[0].encoded_bytes, Some(4));
    }
}
