//! The parallel reader fleet: M pipe workers over N writers, one
//! shared per-step chunk plan.
//!
//! The paper's loose-coupling story stops scaling the moment a single
//! reader must drain everything N writer ranks produce — the gap §3
//! names "the need of strategies for a flexible data distribution".
//! [`run_fleet`] closes it: M workers (threads), each owning its own
//! read engine subscribed to the same N writer transports and its own
//! output shard, coordinated **only** through a per-step plan:
//!
//! ```text
//!  N writers ──announce──▶ every worker's reader
//!                               │ step s chunk table
//!                               ▼
//!                      SharedPlanner (one Assignment per step+var:
//!                      strategy.distribute, complete + disjoint)
//!                        │          │           │
//!                 slices(0)   slices(1)   slices(M-1)
//!                        ▼          ▼           ▼
//!                  worker 0    worker 1 ...  worker M-1
//!                  (fetch own slices via one batched perform,
//!                   store into own output shard)
//! ```
//!
//! **Plan phase.** The first worker to reach step `s` computes the
//! step's [`Assignment`] from the announced chunk table (one
//! `distribute` per variable per step) and publishes it; the other
//! workers reuse it and the entry is pruned once all M have taken
//! their share. Strategies are deterministic (a property-tested
//! invariant), so "first worker plans" is observably identical to the
//! issue of a fixed planner rank — without a cross-thread barrier on
//! the hot path. In debug builds every shared plan is re-checked with
//! [`verify_complete`]; release builds trust the property tests.
//!
//! **Fetch phase.** Each worker runs the pipe's step-forwarding core
//! with the shared plan as its slice filter: per step, one batched
//! `perform_gets` covering exactly its assigned slices — over SST
//! that is one wire request per *owning* writer, so a worker whose
//! slices all live on one writer rank never contacts the others.
//! Unlike the solo serial loop (which probes its output first and can
//! consume a downstream-discarded step without moving data), a fleet
//! worker fetches **before** offering the step to its output: its
//! slices are its share of the step's complete distribution, and
//! skipping the fetch would silently leave them unmoved by any rank.
//! A step the output then discards is dropped and counted in
//! `dropped_steps` — the staged path's read-ahead semantics.
//!
//! **Input contract.** Workers coordinate plans by input-step ordinal
//! (every consumed input step advances it, discarded ones included),
//! so all fleet inputs must present the same step sequence. SST
//! readers over one writer application do: announcements are
//! broadcast to every subscribed reader, and steps retire only after
//! every live reader consumed them — and `run_fleet` takes all M
//! already-open inputs up front, so none can miss a prefix.
//!
//! **Store phase.** Each worker owns an output engine (typically a
//! per-rank BP shard named by [`crate::openpmd::series::shard_path`]);
//! every worker publishes every step, so the union of the shards'
//! chunks per step is exactly the input step — complete and disjoint,
//! asserted end to end by `tests/fleet_conformance.rs`.
//!
//! Workers never exchange payload bytes; the only shared state is the
//! plan cache, a mutex held for microseconds per step. Stragglers are
//! visible, not hidden: [`FleetReport`] carries per-rank bytes, busy
//! seconds and the max/mean imbalance that bounds fleet speedup.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};
use once_cell::sync::Lazy;

use crate::adios::engine::{Engine, VarInfo};
use crate::adios::ops::OpChain;
use crate::distribution::{
    verify_complete, Assignment, ChunkTable, ReaderLayout, Strategy,
};
use crate::obs::metrics::{counter, Counter};
use crate::obs::trace;
use crate::openpmd::chunk::Chunk;
use crate::util::sync::{classes, OrderedMutex};

/// Step+variable assignments actually computed (as opposed to reused
/// from the shared cache by later-arriving ranks).
static PLANS_COMPUTED: Lazy<&'static Counter> =
    Lazy::new(|| counter("fleet.plans_computed"));

use super::metrics::FleetReport;
use super::pipe::{
    fetch_step, forward_payload, Fetched, PipeOptions, PipeReport,
    StepPlan, StepPoller,
};
use super::staged::{run_staged_with_plan, StagedBudget};

/// Fleet configuration: the reader-side parallel layout plus the pipe
/// knobs every worker shares. Fleet width M is `layout.len()`.
pub struct FleetOptions {
    /// Distribution strategy computing the shared per-step plan. Must
    /// be deterministic (all in-tree strategies are).
    pub strategy: Arc<dyn Strategy>,
    /// Reader layout; one worker per rank, in rank order.
    pub layout: ReaderLayout,
    /// Stop each worker after this many *consumed data* steps
    /// (forwarded + downstream-discarded). Unlike the solo pipe —
    /// where only forwarded steps count — a fleet worker's budget must
    /// not stretch when its own output discards, or workers would
    /// consume different input prefixes and desynchronize the shared
    /// plan (leaving the trailing step's distribution partially
    /// unfetched).
    pub max_steps: Option<u64>,
    /// Per-worker idle timeout (same contract as the serial pipe).
    pub idle_timeout: Duration,
    /// Operator-chain override forwarded to every worker's output
    /// (None = forward each variable's announced chain unchanged).
    pub operators: Option<OpChain>,
    /// Per-worker staged read-ahead depth: `0` runs each worker's
    /// serial fetch-before-offer loop; `>= 1` gives every worker its
    /// own [`super::staged`] fetch thread, so within one worker the
    /// store of step N overlaps the load of step N+1 *on top of* the
    /// fleet's cross-worker parallelism. The shared plan still keys on
    /// the input-step ordinal, and a worker's `max_steps` budget is
    /// enforced on the fetch side so every worker consumes the same
    /// input prefix.
    pub depth: usize,
}

impl FleetOptions {
    /// `readers` workers on one host with `strategy` — the common
    /// single-node fleet. `readers == 0` is a typed layout error.
    pub fn local(
        readers: usize,
        strategy: Arc<dyn Strategy>,
    ) -> Result<FleetOptions> {
        Ok(FleetOptions {
            strategy,
            layout: ReaderLayout::local(readers)?,
            max_steps: None,
            idle_timeout: Duration::from_secs(60),
            operators: None,
            depth: 0,
        })
    }
}

/// One step+variable's published plan, pruned once every worker took
/// its share.
struct PlanEntry {
    assignment: Arc<Assignment>,
    taken: usize,
}

/// The fleet's only shared state: compute-once plan cache keyed by
/// (step, variable). Entries live from the first worker reaching a
/// step to the last worker leaving it, so memory is bounded by how far
/// the fastest worker runs ahead (itself bounded by the writers'
/// staging queues).
pub(crate) struct SharedPlanner {
    strategy: Arc<dyn Strategy>,
    layout: ReaderLayout,
    readers: usize,
    plans: OrderedMutex<BTreeMap<(u64, String), PlanEntry>>,
}

impl SharedPlanner {
    pub(crate) fn new(
        strategy: Arc<dyn Strategy>,
        layout: ReaderLayout,
    ) -> SharedPlanner {
        let readers = layout.len();
        SharedPlanner {
            strategy,
            layout,
            readers,
            plans: OrderedMutex::new(
                &classes::FLEET_PLANNER,
                BTreeMap::new(),
            ),
        }
    }

    /// Worker `rank`'s slices of `var` in `step`: compute the step
    /// plan on first arrival, reuse it afterwards, prune on last use.
    /// (Named apart from the lock-free `Assignment::slices` it calls
    /// under its own guard.)
    fn take_slices(
        &self,
        rank: usize,
        step: u64,
        var: &VarInfo,
        table: &ChunkTable,
    ) -> Result<Vec<Chunk>> {
        use std::collections::btree_map::Entry;
        // Span opened BEFORE the planner lock, so contention on the
        // shared plan cache is visible as span time.
        let mut sp = trace::span("fleet.plan")
            .with("step", step)
            .with("rank", rank);
        let key = (step, var.name.clone());
        let mut plans = self.plans.lock()?;
        let entry = match plans.entry(key.clone()) {
            Entry::Occupied(entry) => entry.into_mut(),
            Entry::Vacant(slot) => {
                let assignment =
                    self.strategy.distribute(table, &self.layout);
                // The hot-path contract check rides the debug build:
                // release trusts `tests/distribution_props.rs`.
                #[cfg(debug_assertions)]
                if let Err(why) = verify_complete(table, &assignment) {
                    panic!(
                        "fleet plan for step {step} var {:?} is not a \
                         complete distribution: {why}",
                        var.name
                    );
                }
                #[cfg(not(debug_assertions))]
                let _ = verify_complete; // referenced in debug only
                PLANS_COMPUTED.inc();
                slot.insert(PlanEntry {
                    assignment: Arc::new(assignment),
                    taken: 0,
                })
            }
        };
        let slices: Vec<Chunk> = entry
            .assignment
            .slices(rank)
            .iter()
            .map(|s| s.chunk.clone())
            .collect();
        entry.taken += 1;
        if entry.taken >= self.readers {
            plans.remove(&key);
        }
        sp.set("chunks", slices.len());
        Ok(slices)
    }

    /// Plans currently cached (bounded-memory check for tests).
    #[cfg(test)]
    fn cached(&self) -> usize {
        self.plans.lock().unwrap().len()
    }
}

/// The [`StepPlan`] a fleet worker hands to the pipe core.
struct FleetPlan {
    shared: Arc<SharedPlanner>,
    rank: usize,
}

impl StepPlan for FleetPlan {
    fn slices_for(
        &mut self,
        step: u64,
        var: &VarInfo,
        table: &ChunkTable,
    ) -> Result<Vec<Chunk>> {
        self.shared.take_slices(self.rank, step, var, table)
    }
}

/// One fleet worker's loop: fetch-before-offer over the shared plan.
/// Mirrors the serial loop's polling/accounting (same helpers), but a
/// step is always loaded before the output is probed — the worker's
/// slices are part of the step's complete distribution and must move
/// even if this worker's output then discards the step (counted in
/// `dropped_steps`, exactly like the staged path's read-ahead).
fn run_worker(
    input: &mut dyn Engine,
    output: &mut dyn Engine,
    opts: &PipeOptions,
    plan: &mut dyn StepPlan,
) -> Result<PipeReport> {
    // This worker's lane in the exported trace ("fleet-r<rank>" as a
    // process, one combined fetch+store track).
    trace::set_thread_identity(opts.rank, "worker");
    let mut report = PipeReport::default();
    let wall = Instant::now();
    let mut poller = StepPoller::new(opts.idle_timeout);
    // Input-step ordinal: the shared plan key. Advances for EVERY
    // consumed input step — discarded ones included — so workers over
    // identical input sequences always agree on it.
    let mut ordinal = 0u64;
    loop {
        if let Some(max) = opts.max_steps {
            // Forwarded + dropped: every worker's budget burns at the
            // same input rate whatever its own output discards, so the
            // fleet stops on a common input prefix (see
            // `FleetOptions::max_steps`).
            if report.steps + report.dropped_steps >= max {
                break;
            }
        }
        match fetch_step(input, opts, plan, ordinal)? {
            Fetched::Step(payload) => {
                ordinal += 1;
                forward_payload(output, &payload, &mut report,
                                opts.rank)?;
                poller.activity();
            }
            Fetched::NotReady => poller.not_ready()?,
            Fetched::Discarded => {
                ordinal += 1;
                poller.activity();
            }
            Fetched::EndOfStream => break,
        }
    }
    output.close()?;
    input.close()?;
    report.overlap.wall_seconds = wall.elapsed().as_secs_f64().max(1e-9);
    report.overlap.steps = report.steps;
    report.ops.absorb(input.ops_report());
    report.ops.absorb(output.ops_report());
    Ok(report)
}

/// Run M fleet workers to completion. `inputs[i]` / `outputs[i]` are
/// worker `i`'s engines (one read engine subscribed to all writers,
/// one output shard each); both must match the layout's rank count.
/// Workers run on scoped threads; the first worker error (by rank)
/// fails the fleet after all workers wound down.
pub fn run_fleet(
    inputs: Vec<Box<dyn Engine>>,
    outputs: Vec<Box<dyn Engine>>,
    opts: FleetOptions,
) -> Result<FleetReport> {
    let readers = opts.layout.len();
    if readers == 0 {
        bail!("fleet needs at least one reader rank in its layout");
    }
    if inputs.len() != readers || outputs.len() != readers {
        bail!(
            "fleet layout has {readers} rank(s) but {} input / {} \
             output engine(s) were supplied",
            inputs.len(),
            outputs.len()
        );
    }
    let planner = Arc::new(SharedPlanner::new(
        opts.strategy.clone(),
        opts.layout.clone(),
    ));
    let worker_opts: Vec<PipeOptions> = (0..readers)
        .map(|rank| PipeOptions {
            rank,
            instances: readers,
            strategy: opts.strategy.clone(),
            layout: opts.layout.clone(),
            max_steps: opts.max_steps,
            idle_timeout: opts.idle_timeout,
            depth: opts.depth,
            operators: opts.operators.clone(),
            metrics_sink: None,
        })
        .collect();

    let wall = Instant::now();
    let results: Vec<Result<PipeReport>> =
        std::thread::scope(|scope| {
            // Spawn failures surface as that rank's worker error
            // instead of panicking; already-spawned workers are still
            // joined below, so no rank's result is dropped.
            let mut handles = Vec::with_capacity(readers);
            let mut spawn_err: Option<anyhow::Error> = None;
            for (rank, ((mut input, mut output), wopts)) in inputs
                .into_iter()
                .zip(outputs)
                .zip(worker_opts.iter())
                .enumerate()
            {
                let planner = planner.clone();
                let spawned = std::thread::Builder::new()
                    .name(format!("fleet-r{rank}"))
                    .spawn_scoped(scope, move || {
                        let mut plan =
                            FleetPlan { shared: planner, rank };
                        if wopts.depth > 0 {
                            // Staged read-ahead per worker: this
                            // thread becomes the store side (the
                            // fetch thread labels itself).
                            trace::set_thread_identity(rank, "store");
                            // The worker's budget moves to the fetch
                            // side so the fleet still stops on a
                            // common input prefix.
                            run_staged_with_plan(
                                input.as_mut(),
                                output.as_mut(),
                                wopts,
                                &mut plan,
                                StagedBudget::Fetch(wopts.max_steps),
                            )
                        } else {
                            run_worker(
                                input.as_mut(),
                                output.as_mut(),
                                wopts,
                                &mut plan,
                            )
                        }
                    });
                match spawned {
                    Ok(h) => handles.push(h),
                    Err(e) => {
                        spawn_err = Some(anyhow!(
                            "spawning fleet worker {rank}: {e}"
                        ));
                        break;
                    }
                }
            }
            let mut results: Vec<Result<PipeReport>> = handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(anyhow!("fleet worker panicked"))
                    })
                })
                .collect();
            if let Some(e) = spawn_err {
                results.push(Err(e));
            }
            results
        });

    let mut report = FleetReport::new(readers);
    let mut first_err: Option<anyhow::Error> = None;
    for (rank, result) in results.into_iter().enumerate() {
        match result {
            Ok(worker) => report.absorb_worker(rank, worker),
            Err(e) => {
                if first_err.is_none() {
                    first_err =
                        Some(e.context(format!("fleet worker {rank}")));
                }
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    report.wall_seconds = wall.elapsed().as_secs_f64().max(1e-9);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distribution::{LoadBalanced, RoundRobin};
    use crate::openpmd::chunk::WrittenChunkInfo;
    use crate::openpmd::types::Datatype;

    fn var() -> VarInfo {
        VarInfo {
            name: "/data/0/x".into(),
            dtype: Datatype::F32,
            shape: vec![40],
            ops: OpChain::identity(),
        }
    }

    fn table() -> ChunkTable {
        ChunkTable {
            dataset_extent: vec![40],
            chunks: (0..4)
                .map(|i| {
                    WrittenChunkInfo::new(
                        Chunk::new(vec![i * 10], vec![10]),
                        i as usize,
                        "h",
                    )
                })
                .collect(),
        }
    }

    #[test]
    fn shared_plans_are_disjoint_complete_and_pruned() {
        let layout = ReaderLayout::local(2).unwrap();
        let planner = SharedPlanner::new(Arc::new(RoundRobin), layout);
        let (v, t) = (var(), table());
        let s0 = planner.take_slices(0, 7, &v, &t).unwrap();
        assert_eq!(planner.cached(), 1, "entry must persist for rank 1");
        let s1 = planner.take_slices(1, 7, &v, &t).unwrap();
        assert_eq!(planner.cached(), 0, "entry must be pruned after all \
                                         ranks took their share");
        // Disjoint + complete union.
        assert_eq!(s0.len() + s1.len(), 4);
        for c in &s0 {
            assert!(!s1.contains(c), "chunk {c:?} assigned twice");
        }
    }

    #[test]
    fn first_arriver_plan_is_what_every_rank_sees() {
        // Rank 1 arrives first; rank 0 must still get the complement
        // of what rank 1 took (one shared assignment, not two local
        // ones that could disagree).
        let layout = ReaderLayout::local(2).unwrap();
        let planner =
            SharedPlanner::new(Arc::new(LoadBalanced), layout.clone());
        let (v, t) = (var(), table());
        let s1 = planner.take_slices(1, 0, &v, &t).unwrap();
        let s0 = planner.take_slices(0, 0, &v, &t).unwrap();
        let direct = LoadBalanced.distribute(&t, &layout);
        let want = |r: usize| -> Vec<Chunk> {
            direct.slices(r).iter().map(|s| s.chunk.clone()).collect()
        };
        assert_eq!(s0, want(0));
        assert_eq!(s1, want(1));
    }

    #[test]
    fn discarding_output_still_fetches_the_workers_share() {
        // A fleet worker whose OUTPUT discards a step must still fetch
        // its assigned slices first (fetch-before-offer): skipping the
        // fetch would leave that rank's share of the step unmoved by
        // any rank, a silently incomplete union. The dropped payload
        // is accounted, not silently absent.
        use crate::testing::engines::{CountingSink, InjectedEngine};
        use crate::testing::fixtures;
        // 4 steps in the source, budget of 3: with rank 0's first
        // offer discarded, BOTH workers must still consume exactly the
        // same 3-step input prefix (max_steps counts forwarded +
        // dropped), leaving step 3 untouched by everyone.
        let budget = 3u64;
        let src = std::env::temp_dir().join(format!(
            "opmd-fleet-disc-{}.bp",
            std::process::id()
        ));
        fixtures::write_chunked_bp(&src, budget + 1, 16, 4);
        let inputs: Vec<Box<dyn Engine>> = vec![
            Box::new(crate::adios::bp::BpReader::open(&src).unwrap()),
            Box::new(crate::adios::bp::BpReader::open(&src).unwrap()),
        ];
        // Rank 0's output discards the first step; rank 1's accepts
        // everything.
        let outputs: Vec<Box<dyn Engine>> = vec![
            Box::new(InjectedEngine::discarding(CountingSink::new(), 1)),
            Box::new(CountingSink::new()),
        ];
        let mut opts =
            FleetOptions::local(2, Arc::new(RoundRobin)).unwrap();
        opts.max_steps = Some(budget);
        let report = run_fleet(inputs, outputs, opts).unwrap();
        std::fs::remove_file(&src).ok();

        assert_eq!(report.steps(), budget);
        let r0 = &report.per_rank[0];
        let r1 = &report.per_rank[1];
        assert_eq!(r0.dropped_steps, 1);
        assert_eq!(r0.steps, budget - 1);
        assert_eq!(r1.dropped_steps, 0);
        assert_eq!(r1.steps, budget);
        // THE fix under test: rank 0 fetched its share of every
        // consumed step, including the one its output dropped (16
        // elems x 4 B per step, half per rank) — and its budget did
        // not stretch past the common input prefix.
        assert_eq!(r0.bytes_in, budget * 8 * 4);
        assert_eq!(r1.bytes_in, budget * 8 * 4);
        assert_eq!(report.total_bytes_in(), budget * 16 * 4);
        // The dropped step's bytes never reached rank 0's output.
        assert_eq!(r0.bytes_out, (budget - 1) * 8 * 4);
    }

    #[test]
    fn fleet_rejects_mismatched_engine_counts() {
        let opts =
            FleetOptions::local(2, Arc::new(RoundRobin)).unwrap();
        let err =
            run_fleet(Vec::new(), Vec::new(), opts).unwrap_err();
        assert!(format!("{err}").contains("2 rank(s)"), "{err}");
    }

    #[test]
    fn fleet_options_local_rejects_zero_readers() {
        assert!(FleetOptions::local(0, Arc::new(RoundRobin)).is_err());
    }
}
