//! The staged, overlapped pipe: read-ahead across steps.
//!
//! The serial pipe pays load + store per step — the two latencies add,
//! which is exactly what the paper's streaming argument says they must
//! not do. [`run_staged`] splits the per-step work into the shared
//! core's two stages ([`super::pipe::fetch_step`] /
//! [`super::pipe::store_step`]) running on separate threads:
//!
//! ```text
//!   fetch thread:  [load N] [load N+1] [load N+2] ...
//!                       \        \         \
//!                     bounded step queue (depth = read-ahead)
//!                         \        \         \
//!   store thread:       [store N] [store N+1] [store N+2] ...
//! ```
//!
//! While the output engine writes step N, the input engine is already
//! performing step N+1's batched gets — the store latency hides behind
//! the load (and vice versa), so sustained per-step cost approaches
//! `max(load, store)` instead of `load + store`. This is the pipelined,
//! buffered step forwarding of Eisenhauer et al. 2024 ("Streaming Data
//! in HPC Workflows Using ADIOS") and the MPI-streams double-buffering
//! idea, applied inside the `openpmd-pipe` adaptor.
//!
//! **Backpressure.** The connecting queue is a bounded
//! `std::sync::mpsc::sync_channel` of capacity `depth - 1`: the fetch
//! stage can be at most `depth` steps ahead (one in its hands plus
//! `depth - 1` queued). A slow store blocks the fetch thread on `send`
//! instead of buffering unboundedly; `depth == 1` degenerates to a
//! rendezvous hand-off (still overlapped by one step), `depth == 2` is
//! classic double buffering.
//!
//! **Shutdown and errors, in both directions.**
//!
//! * Fetch side ends (end of stream, input error, idle timeout): the
//!   sender is dropped; the store loop drains whatever was already
//!   queued (mpsc delivers buffered items before the disconnect), then
//!   stops, and the fetch stage's verdict is surfaced after join.
//! * Store side ends (store error or `max_steps` reached): the
//!   receiver is dropped, which fails the fetch thread's next `send`
//!   (even one already blocked on a full queue), and a shared stop
//!   flag interrupts a fetch stage that is instead *polling* a quiet
//!   input (bounded by one backoff sleep, not the idle timeout) — so
//!   the fetch loop unwinds, closes the input engine, and joins
//!   promptly in every case; no deadlock. When `max_steps` was
//!   reached, the run met its contract and the fetch stage's own
//!   verdict is ignored — matching the serial path, which never
//!   touches the input again after the last requested step.
//!
//! The staged path shares the serial path's fetch/store/accounting
//! helpers (`load_open_step`, `store_into_open_step`, `account_load`/
//! `account_store`), so the two report identically and produce
//! byte-identical output for identical inputs. Two read-ahead
//! consequences are inherent and documented: the fetch stage may
//! consume up to `depth` input steps beyond a `max_steps` limit, and a
//! step the output discards has already been loaded (the serial loop
//! instead probes the output *before* loading and drops such steps
//! without moving any data).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::time::Instant;

use anyhow::Result;
use once_cell::sync::Lazy;

use crate::adios::engine::Engine;
use crate::adios::ops::OpsReport;
use crate::obs::metrics::{counter, gauge, Counter, Gauge};
use crate::obs::trace;

use super::pipe::{
    fetch_step, forward_payload, reclaim_payload, Fetched, LocalPlan,
    MetricsEmitter, PipeOptions, PipeReport, StepPayload, StepPlan,
    StepPoller,
};

// Read-ahead queue accounting: depth is the difference of two
// monotonic counters, so both stages can stamp it without sharing
// state beyond the interned handles.
static ENQUEUED: Lazy<&'static Counter> =
    Lazy::new(|| counter("staged.steps_enqueued"));
static DEQUEUED: Lazy<&'static Counter> =
    Lazy::new(|| counter("staged.steps_dequeued"));
static QUEUE_DEPTH: Lazy<&'static Gauge> =
    Lazy::new(|| gauge("staged.queue_depth"));

/// Which stage enforces `max_steps` — the one knob distinguishing a
/// solo staged pipe from a staged fleet worker.
#[derive(Clone, Copy, Debug)]
pub(crate) enum StagedBudget {
    /// Solo-pipe semantics: the store stage stops after this many
    /// *forwarded* steps (downstream discards do not count) and the
    /// fetch stage may read ahead past the limit by up to `depth`.
    Store(Option<u64>),
    /// Fleet-worker semantics: the FETCH stage stops after this many
    /// consumed data steps (forwarded + downstream-dropped — the
    /// fleet's budget unit), so every worker consumes the same input
    /// prefix whatever its own output discards; the store stage drains
    /// everything fetched.
    Fetch(Option<u64>),
}

/// Run the pipe with a dedicated fetch thread reading ahead up to
/// `opts.depth` steps. Same contract as [`super::pipe::run_pipe`];
/// requires `opts.depth >= 1` (use [`super::pipe::run`] to dispatch on
/// depth).
pub fn run_staged(
    input: &mut dyn Engine,
    output: &mut dyn Engine,
    opts: PipeOptions,
) -> Result<PipeReport> {
    let mut plan = LocalPlan::new(&opts);
    let budget = StagedBudget::Store(opts.max_steps);
    run_staged_with_plan(input, output, &opts, &mut plan, budget)
}

/// [`run_staged`] with an explicit slice filter and budget owner — the
/// staged fleet worker's entry point, where `plan` is the fleet's
/// shared step planner instead of a local per-instance one.
pub(crate) fn run_staged_with_plan(
    input: &mut dyn Engine,
    output: &mut dyn Engine,
    opts: &PipeOptions,
    plan: &mut dyn StepPlan,
    budget: StagedBudget,
) -> Result<PipeReport> {
    let depth = opts.depth.max(1);
    let (tx, rx) = sync_channel::<StepPayload>(depth - 1);
    let (store_max, fetch_max) = match budget {
        StagedBudget::Store(max) => (max, None),
        StagedBudget::Fetch(max) => (None, max),
    };
    let rank = opts.rank;
    let mut report = PipeReport::default();
    let wall = Instant::now();
    let stop = AtomicBool::new(false);

    let (store_result, fetch_result, fetch_ops) =
        std::thread::scope(|scope| {
            let stop_flag = &stop;
            let fetch = scope.spawn(move || {
                let r = fetch_loop(&mut *input, opts, plan, tx,
                                   stop_flag, fetch_max);
                // The input engine's operator accounting is read here,
                // on the thread that owns the borrow, and handed back
                // with the verdict.
                (r, input.ops_report())
            });
            let emitter =
                MetricsEmitter::for_sink(opts.metrics_sink.as_ref());
            let store_result = store_loop(
                output,
                rx,
                &mut report,
                store_max,
                rank,
                emitter.as_ref(),
            );
            if let Some(e) = &emitter {
                e.emit_final_line();
            }
            // `store_loop` consumed (and dropped) the receiver, so a
            // fetch stage blocked on a full queue fails its send
            // immediately; the stop flag interrupts one that is polling
            // a quiet input. The join is bounded by one backoff sleep —
            // it cannot deadlock and does not wait out the idle timeout.
            stop.store(true, Ordering::Relaxed);
            let (fetch_result, fetch_ops) = match fetch.join() {
                Ok((r, o)) => (r, o),
                Err(_) => (
                    Err(anyhow::anyhow!("pipe fetch stage panicked")),
                    OpsReport::default(),
                ),
            };
            (store_result, fetch_result, fetch_ops)
        });
    // A store-side failure is the primary verdict (the fetch side then
    // merely observed the hang-up). If the store side completed its
    // `max_steps` contract, the run succeeded no matter how the fetch
    // stage wound down (idle timeout on a now-quiet stream, or an
    // input error past the last requested step) — exactly like the
    // serial path, which never touches the input again. Otherwise the
    // fetch side's verdict stands.
    let reached_max = store_result?;
    if !reached_max {
        fetch_result?;
    }
    output.close()?;
    report.overlap.wall_seconds = wall.elapsed().as_secs_f64().max(1e-9);
    report.overlap.steps = report.steps;
    report.ops.absorb(fetch_ops);
    report.ops.absorb(output.ops_report());
    Ok(report)
}

/// The fetch stage: poll/fetch input steps and feed the bounded queue
/// until end of stream, an input error, the idle timeout, the fetch
/// budget (staged fleet workers), or the store stage hanging up.
/// Closes the input engine on every exit path (over SST that sends
/// `ReaderBye`, so writers stop queueing for us).
fn fetch_loop(
    input: &mut dyn Engine,
    opts: &PipeOptions,
    plan: &mut dyn StepPlan,
    tx: SyncSender<StepPayload>,
    stop: &AtomicBool,
    max_data_steps: Option<u64>,
) -> Result<()> {
    // The dedicated fetch thread's lane in the exported trace.
    trace::set_thread_identity(opts.rank, "fetch");
    let mut poller = StepPoller::new(opts.idle_timeout);
    // Input-step ordinal, the shared-plan key: advances for EVERY
    // consumed input step — discarded ones included — so staged fleet
    // workers over identical input sequences agree on it. (A local
    // plan ignores it, so the solo staged pipe is unaffected.)
    let mut ordinal = 0u64;
    // Data steps actually fetched — what a fleet budget counts.
    let mut fetched = 0u64;
    let result = loop {
        if stop.load(Ordering::Relaxed) {
            // The store stage finished its contract while we were
            // polling a quiet stream: wind down now instead of waiting
            // for the idle timeout.
            break Ok(());
        }
        if let Some(max) = max_data_steps {
            if fetched >= max {
                // Fetch-side budget met (staged fleet worker): stop on
                // this exact input prefix so every worker agrees.
                break Ok(());
            }
        }
        match fetch_step(input, opts, plan, ordinal) {
            Ok(Fetched::Step(payload)) => {
                ordinal += 1;
                fetched += 1;
                // A long span here IS the backpressure signal: time
                // blocked handing off to a full queue.
                let send_failed = {
                    let _sp = trace::span("staged.enqueue")
                        .with("step", payload.step);
                    tx.send(payload).is_err()
                };
                if send_failed {
                    // Store stage hung up (its failure, or max_steps
                    // reached): stop fetching; the store side owns the
                    // verdict.
                    break Ok(());
                }
                ENQUEUED.inc();
                QUEUE_DEPTH.set(
                    ENQUEUED.get().saturating_sub(DEQUEUED.get()),
                );
                // Stamp activity AFTER the hand-off: time spent
                // blocked on a full queue is backpressure, not
                // idleness, and must not eat into the idle budget.
                poller.activity();
            }
            Ok(Fetched::NotReady) => {
                if let Err(e) = poller.not_ready() {
                    break Err(e);
                }
            }
            Ok(Fetched::Discarded) => {
                ordinal += 1;
                poller.activity();
            }
            Ok(Fetched::EndOfStream) => break Ok(()),
            Err(e) => break Err(e),
        }
    };
    match input.close() {
        Ok(()) => result,
        // Keep the first error; a close failure only matters on an
        // otherwise clean exit.
        Err(close_err) => result.and(Err(close_err)),
    }
}

/// The store stage: drain the queue into the output engine, accounting
/// through the exact code the serial path uses. Returns `Ok(true)` if
/// it ended by reaching `max_steps` (its contract is met and the fetch
/// stage's verdict no longer matters), `Ok(false)` if the fetch stage
/// disconnected first.
fn store_loop(
    output: &mut dyn Engine,
    rx: Receiver<StepPayload>,
    report: &mut PipeReport,
    max_steps: Option<u64>,
    rank: usize,
    emitter: Option<&MetricsEmitter>,
) -> Result<bool> {
    loop {
        if let Some(max) = max_steps {
            if report.steps >= max {
                return Ok(true);
            }
        }
        let payload = {
            // Time the store stage starves waiting for the fetch side.
            let _sp = trace::span("staged.dequeue");
            match rx.recv() {
                Ok(p) => p,
                // Fetch stage done (end of stream or its own error,
                // which the caller surfaces after joining it).
                Err(_) => return Ok(false),
            }
        };
        DEQUEUED.inc();
        QUEUE_DEPTH
            .set(ENQUEUED.get().saturating_sub(DEQUEUED.get()));
        forward_payload(output, &payload, report, rank)?;
        // The store side is this payload's end of life: hand every
        // uniquely-owned chunk back to the buffer pool so steady-state
        // staged runs stop allocating (chunks the output still shares
        // are skipped by the refcount check inside).
        reclaim_payload(payload);
        if let Some(e) = emitter {
            e.emit_step_line(report.steps);
        }
    }
}
