//! The L3 pipeline orchestrator (S11): loosely-coupled stages, the
//! `openpmd-pipe` adaptor, and perceived-throughput metrics.
//!
//! A pipeline (Fig. 2) is a set of independent applications cooperating
//! by data exchange: producer → (pipe/analysis/aggregation)* → sink. The
//! orchestrator runs each stage instance on its own thread with its own
//! engines — deliberately *processes-in-miniature*: no shared state
//! besides the transport, exactly like the separate MPI contexts of the
//! paper (and the TCP transport genuinely crosses process boundaries).

pub mod metrics;
pub mod pipe;

pub use metrics::{OpKind, PerceivedThroughput, ThroughputReport};
pub use pipe::{run_pipe, PipeOptions, PipeReport};
