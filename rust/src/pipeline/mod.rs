//! The L3 pipeline orchestrator (S11): loosely-coupled stages, the
//! `openpmd-pipe` adaptor in its two execution modes, and
//! perceived-throughput metrics.
//!
//! A pipeline (Fig. 2) is a set of independent applications cooperating
//! by data exchange: producer → (pipe/analysis/aggregation)* → sink. The
//! orchestrator runs each stage instance on its own thread with its own
//! engines — deliberately *processes-in-miniature*: no shared state
//! besides the transport, exactly like the separate MPI contexts of the
//! paper (and the TCP transport genuinely crosses process boundaries).
//!
//! The `openpmd-pipe` adaptor itself has **two execution paths** behind
//! one step-forwarding core (fetch a step's whole chunk table as one
//! batched perform; store it as one batched perform + publish):
//!
//! * **serial** ([`run_pipe`], `PipeOptions::depth == 0`) — fetch and
//!   store strictly alternate on the calling thread; per-step cost is
//!   load + store. Simple, no extra thread, right for cheap steps.
//! * **staged** ([`run_staged`], `depth >= 1`) — a dedicated fetch
//!   thread reads ahead up to `depth` steps through a bounded queue
//!   while the calling thread stores, so the store of step N overlaps
//!   the load of step N+1 and sustained per-step cost approaches
//!   `max(load, store)`. The bounded queue doubles as backpressure: a
//!   slow store blocks the fetch thread instead of buffering without
//!   limit. [`OverlapReport`] quantifies how much IO time the overlap
//!   hid (`benches/fig8_pipeline.rs` prints serial vs. depth-2 vs.
//!   depth-4 rows).
//!
//! Both paths share the same fetch/store/accounting code, so they are
//! behavior-identical — byte-identical output for identical inputs —
//! and [`run`] dispatches between them on `PipeOptions::depth`.
//!
//! **The parallel reader fleet** ([`fleet`], [`run_fleet`]) scales the
//! adaptor across the reader dimension: M workers, each with its own
//! reader engine subscribed to the N writer transports and its own
//! output shard, coordinated by a shared per-step chunk plan (one
//! complete + disjoint [`crate::distribution::Assignment`] per step
//! and variable, computed once and handed out slice-by-slice). Each
//! worker runs the pipe's step-forwarding core with the shared slice
//! filter ([`pipe::StepPlan`]), fetching its share before offering
//! the step downstream — so fleet shards at any M union to exactly
//! the serial pipe's output. With `FleetOptions::depth > 0` every
//! worker additionally runs the staged read-ahead path (its budget
//! enforced on the fetch side, so workers still stop on a common
//! input prefix). [`FleetReport`] carries the
//! straggler accounting (per-rank bytes/busy time, max/mean imbalance,
//! aggregate throughput) that `benches/fig_fleet.rs` sweeps over
//! M ∈ {1, 2, 4} and strategy.
//!
//! **The chain closes** through the multiplex read layer
//! ([`crate::adios::multiplex`]): a fleet's shard family, reopened via
//! its merged `<out>.index.json`
//! ([`crate::openpmd::series::open_shard_family`]) or any `merge:`
//! composition of sources, is one logical series behind the ordinary
//! engine contract — so `pipe` consumes a fleet's output like any
//! other input and stages chain arbitrarily
//! (produce → fleet → reassemble → pipe/analyze/fleet ...), the
//! paper's loose-coupling vision end to end.
//!
//! **The fan-out daemon** ([`serve`], [`run_serve`]) is the third
//! execution mode: subscribe once to any input spec, stage each step's
//! operator-encoded chunks in a bounded step cache, and serve them to
//! N dynamically joining SST subscribers — encode once, serve N times
//! as `Arc` clones of one staged buffer, so producer-side cost stays
//! flat in N (`benches/fig_serve.rs` sweeps the subscriber count).

pub mod fleet;
pub mod metrics;
pub mod options;
pub mod pipe;
pub mod serve;
pub mod staged;

pub use fleet::{run_fleet, FleetOptions};
pub use options::CommonOptions;
pub use metrics::{
    ops_summary, FleetReport, OpKind, OpsReport, OverlapReport,
    PerceivedThroughput, RankReport, ThroughputReport,
};
pub use pipe::{run, run_pipe, PipeOptions, PipeReport, StepPlan};
pub use serve::{
    run_serve, LagPolicy, ServeDaemon, ServeOptions, ServeReport,
    SubscriberReport,
};
pub use staged::run_staged;
