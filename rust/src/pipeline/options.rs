//! One builder behind every execution mode's option struct.
//!
//! `pipe`, the reader fleet, and the `serve` daemon share most of
//! their knobs (step budget, read-ahead depth, idle timeout, operator
//! override, distribution strategy, metrics sink) but historically
//! each CLI path copied them field-by-field into its own struct —
//! three hand-rolled translations that drifted independently.
//! [`CommonOptions`] is the single translation: `main.rs` parses the
//! shared flag table into it once ([`CommonOptions::from_args`]) and
//! each mode derives its concrete options from the same value
//! ([`pipe`](CommonOptions::pipe), [`fleet`](CommonOptions::fleet),
//! [`serve`](CommonOptions::serve)). Mode-specific knobs (fleet
//! width, serve cache depth / lag policy / listen endpoint) stay
//! arguments of the derivation, so they cannot be set on the wrong
//! mode.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::adios::ops::OpChain;
use crate::distribution::{by_name, Strategy};
use crate::util::cli::Args;

use super::fleet::FleetOptions;
use super::pipe::{MetricsSink, PipeOptions};
use super::serve::{LagPolicy, ServeOptions};

/// The knobs shared by every execution mode, with the same defaults
/// as [`PipeOptions::solo`]. Build with [`CommonOptions::new`] plus
/// the chainable setters, or parse the CLI's shared flag subset with
/// [`CommonOptions::from_args`].
#[derive(Clone)]
pub struct CommonOptions {
    /// Step budget (None = until end of stream). Each mode applies
    /// its own counting rule — see the target structs.
    pub max_steps: Option<u64>,
    /// Staged read-ahead depth (`--pipeline-depth`); the serve daemon
    /// has no store stage to overlap, so it ignores this.
    pub depth: usize,
    /// Give up when the upstream stays silent this long.
    pub idle_timeout: Duration,
    /// Operator-chain override (None = forward announced chains).
    pub operators: Option<OpChain>,
    /// Chunk-distribution strategy (fleet and parallel-pipe plans).
    pub strategy: Arc<dyn Strategy>,
    /// Periodic JSON-lines metric emission.
    pub metrics_sink: Option<MetricsSink>,
}

impl Default for CommonOptions {
    fn default() -> Self {
        CommonOptions::new()
    }
}

impl CommonOptions {
    pub fn new() -> CommonOptions {
        CommonOptions {
            max_steps: None,
            depth: 0,
            idle_timeout: Duration::from_secs(60),
            operators: None,
            strategy: Arc::new(crate::distribution::RoundRobin),
            metrics_sink: None,
        }
    }

    /// Parse the shared flag subset (`--steps`, `--pipeline-depth`,
    /// `--operators`, `--strategy`) from one parsed argument list —
    /// the single place CLI strings become typed pipeline options.
    pub fn from_args(args: &Args) -> Result<CommonOptions> {
        let mut c = CommonOptions::new();
        c.max_steps = args.get_parse::<u64>("steps")?;
        c.depth = args.get_parse_or("pipeline-depth", 0)?;
        c.operators = match args.get("operators") {
            None => None,
            Some(spec) => Some(OpChain::parse(spec).map_err(|e| {
                anyhow::anyhow!("--operators: {e}")
            })?),
        };
        c.strategy =
            Arc::from(by_name(args.get_or("strategy", "roundrobin"))?);
        Ok(c)
    }

    pub fn max_steps(mut self, n: Option<u64>) -> Self {
        self.max_steps = n;
        self
    }

    pub fn depth(mut self, depth: usize) -> Self {
        self.depth = depth;
        self
    }

    pub fn idle_timeout(mut self, t: Duration) -> Self {
        self.idle_timeout = t;
        self
    }

    pub fn operators(mut self, ops: Option<OpChain>) -> Self {
        self.operators = ops;
        self
    }

    pub fn strategy(mut self, strategy: Arc<dyn Strategy>) -> Self {
        self.strategy = strategy;
        self
    }

    pub fn metrics(mut self, sink: Option<MetricsSink>) -> Self {
        self.metrics_sink = sink;
        self
    }

    /// Solo serial/staged pipe options.
    pub fn pipe(&self) -> PipeOptions {
        let mut p = PipeOptions::solo();
        p.max_steps = self.max_steps;
        p.depth = self.depth;
        p.idle_timeout = self.idle_timeout;
        p.operators = self.operators.clone();
        p.strategy = Arc::clone(&self.strategy);
        p.metrics_sink = self.metrics_sink.clone();
        p
    }

    /// Reader-fleet options for `readers` local workers.
    /// (The fleet emits one final metrics snapshot itself — per-step
    /// lines would interleave across workers — so the sink stays with
    /// the caller.)
    pub fn fleet(&self, readers: usize) -> Result<FleetOptions> {
        let mut f =
            FleetOptions::local(readers, Arc::clone(&self.strategy))?;
        f.max_steps = self.max_steps;
        f.depth = self.depth;
        f.idle_timeout = self.idle_timeout;
        f.operators = self.operators.clone();
        Ok(f)
    }

    /// Fan-out daemon options listening on `listen` over `transport`.
    pub fn serve(
        &self,
        listen: String,
        transport: String,
        cache_steps: usize,
        lag: LagPolicy,
    ) -> ServeOptions {
        let mut s = ServeOptions::default();
        s.listen = listen;
        s.transport = transport;
        s.cache_steps = cache_steps;
        s.lag = lag;
        s.max_steps = self.max_steps;
        s.idle_timeout = self.idle_timeout;
        s.operators = self.operators.clone();
        s.metrics_sink = self.metrics_sink.clone();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_builder_feeds_all_three_modes() {
        let common = CommonOptions::new()
            .max_steps(Some(7))
            .depth(2)
            .idle_timeout(Duration::from_secs(3));
        let p = common.pipe();
        assert_eq!(p.max_steps, Some(7));
        assert_eq!(p.depth, 2);
        assert_eq!(p.idle_timeout, Duration::from_secs(3));
        let f = common.fleet(4).unwrap();
        assert_eq!(f.max_steps, Some(7));
        assert_eq!(f.depth, 2);
        let s = common.serve(
            "hub".into(),
            "inproc".into(),
            8,
            LagPolicy::Block,
        );
        assert_eq!(s.max_steps, Some(7));
        assert_eq!(s.cache_steps, 8);
        assert_eq!(s.lag, LagPolicy::Block);
        assert_eq!(s.idle_timeout, Duration::from_secs(3));
    }
}
