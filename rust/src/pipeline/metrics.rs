//! Perceived-throughput accounting, matching the paper's definitions.
//!
//! §4.1: "the perceived throughput which we define through dividing the
//! amount of data to be stored/sent by the time from starting the
//! operation to its completion. Unlike the raw throughput, this includes
//! latency time needed for communication and synchronization. [...] The
//! throughput is computed by average over each single data dump and over
//! each parallel instance, scaled to the total amount of written data."

use std::time::Instant;

use crate::util::stats::{boxplot, BoxPlot};

/// What kind of IO operation a sample describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Producer-side store (file write or stream send).
    Store,
    /// Consumer-side load (file read or stream receive).
    Load,
}

/// One timed IO operation of one parallel instance.
#[derive(Clone, Copy, Debug)]
pub struct OpSample {
    pub kind: OpKind,
    pub bytes: u64,
    pub seconds: f64,
    /// Dump/step index the op belonged to.
    pub step: u64,
    /// Parallel instance that performed it.
    pub instance: usize,
}

/// Collector for op samples; one per benchmark run (merge across
/// instances with [`PerceivedThroughput::absorb`]).
#[derive(Clone, Debug, Default)]
pub struct PerceivedThroughput {
    samples: Vec<OpSample>,
}

/// An in-flight operation timer.
pub struct OpTimer {
    kind: OpKind,
    step: u64,
    instance: usize,
    started: Instant,
}

impl PerceivedThroughput {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start timing an operation (wall clock).
    pub fn start(&self, kind: OpKind, step: u64, instance: usize) -> OpTimer {
        OpTimer { kind, step, instance, started: Instant::now() }
    }

    /// Finish a timed operation.
    pub fn finish(&mut self, timer: OpTimer, bytes: u64) {
        self.record(OpSample {
            kind: timer.kind,
            bytes,
            seconds: timer.started.elapsed().as_secs_f64().max(1e-9),
            step: timer.step,
            instance: timer.instance,
        });
    }

    /// Record a sample with an externally-measured duration (used by the
    /// simulated benchmarks, where time is simulation time).
    pub fn record(&mut self, sample: OpSample) {
        self.samples.push(sample);
    }

    pub fn record_sim(
        &mut self,
        kind: OpKind,
        bytes: u64,
        seconds: f64,
        step: u64,
        instance: usize,
    ) {
        self.record(OpSample { kind, bytes, seconds, step, instance });
    }

    /// Merge another collector (e.g. from another instance thread).
    pub fn absorb(&mut self, other: PerceivedThroughput) {
        self.samples.extend(other.samples);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// The paper's aggregate: mean per-op perceived rate x number of
    /// parallel instances ("scaled to the total amount of written data").
    pub fn report(&self, kind: OpKind, instances: usize) -> ThroughputReport {
        let ops: Vec<&OpSample> =
            self.samples.iter().filter(|s| s.kind == kind).collect();
        if ops.is_empty() {
            return ThroughputReport::default();
        }
        let rates: Vec<f64> =
            ops.iter().map(|s| s.bytes as f64 / s.seconds).collect();
        let times: Vec<f64> = ops.iter().map(|s| s.seconds).collect();
        let total_bytes: u64 = ops.iter().map(|s| s.bytes).sum();
        let mean_rate = rates.iter().sum::<f64>() / rates.len() as f64;
        ThroughputReport {
            total_bytes,
            ops: ops.len(),
            mean_instance_rate: mean_rate,
            aggregate_rate: mean_rate * instances as f64,
            times: boxplot(&times),
        }
    }

    /// Number of distinct steps with at least one sample of `kind`.
    pub fn steps_seen(&self, kind: OpKind) -> usize {
        let mut steps: Vec<u64> = self
            .samples
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.step)
            .collect();
        steps.sort_unstable();
        steps.dedup();
        steps.len()
    }

    /// All operation durations of a kind (for boxplot figures).
    pub fn durations(&self, kind: OpKind) -> Vec<f64> {
        self.samples
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.seconds)
            .collect()
    }
}

/// Aggregated throughput numbers.
#[derive(Clone, Debug)]
pub struct ThroughputReport {
    pub total_bytes: u64,
    pub ops: usize,
    /// Mean per-instance perceived rate, bytes/s.
    pub mean_instance_rate: f64,
    /// Scaled to all instances — the figure the paper plots.
    pub aggregate_rate: f64,
    /// Distribution of operation times (Fig. 7 / Fig. 9 boxplots).
    pub times: BoxPlot,
}

impl Default for ThroughputReport {
    fn default() -> Self {
        ThroughputReport {
            total_bytes: 0,
            ops: 0,
            mean_instance_rate: 0.0,
            aggregate_rate: 0.0,
            times: boxplot(&[0.0]),
        }
    }
}

/// Fraction-of-runtime accounting (the §4.1 "portion of the simulation
/// time that the IO plugin requires").
#[derive(Clone, Copy, Debug, Default)]
pub struct IoShare {
    pub compute_seconds: f64,
    pub raw_io_seconds: f64,
    /// IO including host-side preparation/reorganization.
    pub io_plugin_seconds: f64,
}

impl IoShare {
    pub fn raw_fraction(&self) -> f64 {
        let t = self.compute_seconds + self.io_plugin_seconds;
        if t <= 0.0 { 0.0 } else { self.raw_io_seconds / t }
    }

    pub fn plugin_fraction(&self) -> f64 {
        let t = self.compute_seconds + self.io_plugin_seconds;
        if t <= 0.0 { 0.0 } else { self.io_plugin_seconds / t }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn report_math() {
        let mut m = PerceivedThroughput::new();
        // Two instances, two dumps each, 100 bytes per op.
        m.record_sim(OpKind::Store, 100, 1.0, 0, 0);
        m.record_sim(OpKind::Store, 100, 2.0, 0, 1);
        m.record_sim(OpKind::Store, 100, 1.0, 1, 0);
        m.record_sim(OpKind::Store, 100, 2.0, 1, 1);
        let r = m.report(OpKind::Store, 2);
        assert_eq!(r.total_bytes, 400);
        assert_eq!(r.ops, 4);
        // Rates: 100, 50, 100, 50 -> mean 75; aggregate 150.
        assert!((r.mean_instance_rate - 75.0).abs() < 1e-9);
        assert!((r.aggregate_rate - 150.0).abs() < 1e-9);
        assert_eq!(m.steps_seen(OpKind::Store), 2);
    }

    #[test]
    fn kinds_are_separate() {
        let mut m = PerceivedThroughput::new();
        m.record_sim(OpKind::Store, 10, 1.0, 0, 0);
        m.record_sim(OpKind::Load, 99, 1.0, 0, 0);
        assert_eq!(m.report(OpKind::Store, 1).total_bytes, 10);
        assert_eq!(m.report(OpKind::Load, 1).total_bytes, 99);
    }

    #[test]
    fn timer_measures_wall_clock() {
        let mut m = PerceivedThroughput::new();
        let t = m.start(OpKind::Load, 3, 1);
        std::thread::sleep(Duration::from_millis(15));
        m.finish(t, 1000);
        let r = m.report(OpKind::Load, 1);
        assert!(r.times.median >= 0.014, "{}", r.times.median);
        assert!(r.times.median < 1.0);
    }

    #[test]
    fn absorb_merges() {
        let mut a = PerceivedThroughput::new();
        a.record_sim(OpKind::Store, 1, 1.0, 0, 0);
        let mut b = PerceivedThroughput::new();
        b.record_sim(OpKind::Store, 2, 1.0, 1, 1);
        a.absorb(b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.steps_seen(OpKind::Store), 2);
    }

    #[test]
    fn io_share_fractions() {
        let s = IoShare {
            compute_seconds: 46.0,
            raw_io_seconds: 44.0,
            io_plugin_seconds: 54.0,
        };
        assert!((s.plugin_fraction() - 0.54).abs() < 1e-9);
        assert!((s.raw_fraction() - 0.44).abs() < 1e-9);
    }

    #[test]
    fn empty_report_is_zero() {
        let m = PerceivedThroughput::new();
        let r = m.report(OpKind::Store, 8);
        assert_eq!(r.ops, 0);
        assert_eq!(r.aggregate_rate, 0.0);
    }
}
