//! Perceived-throughput accounting, matching the paper's definitions.
//!
//! §4.1: "the perceived throughput which we define through dividing the
//! amount of data to be stored/sent by the time from starting the
//! operation to its completion. Unlike the raw throughput, this includes
//! latency time needed for communication and synchronization. [...] The
//! throughput is computed by average over each single data dump and over
//! each parallel instance, scaled to the total amount of written data."

use std::time::Instant;

use crate::util::stats::{boxplot, BoxPlot};

/// Operator (compression) accounting, re-exported from the `adios::ops`
/// subsystem: `PipeReport::ops` merges the input engine's decode side
/// and the output engine's encode side, so a pipe run reports data
/// reduction alongside perceived throughput.
pub use crate::adios::ops::OpsReport;

/// One-line human summary of an [`OpsReport`] for pipe/bench output.
pub fn ops_summary(ops: &OpsReport) -> String {
    use crate::util::bytes::{fmt_bytes_f, fmt_rate};
    if ops.is_empty() {
        return "operators: none".into();
    }
    format!(
        "operators: ratio {:.2}x, {} saved, encode {} ({} chunks), \
         decode {} ({} chunks)",
        ops.ratio(),
        fmt_bytes_f(ops.bytes_saved() as f64),
        fmt_rate(ops.encode_rate()),
        ops.chunks_encoded,
        fmt_rate(ops.decode_rate()),
        ops.chunks_decoded,
    )
}

/// What kind of IO operation a sample describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Producer-side store (file write or stream send).
    Store,
    /// Consumer-side load (file read or stream receive).
    Load,
}

/// One timed IO operation of one parallel instance.
#[derive(Clone, Copy, Debug)]
pub struct OpSample {
    pub kind: OpKind,
    pub bytes: u64,
    pub seconds: f64,
    /// Dump/step index the op belonged to.
    pub step: u64,
    /// Parallel instance that performed it.
    pub instance: usize,
}

/// Collector for op samples; one per benchmark run (merge across
/// instances with [`PerceivedThroughput::absorb`]).
#[derive(Clone, Debug, Default)]
pub struct PerceivedThroughput {
    samples: Vec<OpSample>,
}

/// An in-flight operation timer.
pub struct OpTimer {
    kind: OpKind,
    step: u64,
    instance: usize,
    started: Instant,
}

impl PerceivedThroughput {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start timing an operation (wall clock).
    pub fn start(&self, kind: OpKind, step: u64, instance: usize) -> OpTimer {
        OpTimer { kind, step, instance, started: Instant::now() }
    }

    /// Finish a timed operation.
    pub fn finish(&mut self, timer: OpTimer, bytes: u64) {
        self.record(OpSample {
            kind: timer.kind,
            bytes,
            seconds: timer.started.elapsed().as_secs_f64().max(1e-9),
            step: timer.step,
            instance: timer.instance,
        });
    }

    /// Record a sample with an externally-measured duration (used by the
    /// simulated benchmarks, where time is simulation time).
    pub fn record(&mut self, sample: OpSample) {
        self.samples.push(sample);
    }

    pub fn record_sim(
        &mut self,
        kind: OpKind,
        bytes: u64,
        seconds: f64,
        step: u64,
        instance: usize,
    ) {
        self.record(OpSample { kind, bytes, seconds, step, instance });
    }

    /// Merge another collector (e.g. from another instance thread).
    pub fn absorb(&mut self, other: PerceivedThroughput) {
        self.samples.extend(other.samples);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// The paper's aggregate: mean per-op perceived rate x number of
    /// parallel instances ("scaled to the total amount of written data").
    pub fn report(&self, kind: OpKind, instances: usize) -> ThroughputReport {
        let ops: Vec<&OpSample> =
            self.samples.iter().filter(|s| s.kind == kind).collect();
        if ops.is_empty() {
            return ThroughputReport::default();
        }
        let rates: Vec<f64> =
            ops.iter().map(|s| s.bytes as f64 / s.seconds).collect();
        let times: Vec<f64> = ops.iter().map(|s| s.seconds).collect();
        let total_bytes: u64 = ops.iter().map(|s| s.bytes).sum();
        let mean_rate = rates.iter().sum::<f64>() / rates.len() as f64;
        ThroughputReport {
            total_bytes,
            ops: ops.len(),
            mean_instance_rate: mean_rate,
            aggregate_rate: mean_rate * instances as f64,
            times: boxplot(&times),
        }
    }

    /// Number of distinct steps with at least one sample of `kind`.
    pub fn steps_seen(&self, kind: OpKind) -> usize {
        let mut steps: Vec<u64> = self
            .samples
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.step)
            .collect();
        steps.sort_unstable();
        steps.dedup();
        steps.len()
    }

    /// All operation durations of a kind (for boxplot figures).
    pub fn durations(&self, kind: OpKind) -> Vec<f64> {
        self.samples
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.seconds)
            .collect()
    }
}

/// Aggregated throughput numbers.
#[derive(Clone, Debug)]
pub struct ThroughputReport {
    pub total_bytes: u64,
    pub ops: usize,
    /// Mean per-instance perceived rate, bytes/s.
    pub mean_instance_rate: f64,
    /// Scaled to all instances — the figure the paper plots.
    pub aggregate_rate: f64,
    /// Distribution of operation times (Fig. 7 / Fig. 9 boxplots).
    pub times: BoxPlot,
}

impl Default for ThroughputReport {
    fn default() -> Self {
        ThroughputReport {
            total_bytes: 0,
            ops: 0,
            mean_instance_rate: 0.0,
            aggregate_rate: 0.0,
            times: boxplot(&[0.0]),
        }
    }
}

/// Overlap accounting for the staged (read-ahead) pipe: how much IO
/// time the fetch/store concurrency hid from the wall clock.
///
/// The staged pipe runs its two stages on separate threads, so the
/// store of step N proceeds while step N+1 is being loaded. A strictly
/// serial execution of the same work would cost
/// [`OverlapReport::serial_estimate`] (load busy + store busy, added);
/// whatever part of that does not show up in `wall_seconds` was
/// successfully overlapped. Serial runs fill the same struct and show
/// ~zero hidden time, which is what the fig8 bench rows compare.
#[derive(Clone, Copy, Debug, Default)]
pub struct OverlapReport {
    /// Wall-clock duration of the whole pipe run.
    pub wall_seconds: f64,
    /// Total time the fetch stage spent actively loading steps.
    pub load_busy_seconds: f64,
    /// Total time the store stage spent actively writing steps.
    pub store_busy_seconds: f64,
    /// Steps forwarded (denominator for per-step figures).
    pub steps: u64,
}

impl OverlapReport {
    /// What the same work costs when load and store latencies add
    /// instead of overlapping — the serial pipe's per-run IO time.
    pub fn serial_estimate(&self) -> f64 {
        self.load_busy_seconds + self.store_busy_seconds
    }

    /// Seconds of IO hidden by the overlap (~0 for a serial run).
    pub fn hidden_seconds(&self) -> f64 {
        (self.serial_estimate() - self.wall_seconds).max(0.0)
    }

    /// Fraction of the cheaper stage that disappeared from the wall
    /// clock: 1.0 means the store (or load, whichever is smaller) was
    /// completely hidden behind the other stage.
    pub fn overlap_efficiency(&self) -> f64 {
        let bound = self.load_busy_seconds.min(self.store_busy_seconds);
        if bound <= 0.0 {
            0.0
        } else {
            (self.hidden_seconds() / bound).min(1.0)
        }
    }

    /// Stage occupancy: the fraction of the run a stage was busy.
    pub fn occupancy(&self, kind: OpKind) -> f64 {
        if self.wall_seconds <= 0.0 {
            return 0.0;
        }
        let busy = match kind {
            OpKind::Load => self.load_busy_seconds,
            OpKind::Store => self.store_busy_seconds,
        };
        busy / self.wall_seconds
    }

    /// Mean wall-clock per forwarded step.
    pub fn wall_per_step(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.wall_seconds / self.steps as f64
        }
    }
}

/// One fleet worker's distilled accounting, merged from its
/// [`super::pipe::PipeReport`] after the run.
#[derive(Clone, Debug, Default)]
pub struct RankReport {
    pub rank: usize,
    /// Steps this worker forwarded to its output shard.
    pub steps: u64,
    pub dropped_steps: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub chunks: u64,
    /// Seconds the worker spent actively loading + storing (its busy
    /// time; wall minus this is time spent waiting on peers/stream).
    pub busy_seconds: f64,
}

/// Straggler accounting for a parallel reader fleet: per-rank loads,
/// rank imbalance, and aggregate throughput. The number the fleet
/// exists to improve is [`FleetReport::aggregate_rate`]; the number
/// that caps it is [`FleetReport::imbalance`] — a fleet is only as
/// fast as its most-loaded rank, so max/mean rank bytes is the direct
/// measure of how much of the M-fold parallelism a distribution
/// strategy actually delivers.
#[derive(Clone, Debug, Default)]
pub struct FleetReport {
    /// Configured fleet width (M).
    pub readers: usize,
    /// Wall-clock duration of the whole fleet run (slowest worker).
    pub wall_seconds: f64,
    pub per_rank: Vec<RankReport>,
    /// Merged per-op samples of every worker (per-instance rates).
    pub metrics: PerceivedThroughput,
    /// Merged operator accounting of every worker's engines.
    pub ops: OpsReport,
}

impl FleetReport {
    pub fn new(readers: usize) -> FleetReport {
        FleetReport { readers, ..Default::default() }
    }

    /// Fold one worker's pipe report into the fleet view.
    pub fn absorb_worker(
        &mut self,
        rank: usize,
        report: super::pipe::PipeReport,
    ) {
        self.per_rank.push(RankReport {
            rank,
            steps: report.steps,
            dropped_steps: report.dropped_steps,
            bytes_in: report.bytes_in,
            bytes_out: report.bytes_out,
            chunks: report.chunks,
            busy_seconds: report.overlap.load_busy_seconds
                + report.overlap.store_busy_seconds,
        });
        self.metrics.absorb(report.metrics);
        self.ops.absorb(report.ops);
    }

    /// Steps the fleet forwarded (every worker consumes every input
    /// step, so the max over ranks is the fleet's step count).
    pub fn steps(&self) -> u64 {
        self.per_rank.iter().map(|r| r.steps).max().unwrap_or(0)
    }

    pub fn total_bytes_in(&self) -> u64 {
        self.per_rank.iter().map(|r| r.bytes_in).sum()
    }

    pub fn total_bytes_out(&self) -> u64 {
        self.per_rank.iter().map(|r| r.bytes_out).sum()
    }

    /// Aggregate forwarded throughput, bytes/s over the fleet wall
    /// clock — the figure `benches/fig_fleet.rs` sweeps over M.
    pub fn aggregate_rate(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            0.0
        } else {
            self.total_bytes_out() as f64 / self.wall_seconds
        }
    }

    /// Heaviest rank's input bytes — the straggler's load.
    pub fn max_rank_bytes(&self) -> u64 {
        self.per_rank.iter().map(|r| r.bytes_in).max().unwrap_or(0)
    }

    /// Mean input bytes per rank.
    pub fn mean_rank_bytes(&self) -> f64 {
        if self.per_rank.is_empty() {
            0.0
        } else {
            self.total_bytes_in() as f64 / self.per_rank.len() as f64
        }
    }

    /// Max-over-mean rank byte load: 1.0 = perfectly balanced, M =
    /// one rank carried everything. Mirrors
    /// [`crate::distribution::metrics::Quality::balance_factor`], but
    /// measured on what the fleet actually moved.
    pub fn imbalance(&self) -> f64 {
        let mean = self.mean_rank_bytes();
        if mean <= 0.0 {
            1.0
        } else {
            self.max_rank_bytes() as f64 / mean
        }
    }

    /// Busy-time gap between the slowest and the average worker — the
    /// seconds of parallelism lost to stragglers.
    pub fn straggler_seconds(&self) -> f64 {
        if self.per_rank.is_empty() {
            return 0.0;
        }
        let max = self
            .per_rank
            .iter()
            .map(|r| r.busy_seconds)
            .fold(0.0f64, f64::max);
        let mean = self
            .per_rank
            .iter()
            .map(|r| r.busy_seconds)
            .sum::<f64>()
            / self.per_rank.len() as f64;
        (max - mean).max(0.0)
    }

    /// One-line human summary for CLI/bench output.
    pub fn summary(&self) -> String {
        use crate::util::bytes::{fmt_bytes, fmt_rate};
        format!(
            "fleet of {}: {} steps, {} in, {} out, {} at imbalance \
             {:.2}x (straggler +{:.3}s busy)",
            self.readers,
            self.steps(),
            fmt_bytes(self.total_bytes_in()),
            fmt_bytes(self.total_bytes_out()),
            fmt_rate(self.aggregate_rate()),
            self.imbalance(),
            self.straggler_seconds(),
        )
    }
}

/// Fraction-of-runtime accounting (the §4.1 "portion of the simulation
/// time that the IO plugin requires").
#[derive(Clone, Copy, Debug, Default)]
pub struct IoShare {
    pub compute_seconds: f64,
    pub raw_io_seconds: f64,
    /// IO including host-side preparation/reorganization.
    pub io_plugin_seconds: f64,
}

impl IoShare {
    pub fn raw_fraction(&self) -> f64 {
        let t = self.compute_seconds + self.io_plugin_seconds;
        if t <= 0.0 { 0.0 } else { self.raw_io_seconds / t }
    }

    pub fn plugin_fraction(&self) -> f64 {
        let t = self.compute_seconds + self.io_plugin_seconds;
        if t <= 0.0 { 0.0 } else { self.io_plugin_seconds / t }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn report_math() {
        let mut m = PerceivedThroughput::new();
        // Two instances, two dumps each, 100 bytes per op.
        m.record_sim(OpKind::Store, 100, 1.0, 0, 0);
        m.record_sim(OpKind::Store, 100, 2.0, 0, 1);
        m.record_sim(OpKind::Store, 100, 1.0, 1, 0);
        m.record_sim(OpKind::Store, 100, 2.0, 1, 1);
        let r = m.report(OpKind::Store, 2);
        assert_eq!(r.total_bytes, 400);
        assert_eq!(r.ops, 4);
        // Rates: 100, 50, 100, 50 -> mean 75; aggregate 150.
        assert!((r.mean_instance_rate - 75.0).abs() < 1e-9);
        assert!((r.aggregate_rate - 150.0).abs() < 1e-9);
        assert_eq!(m.steps_seen(OpKind::Store), 2);
    }

    #[test]
    fn kinds_are_separate() {
        let mut m = PerceivedThroughput::new();
        m.record_sim(OpKind::Store, 10, 1.0, 0, 0);
        m.record_sim(OpKind::Load, 99, 1.0, 0, 0);
        assert_eq!(m.report(OpKind::Store, 1).total_bytes, 10);
        assert_eq!(m.report(OpKind::Load, 1).total_bytes, 99);
    }

    #[test]
    fn timer_measures_wall_clock() {
        let mut m = PerceivedThroughput::new();
        let t = m.start(OpKind::Load, 3, 1);
        std::thread::sleep(Duration::from_millis(15));
        m.finish(t, 1000);
        let r = m.report(OpKind::Load, 1);
        assert!(r.times.median >= 0.014, "{}", r.times.median);
        assert!(r.times.median < 1.0);
    }

    #[test]
    fn absorb_merges() {
        let mut a = PerceivedThroughput::new();
        a.record_sim(OpKind::Store, 1, 1.0, 0, 0);
        let mut b = PerceivedThroughput::new();
        b.record_sim(OpKind::Store, 2, 1.0, 1, 1);
        a.absorb(b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.steps_seen(OpKind::Store), 2);
    }

    #[test]
    fn io_share_fractions() {
        let s = IoShare {
            compute_seconds: 46.0,
            raw_io_seconds: 44.0,
            io_plugin_seconds: 54.0,
        };
        assert!((s.plugin_fraction() - 0.54).abs() < 1e-9);
        assert!((s.raw_fraction() - 0.44).abs() < 1e-9);
    }

    #[test]
    fn overlap_report_quantifies_hidden_store_time() {
        // 4 steps, 10 ms load + 10 ms store each, run in 45 ms wall:
        // a serial run would have cost 80 ms, so 35 ms were hidden.
        let o = OverlapReport {
            wall_seconds: 0.045,
            load_busy_seconds: 0.040,
            store_busy_seconds: 0.040,
            steps: 4,
        };
        assert!((o.serial_estimate() - 0.080).abs() < 1e-12);
        assert!((o.hidden_seconds() - 0.035).abs() < 1e-12);
        assert!((o.overlap_efficiency() - 0.875).abs() < 1e-9);
        assert!((o.occupancy(OpKind::Load) - 0.040 / 0.045).abs() < 1e-9);
        assert!((o.wall_per_step() - 0.045 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn overlap_report_serial_run_hides_nothing() {
        // Serial run: wall == load + store (plus slack) -> zero hidden.
        let o = OverlapReport {
            wall_seconds: 0.085,
            load_busy_seconds: 0.040,
            store_busy_seconds: 0.040,
            steps: 4,
        };
        assert_eq!(o.hidden_seconds(), 0.0);
        assert_eq!(o.overlap_efficiency(), 0.0);
    }

    #[test]
    fn overlap_report_empty_is_all_zero() {
        let o = OverlapReport::default();
        assert_eq!(o.hidden_seconds(), 0.0);
        assert_eq!(o.overlap_efficiency(), 0.0);
        assert_eq!(o.occupancy(OpKind::Store), 0.0);
        assert_eq!(o.wall_per_step(), 0.0);
    }

    #[test]
    fn empty_report_is_zero() {
        let m = PerceivedThroughput::new();
        let r = m.report(OpKind::Store, 8);
        assert_eq!(r.ops, 0);
        assert_eq!(r.aggregate_rate, 0.0);
    }

    #[test]
    fn fleet_report_math() {
        use crate::pipeline::pipe::PipeReport;
        let mut f = FleetReport::new(2);
        let a = PipeReport {
            steps: 3,
            bytes_in: 300,
            bytes_out: 300,
            overlap: OverlapReport {
                load_busy_seconds: 0.3,
                store_busy_seconds: 0.1,
                ..Default::default()
            },
            ..Default::default()
        };
        let b = PipeReport {
            steps: 3,
            bytes_in: 100,
            bytes_out: 100,
            overlap: OverlapReport {
                load_busy_seconds: 0.1,
                ..Default::default()
            },
            ..Default::default()
        };
        f.absorb_worker(0, a);
        f.absorb_worker(1, b);
        f.wall_seconds = 2.0;
        assert_eq!(f.steps(), 3);
        assert_eq!(f.total_bytes_in(), 400);
        assert_eq!(f.total_bytes_out(), 400);
        assert!((f.aggregate_rate() - 200.0).abs() < 1e-9);
        assert_eq!(f.max_rank_bytes(), 300);
        // max 300 over mean 200 = 1.5x imbalance.
        assert!((f.imbalance() - 1.5).abs() < 1e-9);
        // busy: 0.4 vs 0.1 -> straggler gap 0.4 - 0.25.
        assert!((f.straggler_seconds() - 0.15).abs() < 1e-9);
        let s = f.summary();
        assert!(s.contains("fleet of 2"), "{s}");
        assert!(s.contains("1.50x"), "{s}");
    }

    #[test]
    fn empty_fleet_report_is_neutral() {
        let f = FleetReport::new(4);
        assert_eq!(f.steps(), 0);
        assert_eq!(f.imbalance(), 1.0);
        assert_eq!(f.aggregate_rate(), 0.0);
        assert_eq!(f.straggler_seconds(), 0.0);
    }

    #[test]
    fn ops_summary_renders_both_states() {
        let empty = OpsReport::default();
        assert_eq!(ops_summary(&empty), "operators: none");
        let r = OpsReport {
            chunks_encoded: 3,
            raw_bytes_in: 3000,
            encoded_bytes_out: 1000,
            encode_ns: 1_000_000,
            ..Default::default()
        };
        let s = ops_summary(&r);
        assert!(s.contains("3.00x"), "{s}");
        assert!(s.contains("3 chunks"), "{s}");
    }
}
