//! SST reader: subscribes to one or more writer ranks, merges their step
//! announcements, and pulls assigned chunks.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::adios::engine::{
    Bytes, Engine, Mode, StepStatus, VarDecl, VarInfo,
};
use crate::adios::region;
use crate::adios::transport::{self, Conn, Recv};
use crate::adios::wire::{Msg, StepMeta};
use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};
use crate::openpmd::Attribute;

use super::SstStats;

/// Options for opening a reader.
#[derive(Clone)]
pub struct SstReaderOptions {
    /// Addresses of all writer ranks of the producing application.
    pub writers: Vec<String>,
    /// Transport name ("inproc" | "tcp").
    pub transport: String,
    /// This reader's parallel rank within the consuming application.
    pub rank: usize,
    pub hostname: String,
    /// How long `begin_step` waits before reporting `NotReady`.
    pub begin_step_timeout: Duration,
}

impl Default for SstReaderOptions {
    fn default() -> Self {
        SstReaderOptions {
            writers: Vec::new(),
            transport: "inproc".into(),
            rank: 0,
            hostname: "localhost".into(),
            begin_step_timeout: Duration::from_secs(30),
        }
    }
}

struct WriterConn {
    conn: Box<dyn Conn>,
    writer_rank: usize,
    #[allow(dead_code)]
    hostname: String,
    /// Announces received but not yet consumed, in step order. Several
    /// can pile up while `get` is draining a slow step.
    pending: VecDeque<(u64, StepMeta)>,
    closed: bool,
}

/// Current merged step on the reader.
struct CurrentStep {
    step: u64,
    /// Writer connection index by writer rank (chunks carry ranks).
    metas: Vec<StepMeta>,
}

/// The reader engine.
pub struct SstReader {
    opts: SstReaderOptions,
    writers: Vec<WriterConn>,
    current: Option<CurrentStep>,
    stats: SstStats,
    next_req_id: u64,
    /// Steps skipped during announce reconciliation (writers discarded
    /// non-collectively).
    pub steps_skipped: u64,
}

impl SstReader {
    /// Connect to all writer ranks and handshake.
    pub fn open(opts: SstReaderOptions) -> Result<SstReader> {
        let transport = transport::by_name(&opts.transport)?;
        let mut writers = Vec::with_capacity(opts.writers.len());
        for addr in &opts.writers {
            let mut conn = transport
                .dial(addr)
                .with_context(|| format!("dialing writer at {addr}"))?;
            conn.send(Msg::Hello {
                reader_rank: opts.rank,
                hostname: opts.hostname.clone(),
            })?;
            let (writer_rank, hostname) =
                match conn.recv_timeout(Duration::from_secs(10))? {
                    Recv::Msg(Msg::HelloAck { writer_rank, hostname }) => {
                        (writer_rank, hostname)
                    }
                    _ => bail!("no HelloAck from {addr}"),
                };
            writers.push(WriterConn {
                conn,
                writer_rank,
                hostname,
                pending: VecDeque::new(),
                closed: false,
            });
        }
        Ok(SstReader {
            opts,
            writers,
            current: None,
            stats: SstStats::default(),
            next_req_id: 1,
            steps_skipped: 0,
        })
    }

    pub fn stats(&self) -> SstStats {
        self.stats
    }

    /// Pump one writer connection until it has an announce (>= `min_step`)
    /// or closes. Returns false on timeout.
    fn pump_announce(
        w: &mut WriterConn,
        min_step: u64,
        deadline: std::time::Instant,
    ) -> Result<bool> {
        loop {
            if let Some((s, _)) = w.pending.front() {
                if *s >= min_step {
                    return Ok(true);
                }
                // Stale announce below the reconciliation target: drop it.
                w.pending.pop_front();
                continue;
            }
            if w.closed {
                return Ok(true);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(false);
            }
            match w.conn.recv_timeout(deadline - now)? {
                Recv::Msg(Msg::StepAnnounce { step, meta }) => {
                    w.pending.push_back((step, meta));
                }
                Recv::Msg(Msg::CloseStream) => {
                    w.closed = true;
                }
                Recv::Msg(_) => {
                    // Stray data from a previous step: ignore.
                }
                Recv::TimedOut => return Ok(false),
                Recv::Closed => {
                    w.closed = true;
                }
            }
        }
    }

    /// Merged chunk list of a variable in the current step.
    fn merged_chunks(&self, var: &str) -> Vec<WrittenChunkInfo> {
        let mut out = Vec::new();
        if let Some(cur) = &self.current {
            for meta in &cur.metas {
                for v in &meta.vars {
                    if v.name == var {
                        out.extend(v.chunks.iter().cloned());
                    }
                }
            }
        }
        out
    }
}

impl Engine for SstReader {
    fn engine_type(&self) -> &'static str {
        "sst"
    }

    fn mode(&self) -> Mode {
        Mode::Read
    }

    /// Wait for the next step announced by *all* writers.
    ///
    /// Writers using a shared [`super::WriterGroup`] publish identical
    /// step sequences; without one, writers may discard different steps
    /// and the reader reconciles by advancing to the highest commonly
    /// announced step, counting skips in `steps_skipped`.
    fn begin_step(&mut self) -> Result<StepStatus> {
        if self.current.is_some() {
            bail!("begin_step while a step is open");
        }
        if self.writers.is_empty() {
            return Ok(StepStatus::EndOfStream);
        }
        let deadline =
            std::time::Instant::now() + self.opts.begin_step_timeout;
        let mut target = 0u64;
        // Reconcile until every live writer has announced `target`.
        loop {
            let mut all_ready = true;
            let mut any_live = false;
            for w in self.writers.iter_mut() {
                if !Self::pump_announce(w, target, deadline)? {
                    return Ok(StepStatus::NotReady);
                }
                if w.closed && w.pending.is_empty() {
                    continue;
                }
                any_live = true;
                let (s, _) = w.pending.front().unwrap();
                if *s > target {
                    self.steps_skipped += target.abs_diff(*s).min(1);
                    target = *s;
                    all_ready = false;
                }
            }
            if !any_live {
                return Ok(StepStatus::EndOfStream);
            }
            if all_ready {
                break;
            }
        }
        // Consume the pending announces.
        let mut metas = Vec::new();
        for w in self.writers.iter_mut() {
            if let Some((s, meta)) = w.pending.pop_front() {
                debug_assert_eq!(s, target);
                metas.push(meta);
            }
        }
        self.stats.steps_consumed += 1;
        self.current = Some(CurrentStep { step: target, metas });
        Ok(StepStatus::Ok)
    }

    fn put(&mut self, _var: &VarDecl, _chunk: Chunk, _data: Bytes)
        -> Result<()>
    {
        bail!("put on a read-mode SST engine")
    }

    fn put_attribute(&mut self, _name: &str, _value: Attribute) -> Result<()> {
        bail!("put_attribute on a read-mode SST engine")
    }

    fn available_variables(&self) -> Vec<VarInfo> {
        let mut seen = BTreeMap::new();
        if let Some(cur) = &self.current {
            for meta in &cur.metas {
                for v in &meta.vars {
                    seen.entry(v.name.clone()).or_insert_with(|| VarInfo {
                        name: v.name.clone(),
                        dtype: v.dtype,
                        shape: v.shape.clone(),
                    });
                }
            }
        }
        seen.into_values().collect()
    }

    fn available_chunks(&self, var: &str) -> Vec<WrittenChunkInfo> {
        self.merged_chunks(var)
    }

    fn attribute(&self, name: &str) -> Option<Attribute> {
        let cur = self.current.as_ref()?;
        cur.metas
            .iter()
            .find_map(|m| m.attributes.get(name).cloned())
    }

    fn attribute_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .current
            .iter()
            .flat_map(|c| c.metas.iter())
            .flat_map(|m| m.attributes.keys().cloned())
            .collect();
        names.sort();
        names.dedup();
        names
    }

    /// Load a selection, assembling it from per-writer requests.
    ///
    /// One request is issued per (writer chunk ∩ selection); requests to
    /// different writers are pipelined (all sent before any response is
    /// awaited). Only writers owning intersecting chunks are contacted —
    /// the paper's "connections only between instances that exchange
    /// data".
    fn get(&mut self, var: &str, selection: Chunk) -> Result<Bytes> {
        let cur = self
            .current
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("get outside step"))?;
        let step = cur.step;
        let dtype = self
            .available_variables()
            .into_iter()
            .find(|v| v.name == var)
            .ok_or_else(|| anyhow::anyhow!("unknown variable {var:?}"))?
            .dtype;
        let elem = dtype.size();
        let chunks = self.merged_chunks(var);

        // Plan: per writer rank, the intersections to request.
        let mut plan: BTreeMap<usize, Vec<Chunk>> = BTreeMap::new();
        for info in &chunks {
            if let Some(inter) = info.chunk.intersect(&selection) {
                plan.entry(info.source_rank).or_default().push(inter);
            }
        }
        let total_planned: u64 =
            plan.values().flatten().map(|c| c.num_elements()).sum();
        if total_planned < selection.num_elements() {
            bail!(
                "selection {:?}+{:?} of {var:?} not fully covered by \
                 announced chunks ({total_planned}/{})",
                selection.offset,
                selection.extent,
                selection.num_elements()
            );
        }

        // Fast path: selection exactly matches a single written chunk of a
        // single writer — one request, zero reassembly (the *alignment*
        // property in action).
        let mut out: Vec<u8> = Vec::new();
        let mut assembled = false;

        // Send all requests first (pipelining across writers)...
        let mut outstanding: Vec<(usize, u64, Chunk)> = Vec::new();
        for (writer_rank, sels) in &plan {
            let widx = self
                .writers
                .iter()
                .position(|w| w.writer_rank == *writer_rank)
                .ok_or_else(|| {
                    anyhow::anyhow!("no connection to writer {writer_rank}")
                })?;
            for sel in sels {
                let req_id = self.next_req_id;
                self.next_req_id += 1;
                self.writers[widx].conn.send(Msg::ChunkRequest {
                    req_id,
                    step,
                    var: var.to_string(),
                    sel: sel.clone(),
                })?;
                self.stats.chunk_requests += 1;
                outstanding.push((widx, req_id, sel.clone()));
            }
        }

        let single = outstanding.len() == 1
            && outstanding[0].2 == selection;
        if !single {
            out = vec![0u8; selection.num_elements() as usize * elem];
        }

        // ... then collect responses (per-connection FIFO order).
        for (widx, req_id, sub_sel) in outstanding {
            let data = loop {
                match self.writers[widx].conn.recv()? {
                    Recv::Msg(Msg::ChunkData { req_id: r, data })
                        if r == req_id =>
                    {
                        break data
                    }
                    Recv::Msg(Msg::ChunkError { req_id: r, error })
                        if r == req_id =>
                    {
                        bail!("writer {} failed request: {error}",
                              self.writers[widx].writer_rank)
                    }
                    Recv::Msg(Msg::StepAnnounce { step, meta }) => {
                        // Next steps arriving while we read this one.
                        self.writers[widx].pending.push_back((step, meta));
                    }
                    Recv::Msg(Msg::CloseStream) => {
                        self.writers[widx].closed = true;
                    }
                    Recv::Msg(_) => {}
                    Recv::TimedOut => {}
                    Recv::Closed => bail!(
                        "writer {} vanished mid-request",
                        self.writers[widx].writer_rank
                    ),
                }
            };
            self.stats.bytes_got += data.len() as u64;
            if single {
                return Ok(data);
            }
            let copied = region::copy_region(
                &sub_sel, &data, &selection, &mut out, elem,
            );
            debug_assert_eq!(copied, sub_sel.num_elements());
            assembled = true;
        }
        debug_assert!(assembled || selection.num_elements() == 0);
        Ok(Arc::new(out))
    }

    fn end_step(&mut self) -> Result<()> {
        let cur = self
            .current
            .take()
            .ok_or_else(|| anyhow::anyhow!("end_step without begin_step"))?;
        for w in self.writers.iter_mut() {
            if !w.closed {
                let _ = w.conn.send(Msg::StepDone { step: cur.step });
            }
        }
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        if self.current.is_some() {
            self.end_step()?;
        }
        for w in self.writers.iter_mut() {
            if !w.closed {
                let _ = w.conn.send(Msg::ReaderBye);
                w.closed = true;
            }
        }
        self.writers.clear();
        Ok(())
    }
}

impl Drop for SstReader {
    fn drop(&mut self) {
        let _ = self.close();
    }
}
