//! SST reader: subscribes to one or more writer ranks, merges their step
//! announcements, and pulls assigned chunks.
//!
//! Two-phase read side: `get_deferred` enqueues selections;
//! `perform_gets` plans the whole batch against the step's merged chunk
//! table and contacts each owning writer **once** — one `GetBatch`
//! request, one `GetBatchReply` — however many selections the batch
//! carries. Exact-chunk selections over the in-process transport come
//! back as the writer's own `Arc` (zero-copy, the RDMA analogy).

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};
use once_cell::sync::Lazy;

use crate::adios::engine::{
    Bytes, DeferredGet, Engine, GetHandle, GetQueue, Mode, StepStatus,
    VarHandle, VarDecl, VarInfo,
};
use crate::adios::ops::{self, OpChain, OpsReport};
use crate::adios::region;
use crate::adios::transport::{self, Conn, Recv};
use crate::adios::wire::{GetItem, GetReply, Msg, StepMeta};
use crate::obs::metrics::{counter, Counter};
use crate::obs::trace;
use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};
use crate::openpmd::types::Datatype;
use crate::openpmd::Attribute;
use crate::util::pool;

use super::SstStats;

static GET_BATCHES: Lazy<&'static Counter> =
    Lazy::new(|| counter("sst.get_batches"));
static GET_BYTES: Lazy<&'static Counter> =
    Lazy::new(|| counter("sst.get_bytes"));

/// Options for opening a reader.
#[derive(Clone)]
pub struct SstReaderOptions {
    /// Addresses of all writer ranks of the producing application.
    pub writers: Vec<String>,
    /// Transport name ("inproc" | "tcp").
    pub transport: String,
    /// This reader's parallel rank within the consuming application.
    pub rank: usize,
    pub hostname: String,
    /// How long `begin_step` waits before reporting `NotReady`.
    pub begin_step_timeout: Duration,
    /// Operator codecs to advertise in the handshake. `None` (default)
    /// advertises everything this build supports; tests restrict it to
    /// exercise the writer's raw-fallback negotiation path.
    pub codecs: Option<Vec<String>>,
}

impl Default for SstReaderOptions {
    fn default() -> Self {
        SstReaderOptions {
            writers: Vec::new(),
            transport: "inproc".into(),
            rank: 0,
            hostname: "localhost".into(),
            begin_step_timeout: Duration::from_secs(30),
            codecs: None,
        }
    }
}

struct WriterConn {
    conn: Box<dyn Conn>,
    writer_rank: usize,
    /// From the writer's `HelloAck`; named in connection-loss errors
    /// so a torn stream points at the failing host.
    hostname: String,
    /// Announces received but not yet consumed, in step order. Several
    /// can pile up while `get` is draining a slow step.
    pending: VecDeque<(u64, StepMeta)>,
    closed: bool,
}

/// Current merged step on the reader.
struct CurrentStep {
    step: u64,
    /// Writer connection index by writer rank (chunks carry ranks).
    metas: Vec<StepMeta>,
}

/// The reader engine.
pub struct SstReader {
    opts: SstReaderOptions,
    writers: Vec<WriterConn>,
    current: Option<CurrentStep>,
    stats: SstStats,
    next_req_id: u64,
    /// Deferred-get queue (two-phase API).
    gets: GetQueue,
    /// Decode-side operator accounting.
    ops_stats: OpsReport,
    /// Reusable `perform_batch` plan scratch, cleared between batches.
    plan: PlanScratch,
    /// Steps skipped during announce reconciliation (writers discarded
    /// non-collectively).
    pub steps_skipped: u64,
}

/// One merged per-variable chunk table in the batch plan.
struct PlanVar {
    name: String,
    elem: usize,
    dtype: Datatype,
    ops: OpChain,
    chunks: Vec<WrittenChunkInfo>,
}

/// Reusable plan scratch: `perform_batch` used to rebuild a
/// `BTreeMap<String, VarTable>` — fresh `String` keys, chain clones and
/// chunk-table vectors — on every batch. These slots persist on the
/// reader with their capacity intact and are cleared between batches,
/// so a steady-state batch's merge phase stops allocating once a batch
/// has seen the step's variable set. Lookups are a linear scan: a batch
/// references a handful of variables, far below BTreeMap break-even.
#[derive(Default)]
struct PlanScratch {
    vars: Vec<PlanVar>,
    /// Slots in use this batch; `vars[live..]` is retained capacity.
    live: usize,
}

impl PlanScratch {
    fn reset(&mut self) {
        self.live = 0;
    }

    fn find(&self, name: &str) -> Option<usize> {
        self.vars[..self.live].iter().position(|v| v.name == name)
    }

    /// Claim a cleared slot for `name`, reusing a retired slot's
    /// allocations when one exists.
    fn open_slot(&mut self, name: &str) -> &mut PlanVar {
        if self.live == self.vars.len() {
            self.vars.push(PlanVar {
                name: String::new(),
                elem: 0,
                dtype: Datatype::U8,
                ops: OpChain::default(),
                chunks: Vec::new(),
            });
        }
        let live = self.live;
        self.live += 1;
        let slot = &mut self.vars[live];
        slot.name.clear();
        slot.name.push_str(name);
        slot.chunks.clear();
        slot
    }
}

impl SstReader {
    /// Connect to all writer ranks and handshake.
    pub fn open(opts: SstReaderOptions) -> Result<SstReader> {
        let transport = transport::by_name(&opts.transport)?;
        let codecs = opts
            .codecs
            .clone()
            .unwrap_or_else(ops::supported_codecs);
        let mut writers = Vec::with_capacity(opts.writers.len());
        for addr in &opts.writers {
            let mut conn = transport
                .dial(addr)
                .with_context(|| format!("dialing writer at {addr}"))?;
            conn.send(Msg::Hello {
                reader_rank: opts.rank,
                hostname: opts.hostname.clone(),
                codecs: codecs.clone(),
            })?;
            let (writer_rank, hostname) =
                match conn.recv_timeout(Duration::from_secs(10))? {
                    Recv::Msg(Msg::HelloAck { writer_rank, hostname }) => {
                        (writer_rank, hostname)
                    }
                    _ => bail!("no HelloAck from {addr}"),
                };
            writers.push(WriterConn {
                conn,
                writer_rank,
                hostname,
                pending: VecDeque::new(),
                closed: false,
            });
        }
        Ok(SstReader {
            opts,
            writers,
            current: None,
            stats: SstStats::default(),
            next_req_id: 1,
            gets: GetQueue::default(),
            ops_stats: OpsReport::default(),
            plan: PlanScratch::default(),
            steps_skipped: 0,
        })
    }

    pub fn stats(&self) -> SstStats {
        self.stats
    }

    /// Pump one writer connection until it has an announce (>= `min_step`)
    /// or closes. Returns false on timeout.
    fn pump_announce(
        w: &mut WriterConn,
        min_step: u64,
        deadline: std::time::Instant,
    ) -> Result<bool> {
        loop {
            if let Some((s, _)) = w.pending.front() {
                if *s >= min_step {
                    return Ok(true);
                }
                // Stale announce below the reconciliation target: drop it.
                w.pending.pop_front();
                continue;
            }
            if w.closed {
                return Ok(true);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(false);
            }
            match w.conn.recv_timeout(deadline - now)? {
                Recv::Msg(Msg::StepAnnounce { step, meta }) => {
                    w.pending.push_back((step, meta));
                }
                Recv::Msg(Msg::CloseStream) => {
                    w.closed = true;
                }
                Recv::Msg(_) => {
                    // Stray data from a previous step: ignore.
                }
                Recv::TimedOut => return Ok(false),
                Recv::Closed => {
                    w.closed = true;
                }
            }
        }
    }

    /// Merged chunk list of a variable in the current step.
    fn merged_chunks(&self, var: &str) -> Vec<WrittenChunkInfo> {
        let mut out = Vec::new();
        if let Some(cur) = &self.current {
            for meta in &cur.metas {
                for v in &meta.vars {
                    if v.name == var {
                        out.extend(v.chunks.iter().cloned());
                    }
                }
            }
        }
        out
    }

    /// Element size of a variable in the current step.
    fn elem_size(&self, var: &str) -> Result<usize> {
        self.current
            .iter()
            .flat_map(|c| c.metas.iter())
            .flat_map(|m| m.vars.iter())
            .find(|v| v.name == var)
            .map(|v| v.dtype.size())
            .ok_or_else(|| anyhow::anyhow!("unknown variable {var:?}"))
    }

    /// Receive one batched reply from writer `widx`, pumping other
    /// traffic (step announces, close notices) into the pending queues.
    fn recv_batch_reply(&mut self, widx: usize, req_id: u64)
        -> Result<Vec<GetReply>>
    {
        loop {
            match self.writers[widx].conn.recv()? {
                Recv::Msg(Msg::GetBatchReply { req_id: r, items })
                    if r == req_id =>
                {
                    return Ok(items)
                }
                Recv::Msg(Msg::StepAnnounce { step, meta }) => {
                    // Next steps arriving while we read this one.
                    self.writers[widx].pending.push_back((step, meta));
                }
                Recv::Msg(Msg::CloseStream) => {
                    self.writers[widx].closed = true;
                }
                Recv::Msg(_) => {}
                Recv::TimedOut => {}
                Recv::Closed => bail!(
                    "writer {} ({}) vanished mid-request",
                    self.writers[widx].writer_rank,
                    self.writers[widx].hostname
                ),
            }
        }
    }
}

impl Engine for SstReader {
    fn engine_type(&self) -> &'static str {
        "sst"
    }

    fn mode(&self) -> Mode {
        Mode::Read
    }

    /// Wait for the next step announced by *all* writers.
    ///
    /// Writers using a shared [`super::WriterGroup`] publish identical
    /// step sequences; without one, writers may discard different steps
    /// and the reader reconciles by advancing to the highest commonly
    /// announced step, counting skips in `steps_skipped`.
    fn begin_step(&mut self) -> Result<StepStatus> {
        if self.current.is_some() {
            bail!("begin_step while a step is open");
        }
        if self.writers.is_empty() {
            return Ok(StepStatus::EndOfStream);
        }
        let deadline =
            std::time::Instant::now() + self.opts.begin_step_timeout;
        let mut target = 0u64;
        // Reconcile until every live writer has announced `target`.
        loop {
            let mut all_ready = true;
            let mut any_live = false;
            for w in self.writers.iter_mut() {
                if !Self::pump_announce(w, target, deadline)? {
                    return Ok(StepStatus::NotReady);
                }
                // `pump_announce` only returns success with an empty
                // queue when the writer closed without announcing
                // `target`; either way an empty queue means this
                // writer contributes nothing to the step.
                let Some(&(s, _)) = w.pending.front() else {
                    continue;
                };
                any_live = true;
                if s > target {
                    self.steps_skipped += target.abs_diff(s).min(1);
                    target = s;
                    all_ready = false;
                }
            }
            if !any_live {
                return Ok(StepStatus::EndOfStream);
            }
            if all_ready {
                break;
            }
        }
        // Consume the pending announces.
        let mut metas = Vec::new();
        for w in self.writers.iter_mut() {
            if let Some((s, meta)) = w.pending.pop_front() {
                debug_assert_eq!(s, target);
                metas.push(meta);
            }
        }
        self.stats.steps_consumed += 1;
        self.current = Some(CurrentStep { step: target, metas });
        Ok(StepStatus::Ok)
    }

    fn define_variable(&mut self, _decl: &VarDecl) -> Result<VarHandle> {
        bail!("define_variable on a read-mode SST engine")
    }

    fn put_deferred(&mut self, _var: &VarHandle, _chunk: Chunk,
                    _data: Bytes) -> Result<()> {
        bail!("put on a read-mode SST engine")
    }

    fn put_span(&mut self, _var: &VarHandle, _chunk: Chunk)
        -> Result<&mut [u8]>
    {
        bail!("put_span on a read-mode SST engine")
    }

    fn perform_puts(&mut self) -> Result<()> {
        bail!("perform_puts on a read-mode SST engine")
    }

    fn put_attribute(&mut self, _name: &str, _value: Attribute) -> Result<()> {
        bail!("put_attribute on a read-mode SST engine")
    }

    fn available_variables(&self) -> Vec<VarInfo> {
        let mut seen = BTreeMap::new();
        if let Some(cur) = &self.current {
            for meta in &cur.metas {
                for v in &meta.vars {
                    seen.entry(v.name.clone()).or_insert_with(|| VarInfo {
                        name: v.name.clone(),
                        dtype: v.dtype,
                        shape: v.shape.clone(),
                        ops: v.ops.clone(),
                    });
                }
            }
        }
        seen.into_values().collect()
    }

    fn available_chunks(&self, var: &str) -> Vec<WrittenChunkInfo> {
        self.merged_chunks(var)
    }

    fn attribute(&self, name: &str) -> Option<Attribute> {
        let cur = self.current.as_ref()?;
        cur.metas
            .iter()
            .find_map(|m| m.attributes.get(name).cloned())
    }

    fn attribute_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .current
            .iter()
            .flat_map(|c| c.metas.iter())
            .flat_map(|m| m.attributes.keys().cloned())
            .collect();
        names.sort();
        names.dedup();
        names
    }

    /// Enqueue a selection load. Coverage is validated up front so a
    /// selection no announced chunk can satisfy fails fast, before any
    /// wire traffic.
    fn get_deferred(&mut self, var: &str, selection: Chunk)
        -> Result<GetHandle>
    {
        if self.current.is_none() {
            bail!("get outside step");
        }
        self.elem_size(var)?; // unknown-variable check
        let covered: u64 = self
            .merged_chunks(var)
            .iter()
            .filter_map(|info| info.chunk.intersect(&selection))
            .map(|c| c.num_elements())
            .sum();
        if covered < selection.num_elements() {
            bail!(
                "selection {:?}+{:?} of {var:?} not fully covered by \
                 announced chunks ({covered}/{})",
                selection.offset,
                selection.extent,
                selection.num_elements()
            );
        }
        Ok(self.gets.defer(var, selection))
    }

    /// Execute the whole deferred batch: one `GetBatch` request per
    /// owning writer for *all* batched selections, then one reply per
    /// writer, then reassembly. Only writers owning intersecting chunks
    /// are contacted — the paper's "connections only between instances
    /// that exchange data".
    fn perform_gets(&mut self) -> Result<()> {
        let pending: Vec<DeferredGet> = self.gets.drain_pending();
        if pending.is_empty() {
            return Ok(());
        }
        match self.perform_batch(&pending) {
            Ok(()) => Ok(()),
            Err(e) => {
                // A mid-batch failure (reply-count mismatch, writer-side
                // error item, vanished writer) must not leave the
                // already-drained gets dangling: poison every handle of
                // the batch so a later `take_get` reports this error
                // instead of "unknown handle".
                self.gets.fail_batch(&pending, &e);
                Err(e)
            }
        }
    }

    fn take_get(&mut self, handle: GetHandle) -> Result<Bytes> {
        self.gets.take(handle)
    }

    fn end_step(&mut self) -> Result<()> {
        // Deferred gets that were never performed are dropped: their
        // handles could no longer be redeemed after the step closes, so
        // fetching them here would move bytes straight into the void.
        self.gets.reset();
        let cur = self
            .current
            .take()
            .ok_or_else(|| anyhow::anyhow!("end_step without begin_step"))?;
        for w in self.writers.iter_mut() {
            if !w.closed {
                let _ = w.conn.send(Msg::StepDone { step: cur.step });
            }
        }
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        if self.current.is_some() {
            self.end_step()?;
        }
        for w in self.writers.iter_mut() {
            if !w.closed {
                let _ = w.conn.send(Msg::ReaderBye);
                w.closed = true;
            }
        }
        self.writers.clear();
        Ok(())
    }

    fn ops_report(&self) -> OpsReport {
        self.ops_stats
    }
}

impl SstReader {
    /// The body of [`Engine::perform_gets`] for one drained batch; on
    /// error the caller poisons every handle in `pending`.
    fn perform_batch(&mut self, pending: &[DeferredGet]) -> Result<()> {
        // Span covers the full round trip: plan, pipelined requests,
        // replies, reassembly. The reader holds no locks here.
        let mut sp = trace::span("sst.get_batch").with("gets", pending.len());
        // Merge each requested variable's chunk table ONCE per batch
        // instead of once per deferred get: a fleet worker batches one
        // slice set per variable per step, and with N writers x many
        // slices the repeated metadata sweep was the plan-phase cost.
        // The merge writes into `self.plan`, reusable scratch that
        // keeps its allocations across batches.
        self.plan.reset();
        let step;
        {
            let cur = self.current.as_ref().ok_or_else(|| {
                anyhow::anyhow!("perform_gets outside step")
            })?;
            step = cur.step;
            for g in pending {
                if self.plan.find(&g.var).is_some() {
                    continue;
                }
                let mut claimed = false;
                for meta in &cur.metas {
                    for v in &meta.vars {
                        if v.name != g.var {
                            continue;
                        }
                        if !claimed {
                            claimed = true;
                            let slot = self.plan.open_slot(&g.var);
                            slot.elem = v.dtype.size();
                            slot.dtype = v.dtype;
                            slot.ops.clone_from(&v.ops);
                        }
                        let li = self.plan.live - 1;
                        self.plan.vars[li]
                            .chunks
                            .extend(v.chunks.iter().cloned());
                    }
                }
                if !claimed {
                    bail!("unknown variable {:?}", g.var);
                }
            }
        }

        // Plan: for every deferred get, the (writer, intersection)
        // parts; grouped per writer into one batched request.
        struct Part {
            get_idx: usize,
            sel: Chunk,
        }
        let mut per_writer: BTreeMap<usize, Vec<Part>> = BTreeMap::new();
        let mut vt_idx = Vec::with_capacity(pending.len());
        let mut part_count = vec![0usize; pending.len()];
        for (gi, g) in pending.iter().enumerate() {
            let vi = self.plan.find(&g.var).ok_or_else(|| {
                anyhow::anyhow!("unknown variable {:?}", g.var)
            })?;
            vt_idx.push(vi);
            let vt = &self.plan.vars[vi];
            let mut covered = 0u64;
            for info in &vt.chunks {
                if let Some(inter) = info.chunk.intersect(&g.selection) {
                    covered += inter.num_elements();
                    part_count[gi] += 1;
                    per_writer
                        .entry(info.source_rank)
                        .or_default()
                        .push(Part { get_idx: gi, sel: inter });
                }
            }
            if covered < g.selection.num_elements() {
                bail!(
                    "selection {:?}+{:?} of {:?} not fully covered by \
                     announced chunks ({covered}/{})",
                    g.selection.offset,
                    g.selection.extent,
                    g.var,
                    g.selection.num_elements()
                );
            }
        }

        // Send one batched request per writer (pipelined: all requests
        // go out before any reply is awaited).
        sp.set("step", step);
        sp.set("writers", per_writer.len());
        let mut sent: Vec<(usize, u64, Vec<Part>)> = Vec::new();
        for (writer_rank, parts) in per_writer {
            let widx = self
                .writers
                .iter()
                .position(|w| w.writer_rank == writer_rank)
                .ok_or_else(|| {
                    anyhow::anyhow!("no connection to writer {writer_rank}")
                })?;
            let req_id = self.next_req_id;
            self.next_req_id += 1;
            let items: Vec<GetItem> = parts
                .iter()
                .map(|p| GetItem {
                    var: pending[p.get_idx].var.clone(),
                    sel: p.sel.clone(),
                })
                .collect();
            self.stats.chunk_requests += items.len() as u64;
            self.stats.batch_requests += 1;
            self.writers[widx]
                .conn
                .send(Msg::GetBatch { req_id, step, items })?;
            sent.push((widx, req_id, parts));
        }

        // Collect one reply per writer and assemble. A get whose single
        // part IS its selection passes the writer's Arc through
        // untouched (zero-copy on inproc).
        let mut passthrough: Vec<Option<Bytes>> = vec![None; pending.len()];
        let mut buffers: Vec<Option<pool::PooledBuf>> = Vec::new();
        buffers.resize_with(pending.len(), || None);
        let mut batch_bytes = 0u64;
        let mut reassembly_allocs = 0u64;
        for (widx, req_id, parts) in sent {
            let replies = self.recv_batch_reply(widx, req_id)?;
            self.stats.data_messages += 1;
            if replies.len() != parts.len() {
                bail!(
                    "writer {} replied {} items to a {}-item batch",
                    self.writers[widx].writer_rank,
                    replies.len(),
                    parts.len()
                );
            }
            for (part, reply) in parts.iter().zip(replies) {
                let data = match reply {
                    GetReply::Data(d) => {
                        self.stats.bytes_got += d.len() as u64;
                        batch_bytes += d.len() as u64;
                        d
                    }
                    GetReply::Encoded(d) => {
                        // Operator-framed wire payload: fewer bytes
                        // moved, one decode here. The frame's declared
                        // raw size must match what this part's
                        // selection needs.
                        self.stats.bytes_got += d.len() as u64;
                        batch_bytes += d.len() as u64;
                        let pv = &self.plan.vars[vt_idx[part.get_idx]];
                        let raw = ops::decode_get(&pv.ops, pv.dtype,
                                                  &part.sel, &d,
                                                  &mut self.ops_stats)
                            .map_err(|e| anyhow::anyhow!(
                                "writer {}: {e}",
                                self.writers[widx].writer_rank
                            ))?;
                        // The framed wire buffer is dead once decoded.
                        pool::reclaim_bytes(d);
                        raw
                    }
                    GetReply::Error(e) => bail!(
                        "writer {} failed request: {e}",
                        self.writers[widx].writer_rank
                    ),
                };
                let g = &pending[part.get_idx];
                if part_count[part.get_idx] == 1
                    && part.sel == g.selection
                {
                    passthrough[part.get_idx] = Some(data);
                    continue;
                }
                let elem = self.plan.vars[vt_idx[part.get_idx]].elem;
                if buffers[part.get_idx].is_none() {
                    let b = pool::acquire_zeroed(
                        g.selection.num_elements() as usize * elem,
                    );
                    reassembly_allocs += b.fresh() as u64;
                    buffers[part.get_idx] = Some(b);
                }
                if let Some(buf) = buffers[part.get_idx].as_mut() {
                    let copied = region::copy_region(
                        &part.sel, &data, &g.selection, buf, elem,
                    );
                    debug_assert_eq!(copied, part.sel.num_elements());
                }
                // The part's wire payload is dead after the copy.
                pool::reclaim_bytes(data);
            }
        }

        for (gi, g) in pending.iter().enumerate() {
            let data = match passthrough[gi].take() {
                Some(d) => d,
                None => match buffers[gi].take() {
                    Some(b) => Arc::new(b.detach()),
                    None => Arc::new(Vec::new()),
                },
            };
            self.gets.complete(g.handle, data);
        }
        self.ops_stats.allocations += reassembly_allocs;
        GET_BATCHES.inc();
        GET_BYTES.add(batch_bytes);
        sp.set("bytes", batch_bytes);
        Ok(())
    }
}

impl Drop for SstReader {
    fn drop(&mut self) {
        let _ = self.close();
    }
}
