//! SST writer: stages steps in memory and serves batched chunk requests.
//!
//! Two-phase write side: `put_deferred` / `put_span` enqueue into the
//! engine's [`PutQueue`]; `perform_puts` (implied by `end_step`) moves
//! the batch into the staged step in one pass. A step discarded under
//! backpressure drops its deferred queue wholesale — no data movement.
//! On the serving side one `GetBatch` request yields one `GetBatchReply`
//! carrying every selection the reader deferred — one wire message per
//! reader pair per step.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Result};
use once_cell::sync::Lazy;

use crate::obs::metrics::{counter, Counter};
use crate::obs::trace;
use crate::util::pool;
use crate::util::sync::{
    classes, OrderedCondvar, OrderedGuard, OrderedMutex,
};

use crate::adios::engine::{
    Bytes, Engine, GetHandle, Mode, PutQueue, StepStatus, VarDecl,
    VarHandle, VarInfo,
};
use crate::adios::ops::{self, OpCtx, OpsReport};
use crate::adios::region;
use crate::adios::transport::{self, ConnTx, Recv};
use crate::adios::wire::{GetReply, Msg, VarMeta};
use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};
use crate::openpmd::Attribute;

use super::{QueueConfig, QueueFullPolicy, SstStats, StagedStep};

// Interned obs handles (registry lock touched once, at first deref).
static PUT_BYTES: Lazy<&'static Counter> =
    Lazy::new(|| counter("sst.put_bytes"));
static STAGED_BYTES: Lazy<&'static Counter> =
    Lazy::new(|| counter("sst.staged_bytes"));
static ANNOUNCES: Lazy<&'static Counter> =
    Lazy::new(|| counter("sst.announce_msgs"));
static SERVE_BATCHES: Lazy<&'static Counter> =
    Lazy::new(|| counter("sst.serve_batches"));
static SERVE_BYTES: Lazy<&'static Counter> =
    Lazy::new(|| counter("sst.serve_bytes"));

/// Options for opening a writer.
#[derive(Clone)]
pub struct SstWriterOptions {
    /// Listen hint: `inproc://name` or `tcp://host:port` (port 0 ok).
    pub listen: String,
    /// Transport name: `"inproc"` or `"tcp"`.
    pub transport: String,
    /// This writer's parallel rank within the producing application.
    pub rank: usize,
    /// Hostname used for topology-aware distribution.
    pub hostname: String,
    pub queue: QueueConfig,
    /// Optional collective-discard group shared by all writer ranks of one
    /// application (the MPI analog).
    pub group: Option<Arc<WriterGroup>>,
    /// How long `close` lingers for readers to subscribe and drain the
    /// staged steps before tearing the stream down. Readers that arrive
    /// within the linger still see every staged step.
    pub close_linger: Duration,
}

impl Default for SstWriterOptions {
    fn default() -> Self {
        SstWriterOptions {
            listen: String::new(),
            transport: "inproc".into(),
            rank: 0,
            hostname: "localhost".into(),
            queue: QueueConfig::default(),
            group: None,
            close_linger: Duration::from_secs(10),
        }
    }
}

/// Collective discard decisions across the writer ranks of one
/// application: the first rank to reach a step index decides (based on its
/// own queue occupancy) and the others follow, so all ranks publish the
/// same step sequence.
pub struct WriterGroup {
    decisions: OrderedMutex<HashMap<u64, bool>>,
}

impl Default for WriterGroup {
    fn default() -> WriterGroup {
        WriterGroup {
            decisions: OrderedMutex::new(
                &classes::SST_GROUP_DECISIONS,
                HashMap::new(),
            ),
        }
    }
}

impl WriterGroup {
    pub fn new() -> Arc<WriterGroup> {
        Arc::new(WriterGroup::default())
    }

    /// Returns `true` if step `step` should be kept (published).
    fn decide(
        &self,
        step: u64,
        keep_if_first: impl FnOnce() -> bool,
    ) -> Result<bool> {
        let mut d = self.decisions.lock()?;
        Ok(*d.entry(step).or_insert_with(keep_if_first))
    }
}

/// Service-thread lock helper: threads with no `Result` channel back to
/// the producer log the poison and bow out instead of re-panicking.
fn lock_or_warn<T>(m: &OrderedMutex<T>) -> Option<OrderedGuard<'_, T>> {
    match m.lock() {
        Ok(g) => Some(g),
        Err(e) => {
            crate::warn_log!("sst-writer", "{e}; stopping service thread");
            None
        }
    }
}

struct ReaderPeer {
    tx: OrderedMutex<Box<dyn ConnTx>>,
    /// Highest step this reader has fully consumed (StepDone).
    done: AtomicU64,
    alive: AtomicBool,
    /// Reader rank, named in the serve thread's diagnostics.
    rank: usize,
    /// Operator codecs this reader advertised in its Hello (operator
    /// negotiation): chains outside this set are served decoded.
    codecs: Vec<String>,
}

#[derive(Default)]
struct Shared {
    /// step -> staged payloads+meta, in publish order.
    published: BTreeMap<u64, Arc<StagedStep>>,
    readers: Vec<Arc<ReaderPeer>>,
    stats: SstStats,
    /// Operator accounting: encode side of `perform_puts` plus any
    /// decode/re-encode the serve threads do for partial selections or
    /// codec-less readers.
    ops: OpsReport,
    closed: bool,
    /// At least one reader completed the handshake at some point.
    ever_had_reader: bool,
}

/// The writer engine. One instance per producing rank and stream.
pub struct SstWriter {
    opts: SstWriterOptions,
    address: String,
    shared: Arc<OrderedMutex<Shared>>,
    /// Signalled when a step retires or a reader joins/leaves.
    retire_cv: Arc<OrderedCondvar>,
    accept_thread: Option<JoinHandle<()>>,
    service_threads: Arc<OrderedMutex<Vec<JoinHandle<()>>>>,
    stop: Arc<AtomicBool>,
    /// Step being built between begin_step/end_step.
    current: Option<StagedStep>,
    /// Variable registry + deferred-put queue (two-phase API).
    puts: PutQueue,
    next_step: u64,
    /// True if begin_step returned Discarded for the current step.
    discarding: bool,
}

impl SstWriter {
    /// Open the stream and start accepting readers.
    pub fn open(opts: SstWriterOptions) -> Result<SstWriter> {
        let transport = transport::by_name(&opts.transport)?;
        let mut listener = transport.listen(&opts.listen)?;
        let address = listener.address();
        let shared = Arc::new(OrderedMutex::new(
            &classes::SST_WRITER_SHARED,
            Shared::default(),
        ));
        let retire_cv =
            Arc::new(OrderedCondvar::new(&classes::SST_WRITER_SHARED));
        let stop = Arc::new(AtomicBool::new(false));
        let service_threads = Arc::new(OrderedMutex::new(
            &classes::SST_SERVICE_THREADS,
            Vec::new(),
        ));

        let accept_thread = {
            let shared = shared.clone();
            let stop = stop.clone();
            let cv = retire_cv.clone();
            let threads = service_threads.clone();
            let writer_rank = opts.rank;
            let hostname = opts.hostname.clone();
            std::thread::Builder::new()
                .name(format!("sst-accept-{}", opts.rank))
                .spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        match listener.accept_timeout(Duration::from_millis(50))
                        {
                            Ok(Some(conn)) => {
                                if let Err(e) = serve_reader(
                                    conn, &shared, &cv, &threads,
                                    writer_rank, &hostname, &stop,
                                ) {
                                    crate::warn_log!(
                                        "sst-writer",
                                        "reader handshake failed: {e:#}"
                                    );
                                }
                            }
                            Ok(None) => {}
                            Err(e) => {
                                crate::warn_log!("sst-writer",
                                                 "accept error: {e:#}");
                                break;
                            }
                        }
                    }
                })?
        };

        Ok(SstWriter {
            opts,
            address,
            shared,
            retire_cv,
            accept_thread: Some(accept_thread),
            service_threads,
            stop,
            current: None,
            puts: PutQueue::default(),
            next_step: 0,
            discarding: false,
        })
    }

    /// The resolved address readers should dial.
    pub fn address(&self) -> String {
        self.address.clone()
    }

    pub fn stats(&self) -> Result<SstStats> {
        Ok(self.shared.lock()?.stats)
    }

    /// Number of currently subscribed readers.
    pub fn reader_count(&self) -> Result<usize> {
        Ok(self
            .shared
            .lock()?
            .readers
            .iter()
            .filter(|r| r.alive.load(Ordering::Relaxed))
            .count())
    }

    /// Queue occupancy check + retirement: drop steps every live reader
    /// has consumed. Called with the lock held.
    fn retire_locked(shared: &mut Shared) {
        let live: Vec<&Arc<ReaderPeer>> = shared
            .readers
            .iter()
            .filter(|r| r.alive.load(Ordering::Relaxed))
            .collect();
        if live.is_empty() {
            return;
        }
        let min_done = live
            .iter()
            .map(|r| r.done.load(Ordering::Relaxed))
            .min()
            .unwrap_or(0);
        // done stores step+1 so that 0 means "nothing consumed".
        let retained: Vec<u64> = shared
            .published
            .keys()
            .copied()
            .filter(|&s| s < min_done)
            .collect();
        for s in retained {
            shared.published.remove(&s);
        }
    }

    fn queue_has_room(&self) -> Result<bool> {
        let mut shared = self.shared.lock()?;
        Self::retire_locked(&mut shared);
        Ok(shared.published.len() < self.opts.queue.limit)
    }
}

/// Per-reader service: handshake, then answer requests until the reader
/// leaves. The rx half blocks in its own thread; the tx half lives in the
/// peer table so `end_step` can push announcements.
fn serve_reader(
    conn: Box<dyn transport::Conn>,
    shared: &Arc<OrderedMutex<Shared>>,
    cv: &Arc<OrderedCondvar>,
    threads: &Arc<OrderedMutex<Vec<JoinHandle<()>>>>,
    writer_rank: usize,
    hostname: &str,
    stop: &Arc<AtomicBool>,
) -> Result<()> {
    let mut conn = conn;
    // Handshake happens synchronously on the accept thread.
    let (hello, codecs) = match conn.recv_timeout(Duration::from_secs(10))?
    {
        Recv::Msg(Msg::Hello { reader_rank, codecs, .. }) => {
            (reader_rank, codecs)
        }
        other => bail!(
            "expected Hello, got {:?}",
            std::mem::discriminant(&match other {
                Recv::Msg(m) => m,
                _ => Msg::CloseStream,
            })
        ),
    };
    conn.send(Msg::HelloAck { writer_rank, hostname: hostname.into() })?;
    let (tx, mut rx) = conn.split()?;

    let peer = Arc::new(ReaderPeer {
        tx: OrderedMutex::new(&classes::SST_PEER_TX, tx),
        done: AtomicU64::new(0),
        alive: AtomicBool::new(true),
        rank: hello,
        codecs,
    });

    // Late joiners see the currently staged steps. Backlog replay and
    // peer registration happen in ONE critical section: a step published
    // between the two would otherwise be announced to nobody — not in
    // the backlog, and the reader not yet in the peer table.
    {
        let mut sh = shared.lock()?;
        let mut backlog: Vec<Msg> = sh
            .published
            .iter()
            .map(|(step, staged)| Msg::StepAnnounce {
                step: *step,
                meta: staged.meta.clone(),
            })
            .collect();
        if sh.closed {
            backlog.push(Msg::CloseStream);
        }
        let mut tx = peer.tx.lock()?;
        for m in backlog {
            // lint:allow(lock-across-blocking): the backlog must go
            // out under the registration lock, or a concurrent
            // end_step could publish a step this reader never hears
            // about
            tx.send(m)?;
        }
        drop(tx);
        sh.readers.push(peer.clone());
        sh.ever_had_reader = true;
    }
    cv.notify_all();

    let shared = shared.clone();
    let cv = cv.clone();
    let stop = stop.clone();
    let handle = std::thread::Builder::new()
        .name(format!("sst-serve-r{hello}"))
        .spawn(move || {
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                match rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(Recv::Msg(Msg::GetBatch { req_id, step, items })) => {
                        // Span opened before any lock: it covers the
                        // whole request turnaround (lock waits, codec
                        // work, the reply send) in one event.
                        let mut sp = trace::span("sst.serve_batch")
                            .with("step", step)
                            .with("reader", peer.rank)
                            .with("items", items.len());
                        // Grab the staged step's Arc under the lock, but
                        // serve (extract/decode/re-encode — potentially
                        // CPU-bound codec work) OUTSIDE it, so concurrent
                        // readers and the producer's perform_puts never
                        // serialize on compression.
                        let staged = {
                            let Some(mut sh) = lock_or_warn(&shared)
                            else {
                                break;
                            };
                            sh.stats.batch_requests += 1;
                            sh.stats.chunk_requests += items.len() as u64;
                            sh.published.get(&step).cloned()
                        };
                        let mut local_ops = OpsReport::default();
                        let mut served_bytes = 0u64;
                        let mut replies = Vec::with_capacity(items.len());
                        for item in &items {
                            let served = match &staged {
                                None => Err(anyhow::anyhow!(
                                    "step {step} not staged (retired?)"
                                )),
                                Some(staged) => serve_request(
                                    staged, &item.var, &item.sel,
                                    &peer.codecs, &mut local_ops,
                                ),
                            };
                            match served {
                                Ok(r) => {
                                    served_bytes += match &r {
                                        GetReply::Data(d) => d.len(),
                                        GetReply::Encoded(d) => d.len(),
                                        GetReply::Error(_) => 0,
                                    } as u64;
                                    replies.push(r);
                                }
                                Err(e) => replies.push(
                                    GetReply::Error(format!("{e:#}")),
                                ),
                            }
                        }
                        SERVE_BATCHES.inc();
                        SERVE_BYTES.add(served_bytes);
                        sp.set("bytes", served_bytes);
                        {
                            let Some(mut sh) = lock_or_warn(&shared)
                            else {
                                break;
                            };
                            sh.stats.bytes_served += served_bytes;
                            sh.stats.data_messages += 1;
                            sh.ops.absorb(local_ops);
                        }
                        let reply =
                            Msg::GetBatchReply { req_id, items: replies };
                        let sent = match peer.tx.lock() {
                            Ok(mut tx) => tx.send(reply).is_ok(),
                            Err(_) => false,
                        };
                        if !sent {
                            break;
                        }
                    }
                    Ok(Recv::Msg(Msg::StepDone { step })) => {
                        // done holds step+1 (see retire_locked).
                        peer.done.fetch_max(step + 1, Ordering::Relaxed);
                        let Some(mut sh) = lock_or_warn(&shared) else {
                            break;
                        };
                        SstWriter::retire_locked(&mut sh);
                        drop(sh);
                        cv.notify_all();
                    }
                    Ok(Recv::Msg(Msg::ReaderBye)) | Ok(Recv::Closed) => break,
                    Ok(Recv::TimedOut) => {}
                    Ok(Recv::Msg(other)) => {
                        crate::warn_log!(
                            "sst-writer",
                            "unexpected message from reader {}: tag-ish {:?}",
                            peer.rank,
                            std::mem::discriminant(&other)
                        );
                    }
                    Err(e) => {
                        crate::warn_log!(
                            "sst-writer",
                            "reader {} recv error: {e:#}",
                            peer.rank
                        );
                        break;
                    }
                }
            }
            peer.alive.store(false, Ordering::Relaxed);
            if let Some(mut sh) = lock_or_warn(&shared) {
                SstWriter::retire_locked(&mut sh);
            }
            cv.notify_all();
        })?;
    threads.lock()?.push(handle);
    Ok(())
}

/// Extract `sel` of `var` from a staged step (lock held by caller).
///
/// Chunks of operated variables are staged operator-framed. An
/// exact-chunk selection to a codec-capable reader passes the staged
/// frame through untouched (one encode at `perform_puts`, zero work per
/// reader — the compressed analog of the inproc zero-copy). A partial
/// selection decodes the overlapping chunks, assembles raw bytes, and
/// re-encodes for the wire; readers that did not advertise the chain's
/// codecs get decoded raw bytes instead.
///
/// `pub(crate)`: the `pipeline::serve` fan-out daemon answers its
/// subscribers' `GetBatch` requests through this same resolution, so
/// direct SST subscription and daemon subscription stay byte-identical
/// by construction.
pub(crate) fn serve_request(
    staged: &StagedStep,
    var: &str,
    sel: &Chunk,
    peer_codecs: &[String],
    ops_stats: &mut OpsReport,
) -> Result<GetReply> {
    let chunks = staged
        .data
        .get(var)
        .ok_or_else(|| anyhow::anyhow!("no such variable {var:?}"))?;
    let vm = staged
        .meta
        .vars
        .iter()
        .find(|v| v.name == var)
        .ok_or_else(|| anyhow::anyhow!("no metadata for {var:?}"))?;
    let elem = vm.dtype.size();
    if vm.ops.is_identity() {
        // Fast path: a stored chunk *is* the selection -> hand back the
        // Arc without copying.
        for (chunk, data) in chunks {
            if chunk == sel {
                return Ok(GetReply::Data(data.clone()));
            }
        }
        let mut out =
            pool::acquire_zeroed(sel.num_elements() as usize * elem);
        ops_stats.allocations += out.fresh() as u64;
        let mut covered = 0u64;
        for (chunk, data) in chunks {
            covered +=
                region::copy_region(chunk, data, sel, &mut out, elem);
        }
        if covered < sel.num_elements() {
            bail!(
                "selection {:?}+{:?} of {var:?} only partially present \
                 at this writer ({covered}/{} elements)",
                sel.offset,
                sel.extent,
                sel.num_elements()
            );
        }
        return Ok(GetReply::Data(Arc::new(out.detach())));
    }

    let peer_ok = vm.ops.supported_by(peer_codecs);
    if peer_ok {
        // Exact-chunk passthrough of the staged frame.
        for (chunk, data) in chunks {
            if chunk == sel {
                return Ok(GetReply::Encoded(data.clone()));
            }
        }
    }
    // Assemble the selection raw from decoded chunks.
    let mut out =
        pool::acquire_zeroed(sel.num_elements() as usize * elem);
    ops_stats.allocations += out.fresh() as u64;
    let mut covered = 0u64;
    for (chunk, data) in chunks {
        if chunk.intersect(sel).is_none() {
            continue;
        }
        let raw = ops::decode_get(&vm.ops, vm.dtype, chunk, data,
                                  ops_stats)
            .map_err(|e| anyhow::anyhow!("{var}: {e}"))?;
        covered += region::copy_region(chunk, &raw, sel, &mut out, elem);
        // Decode scratch is chunk-local: recycle it for the next one.
        pool::reclaim_bytes(raw);
    }
    if covered < sel.num_elements() {
        bail!(
            "selection {:?}+{:?} of {var:?} only partially present at \
             this writer ({covered}/{} elements)",
            sel.offset,
            sel.extent,
            sel.num_elements()
        );
    }
    if peer_ok {
        let octx = OpCtx { dtype: vm.dtype, extent: &sel.extent };
        let framed =
            ops::encode_bytes(&vm.ops, &octx, &out, ops_stats).map_err(
                |e| anyhow::anyhow!("{var}: operator encode: {e}"),
            )?;
        Ok(GetReply::Encoded(framed))
    } else {
        Ok(GetReply::Data(Arc::new(out.detach())))
    }
}

impl Engine for SstWriter {
    fn engine_type(&self) -> &'static str {
        "sst"
    }

    fn mode(&self) -> Mode {
        Mode::Write
    }

    fn begin_step(&mut self) -> Result<StepStatus> {
        if self.current.is_some() {
            bail!("begin_step while a step is open");
        }
        if self.discarding {
            // Previous discarded step was never end_step'ed: drop its
            // deferred queue now.
            self.discarding = false;
            self.puts.discard();
        }
        let step = self.next_step;
        let has_room = self.queue_has_room()?;
        let keep = match (&self.opts.group, self.opts.queue.policy) {
            (Some(group), QueueFullPolicy::Discard) => {
                group.decide(step, || has_room)?
            }
            (None, QueueFullPolicy::Discard) => has_room,
            (_, QueueFullPolicy::Block) => {
                // Block until the queue drains.
                let mut sh = self.shared.lock()?;
                loop {
                    Self::retire_locked(&mut sh);
                    if sh.published.len() < self.opts.queue.limit {
                        break;
                    }
                    let (guard, timeout) = self.retire_cv.wait_timeout(
                        sh,
                        Duration::from_millis(200),
                    )?;
                    sh = guard;
                    if timeout.timed_out() && sh.closed {
                        bail!("writer closed while blocked on full queue");
                    }
                }
                true
            }
        };
        if !keep {
            self.next_step += 1;
            self.discarding = true;
            self.shared.lock()?.stats.steps_discarded += 1;
            return Ok(StepStatus::Discarded);
        }
        self.discarding = false;
        self.current = Some(StagedStep::default());
        Ok(StepStatus::Ok)
    }

    fn define_variable(&mut self, decl: &VarDecl) -> Result<VarHandle> {
        self.puts.define(decl)
    }

    fn put_deferred(&mut self, var: &VarHandle, chunk: Chunk, data: Bytes)
        -> Result<()>
    {
        if self.current.is_none() && !self.discarding {
            bail!("put outside step");
        }
        self.puts.enqueue(var, chunk, data)
    }

    fn put_span(&mut self, var: &VarHandle, chunk: Chunk)
        -> Result<&mut [u8]>
    {
        if self.current.is_none() && !self.discarding {
            bail!("put_span outside step");
        }
        self.puts.span(var, chunk)
    }

    fn perform_puts(&mut self) -> Result<()> {
        if self.discarding {
            // Discarded step: the whole deferred queue is dropped before
            // any data movement — the producer continues unblocked.
            self.puts.discard();
            return Ok(());
        }
        let pending = self.puts.drain();
        if pending.is_empty() {
            return Ok(());
        }
        let mut sp = trace::span("sst.perform_puts")
            .with("step", self.next_step)
            .with("chunks", pending.len());
        let staged = self
            .current
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("perform_puts outside step"))?;
        let mut put_bytes = 0u64;
        let mut staged_bytes = 0u64;
        let mut local_ops = OpsReport::default();
        for p in pending {
            // Operated chunks are staged encoded: the chain runs once
            // here, and the staging queue itself holds fewer bytes.
            // `bytes_put` keeps counting raw produced bytes.
            put_bytes += p.data.len() as u64;
            let data =
                ops::encode_put(&p.var, &p.chunk, p.data, &mut local_ops)?;
            // Announce the staged size: readers planning a cost-aware
            // distribution then balance the bytes that will actually
            // cross the wire, not just element counts.
            let info = WrittenChunkInfo::new(
                p.chunk.clone(),
                self.opts.rank,
                self.opts.hostname.clone(),
            )
            .with_encoded_bytes(data.len() as u64);
            staged_bytes += data.len() as u64;
            match staged
                .meta
                .vars
                .iter_mut()
                .find(|v| v.name == p.var.name())
            {
                Some(vm) => vm.chunks.push(info),
                None => staged.meta.vars.push(VarMeta {
                    name: p.var.name().to_string(),
                    dtype: p.var.dtype(),
                    shape: p.var.shape().to_vec(),
                    ops: p.var.ops().clone(),
                    chunks: vec![info],
                }),
            }
            staged
                .data
                .entry(p.var.name().to_string())
                .or_default()
                .push((p.chunk, data));
        }
        PUT_BYTES.add(put_bytes);
        STAGED_BYTES.add(staged_bytes);
        sp.set("bytes", put_bytes);
        sp.set("staged_bytes", staged_bytes);
        let mut sh = self.shared.lock()?;
        sh.stats.bytes_put += put_bytes;
        sh.ops.absorb(local_ops);
        Ok(())
    }

    fn put_attribute(&mut self, name: &str, value: Attribute) -> Result<()> {
        if self.discarding {
            return Ok(()); // discarded step: metadata is dropped too
        }
        let staged = self
            .current
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("put_attribute outside step"))?;
        staged.meta.attributes.insert(name.to_string(), value);
        Ok(())
    }

    fn available_variables(&self) -> Vec<VarInfo> {
        Vec::new() // write side
    }

    fn available_chunks(&self, _var: &str) -> Vec<WrittenChunkInfo> {
        Vec::new()
    }

    fn attribute(&self, _name: &str) -> Option<Attribute> {
        None
    }

    fn attribute_names(&self) -> Vec<String> {
        Vec::new()
    }

    fn get_deferred(&mut self, _var: &str, _selection: Chunk)
        -> Result<GetHandle>
    {
        bail!("get on a write-mode SST engine")
    }

    fn perform_gets(&mut self) -> Result<()> {
        bail!("perform_gets on a write-mode SST engine")
    }

    fn take_get(&mut self, _handle: GetHandle) -> Result<Bytes> {
        bail!("take_get on a write-mode SST engine")
    }

    fn end_step(&mut self) -> Result<()> {
        if self.discarding {
            self.discarding = false;
            self.puts.discard();
            return Ok(());
        }
        self.perform_puts()?;
        let staged = self
            .current
            .take()
            .ok_or_else(|| anyhow::anyhow!("end_step without begin_step"))?;
        let step = self.next_step;
        self.next_step += 1;
        let staged = Arc::new(staged);
        // Publish under the lock, announce outside it: a slow reader
        // socket must not stall the service threads on `shared`. A
        // reader joining after the snapshot replays the freshly inserted
        // step from the backlog instead (see serve_reader), so every
        // peer hears about the step exactly once.
        let mut sh = self.shared.lock()?;
        sh.stats.steps_published += 1;
        sh.published.insert(step, staged.clone());
        let peers: Vec<Arc<ReaderPeer>> = sh
            .readers
            .iter()
            .filter(|r| r.alive.load(Ordering::Relaxed))
            .cloned()
            .collect();
        drop(sh);
        let _sp = trace::span("sst.announce")
            .with("step", step)
            .with("readers", peers.len());
        ANNOUNCES.add(peers.len() as u64);
        for r in peers {
            let ok = match r.tx.lock() {
                Ok(mut tx) => tx
                    .send(Msg::StepAnnounce {
                        step,
                        meta: staged.meta.clone(),
                    })
                    .is_ok(),
                Err(_) => false,
            };
            if !ok {
                r.alive.store(false, Ordering::Relaxed);
            }
        }
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        if self.current.is_some() || self.discarding {
            self.end_step()?;
        }
        // Same publish-then-announce split as end_step: flip `closed`
        // and snapshot the live peers under the lock, send CloseStream
        // outside it. Readers that join after the flip get CloseStream
        // appended to their backlog replay.
        let peers: Vec<Arc<ReaderPeer>> = {
            let mut sh = self.shared.lock()?;
            if sh.closed {
                return Ok(());
            }
            sh.closed = true;
            sh.readers
                .iter()
                .filter(|r| r.alive.load(Ordering::Relaxed))
                .cloned()
                .collect()
        };
        for r in peers {
            if let Ok(mut tx) = r.tx.lock() {
                let _ = tx.send(Msg::CloseStream);
            }
        }
        // Linger so that (a) readers that already subscribed can finish
        // draining the staged steps, and (b) readers whose handshake is
        // still in flight are not stranded mid-connect.
        let deadline = std::time::Instant::now() + self.opts.close_linger;
        loop {
            let mut sh = self.shared.lock()?;
            Self::retire_locked(&mut sh);
            if sh.published.is_empty() {
                break;
            }
            let live_readers = sh
                .readers
                .iter()
                .any(|r| r.alive.load(Ordering::Relaxed));
            if !live_readers && sh.ever_had_reader {
                // All subscribers consumed what they wanted and left.
                break;
            }
            let (guard, _) = self
                .retire_cv
                .wait_timeout(sh, Duration::from_millis(50))?;
            drop(guard);
            if std::time::Instant::now() > deadline {
                crate::warn_log!("sst-writer",
                                 "close linger expired with steps staged");
                break;
            }
        }
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let threads: Vec<_> =
            std::mem::take(&mut *self.service_threads.lock()?);
        for t in threads {
            let _ = t.join();
        }
        Ok(())
    }

    fn ops_report(&self) -> OpsReport {
        // The trait returns a bare report: on poison, report empty
        // rather than tearing the caller down for a diagnostics read.
        match self.shared.lock() {
            Ok(sh) => sh.ops,
            Err(e) => {
                crate::warn_log!("sst-writer", "{e}; reporting empty ops");
                OpsReport::default()
            }
        }
    }
}

impl Drop for SstWriter {
    fn drop(&mut self) {
        let _ = self.close();
    }
}
