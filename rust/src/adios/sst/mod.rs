//! SST — the sustainable staging transport (S4): streaming loose coupling.
//!
//! The engine the paper is about. A writer publishes steps into an
//! in-memory staging queue; readers subscribe dynamically and pull the
//! chunks they were assigned by a distribution strategy. Key semantics
//! reproduced from ADIOS2 SST:
//!
//! * **publish/subscribe**: any number of readers can register while the
//!   stream runs; each reader sees every published step (from its join
//!   time onward).
//! * **per-pair connections**: communication happens only between writer
//!   and reader instances that actually exchange data; a reader that
//!   requests nothing from a writer costs that writer nothing but the
//!   announcement.
//! * **`QueueFullPolicy`** (§4.1, footnote 12): when the staging queue is
//!   full because readers lag, `Discard` drops the *new* step before any
//!   data movement — the producer is never blocked and "IO granularity is
//!   automatically reduced"; `Block` applies backpressure instead.
//! * **queue retirement**: a step leaves the queue when every subscribed
//!   reader has called `end_step` on it.
//!
//! Writers of one parallel application can share a [`WriterGroup`] so the
//! discard decision is collective (the role MPI plays in ADIOS2) — without
//! it, writer ranks could discard different steps and readers would have
//! to skip unaligned steps.

mod reader;
mod writer;

pub use reader::{SstReader, SstReaderOptions};
pub use writer::{SstWriter, SstWriterOptions, WriterGroup};
pub(crate) use writer::serve_request;

use std::collections::BTreeMap;

use super::engine::Bytes;
use super::wire::StepMeta;
use crate::openpmd::chunk::Chunk;

/// Queue-full behaviour (ADIOS2 `QueueFullPolicy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueFullPolicy {
    /// Drop the new step; producer continues (paper's choice).
    Discard,
    /// Block the producer until the queue drains.
    Block,
}

/// Staging queue configuration.
#[derive(Clone, Copy, Debug)]
pub struct QueueConfig {
    pub policy: QueueFullPolicy,
    /// Max steps staged and not yet retired ("QueueLimit").
    pub limit: usize,
}

impl Default for QueueConfig {
    fn default() -> Self {
        QueueConfig { policy: QueueFullPolicy::Discard, limit: 2 }
    }
}

/// Counters exposed by both engine sides, used by the pipeline metrics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SstStats {
    pub steps_published: u64,
    pub steps_discarded: u64,
    pub steps_consumed: u64,
    pub bytes_put: u64,
    pub bytes_served: u64,
    pub bytes_got: u64,
    /// Individual selections requested/served (batch items).
    pub chunk_requests: u64,
    /// Batched wire round trips: `GetBatch` requests sent (reader) /
    /// served (writer). With the two-phase API this is one per writer
    /// pair per step, however many chunks the step carries — the
    /// "one wire message per step" property the benches assert.
    pub batch_requests: u64,
    /// Batched data replies received (reader) / sent (writer).
    pub data_messages: u64,
}

/// One step staged at the writer: metadata + payloads keyed by variable.
#[derive(Debug, Default)]
pub(crate) struct StagedStep {
    pub meta: StepMeta,
    /// var name -> list of (chunk, payload) from this writer.
    pub data: BTreeMap<String, Vec<(Chunk, Bytes)>>,
}
