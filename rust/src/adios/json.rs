//! Serial JSON backend (S6): the prototyping engine at the bottom of the
//! paper's Fig. 3 stack ("a serial JSON backend serves for prototyping
//! and learning purposes").
//!
//! One file per step, `step-<N>.json` in a directory, data inline as
//! number arrays. Slow and verbose by design — its value is that a human
//! can `cat` a step and see the full self-describing structure.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use once_cell::sync::Lazy;

use super::engine::{
    Bytes, Engine, GetHandle, GetQueue, Mode, PutQueue, StepStatus,
    VarDecl, VarHandle, VarInfo,
};
use super::ops::{self, OpChain, OpsReport};
use super::region;
use crate::obs::metrics::{counter, Counter};
use crate::obs::trace;
use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};
use crate::openpmd::types::Datatype;
use crate::openpmd::Attribute;
use crate::util::bytes::{b64_decode, b64_encode};
use crate::util::json::{parse, Json};

static JSON_PUT_CHUNKS: Lazy<&'static Counter> =
    Lazy::new(|| counter("json.put_chunks"));
static JSON_PUT_BYTES: Lazy<&'static Counter> =
    Lazy::new(|| counter("json.put_bytes"));
static JSON_GET_SWEEPS: Lazy<&'static Counter> =
    Lazy::new(|| counter("json.get_sweeps"));
static JSON_GET_BYTES: Lazy<&'static Counter> =
    Lazy::new(|| counter("json.get_bytes"));

/// Encode a payload as a JSON number array for its dtype.
fn data_to_json(dtype: Datatype, data: &[u8]) -> Json {
    let mut arr = Vec::new();
    match dtype {
        Datatype::F32 => {
            for c in data.chunks_exact(4) {
                arr.push(Json::Num(
                    f32::from_le_bytes(c.try_into().unwrap()) as f64
                ));
            }
        }
        Datatype::F64 => {
            for c in data.chunks_exact(8) {
                arr.push(Json::Num(f64::from_le_bytes(c.try_into().unwrap())));
            }
        }
        Datatype::I32 => {
            for c in data.chunks_exact(4) {
                arr.push(Json::Num(
                    i32::from_le_bytes(c.try_into().unwrap()) as f64
                ));
            }
        }
        Datatype::I64 => {
            for c in data.chunks_exact(8) {
                arr.push(Json::Num(
                    i64::from_le_bytes(c.try_into().unwrap()) as f64
                ));
            }
        }
        Datatype::U32 => {
            for c in data.chunks_exact(4) {
                arr.push(Json::Num(
                    u32::from_le_bytes(c.try_into().unwrap()) as f64
                ));
            }
        }
        Datatype::U64 => {
            for c in data.chunks_exact(8) {
                arr.push(Json::Num(
                    u64::from_le_bytes(c.try_into().unwrap()) as f64
                ));
            }
        }
        Datatype::U8 => {
            for b in data {
                arr.push(Json::Num(*b as f64));
            }
        }
    }
    Json::Arr(arr)
}

fn json_to_data(dtype: Datatype, arr: &[Json]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(arr.len() * dtype.size());
    for v in arr {
        let x = v
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("non-numeric data entry"))?;
        match dtype {
            Datatype::F32 => out.extend_from_slice(&(x as f32).to_le_bytes()),
            Datatype::F64 => out.extend_from_slice(&x.to_le_bytes()),
            Datatype::I32 => out.extend_from_slice(&(x as i32).to_le_bytes()),
            Datatype::I64 => out.extend_from_slice(&(x as i64).to_le_bytes()),
            Datatype::U32 => out.extend_from_slice(&(x as u32).to_le_bytes()),
            Datatype::U64 => out.extend_from_slice(&(x as u64).to_le_bytes()),
            Datatype::U8 => out.push(x as u8),
        }
    }
    Ok(out)
}

fn attr_to_json(a: &Attribute) -> Json {
    match a {
        Attribute::Str(s) => Json::Str(s.clone()),
        Attribute::F64(x) => Json::Num(*x),
        Attribute::I64(x) => Json::Num(*x as f64),
        Attribute::U64(x) => Json::Num(*x as f64),
        Attribute::Bool(b) => Json::Bool(*b),
        Attribute::VecF64(v) => {
            Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
        }
        Attribute::VecU64(v) => {
            Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
        }
        Attribute::VecStr(v) => {
            Json::Arr(v.iter().map(|s| Json::Str(s.clone())).collect())
        }
    }
}

fn json_to_attr(j: &Json) -> Attribute {
    match j {
        Json::Str(s) => Attribute::Str(s.clone()),
        Json::Num(x) => Attribute::F64(*x),
        Json::Bool(b) => Attribute::Bool(*b),
        Json::Arr(v) if v.iter().all(|x| matches!(x, Json::Str(_))) => {
            Attribute::VecStr(
                v.iter().map(|x| x.as_str().unwrap().to_string()).collect(),
            )
        }
        Json::Arr(v) => Attribute::VecF64(
            v.iter().map(|x| x.as_f64().unwrap_or(f64::NAN)).collect(),
        ),
        _ => Attribute::Str(j.to_string()),
    }
}

// ======================================================================

/// Writer: one pretty-printed JSON file per step.
pub struct JsonWriter {
    dir: PathBuf,
    rank: usize,
    hostname: String,
    step: u64,
    current: Option<(BTreeMap<String, Attribute>,
                     BTreeMap<String, (VarHandle, Vec<(Chunk, Bytes)>)>)>,
    /// Variable registry + deferred-put queue (two-phase API).
    puts: PutQueue,
    /// Encode-side operator accounting.
    ops_stats: OpsReport,
}

impl JsonWriter {
    pub fn create(dir: impl AsRef<Path>, rank: usize,
                  hostname: &str) -> Result<JsonWriter> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        Ok(JsonWriter {
            dir,
            rank,
            hostname: hostname.to_string(),
            step: 0,
            current: None,
            puts: PutQueue::default(),
            ops_stats: OpsReport::default(),
        })
    }
}

impl Engine for JsonWriter {
    fn engine_type(&self) -> &'static str {
        "json"
    }

    fn mode(&self) -> Mode {
        Mode::Write
    }

    fn begin_step(&mut self) -> Result<StepStatus> {
        if self.current.is_some() {
            bail!("begin_step while a step is open");
        }
        self.current = Some((BTreeMap::new(), BTreeMap::new()));
        Ok(StepStatus::Ok)
    }

    fn define_variable(&mut self, decl: &VarDecl) -> Result<VarHandle> {
        self.puts.define(decl)
    }

    fn put_deferred(&mut self, var: &VarHandle, chunk: Chunk, data: Bytes)
        -> Result<()>
    {
        if self.current.is_none() {
            bail!("put outside step");
        }
        self.puts.enqueue(var, chunk, data)
    }

    fn put_span(&mut self, var: &VarHandle, chunk: Chunk)
        -> Result<&mut [u8]>
    {
        if self.current.is_none() {
            bail!("put_span outside step");
        }
        self.puts.span(var, chunk)
    }

    fn perform_puts(&mut self) -> Result<()> {
        let pending = self.puts.drain();
        if pending.is_empty() {
            return Ok(());
        }
        let mut sp = trace::span("json.perform_puts")
            .with("step", self.step)
            .with("chunks", pending.len());
        let mut put_bytes = 0u64;
        JSON_PUT_CHUNKS.add(pending.len() as u64);
        let (_, vars) = self
            .current
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("perform_puts outside step"))?;
        for p in pending {
            // Operated variables are stored compressed (base64 in the
            // step document); the chain is applied here in the
            // deferred core, like every other backend.
            let data = ops::encode_put(&p.var, &p.chunk, p.data,
                                       &mut self.ops_stats)?;
            put_bytes += data.len() as u64;
            vars.entry(p.var.name().to_string())
                .or_insert_with(|| (p.var.clone(), Vec::new()))
                .1
                .push((p.chunk, data));
        }
        JSON_PUT_BYTES.add(put_bytes);
        sp.set("bytes", put_bytes);
        Ok(())
    }

    fn put_attribute(&mut self, name: &str, value: Attribute) -> Result<()> {
        let (attrs, _) = self
            .current
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("put_attribute outside step"))?;
        attrs.insert(name.to_string(), value);
        Ok(())
    }

    fn available_variables(&self) -> Vec<VarInfo> {
        Vec::new()
    }

    fn available_chunks(&self, _var: &str) -> Vec<WrittenChunkInfo> {
        Vec::new()
    }

    fn attribute(&self, _name: &str) -> Option<Attribute> {
        None
    }

    fn attribute_names(&self) -> Vec<String> {
        Vec::new()
    }

    fn get_deferred(&mut self, _var: &str, _selection: Chunk)
        -> Result<GetHandle>
    {
        bail!("get on a write-mode JSON engine")
    }

    fn perform_gets(&mut self) -> Result<()> {
        bail!("perform_gets on a write-mode JSON engine")
    }

    fn take_get(&mut self, _handle: GetHandle) -> Result<Bytes> {
        bail!("take_get on a write-mode JSON engine")
    }

    fn end_step(&mut self) -> Result<()> {
        self.perform_puts()?;
        let (attrs, vars) = self
            .current
            .take()
            .ok_or_else(|| anyhow::anyhow!("end_step without begin_step"))?;
        let mut attr_obj = BTreeMap::new();
        for (k, v) in &attrs {
            attr_obj.insert(k.clone(), attr_to_json(v));
        }
        let mut var_obj = BTreeMap::new();
        for (name, (handle, chunks)) in &vars {
            let mut chunk_arr = Vec::new();
            for (chunk, data) in chunks {
                let mut c = BTreeMap::new();
                c.insert(
                    "offset".into(),
                    Json::Arr(chunk.offset.iter()
                              .map(|x| Json::Num(*x as f64)).collect()),
                );
                c.insert(
                    "extent".into(),
                    Json::Arr(chunk.extent.iter()
                              .map(|x| Json::Num(*x as f64)).collect()),
                );
                c.insert("sourceRank".into(),
                         Json::Num(self.rank as f64));
                c.insert("hostname".into(),
                         Json::Str(self.hostname.clone()));
                c.insert("encodedBytes".into(),
                         Json::Num(data.len() as f64));
                if handle.ops().is_identity() {
                    c.insert("data".into(),
                             data_to_json(handle.dtype(), data));
                } else {
                    // Operator-framed payload, stored compressed.
                    c.insert("data64".into(),
                             Json::Str(b64_encode(data)));
                }
                chunk_arr.push(Json::Obj(c));
            }
            let mut v = BTreeMap::new();
            v.insert("dtype".into(),
                     Json::Str(handle.dtype().name().to_string()));
            if !handle.ops().is_identity() {
                v.insert("ops".into(),
                         Json::Str(handle.ops().to_string()));
            }
            v.insert(
                "shape".into(),
                Json::Arr(handle.shape().iter()
                          .map(|x| Json::Num(*x as f64)).collect()),
            );
            v.insert("chunks".into(), Json::Arr(chunk_arr));
            var_obj.insert(name.clone(), Json::Obj(v));
        }
        let mut doc = BTreeMap::new();
        doc.insert("step".into(), Json::Num(self.step as f64));
        doc.insert("attributes".into(), Json::Obj(attr_obj));
        doc.insert("variables".into(), Json::Obj(var_obj));
        let path = self.dir.join(format!("step-{}.json", self.step));
        std::fs::write(&path, Json::Obj(doc).to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))?;
        self.step += 1;
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        if self.current.is_some() {
            self.end_step()?;
        }
        Ok(())
    }

    fn ops_report(&self) -> OpsReport {
        self.ops_stats
    }
}

// ======================================================================

/// Reader: consumes `step-N.json` files in order.
pub struct JsonReader {
    dir: PathBuf,
    step: u64,
    current: Option<Json>,
    /// Deferred-get queue (two-phase API).
    gets: GetQueue,
    /// Decode-side operator accounting.
    ops_stats: OpsReport,
}

impl JsonReader {
    pub fn open(dir: impl AsRef<Path>) -> Result<JsonReader> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            bail!("{} is not a directory", dir.display());
        }
        Ok(JsonReader {
            dir,
            step: 0,
            current: None,
            gets: GetQueue::default(),
            ops_stats: OpsReport::default(),
        })
    }

    fn var(&self, name: &str) -> Option<&Json> {
        self.current.as_ref()?.get("variables")?.get(name)
    }
}

fn parse_dtype(s: &str) -> Result<Datatype> {
    Ok(match s {
        "f32" => Datatype::F32,
        "f64" => Datatype::F64,
        "i32" => Datatype::I32,
        "i64" => Datatype::I64,
        "u32" => Datatype::U32,
        "u64" => Datatype::U64,
        "u8" => Datatype::U8,
        other => bail!("unknown dtype {other:?}"),
    })
}

impl Engine for JsonReader {
    fn engine_type(&self) -> &'static str {
        "json"
    }

    fn mode(&self) -> Mode {
        Mode::Read
    }

    fn begin_step(&mut self) -> Result<StepStatus> {
        if self.current.is_some() {
            bail!("begin_step while a step is open");
        }
        let path = self.dir.join(format!("step-{}.json", self.step));
        if !path.exists() {
            return Ok(StepStatus::EndOfStream);
        }
        let text = std::fs::read_to_string(&path)?;
        self.current =
            Some(parse(&text).map_err(|e| anyhow::anyhow!(e))?);
        Ok(StepStatus::Ok)
    }

    fn define_variable(&mut self, _decl: &VarDecl) -> Result<VarHandle> {
        bail!("define_variable on a read-mode JSON engine")
    }

    fn put_deferred(&mut self, _var: &VarHandle, _chunk: Chunk,
                    _data: Bytes) -> Result<()> {
        bail!("put on a read-mode JSON engine")
    }

    fn put_span(&mut self, _var: &VarHandle, _chunk: Chunk)
        -> Result<&mut [u8]>
    {
        bail!("put_span on a read-mode JSON engine")
    }

    fn perform_puts(&mut self) -> Result<()> {
        bail!("perform_puts on a read-mode JSON engine")
    }

    fn put_attribute(&mut self, _name: &str, _value: Attribute) -> Result<()> {
        bail!("put_attribute on a read-mode JSON engine")
    }

    fn available_variables(&self) -> Vec<VarInfo> {
        let mut out = Vec::new();
        if let Some(vars) = self
            .current
            .as_ref()
            .and_then(|c| c.get("variables"))
            .and_then(|v| v.as_obj())
        {
            for (name, v) in vars {
                let dtype = v
                    .get("dtype")
                    .and_then(|d| d.as_str())
                    .and_then(|s| parse_dtype(s).ok());
                let shape = v.get("shape").and_then(|s| s.as_u64_vec());
                // Missing "ops" means identity; an unparseable chain
                // makes the variable invisible (consistent with how
                // malformed dtype/shape entries are treated).
                let ops = match v.get("ops").and_then(|o| o.as_str()) {
                    Some(spec) => OpChain::parse(spec).ok(),
                    None => Some(OpChain::identity()),
                };
                if let (Some(dtype), Some(shape), Some(ops)) =
                    (dtype, shape, ops)
                {
                    out.push(VarInfo {
                        name: name.clone(),
                        dtype,
                        shape,
                        ops,
                    });
                }
            }
        }
        out
    }

    fn available_chunks(&self, var: &str) -> Vec<WrittenChunkInfo> {
        let mut out = Vec::new();
        if let Some(chunks) = self
            .var(var)
            .and_then(|v| v.get("chunks"))
            .and_then(|c| c.as_arr())
        {
            for c in chunks {
                let offset = c.get("offset").and_then(|o| o.as_u64_vec());
                let extent = c.get("extent").and_then(|e| e.as_u64_vec());
                let rank = c
                    .get("sourceRank")
                    .and_then(|r| r.as_u64())
                    .unwrap_or(0) as usize;
                let hostname = c
                    .get("hostname")
                    .and_then(|h| h.as_str())
                    .unwrap_or("")
                    .to_string();
                let encoded_bytes =
                    c.get("encodedBytes").and_then(|b| b.as_u64());
                if let (Some(offset), Some(extent)) = (offset, extent) {
                    out.push(WrittenChunkInfo {
                        chunk: Chunk { offset, extent },
                        source_rank: rank,
                        hostname,
                        encoded_bytes,
                        source_id: None,
                    });
                }
            }
        }
        out
    }

    fn attribute(&self, name: &str) -> Option<Attribute> {
        self.current
            .as_ref()?
            .get("attributes")?
            .get(name)
            .map(json_to_attr)
    }

    fn attribute_names(&self) -> Vec<String> {
        self.current
            .as_ref()
            .and_then(|c| c.get("attributes"))
            .and_then(|a| a.as_obj())
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    fn get_deferred(&mut self, var: &str, selection: Chunk)
        -> Result<GetHandle>
    {
        if self.current.is_none() {
            bail!("get outside step");
        }
        if !self.available_variables().iter().any(|v| v.name == var) {
            bail!("unknown variable {var:?}");
        }
        Ok(self.gets.defer(var, selection))
    }

    fn perform_gets(&mut self) -> Result<()> {
        let pending = self.gets.drain_pending();
        if pending.is_empty() {
            return Ok(());
        }
        let mut sp = trace::span("json.get_sweep")
            .with("step", self.step)
            .with("gets", pending.len());
        let mut got_bytes = 0u64;
        let mut failure = None;
        for g in &pending {
            match self.fetch(&g.var, &g.selection) {
                Ok(data) => {
                    got_bytes += data.len() as u64;
                    self.gets.complete(g.handle, data);
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        JSON_GET_SWEEPS.inc();
        JSON_GET_BYTES.add(got_bytes);
        sp.set("bytes", got_bytes);
        if let Some(e) = failure {
            // Poison the whole drained batch so take_get reports this
            // error, not "unknown handle".
            self.gets.fail_batch(&pending, &e);
            return Err(e);
        }
        Ok(())
    }

    fn take_get(&mut self, handle: GetHandle) -> Result<Bytes> {
        self.gets.take(handle)
    }

    fn end_step(&mut self) -> Result<()> {
        // Deferred gets that were never performed are dropped: their
        // handles die with the step.
        self.gets.reset();
        if self.current.take().is_none() {
            bail!("end_step without begin_step");
        }
        self.step += 1;
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        self.gets.reset();
        self.current = None;
        Ok(())
    }

    fn ops_report(&self) -> OpsReport {
        self.ops_stats
    }
}

impl JsonReader {
    /// Load one selection from the current step document, reversing the
    /// variable's operator chain on compressed (`data64`) chunks.
    fn fetch(&mut self, var: &str, selection: &Chunk) -> Result<Bytes> {
        let info = self
            .available_variables()
            .into_iter()
            .find(|v| v.name == var)
            .ok_or_else(|| anyhow::anyhow!("unknown variable {var:?}"))?;
        let elem = info.dtype.size();
        // Collect the raw chunk table first (the JSON document borrows
        // `self.current`, while decoding mutates `self.ops_stats`).
        enum Payload {
            Numbers(Vec<u8>),
            Framed(Vec<u8>),
        }
        let mut table: Vec<(Chunk, Payload)> = Vec::new();
        {
            let chunks = self
                .var(var)
                .and_then(|v| v.get("chunks"))
                .and_then(|c| c.as_arr())
                .ok_or_else(|| anyhow::anyhow!("no chunks for {var:?}"))?;
            for c in chunks {
                let offset = c
                    .get("offset")
                    .and_then(|o| o.as_u64_vec())
                    .ok_or_else(|| {
                        anyhow::anyhow!("chunk missing offset")
                    })?;
                let extent = c
                    .get("extent")
                    .and_then(|e| e.as_u64_vec())
                    .ok_or_else(|| {
                        anyhow::anyhow!("chunk missing extent")
                    })?;
                let chunk = Chunk { offset, extent };
                if chunk.intersect(selection).is_none() {
                    continue;
                }
                let payload = if let Some(b64) =
                    c.get("data64").and_then(|d| d.as_str())
                {
                    Payload::Framed(
                        b64_decode(b64)
                            .map_err(|e| anyhow::anyhow!("{var}: {e}"))?,
                    )
                } else {
                    let arr = c
                        .get("data")
                        .and_then(|d| d.as_arr())
                        .ok_or_else(|| {
                            anyhow::anyhow!("chunk missing data")
                        })?;
                    Payload::Numbers(json_to_data(info.dtype, arr)?)
                };
                table.push((chunk, payload));
            }
        }
        let mut out = vec![0u8; selection.num_elements() as usize * elem];
        let mut covered = 0u64;
        for (chunk, payload) in table {
            let raw: Bytes = match payload {
                Payload::Numbers(data) => Arc::new(data),
                Payload::Framed(framed) => {
                    ops::decode_get(&info.ops, info.dtype, &chunk,
                                    &framed, &mut self.ops_stats)
                        .map_err(|e| anyhow::anyhow!("{var}: {e}"))?
                }
            };
            covered += region::copy_region(
                &chunk, &raw, selection, &mut out, elem,
            );
        }
        if covered < selection.num_elements() {
            bail!("selection only partially covered");
        }
        Ok(Arc::new(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::engine::cast;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "openpmd-stream-json-{name}-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn json_round_trip() {
        let dir = tmp_dir("rt");
        let mut w = JsonWriter::create(&dir, 2, "nodeA").unwrap();
        w.begin_step().unwrap();
        w.put_attribute("/data/0/time", Attribute::F64(0.5)).unwrap();
        w.put_attribute("labels",
                        Attribute::VecStr(vec!["x".into(), "y".into()]))
            .unwrap();
        let var = VarDecl::new("/data/0/particles/e/weighting",
                               Datatype::F32, vec![6]);
        w.put(&var, Chunk::new(vec![0], vec![3]),
              cast::f32_to_bytes(&[1.0, 2.0, 3.0]))
            .unwrap();
        w.put(&var, Chunk::new(vec![3], vec![3]),
              cast::f32_to_bytes(&[4.0, 5.0, 6.0]))
            .unwrap();
        w.end_step().unwrap();
        w.close().unwrap();

        let mut r = JsonReader::open(&dir).unwrap();
        assert_eq!(r.begin_step().unwrap(), StepStatus::Ok);
        assert_eq!(r.attribute("/data/0/time").unwrap().as_f64(), Some(0.5));
        let vars = r.available_variables();
        assert_eq!(vars.len(), 1);
        assert_eq!(vars[0].dtype, Datatype::F32);
        let chunks = r.available_chunks(&vars[0].name);
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0].hostname, "nodeA");
        assert_eq!(chunks[0].source_rank, 2);
        let data = r.get(&vars[0].name, Chunk::new(vec![1], vec![4])).unwrap();
        assert_eq!(cast::bytes_to_f32(&data).unwrap(),
                   vec![2.0, 3.0, 4.0, 5.0]);
        r.end_step().unwrap();
        assert_eq!(r.begin_step().unwrap(), StepStatus::EndOfStream);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_is_human_readable() {
        let dir = tmp_dir("human");
        let mut w = JsonWriter::create(&dir, 0, "h").unwrap();
        w.begin_step().unwrap();
        let var = VarDecl::new("/x", Datatype::U8, vec![2]);
        w.put(&var, Chunk::new(vec![0], vec![2]), Arc::new(vec![7, 9]))
            .unwrap();
        w.end_step().unwrap();
        let text =
            std::fs::read_to_string(dir.join("step-0.json")).unwrap();
        assert!(text.contains("\"variables\""));
        assert!(text.contains("\"/x\""));
        assert!(text.contains('\n')); // pretty-printed
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn operated_variable_round_trips_as_base64() {
        let dir = tmp_dir("ops");
        let chain = OpChain::parse("shuffle|rle").unwrap();
        let xs = vec![2.5f32; 64];
        let mut w = JsonWriter::create(&dir, 0, "h").unwrap();
        w.begin_step().unwrap();
        let decl = VarDecl::new("/data/0/x", Datatype::F32, vec![64])
            .with_ops(chain.clone());
        let h = w.define_variable(&decl).unwrap();
        w.put_deferred(&h, Chunk::whole(vec![64]),
                       cast::f32_to_bytes(&xs))
            .unwrap();
        w.end_step().unwrap();
        assert!(w.ops_report().ratio() > 4.0);
        w.close().unwrap();

        // The document stores base64, not a number array, and records
        // the chain.
        let text =
            std::fs::read_to_string(dir.join("step-0.json")).unwrap();
        assert!(text.contains("\"data64\""), "{text}");
        assert!(text.contains("shuffle|rle"), "{text}");
        assert!(!text.contains("\"data\""), "{text}");

        let mut r = JsonReader::open(&dir).unwrap();
        assert_eq!(r.begin_step().unwrap(), StepStatus::Ok);
        let vars = r.available_variables();
        assert_eq!(vars[0].ops, chain);
        let data = r.get("/data/0/x", Chunk::new(vec![3], vec![7]))
            .unwrap();
        assert_eq!(cast::bytes_to_f32(&data).unwrap(), vec![2.5f32; 7]);
        assert_eq!(r.ops_report().chunks_decoded, 1);
        r.end_step().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_dir_is_end_of_stream() {
        let dir = tmp_dir("empty");
        std::fs::create_dir_all(&dir).unwrap();
        let mut r = JsonReader::open(&dir).unwrap();
        assert_eq!(r.begin_step().unwrap(), StepStatus::EndOfStream);
        std::fs::remove_dir_all(&dir).ok();
    }
}
