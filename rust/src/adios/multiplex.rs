//! The multiplexing virtual read engine: N child readers, ONE logical
//! series.
//!
//! PR 4's reader fleet fans a stream *out* into `out.r<i>ofM.bp` shards
//! plus a merged index; this module is the inverse — the reassembly
//! side of the paper's loose-coupling chain (produce → fleet →
//! reassemble → consume), and the general composition primitive behind
//! it: [`MultiplexReader`] implements the full two-phase read
//! [`Engine`] contract over an arbitrary set of child read engines, so
//! *any* set of producers can be treated as one chunk table
//! (Eisenhauer et al. 2024's N-writer/M-reader stage chaining).
//!
//! * **Steps are aligned across children.** `begin_step` opens the
//!   next step on every child and only reports `Ok` once all of them
//!   agree; a child that is `NotReady` leaves the others' steps parked
//!   open until the barrier completes. The barrier is
//!   *discard-consistent*: a step any child discards is discarded
//!   everywhere (already-open peers consume it without data movement)
//!   and accounted in [`MultiplexReader::discarded_steps`]. Children
//!   must present the same step sequence — a family whose members end
//!   at different steps is a typed alignment error, not silent
//!   truncation.
//! * **Tables merge with provenance.** `available_variables` is the
//!   union of the children's declarations (conflicting redeclarations
//!   are errors at the step barrier); `available_chunks` concatenates
//!   the children's tables with each entry stamped with its child
//!   index ([`WrittenChunkInfo::source_id`]), so distribution
//!   strategies planning over the merged table keep the provenance
//!   through their [`crate::distribution::ChunkSlice`]s.
//! * **Gets route to the owning child.** `get_deferred` intersects the
//!   selection with each child's coverage and defers one child-get per
//!   intersection piece; `perform_gets` executes **one batched perform
//!   per involved child per step** (preserving each backend's own
//!   batching — one wire request per writer over SST, one seek-ordered
//!   sweep over BP); `take_get` reassembles the pieces densely (a
//!   selection that exactly matches one child chunk is handed through
//!   zero-copy).
//!
//! Input-spec resolution lives in [`super::spec`]: parse any spec the
//! pipe accepts (`sst+addr,...`, `serve+addr`, `shards:<index.json>`,
//! `merge:a,b,...`, or a bare BP/JSON path) into a typed
//! [`super::spec::SourceSpec`] and open it — "one engine" as the
//! universal interface to any composition of sources. The former free
//! functions [`open_merge`] / [`open_source`] / [`open_series_source`]
//! remain as deprecated shims for one release.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::engine::{
    Bytes, Engine, GetHandle, GetQueue, Mode, StepStatus, VarDecl,
    VarHandle, VarInfo,
};
use super::ops::OpsReport;
use super::region;
use crate::obs::trace;
use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};
use crate::openpmd::Attribute;

/// Where a child engine stands relative to the step barrier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ChildStep {
    /// No step open (not yet polled this round, or consumed).
    Idle,
    /// The child's next step is open, parked until the barrier
    /// completes.
    Open,
    /// The child discarded (and thereby consumed) this round's step —
    /// remembered until the barrier resolves, so a still-NotReady
    /// sibling cannot desynchronize the ordinals.
    Dropped,
    /// The child reported end of stream.
    Ended,
}

/// One child engine plus its barrier state and display name.
struct Child {
    name: String,
    engine: Box<dyn Engine>,
    step: ChildStep,
}

/// The merged view of one aligned step: union of variable
/// declarations plus the provenance-stamped merged chunk tables.
struct StepView {
    /// Merged declarations in deterministic (name-sorted) order.
    vars: Vec<VarInfo>,
    /// Variable name -> merged chunk table, every entry stamped with
    /// its owning child via `source_id`.
    tables: BTreeMap<String, Vec<WrittenChunkInfo>>,
}

/// One piece of a routed get: the sub-selection a single child serves.
struct Piece {
    child: usize,
    chunk: Chunk,
    handle: GetHandle,
}

/// The routing plan of one deferred multiplex get.
struct GetPlan {
    pieces: Vec<Piece>,
    elem: usize,
}

/// See the module docs.
pub struct MultiplexReader {
    children: Vec<Child>,
    view: Option<StepView>,
    /// Steps dropped by the discard-consistent barrier.
    discarded: u64,
    /// Handle bookkeeping for the multiplexer's own get lifecycle.
    gets: GetQueue,
    /// Multiplex handle -> routing plan (child handles to redeem).
    plans: BTreeMap<u64, GetPlan>,
}

impl MultiplexReader {
    /// Multiplex `children` (all read-mode) into one logical series.
    pub fn over(children: Vec<Box<dyn Engine>>) -> Result<MultiplexReader> {
        let names = (0..children.len())
            .map(|i| format!("child {i}"))
            .collect();
        Self::over_named(names, children)
    }

    /// [`MultiplexReader::over`] with display names (shard paths,
    /// source specs) for error messages.
    pub fn over_named(
        names: Vec<String>,
        children: Vec<Box<dyn Engine>>,
    ) -> Result<MultiplexReader> {
        if children.is_empty() {
            bail!("multiplex reader needs at least one child engine");
        }
        if names.len() != children.len() {
            bail!(
                "multiplex reader got {} name(s) for {} child(ren)",
                names.len(),
                children.len()
            );
        }
        for (name, child) in names.iter().zip(&children) {
            if child.mode() != Mode::Read {
                bail!("multiplex child {name} is not a read engine");
            }
        }
        Ok(MultiplexReader {
            children: names
                .into_iter()
                .zip(children)
                .map(|(name, engine)| Child {
                    name,
                    engine,
                    step: ChildStep::Idle,
                })
                .collect(),
            view: None,
            discarded: 0,
            gets: GetQueue::default(),
            plans: BTreeMap::new(),
        })
    }

    /// Number of child engines.
    pub fn width(&self) -> usize {
        self.children.len()
    }

    /// Steps dropped by the discard-consistent barrier (a step any
    /// child discarded was discarded everywhere and counted here).
    pub fn discarded_steps(&self) -> u64 {
        self.discarded
    }

    /// Build the merged step view once all children are `Open`:
    /// union the declarations (conflicts are errors) and stamp every
    /// merged chunk with its owning child.
    fn build_view(&self) -> Result<StepView> {
        let mut merged: BTreeMap<String, VarInfo> = BTreeMap::new();
        let mut tables: BTreeMap<String, Vec<WrittenChunkInfo>> =
            BTreeMap::new();
        for (idx, child) in self.children.iter().enumerate() {
            for var in child.engine.available_variables() {
                match merged.get(&var.name) {
                    None => {
                        merged.insert(var.name.clone(), var.clone());
                    }
                    Some(seen) => {
                        if seen.dtype != var.dtype
                            || seen.shape != var.shape
                            || seen.ops != var.ops
                        {
                            bail!(
                                "multiplex child {} redeclares {:?} \
                                 ({:?} {:?}) conflicting with an \
                                 earlier child ({:?} {:?})",
                                child.name, var.name, var.dtype,
                                var.shape, seen.dtype, seen.shape
                            );
                        }
                    }
                }
                let table = tables.entry(var.name.clone()).or_default();
                for info in child.engine.available_chunks(&var.name) {
                    table.push(info.with_source_id(idx));
                }
            }
        }
        Ok(StepView {
            vars: merged.into_values().collect(),
            tables,
        })
    }
}

impl Engine for MultiplexReader {
    fn engine_type(&self) -> &'static str {
        "multiplex"
    }

    fn mode(&self) -> Mode {
        Mode::Read
    }

    fn begin_step(&mut self) -> Result<StepStatus> {
        if self.view.is_some() {
            bail!("begin_step while a step is open");
        }
        // The alignment barrier: span duration is the cost of polling
        // every unresolved child plus (on the Ok path) the view merge.
        let mut sp = trace::span("multiplex.align")
            .with("children", self.children.len());
        // Poll every child that has not resolved this round yet
        // (children holding an Open or Dropped verdict from an earlier
        // NotReady round are parked).
        let mut any_not_ready = false;
        for child in &mut self.children {
            if child.step != ChildStep::Idle {
                continue;
            }
            match child.engine.begin_step()? {
                StepStatus::Ok => child.step = ChildStep::Open,
                StepStatus::NotReady => any_not_ready = true,
                // The child consumed (discarded) its own step; the
                // verdict is remembered until every sibling resolves
                // the same ordinal.
                StepStatus::Discarded => child.step = ChildStep::Dropped,
                StepStatus::EndOfStream => child.step = ChildStep::Ended,
            }
        }
        if any_not_ready {
            // Children with a verdict stay parked; the next poll only
            // touches the stragglers — the barrier must not resolve
            // an ordinal some child has not yet seen.
            sp.set("status", "not_ready");
            return Ok(StepStatus::NotReady);
        }
        if self.children.iter().any(|c| c.step == ChildStep::Dropped) {
            // A sibling that instead ENDED never presented this
            // ordinal at all: that is a misaligned family, not a
            // consistent discard — erroring here keeps the "identical
            // step sequences" contract instead of silently truncating
            // behind a trailing discard.
            if self.children.iter().any(|c| c.step == ChildStep::Ended)
            {
                bail!(
                    "multiplexed sources are misaligned: a source \
                     discarded a step that an already-ended sibling \
                     never presented — a shard family must present \
                     identical step sequences"
                );
            }
            // Discard-consistent barrier: the step one child dropped is
            // dropped everywhere. Peers that already opened it consume
            // it without any data movement, exactly like the serial
            // pipe's output-probe path.
            for child in &mut self.children {
                match child.step {
                    ChildStep::Open => {
                        child.engine.end_step()?;
                        child.step = ChildStep::Idle;
                    }
                    ChildStep::Dropped => child.step = ChildStep::Idle,
                    ChildStep::Idle | ChildStep::Ended => {}
                }
            }
            self.discarded += 1;
            sp.set("status", "discarded");
            return Ok(StepStatus::Discarded);
        }
        let ended = self
            .children
            .iter()
            .filter(|c| c.step == ChildStep::Ended)
            .count();
        if ended == self.children.len() {
            sp.set("status", "end_of_stream");
            return Ok(StepStatus::EndOfStream);
        }
        if ended > 0 {
            let done: Vec<&str> = self
                .children
                .iter()
                .filter(|c| c.step == ChildStep::Ended)
                .map(|c| c.name.as_str())
                .collect();
            bail!(
                "multiplexed sources are misaligned: {} ended while \
                 {} other source(s) still have steps — a shard family \
                 must present identical step sequences",
                done.join(", "),
                self.children.len() - ended
            );
        }
        // All Open: the barrier holds, merge the step.
        self.view = Some(self.build_view()?);
        sp.set("status", "ok");
        Ok(StepStatus::Ok)
    }

    fn define_variable(&mut self, _decl: &VarDecl) -> Result<VarHandle> {
        bail!("define_variable on a read-mode multiplex engine")
    }

    fn put_deferred(&mut self, _var: &VarHandle, _chunk: Chunk,
                    _data: Bytes) -> Result<()> {
        bail!("put on a read-mode multiplex engine")
    }

    fn put_span(&mut self, _var: &VarHandle, _chunk: Chunk)
        -> Result<&mut [u8]>
    {
        bail!("put_span on a read-mode multiplex engine")
    }

    fn perform_puts(&mut self) -> Result<()> {
        bail!("perform_puts on a read-mode multiplex engine")
    }

    fn put_attribute(&mut self, _name: &str, _value: Attribute)
        -> Result<()>
    {
        bail!("put_attribute on a read-mode multiplex engine")
    }

    fn available_variables(&self) -> Vec<VarInfo> {
        self.view
            .as_ref()
            .map(|v| v.vars.clone())
            .unwrap_or_default()
    }

    fn available_chunks(&self, var: &str) -> Vec<WrittenChunkInfo> {
        self.view
            .as_ref()
            .and_then(|v| v.tables.get(var).cloned())
            .unwrap_or_default()
    }

    fn attribute(&self, name: &str) -> Option<Attribute> {
        if self.view.is_none() {
            return None;
        }
        // First child holding the attribute wins (a shard family
        // replicates the full attribute set into every shard).
        self.children
            .iter()
            .find_map(|c| c.engine.attribute(name))
    }

    fn attribute_names(&self) -> Vec<String> {
        if self.view.is_none() {
            return Vec::new();
        }
        let mut names = BTreeSet::new();
        for child in &self.children {
            names.extend(child.engine.attribute_names());
        }
        names.into_iter().collect()
    }

    fn get_deferred(&mut self, var: &str, selection: Chunk)
        -> Result<GetHandle>
    {
        let view = self
            .view
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("get outside step"))?;
        let info = view
            .vars
            .iter()
            .find(|v| v.name == var)
            .ok_or_else(|| anyhow::anyhow!("unknown variable {var:?}"))?;
        let elem = info.dtype.size();
        // Route: one child-get per (child chunk ∩ selection) piece.
        // Dedup per (child, piece) so two overlapping table entries of
        // one child do not fetch the same region twice.
        let mut pieces: Vec<(usize, Chunk)> = Vec::new();
        if let Some(table) = view.tables.get(var) {
            for entry in table {
                let child = entry.source_id.unwrap_or(0);
                if let Some(inter) = entry.chunk.intersect(&selection) {
                    if !pieces
                        .iter()
                        .any(|(c, p)| *c == child && *p == inter)
                    {
                        pieces.push((child, inter));
                    }
                }
            }
        }
        if pieces.is_empty() {
            bail!("no chunks of {var:?} cover the selection");
        }
        let mut routed = Vec::with_capacity(pieces.len());
        for (child, chunk) in pieces {
            let handle = match self.children[child]
                .engine
                .get_deferred(var, chunk.clone())
            {
                Ok(h) => h,
                Err(e) => {
                    return Err(e.context(format!(
                        "routing get of {var:?} to multiplex {}",
                        self.children[child].name
                    )));
                }
            };
            routed.push(Piece { child, chunk, handle });
        }
        let handle = self.gets.defer(var, selection);
        self.plans
            .insert(handle.0, GetPlan { pieces: routed, elem });
        Ok(handle)
    }

    fn perform_gets(&mut self) -> Result<()> {
        let batch = self.gets.drain_pending();
        if batch.is_empty() {
            return Ok(());
        }
        if self.view.is_none() {
            bail!("perform_gets outside step");
        }
        let _sp = trace::span("multiplex.perform_gets")
            .with("gets", batch.len());
        // One batched perform per involved child — each backend keeps
        // its own batching (one wire request per writer over SST, one
        // file sweep over BP).
        let involved: BTreeSet<usize> = batch
            .iter()
            .filter_map(|g| self.plans.get(&g.handle.0))
            .flat_map(|p| p.pieces.iter().map(|piece| piece.child))
            .collect();
        for child in involved {
            if let Err(e) = self.children[child].engine.perform_gets() {
                let e = e.context(format!(
                    "multiplex {} failed its batch",
                    self.children[child].name
                ));
                for g in &batch {
                    self.plans.remove(&g.handle.0);
                }
                self.gets.fail_batch(&batch, &e);
                return Err(e);
            }
        }
        // Redeem and reassemble each multiplex get.
        let mut failure: Option<anyhow::Error> = None;
        for g in &batch {
            let plan = match self.plans.remove(&g.handle.0) {
                Some(p) => p,
                None => continue,
            };
            match assemble(&mut self.children, &g.selection, plan, &g.var)
            {
                Ok(data) => self.gets.complete(g.handle, data),
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = failure {
            for g in &batch {
                self.plans.remove(&g.handle.0);
            }
            self.gets.fail_batch(&batch, &e);
            return Err(e);
        }
        Ok(())
    }

    fn take_get(&mut self, handle: GetHandle) -> Result<Bytes> {
        self.gets.take(handle)
    }

    fn end_step(&mut self) -> Result<()> {
        if self.view.take().is_none() {
            bail!("end_step without an aligned open step");
        }
        self.gets.reset();
        self.plans.clear();
        for child in &mut self.children {
            debug_assert_eq!(child.step, ChildStep::Open);
            child.engine.end_step()?;
            child.step = ChildStep::Idle;
        }
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        self.gets.reset();
        self.plans.clear();
        self.view = None;
        for child in &mut self.children {
            child.engine.close()?;
        }
        Ok(())
    }

    fn ops_report(&self) -> OpsReport {
        // Aggregate decode-side accounting across every child.
        let mut report = OpsReport::default();
        for child in &self.children {
            report.absorb(child.engine.ops_report());
        }
        report
    }
}

/// Reassemble one routed get from its children's piece payloads.
/// Free function (not a method) so `perform_gets` can call it while
/// holding the drained batch.
fn assemble(
    children: &mut [Child],
    selection: &Chunk,
    plan: GetPlan,
    var: &str,
) -> Result<Bytes> {
    // Perfect alignment fast path: the selection IS one child chunk —
    // hand the child's buffer through without copying, so a
    // reassembled shard family costs what the pre-fleet serial stream
    // cost.
    if let [piece] = plan.pieces.as_slice() {
        if piece.chunk == *selection {
            return children[piece.child].engine.take_get(piece.handle);
        }
    }
    let elem = plan.elem;
    let n = selection.num_elements() as usize;
    let mut out = vec![0u8; n * elem];
    // Element-level coverage map: pieces from different children may
    // overlap (replicated merge sources), so completeness is the
    // UNION of the pieces — summing per-piece copy counts would let an
    // overlap mask a genuine gap and return silent zeros. The map is
    // marked through the same region walk that places the bytes.
    let mut cov = vec![0u8; n];
    for piece in &plan.pieces {
        let data = children[piece.child].engine.take_get(piece.handle)?;
        region::copy_region(&piece.chunk, &data, selection, &mut out,
                            elem);
        let ones = vec![1u8; piece.chunk.num_elements() as usize];
        region::copy_region(&piece.chunk, &ones, selection, &mut cov, 1);
    }
    let covered = cov.iter().filter(|&&c| c != 0).count() as u64;
    if covered < selection.num_elements() {
        bail!(
            "selection of {var:?} only partially covered by the \
             multiplexed sources ({covered}/{} elements)",
            selection.num_elements()
        );
    }
    Ok(Arc::new(out))
}

// ======================================================================
// Source openers
// ======================================================================

/// Open one concrete series source for multiplexing: a `*.index.json`
/// path nests a whole shard family, a directory is a JSON step series,
/// anything else a BP file.
#[deprecated(
    since = "0.10.0",
    note = "use adios::spec::open_series_path (or \
            SourceSpec::Series.open); this shim is removed next release"
)]
pub fn open_series_source(path: impl AsRef<Path>) -> Result<Box<dyn Engine>> {
    super::spec::open_series_path(path)
}

/// Open a `merge:a,b,...` composition: every source becomes one child
/// of a [`MultiplexReader`]. Sources may mix backends freely (bp +
/// json + nested shard families) — the merged stream is one logical
/// series either way.
#[deprecated(
    since = "0.10.0",
    note = "parse a merge: spec with adios::spec::SourceSpec and open \
            it; this shim is removed next release"
)]
pub fn open_merge(sources: &[String]) -> Result<MultiplexReader> {
    if sources.is_empty() {
        bail!("merge needs at least one source");
    }
    let mut children = Vec::with_capacity(sources.len());
    for source in sources {
        children.push(
            super::spec::open_series_path(source)
                .with_context(|| format!("opening merge source {source}"))?,
        );
    }
    MultiplexReader::over_named(sources.to_vec(), children)
}

/// Resolve a pipe *input spec* to an engine — the universal entry the
/// CLI and tests formerly shared, now a thin shim over the typed
/// [`super::spec::SourceSpec`] grammar.
///
/// `rank` names the consuming worker's rank within a reader fleet. It
/// is honored only by rank-aware (streaming) specs — see
/// [`super::spec::SourceSpec::rank_aware`]; the typed API makes that
/// contract explicit where this signature silently dropped it. The
/// shim validates the rank against an unbounded fleet width
/// (`rank + 1`), preserving the old accept-anything behavior.
#[deprecated(
    since = "0.10.0",
    note = "use adios::spec::SourceSpec::parse(..)?.open(slot); this \
            shim is removed next release"
)]
pub fn open_source(spec: &str, rank: usize) -> Result<Box<dyn Engine>> {
    use super::spec::{ReaderSlot, SourceSpec};
    let parsed = SourceSpec::parse(spec)?;
    parsed.open(ReaderSlot::of(rank, rank + 1)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::bp::{BpReader, BpWriter, WriterCtx};
    use crate::adios::engine::cast;
    use crate::adios::json::JsonWriter;
    use crate::openpmd::types::Datatype;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("opmd-mux-{name}-{}", std::process::id()))
    }

    /// Write `steps` steps of the f32 variable `/data/0/x` (extent
    /// `total`) into `engine`, contributing only `[offset, offset+n)`
    /// with value `step*1000 + g` at global element `g`.
    fn write_slice(
        engine: &mut dyn Engine,
        steps: u64,
        total: u64,
        offset: u64,
        n: u64,
    ) {
        let decl = VarDecl::new("/data/0/x", Datatype::F32, vec![total]);
        for step in 0..steps {
            assert_eq!(engine.begin_step().unwrap(), StepStatus::Ok);
            engine
                .put_attribute("/data/0/time",
                               Attribute::F64(step as f64))
                .unwrap();
            let h = engine.define_variable(&decl).unwrap();
            let xs: Vec<f32> = (0..n)
                .map(|i| (step * 1000 + offset + i) as f32)
                .collect();
            engine
                .put_deferred(&h, Chunk::new(vec![offset], vec![n]),
                              cast::f32_to_bytes(&xs))
                .unwrap();
            engine.end_step().unwrap();
        }
        engine.close().unwrap();
    }

    #[test]
    fn merges_two_bp_halves_into_one_series() {
        let a = tmp("half-a.bp");
        let b = tmp("half-b.bp");
        let mut wa = BpWriter::create(&a, WriterCtx::default()).unwrap();
        let mut wb = BpWriter::create(&b, WriterCtx {
            rank: 1,
            hostname: "h".into(),
        })
        .unwrap();
        write_slice(&mut wa, 2, 8, 0, 4);
        write_slice(&mut wb, 2, 8, 4, 4);
        let mut mux = MultiplexReader::over(vec![
            Box::new(BpReader::open(&a).unwrap()),
            Box::new(BpReader::open(&b).unwrap()),
        ])
        .unwrap();
        for step in 0..2u64 {
            assert_eq!(mux.begin_step().unwrap(), StepStatus::Ok);
            let vars = mux.available_variables();
            assert_eq!(vars.len(), 1);
            assert_eq!(vars[0].shape, vec![8]);
            // Provenance: merged table stamps the child index.
            let chunks = mux.available_chunks("/data/0/x");
            assert_eq!(chunks.len(), 2);
            assert_eq!(chunks[0].source_id, Some(0));
            assert_eq!(chunks[1].source_id, Some(1));
            assert_eq!(
                mux.attribute("/data/0/time").unwrap().as_f64(),
                Some(step as f64)
            );
            // A cross-child whole read reassembles both halves.
            let data = mux.get("/data/0/x", Chunk::whole(vec![8])).unwrap();
            let want: Vec<f32> =
                (0..8).map(|g| (step * 1000 + g) as f32).collect();
            assert_eq!(cast::bytes_to_f32(&data).unwrap(), want);
            mux.end_step().unwrap();
        }
        assert_eq!(mux.begin_step().unwrap(), StepStatus::EndOfStream);
        mux.close().unwrap();
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn aligned_reads_route_to_the_owning_child() {
        let a = tmp("route-a.bp");
        let b = tmp("route-b.bp");
        let mut wa = BpWriter::create(&a, WriterCtx::default()).unwrap();
        let mut wb = BpWriter::create(&b, WriterCtx::default()).unwrap();
        write_slice(&mut wa, 1, 8, 0, 4);
        write_slice(&mut wb, 1, 8, 4, 4);
        let mut mux = MultiplexReader::over(vec![
            Box::new(BpReader::open(&a).unwrap()),
            Box::new(BpReader::open(&b).unwrap()),
        ])
        .unwrap();
        assert_eq!(mux.begin_step().unwrap(), StepStatus::Ok);
        // Two aligned gets, one per child chunk: one perform serves
        // both children in one batch each.
        let h0 = mux
            .get_deferred("/data/0/x", Chunk::new(vec![0], vec![4]))
            .unwrap();
        let h1 = mux
            .get_deferred("/data/0/x", Chunk::new(vec![4], vec![4]))
            .unwrap();
        mux.perform_gets().unwrap();
        let lo = cast::bytes_to_f32(&mux.take_get(h0).unwrap()).unwrap();
        let hi = cast::bytes_to_f32(&mux.take_get(h1).unwrap()).unwrap();
        assert_eq!(lo, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(hi, vec![4.0, 5.0, 6.0, 7.0]);
        mux.end_step().unwrap();
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    #[allow(deprecated)] // the shim must stay covered until removal
    fn mixed_backend_merge_bp_plus_json() {
        let a = tmp("mixed-a.bp");
        let d = tmp("mixed-json");
        let mut wa = BpWriter::create(&a, WriterCtx::default()).unwrap();
        let mut wd = JsonWriter::create(&d, 1, "h").unwrap();
        write_slice(&mut wa, 2, 6, 0, 3);
        write_slice(&mut wd, 2, 6, 3, 3);
        let mut mux = open_merge(&[
            a.display().to_string(),
            d.display().to_string(),
        ])
        .unwrap();
        assert_eq!(mux.width(), 2);
        for step in 0..2u64 {
            assert_eq!(mux.begin_step().unwrap(), StepStatus::Ok);
            let data = mux.get("/data/0/x", Chunk::whole(vec![6])).unwrap();
            let want: Vec<f32> =
                (0..6).map(|g| (step * 1000 + g) as f32).collect();
            assert_eq!(cast::bytes_to_f32(&data).unwrap(), want);
            mux.end_step().unwrap();
        }
        assert_eq!(mux.begin_step().unwrap(), StepStatus::EndOfStream);
        std::fs::remove_file(&a).ok();
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn overlapping_children_cannot_mask_a_coverage_gap() {
        // A covers [0,6) and B covers [2,6) — overlapping, with a
        // genuine gap at [6,8). Summed piece counts (6 + 4 = 10 >= 8)
        // would wave the whole-selection read through with silent
        // zeros; the union coverage map must reject it.
        let a = tmp("overlap-a.bp");
        let b = tmp("overlap-b.bp");
        let mut wa = BpWriter::create(&a, WriterCtx::default()).unwrap();
        let mut wb = BpWriter::create(&b, WriterCtx::default()).unwrap();
        write_slice(&mut wa, 1, 8, 0, 6);
        write_slice(&mut wb, 1, 8, 2, 4);
        let mut mux = MultiplexReader::over(vec![
            Box::new(BpReader::open(&a).unwrap()),
            Box::new(BpReader::open(&b).unwrap()),
        ])
        .unwrap();
        assert_eq!(mux.begin_step().unwrap(), StepStatus::Ok);
        let err = mux
            .get("/data/0/x", Chunk::whole(vec![8]))
            .unwrap_err();
        assert!(format!("{err}").contains("partially covered"),
                "{err}");
        // A selection the overlapping pair DOES cover reads fine (the
        // replicas hold identical values by construction).
        let data = mux
            .get("/data/0/x", Chunk::new(vec![0], vec![6]))
            .unwrap();
        assert_eq!(cast::bytes_to_f32(&data).unwrap(),
                   vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        mux.end_step().unwrap();
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn misaligned_step_counts_are_a_typed_error() {
        let a = tmp("misalign-a.bp");
        let b = tmp("misalign-b.bp");
        let mut wa = BpWriter::create(&a, WriterCtx::default()).unwrap();
        let mut wb = BpWriter::create(&b, WriterCtx::default()).unwrap();
        write_slice(&mut wa, 3, 8, 0, 4);
        write_slice(&mut wb, 2, 8, 4, 4);
        let mut mux = MultiplexReader::over(vec![
            Box::new(BpReader::open(&a).unwrap()),
            Box::new(BpReader::open(&b).unwrap()),
        ])
        .unwrap();
        for _ in 0..2 {
            assert_eq!(mux.begin_step().unwrap(), StepStatus::Ok);
            mux.end_step().unwrap();
        }
        let err = mux.begin_step().unwrap_err();
        assert!(format!("{err}").contains("misaligned"), "{err}");
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn conflicting_redeclaration_is_an_error() {
        let a = tmp("conflict-a.bp");
        let b = tmp("conflict-b.bp");
        let mut wa = BpWriter::create(&a, WriterCtx::default()).unwrap();
        write_slice(&mut wa, 1, 8, 0, 4);
        // Same variable name, different extent.
        let mut wb = BpWriter::create(&b, WriterCtx::default()).unwrap();
        write_slice(&mut wb, 1, 16, 4, 4);
        let mut mux = MultiplexReader::over(vec![
            Box::new(BpReader::open(&a).unwrap()),
            Box::new(BpReader::open(&b).unwrap()),
        ])
        .unwrap();
        let err = mux.begin_step().unwrap_err();
        assert!(format!("{err}").contains("redeclares"), "{err}");
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    /// Minimal scripted read child: plays a fixed `begin_step` status
    /// sequence (steps carry no data) and counts how often it was
    /// polled, for barrier-behavior tests.
    struct Scripted {
        script: Vec<StepStatus>,
        cursor: usize,
        begins: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    }

    impl Scripted {
        fn new(
            script: Vec<StepStatus>,
        ) -> (Scripted, std::sync::Arc<std::sync::atomic::AtomicUsize>)
        {
            let begins = std::sync::Arc::new(
                std::sync::atomic::AtomicUsize::new(0),
            );
            (Scripted { script, cursor: 0, begins: begins.clone() },
             begins)
        }
    }

    impl Engine for Scripted {
        fn engine_type(&self) -> &'static str {
            "scripted"
        }

        fn mode(&self) -> Mode {
            Mode::Read
        }

        fn begin_step(&mut self) -> Result<StepStatus> {
            self.begins
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let status = self
                .script
                .get(self.cursor)
                .copied()
                .unwrap_or(StepStatus::EndOfStream);
            if self.cursor < self.script.len() {
                self.cursor += 1;
            }
            Ok(status)
        }

        fn define_variable(&mut self, _d: &VarDecl) -> Result<VarHandle> {
            bail!("read-mode")
        }

        fn put_deferred(&mut self, _v: &VarHandle, _c: Chunk, _d: Bytes)
            -> Result<()>
        {
            bail!("read-mode")
        }

        fn put_span(&mut self, _v: &VarHandle, _c: Chunk)
            -> Result<&mut [u8]>
        {
            bail!("read-mode")
        }

        fn perform_puts(&mut self) -> Result<()> {
            bail!("read-mode")
        }

        fn put_attribute(&mut self, _n: &str, _v: Attribute)
            -> Result<()>
        {
            bail!("read-mode")
        }

        fn available_variables(&self) -> Vec<VarInfo> {
            Vec::new()
        }

        fn available_chunks(&self, _v: &str) -> Vec<WrittenChunkInfo> {
            Vec::new()
        }

        fn attribute(&self, _n: &str) -> Option<Attribute> {
            None
        }

        fn attribute_names(&self) -> Vec<String> {
            Vec::new()
        }

        fn get_deferred(&mut self, _v: &str, _s: Chunk)
            -> Result<GetHandle>
        {
            bail!("scripted child has no data")
        }

        fn perform_gets(&mut self) -> Result<()> {
            Ok(())
        }

        fn take_get(&mut self, _h: GetHandle) -> Result<Bytes> {
            bail!("scripted child has no data")
        }

        fn end_step(&mut self) -> Result<()> {
            Ok(())
        }

        fn close(&mut self) -> Result<()> {
            Ok(())
        }
    }

    #[test]
    fn discard_consistent_barrier_drops_the_step_everywhere() {
        // Child A offers two data steps; child B discards the first.
        // The barrier must discard step 0 everywhere (A's open step
        // consumed without data movement) and align step 1.
        use StepStatus::{Discarded, Ok as StepOk};
        let (a, _) = Scripted::new(vec![StepOk, StepOk]);
        let (b, _) = Scripted::new(vec![Discarded, StepOk]);
        let mut mux = MultiplexReader::over(vec![
            Box::new(a),
            Box::new(b),
        ])
        .unwrap();
        assert_eq!(mux.begin_step().unwrap(), StepStatus::Discarded);
        assert_eq!(mux.discarded_steps(), 1);
        assert_eq!(mux.begin_step().unwrap(), StepStatus::Ok);
        mux.end_step().unwrap();
        assert_eq!(mux.begin_step().unwrap(), StepStatus::EndOfStream);
    }

    #[test]
    fn not_ready_parks_resolved_children_without_repolling() {
        // Child A is ready immediately; child B needs three polls.
        // While B straggles, A's open step is parked — A must be
        // polled exactly once per aligned step, or ordinals would
        // shear apart.
        use StepStatus::{EndOfStream, NotReady, Ok as StepOk};
        let (a, a_begins) =
            Scripted::new(vec![StepOk, EndOfStream]);
        let (b, _) = Scripted::new(vec![NotReady, NotReady, StepOk,
                                        EndOfStream]);
        let mut mux = MultiplexReader::over(vec![
            Box::new(a),
            Box::new(b),
        ])
        .unwrap();
        assert_eq!(mux.begin_step().unwrap(), StepStatus::NotReady);
        assert_eq!(mux.begin_step().unwrap(), StepStatus::NotReady);
        assert_eq!(mux.begin_step().unwrap(), StepStatus::Ok);
        assert_eq!(
            a_begins.load(std::sync::atomic::Ordering::Relaxed),
            1,
            "parked child was re-polled"
        );
        mux.end_step().unwrap();
        assert_eq!(mux.begin_step().unwrap(), StepStatus::EndOfStream);
    }

    #[test]
    fn late_discard_verdicts_survive_not_ready_rounds() {
        // B discards step 0 while C is still NotReady: the Dropped
        // verdict must be remembered (not re-polled), so when C
        // resolves, the barrier discards ordinal 0 for everyone and
        // step 1 aligns correctly.
        use StepStatus::{Discarded, EndOfStream, NotReady,
                         Ok as StepOk};
        let (a, _) =
            Scripted::new(vec![StepOk, StepOk, EndOfStream]);
        let (b, b_begins) =
            Scripted::new(vec![Discarded, StepOk, EndOfStream]);
        let (c, _) = Scripted::new(vec![NotReady, StepOk, StepOk,
                                        EndOfStream]);
        let mut mux = MultiplexReader::over(vec![
            Box::new(a),
            Box::new(b),
            Box::new(c),
        ])
        .unwrap();
        assert_eq!(mux.begin_step().unwrap(), StepStatus::NotReady);
        assert_eq!(mux.begin_step().unwrap(), StepStatus::Discarded);
        assert_eq!(
            b_begins.load(std::sync::atomic::Ordering::Relaxed),
            1,
            "a Dropped child must not be re-polled before the barrier \
             resolves"
        );
        assert_eq!(mux.discarded_steps(), 1);
        assert_eq!(mux.begin_step().unwrap(), StepStatus::Ok);
        mux.end_step().unwrap();
        assert_eq!(mux.begin_step().unwrap(), StepStatus::EndOfStream);
    }

    #[test]
    fn trailing_discard_against_an_ended_sibling_is_misalignment() {
        use StepStatus::{Discarded, EndOfStream, Ok as StepOk};
        // A ends after one step; B discards a second ordinal A never
        // presented. That is a misaligned family — it must error, not
        // count a phantom discarded step and truncate silently.
        let (a, _) = Scripted::new(vec![StepOk, EndOfStream]);
        let (b, _) = Scripted::new(vec![StepOk, Discarded]);
        let mut mux = MultiplexReader::over(vec![
            Box::new(a),
            Box::new(b),
        ])
        .unwrap();
        assert_eq!(mux.begin_step().unwrap(), StepStatus::Ok);
        mux.end_step().unwrap();
        let err = mux.begin_step().unwrap_err();
        assert!(format!("{err}").contains("misaligned"), "{err}");
        assert_eq!(mux.discarded_steps(), 0);
    }

    #[test]
    fn write_mode_children_are_rejected() {
        let a = tmp("wmode.bp");
        let w = BpWriter::create(&a, WriterCtx::default()).unwrap();
        let err =
            MultiplexReader::over(vec![Box::new(w)]).unwrap_err();
        assert!(format!("{err}").contains("not a read engine"), "{err}");
        std::fs::remove_file(&a).ok();
    }

    #[test]
    fn empty_multiplexer_is_rejected() {
        assert!(MultiplexReader::over(Vec::new()).is_err());
    }
}
