//! Dense n-dimensional region copies.
//!
//! Engines store chunk payloads densely in row-major order of the chunk's
//! own extent. Serving a `get(selection)` means copying the intersection
//! of each stored chunk into the right place of the selection's dense
//! buffer — the *alignment* property of §3.1 exists precisely because
//! this re-assembly is work that perfectly aligned reads avoid.

use crate::openpmd::chunk::Chunk;

/// Row-major strides (in elements) for an extent.
pub fn strides(extent: &[u64]) -> Vec<u64> {
    let nd = extent.len();
    let mut s = vec![1u64; nd];
    for d in (0..nd.saturating_sub(1)).rev() {
        s[d] = s[d + 1] * extent[d + 1];
    }
    s
}

/// Linear element index of `point` (absolute coords) within `chunk`.
fn linear_index(chunk: &Chunk, point: &[u64], strides: &[u64]) -> u64 {
    let mut idx = 0;
    for d in 0..point.len() {
        idx += (point[d] - chunk.offset[d]) * strides[d];
    }
    idx
}

/// Copy the intersection of `src_chunk` (backed by `src`, dense row-major)
/// and `dst_chunk` (backed by `dst`) from `src` into `dst`.
///
/// `elem` is the element size in bytes. Returns the number of elements
/// copied (0 if disjoint).
pub fn copy_region(
    src_chunk: &Chunk,
    src: &[u8],
    dst_chunk: &Chunk,
    dst: &mut [u8],
    elem: usize,
) -> u64 {
    let inter = match src_chunk.intersect(dst_chunk) {
        Some(i) => i,
        None => return 0,
    };
    let nd = inter.ndim();
    debug_assert_eq!(src.len() as u64,
                     src_chunk.num_elements() * elem as u64);
    debug_assert_eq!(dst.len() as u64,
                     dst_chunk.num_elements() * elem as u64);

    let s_str = strides(&src_chunk.extent);
    let d_str = strides(&dst_chunk.extent);

    if nd == 0 {
        dst[..elem].copy_from_slice(&src[..elem]);
        return 1;
    }

    // Iterate over all "rows" of the intersection: the innermost dimension
    // is contiguous in both buffers, so each row is one memcpy.
    let row_len = inter.extent[nd - 1];
    let row_bytes = row_len as usize * elem;
    let outer_dims = &inter.extent[..nd - 1];
    let n_rows: u64 = outer_dims.iter().product();

    let mut point = inter.offset.clone();
    let mut copied = 0u64;
    for _ in 0..n_rows.max(1) {
        let s_idx = linear_index(src_chunk, &point, &s_str) as usize * elem;
        let d_idx = linear_index(dst_chunk, &point, &d_str) as usize * elem;
        dst[d_idx..d_idx + row_bytes]
            .copy_from_slice(&src[s_idx..s_idx + row_bytes]);
        copied += row_len;
        // Advance the outer index (odometer), innermost-first.
        for d in (0..nd - 1).rev() {
            point[d] += 1;
            if point[d] < inter.offset[d] + inter.extent[d] {
                break;
            }
            point[d] = inter.offset[d];
        }
    }
    copied
}

/// Extract a selection from a single chunk into a fresh dense buffer.
/// Panics if the chunk does not fully contain the selection.
pub fn extract(
    src_chunk: &Chunk,
    src: &[u8],
    selection: &Chunk,
    elem: usize,
) -> Vec<u8> {
    assert!(src_chunk.contains(selection),
            "extract: {selection:?} not contained in {src_chunk:?}");
    let mut out = crate::util::pool::acquire_zeroed(
        selection.num_elements() as usize * elem,
    );
    let copied = copy_region(src_chunk, src, selection, &mut out, elem);
    debug_assert_eq!(copied, selection.num_elements());
    out.detach()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill_pattern(chunk: &Chunk) -> Vec<u8> {
        // Element value = its absolute odometer coordinate hash, 4 bytes.
        let n = chunk.num_elements() as usize;
        let st = strides(&chunk.extent);
        let mut out = vec![0u8; n * 4];
        let nd = chunk.ndim();
        for lin in 0..n as u64 {
            // Decompose lin into absolute coords.
            let mut rem = lin;
            let mut key = 0u64;
            for d in 0..nd {
                let coord = chunk.offset[d] + rem / st[d];
                rem %= st[d];
                key = key.wrapping_mul(1000003).wrapping_add(coord);
            }
            out[lin as usize * 4..lin as usize * 4 + 4]
                .copy_from_slice(&(key as u32).to_le_bytes());
        }
        out
    }

    #[test]
    fn strides_row_major() {
        assert_eq!(strides(&[4, 5, 6]), vec![30, 6, 1]);
        assert_eq!(strides(&[7]), vec![1]);
        assert_eq!(strides(&[]), Vec::<u64>::new());
    }

    #[test]
    fn one_dim_copy() {
        let src_c = Chunk::new(vec![10], vec![20]);
        let src = fill_pattern(&src_c);
        let dst_c = Chunk::new(vec![0], vec![15]);
        let mut dst = vec![0u8; 15 * 4];
        let copied = copy_region(&src_c, &src, &dst_c, &mut dst, 4);
        assert_eq!(copied, 5); // overlap [10, 15)
        // dst elements 10..15 must equal src elements 0..5
        assert_eq!(&dst[40..60], &src[0..20]);
        assert!(dst[..40].iter().all(|&b| b == 0));
    }

    #[test]
    fn two_dim_extraction_matches_pattern() {
        let src_c = Chunk::new(vec![2, 3], vec![8, 9]);
        let src = fill_pattern(&src_c);
        let sel = Chunk::new(vec![4, 5], vec![3, 4]);
        let got = extract(&src_c, &src, &sel, 4);
        let want = fill_pattern(&sel);
        assert_eq!(got, want);
    }

    #[test]
    fn three_dim_reassembly_from_parts() {
        // Dataset [4, 4, 4] split into two chunks along dim 0;
        // a selection spanning both must reassemble exactly.
        let a = Chunk::new(vec![0, 0, 0], vec![2, 4, 4]);
        let b = Chunk::new(vec![2, 0, 0], vec![2, 4, 4]);
        let sel = Chunk::new(vec![1, 1, 0], vec![2, 2, 4]);
        let mut dst = vec![0u8; sel.num_elements() as usize * 4];
        let c1 = copy_region(&a, &fill_pattern(&a), &sel, &mut dst, 4);
        let c2 = copy_region(&b, &fill_pattern(&b), &sel, &mut dst, 4);
        assert_eq!(c1 + c2, sel.num_elements());
        assert_eq!(dst, fill_pattern(&sel));
    }

    #[test]
    fn disjoint_copies_nothing() {
        let a = Chunk::new(vec![0], vec![4]);
        let b = Chunk::new(vec![4], vec![4]);
        let src = fill_pattern(&a);
        let mut dst = vec![0xFFu8; 16];
        assert_eq!(copy_region(&a, &src, &b, &mut dst, 4), 0);
        assert!(dst.iter().all(|&x| x == 0xFF));
    }

    #[test]
    fn identical_chunks_full_copy() {
        let c = Chunk::new(vec![5, 5], vec![3, 3]);
        let src = fill_pattern(&c);
        let mut dst = vec![0u8; src.len()];
        assert_eq!(copy_region(&c, &src, &c, &mut dst, 4), 9);
        assert_eq!(dst, src);
    }

    #[test]
    #[should_panic]
    fn extract_requires_containment() {
        let c = Chunk::new(vec![0], vec![4]);
        let sel = Chunk::new(vec![2], vec![4]);
        extract(&c, &vec![0u8; 16], &sel, 4);
    }
}
