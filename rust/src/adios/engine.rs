//! The step-oriented engine abstraction — **v2: two-phase, handle-based**.
//!
//! Mirrors the ADIOS2 programming model the paper's performance story
//! rests on. An engine is opened in write or read mode; IO happens in
//! *steps* (here: one openPMD iteration per step). Within a step the API
//! is *deferred and batched*, exactly like ADIOS2's `Put(..., Mode::
//! Deferred)` / `Get(...)` + `PerformPuts` / `PerformGets` + `Span`:
//!
//! * **Write side.** [`Engine::define_variable`] validates a [`VarDecl`]
//!   once and returns a typed [`VarHandle`]; [`Engine::put_deferred`]
//!   only *enqueues* a chunk write (the payload `Arc` is captured, not
//!   copied); [`Engine::put_span`] hands out a mutable slice of the
//!   engine's own staging buffer so producers serialize **directly into
//!   the engine** (zero-copy on the in-process "RDMA" transport);
//!   [`Engine::perform_puts`] executes the whole batch. `end_step`
//!   implies a final `perform_puts` and *publishes* the step.
//! * **Read side.** [`Engine::get_deferred`] enqueues a selection and
//!   returns a [`GetHandle`]; [`Engine::perform_gets`] executes the whole
//!   batch — over SST this sends **one** wire request per writer for the
//!   entire batch instead of one per chunk — and [`Engine::take_get`]
//!   yields the densely packed bytes.
//! * **Backpressure.** `begin_step` on the write side may *discard* the
//!   step (SST's `QueueFullPolicy=Discard`, the mechanism behind the
//!   paper's "outputs are dropped as soon as the IO time cannot be
//!   hidden"). A discarded step's deferred queue is dropped wholesale at
//!   `end_step`/`perform_puts` — the producer is never blocked and no
//!   data moves.
//!
//! The eager v1 entry points [`Engine::put`] and [`Engine::get`] survive
//! as provided methods expressed in terms of the deferred core
//! (`defer` + immediate `perform`), so eager and batched paths are
//! byte-identical by construction — the engine-conformance suite in
//! `testing/` asserts this for every backend.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::ops::{OpChain, OpsReport};
use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};
use crate::openpmd::types::Datatype;
use crate::openpmd::Attribute;

/// Reference-counted, immutable data buffer.
///
/// Chunk payloads are handed between pipeline stages as `Bytes`; the
/// in-process transport forwards the `Arc` itself (zero-copy — the
/// property RDMA buys on real fabric).
pub type Bytes = Arc<Vec<u8>>;

/// Open mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Write,
    Read,
}

/// Result of `begin_step`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepStatus {
    /// Step is open; proceed with put/get.
    Ok,
    /// (read) No step available yet — poll again later.
    NotReady,
    /// (write, Discard policy) Writer queue full: the step was discarded
    /// before any data movement; the producer continues unblocked.
    Discarded,
    /// Stream ended: writer closed (read) / engine closed (write).
    EndOfStream,
}

/// Variable declaration passed to [`Engine::define_variable`].
#[derive(Clone, Debug, PartialEq)]
pub struct VarDecl {
    pub name: String,
    pub dtype: Datatype,
    /// Global dataset extent.
    pub shape: Vec<u64>,
    /// Operator chain applied to every chunk payload at put time and
    /// reversed at get time (ADIOS2's `AddOperation`). Identity by
    /// default; validated against `dtype` once, at `define_variable`.
    pub ops: OpChain,
}

impl VarDecl {
    pub fn new(name: impl Into<String>, dtype: Datatype,
               shape: Vec<u64>) -> Self {
        VarDecl { name: name.into(), dtype, shape,
                  ops: OpChain::identity() }
    }

    /// Attach an operator chain (builder style).
    pub fn with_ops(mut self, ops: OpChain) -> Self {
        self.ops = ops;
        self
    }
}

/// Typed, validated variable handle returned by
/// [`Engine::define_variable`]. Cheap to clone (the name and shape are
/// shared), checked once at definition time instead of on every put.
#[derive(Clone, Debug)]
pub struct VarHandle {
    id: u32,
    name: Arc<str>,
    dtype: Datatype,
    shape: Arc<[u64]>,
    ops: OpChain,
}

impl PartialEq for VarHandle {
    fn eq(&self, other: &Self) -> bool {
        self.id == other.id && self.name == other.name
    }
}

impl VarHandle {
    pub fn id(&self) -> u32 {
        self.id
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn dtype(&self) -> Datatype {
        self.dtype
    }

    pub fn shape(&self) -> &[u64] {
        &self.shape
    }

    /// The operator chain this variable was declared with.
    pub fn ops(&self) -> &OpChain {
        &self.ops
    }

    /// Validate `chunk` against this variable (rank, bounds) and return
    /// the dense payload size in bytes.
    pub fn chunk_bytes(&self, chunk: &Chunk) -> Result<usize> {
        if chunk.ndim() != self.shape.len() {
            bail!(
                "{}: chunk rank {} != dataset rank {}",
                self.name, chunk.ndim(), self.shape.len()
            );
        }
        for d in 0..chunk.ndim() {
            if chunk.offset[d] + chunk.extent[d] > self.shape[d] {
                bail!(
                    "{}: chunk {:?}+{:?} exceeds dataset extent {:?} \
                     in dim {d}",
                    self.name, chunk.offset, chunk.extent, self.shape
                );
            }
        }
        Ok(chunk.num_elements() as usize * self.dtype.size())
    }
}

/// Handle for a deferred read, redeemed via [`Engine::take_get`] after
/// [`Engine::perform_gets`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GetHandle(pub(crate) u64);

/// Variable metadata visible on the read side.
#[derive(Clone, Debug, PartialEq)]
pub struct VarInfo {
    pub name: String,
    pub dtype: Datatype,
    pub shape: Vec<u64>,
    /// Operator chain the writer declared for this variable — the read
    /// side decodes with it, and `pipeline::pipe` forwards it so a
    /// piped stream stays transformed end to end.
    pub ops: OpChain,
}

/// The engine trait. One instance per parallel rank and stream.
///
/// Engines are `Send` so ranks can run on their own threads; they are not
/// `Sync` — concurrency between ranks, not within one.
pub trait Engine: Send {
    /// Engine family name, e.g. `"bp"`, `"sst"`, `"json"`.
    fn engine_type(&self) -> &'static str;

    fn mode(&self) -> Mode;

    /// Open the next step.
    fn begin_step(&mut self) -> Result<StepStatus>;

    // ---- write side: two-phase --------------------------------------

    /// (write) Declare a variable once, validating the declaration and
    /// returning a typed handle. Redefining with an identical declaration
    /// returns the same handle; a conflicting redefinition is an error.
    /// May be called outside a step.
    fn define_variable(&mut self, decl: &VarDecl) -> Result<VarHandle>;

    /// (write) Enqueue one chunk write. The payload is captured by `Arc`
    /// — no copy, no IO. Nothing moves until [`Engine::perform_puts`] or
    /// `end_step`.
    fn put_deferred(&mut self, var: &VarHandle, chunk: Chunk, data: Bytes)
        -> Result<()>;

    /// (write) Reserve a staging span for one chunk and return it for
    /// in-place serialization — ADIOS2's `Span`: the producer writes
    /// directly into the engine's staging buffer, which the in-process
    /// transport later hands to readers without any further copy.
    /// The span is valid until the next call on this engine.
    fn put_span(&mut self, var: &VarHandle, chunk: Chunk)
        -> Result<&mut [u8]>;

    /// (write) Execute every enqueued put as one batch. On a discarded
    /// step this drops the queue instead.
    fn perform_puts(&mut self) -> Result<()>;

    /// (write) Attach an attribute to the current step.
    fn put_attribute(&mut self, name: &str, value: Attribute) -> Result<()>;

    // ---- read side --------------------------------------------------

    /// (read) Variables visible in the current step.
    fn available_variables(&self) -> Vec<VarInfo>;

    /// (read) Chunk table of a variable in the current step — the input to
    /// the §3 distribution strategies.
    fn available_chunks(&self, var: &str) -> Vec<WrittenChunkInfo>;

    /// (read) Attributes of the current step.
    fn attribute(&self, name: &str) -> Option<Attribute>;

    /// (read) All attribute names in the current step.
    fn attribute_names(&self) -> Vec<String>;

    /// (read) Enqueue a selection load. Nothing moves until
    /// [`Engine::perform_gets`].
    fn get_deferred(&mut self, var: &str, selection: Chunk)
        -> Result<GetHandle>;

    /// (read) Execute every enqueued get as one batch. Over SST this
    /// contacts each owning writer exactly once for the whole batch.
    fn perform_gets(&mut self) -> Result<()>;

    /// (read) Redeem a performed get: densely packed bytes in row-major
    /// order of the selection. Each handle can be taken once.
    fn take_get(&mut self, handle: GetHandle) -> Result<Bytes>;

    // ---- step / lifecycle -------------------------------------------

    /// Close the current step. On the write side this implies a final
    /// `perform_puts` and then *publishes* the step (file flush /
    /// stream delivery). On the read side, deferred gets that were
    /// never performed are dropped — their handles die with the step,
    /// so there is nobody left to redeem a late fetch.
    fn end_step(&mut self) -> Result<()>;

    /// Close the engine (writer: signals end-of-stream to readers).
    fn close(&mut self) -> Result<()>;

    // ---- operators --------------------------------------------------

    /// Cumulative operator (compression) statistics of this engine:
    /// encode side on writers, decode side on readers. Engines without
    /// an operator path report the empty default.
    fn ops_report(&self) -> OpsReport {
        OpsReport::default()
    }

    // ---- eager v1 conveniences, built on the deferred core ----------

    /// (write) Declare-and-write one chunk immediately: `define` +
    /// `put_deferred` + `perform_puts`. Byte-identical to the deferred
    /// path by construction.
    fn put(&mut self, var: &VarDecl, chunk: Chunk, data: Bytes)
        -> Result<()>
    {
        let handle = self.define_variable(var)?;
        self.put_deferred(&handle, chunk, data)?;
        self.perform_puts()
    }

    /// (read) Load a selection immediately: `get_deferred` +
    /// `perform_gets` + `take_get`.
    fn get(&mut self, var: &str, selection: Chunk) -> Result<Bytes> {
        let handle = self.get_deferred(var, selection)?;
        self.perform_gets()?;
        self.take_get(handle)
    }
}

// ======================================================================
// Deferred-queue machinery shared by the backends
// ======================================================================

/// Payload of a pending put: either a caller-owned `Arc` (from
/// `put_deferred`) or an engine-owned staging buffer (from `put_span`).
#[derive(Debug)]
pub enum PutPayload {
    Shared(Bytes),
    Owned(Vec<u8>),
}

impl PutPayload {
    pub fn len(&self) -> usize {
        match self {
            PutPayload::Shared(b) => b.len(),
            PutPayload::Owned(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The raw payload bytes (input to an operator encode).
    pub fn as_slice(&self) -> &[u8] {
        match self {
            PutPayload::Shared(b) => b,
            PutPayload::Owned(v) => v,
        }
    }

    /// Convert into `Bytes` without copying: an owned staging buffer is
    /// wrapped in a fresh `Arc`.
    pub fn into_bytes(self) -> Bytes {
        match self {
            PutPayload::Shared(b) => b,
            PutPayload::Owned(v) => Arc::new(v),
        }
    }
}

/// One enqueued chunk write.
#[derive(Debug)]
pub struct PendingPut {
    pub var: VarHandle,
    pub chunk: Chunk,
    pub data: PutPayload,
}

/// Write-side deferred machinery: the variable registry (engine
/// lifetime) plus the pending-put queue (one step). Backends embed this
/// and drain it in their `perform_puts`.
///
/// The registry retains one entry per distinct variable name for the
/// engine's lifetime — matching ADIOS2, where defined variables live as
/// long as the IO object. Under openPMD's per-iteration naming
/// (`/data/{i}/...`) that is a handful of small entries per step;
/// streams with very many steps that need a hard bound should reuse
/// names (variable-based iteration encoding) or recreate the engine.
#[derive(Debug, Default)]
pub struct PutQueue {
    vars: Vec<VarHandle>,
    by_name: BTreeMap<String, u32>,
    pending: Vec<PendingPut>,
}

impl PutQueue {
    /// Validate a declaration once and hand out (or re-hand-out) its
    /// typed handle.
    pub fn define(&mut self, decl: &VarDecl) -> Result<VarHandle> {
        if decl.name.is_empty() {
            bail!("variable name must not be empty");
        }
        if decl.shape.len() > 64 {
            bail!("variable {}: implausible rank {}", decl.name,
                  decl.shape.len());
        }
        // Operator-chain validation happens once, here — not per put.
        // Lossy-codec-on-integer and codec/dtype mismatches are typed
        // `OpsError`s surfaced at definition time.
        decl.ops
            .validate_for(decl.dtype)
            .map_err(|e| anyhow::anyhow!("variable {}: {e}", decl.name))?;
        if let Some(&id) = self.by_name.get(&decl.name) {
            let existing = &self.vars[id as usize];
            if existing.dtype != decl.dtype
                || existing.shape.as_ref() != decl.shape.as_slice()
                || existing.ops != decl.ops
            {
                bail!("conflicting redeclaration of {}", decl.name);
            }
            return Ok(existing.clone());
        }
        let id = self.vars.len() as u32;
        let handle = VarHandle {
            id,
            name: Arc::from(decl.name.as_str()),
            dtype: decl.dtype,
            shape: Arc::from(decl.shape.as_slice()),
            ops: decl.ops.clone(),
        };
        self.vars.push(handle.clone());
        self.by_name.insert(decl.name.clone(), id);
        Ok(handle)
    }

    /// Check a handle actually came from this engine's registry —
    /// name, dtype AND shape must match, so a stale handle from another
    /// engine cannot smuggle in the wrong bounds.
    fn check_handle(&self, var: &VarHandle) -> Result<()> {
        let known = self
            .vars
            .get(var.id as usize)
            .map(|v| {
                v.name == var.name
                    && v.dtype == var.dtype
                    && v.shape == var.shape
                    && v.ops == var.ops
            })
            .unwrap_or(false);
        if !known {
            bail!("unknown variable handle {:?} (wrong engine?)", var.name);
        }
        Ok(())
    }

    /// Enqueue a shared-payload put, validating chunk and byte length.
    pub fn enqueue(&mut self, var: &VarHandle, chunk: Chunk, data: Bytes)
        -> Result<()>
    {
        self.check_handle(var)?;
        let expect = var.chunk_bytes(&chunk)?;
        if data.len() != expect {
            bail!(
                "put {}: payload {} bytes, chunk needs {expect}",
                var.name, data.len()
            );
        }
        self.pending.push(PendingPut {
            var: var.clone(),
            chunk,
            data: PutPayload::Shared(data),
        });
        Ok(())
    }

    /// Enqueue an engine-owned staging buffer and return it for in-place
    /// serialization.
    pub fn span(&mut self, var: &VarHandle, chunk: Chunk)
        -> Result<&mut [u8]>
    {
        self.check_handle(var)?;
        let len = var.chunk_bytes(&chunk)?;
        // Pool-recycled staging: the buffer's capacity comes back via
        // reclaim once the downstream payload retires.
        let staging = crate::util::pool::acquire_zeroed(len).detach();
        self.pending.push(PendingPut {
            var: var.clone(),
            chunk,
            data: PutPayload::Owned(staging),
        });
        match &mut self.pending.last_mut().unwrap().data {
            PutPayload::Owned(buf) => Ok(buf.as_mut_slice()),
            PutPayload::Shared(_) => unreachable!(),
        }
    }

    /// Drain the queue for execution.
    pub fn drain(&mut self) -> Vec<PendingPut> {
        std::mem::take(&mut self.pending)
    }

    /// Drop the queue (discarded step). Returns how many puts were
    /// dropped.
    pub fn discard(&mut self) -> usize {
        let n = self.pending.len();
        self.pending.clear();
        n
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

/// One enqueued read.
#[derive(Clone, Debug)]
pub struct DeferredGet {
    pub handle: GetHandle,
    pub var: String,
    pub selection: Chunk,
}

/// Read-side deferred machinery: the pending-get queue plus the results
/// of the last `perform_gets`. Backends embed this.
#[derive(Debug, Default)]
pub struct GetQueue {
    next_id: u64,
    pending: Vec<DeferredGet>,
    ready: BTreeMap<u64, Bytes>,
    /// Handles whose batch failed mid-flight: `take` surfaces the
    /// recorded batch error instead of a baffling "unknown handle".
    poisoned: BTreeMap<u64, String>,
}

impl GetQueue {
    pub fn defer(&mut self, var: &str, selection: Chunk) -> GetHandle {
        let handle = GetHandle(self.next_id);
        self.next_id += 1;
        self.pending.push(DeferredGet {
            handle,
            var: var.to_string(),
            selection,
        });
        handle
    }

    pub fn has_pending(&self) -> bool {
        !self.pending.is_empty()
    }

    /// Drain enqueued gets for execution.
    pub fn drain_pending(&mut self) -> Vec<DeferredGet> {
        std::mem::take(&mut self.pending)
    }

    /// Record a performed get's result.
    pub fn complete(&mut self, handle: GetHandle, data: Bytes) {
        self.ready.insert(handle.0, data);
    }

    /// Mark a drained-but-never-completed get as failed: a later `take`
    /// reports `why` instead of "unknown handle". Backends whose
    /// `perform_gets` bails mid-batch poison every handle of the failed
    /// batch so the error survives to the redeem site.
    pub fn poison(&mut self, handle: GetHandle, why: impl Into<String>) {
        self.ready.remove(&handle.0);
        self.poisoned.insert(handle.0, why.into());
    }

    /// The shared failure path of `perform_gets` implementations:
    /// poison every handle of a drained batch with `err`, so whether
    /// the batch died on the wire (SST) or mid-sweep in a file backend,
    /// each of its handles reports the batch error — including any that
    /// had already completed before the failure (the batch is
    /// all-or-nothing from the caller's point of view).
    pub fn fail_batch(&mut self, batch: &[DeferredGet], err: &anyhow::Error) {
        let why = format!("{err:#}");
        for g in batch {
            self.poison(g.handle, why.clone());
        }
    }

    /// Redeem a performed get (once).
    pub fn take(&mut self, handle: GetHandle) -> Result<Bytes> {
        if self.pending.iter().any(|g| g.handle == handle) {
            bail!("get handle not performed yet — call perform_gets first");
        }
        if let Some(data) = self.ready.remove(&handle.0) {
            return Ok(data);
        }
        if let Some(why) = self.poisoned.remove(&handle.0) {
            bail!("get failed during perform_gets: {why}");
        }
        bail!("unknown or already-taken get handle (or the step ended)")
    }

    /// Forget deferred, unredeemed and poisoned gets (step boundary).
    pub fn reset(&mut self) {
        self.pending.clear();
        self.ready.clear();
        self.poisoned.clear();
    }
}

// ======================================================================
// Engine selection
// ======================================================================

/// Runtime-selectable engine kind — the *flexibility* property: which
/// backend moves the bytes is a config value, not code.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// BP file engine; value = number of writer ranks per aggregate file.
    Bp { aggregation: usize },
    /// SST staging engine over the named transport ("inproc" | "tcp").
    Sst { transport: String },
    /// Serial JSON files.
    Json,
    /// Read-only multiplexed shard family: open every shard named by a
    /// fleet's `<out>.index.json` and present them as ONE logical
    /// series via [`super::multiplex::MultiplexReader`]. The value is
    /// the index path.
    Shards { index: String },
    /// Read-only ad-hoc merge of concrete series sources (BP files,
    /// JSON step directories, or nested `*.index.json` shard families)
    /// into one logical series — the `merge:a,b,...` spec.
    Merge { sources: Vec<String> },
}

impl EngineKind {
    /// Parse `"bp"`, `"bp:6"`, `"sst"`, `"sst:tcp"`, `"json"`,
    /// `"shards:<index.json>"`, `"merge:a,b,..."`.
    ///
    /// Rejects degenerate configurations: `bp:0` (zero aggregation would
    /// make node-level file aggregation divide-by-zero downstream),
    /// `sst:` (an empty transport name can never resolve), `shards:`
    /// without an index path, and `merge:` with zero or empty sources.
    pub fn parse(s: &str) -> Result<EngineKind> {
        let (kind, arg) = match s.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (s, None),
        };
        Ok(match kind.to_ascii_lowercase().as_str() {
            "bp" => {
                let aggregation =
                    arg.map(|a| a.parse()).transpose()?.unwrap_or(1);
                if aggregation == 0 {
                    bail!("bp aggregation must be >= 1 (got bp:0)");
                }
                EngineKind::Bp { aggregation }
            }
            "sst" => {
                let transport = arg.unwrap_or("inproc");
                if transport.is_empty() {
                    bail!("sst transport name must not be empty (got \"sst:\")");
                }
                EngineKind::Sst { transport: transport.to_string() }
            }
            "json" => EngineKind::Json,
            "shards" => {
                let index = arg.unwrap_or("");
                if index.is_empty() {
                    bail!("shards spec needs an index path \
                           (shards:<out>.index.json)");
                }
                EngineKind::Shards { index: index.to_string() }
            }
            "merge" => {
                let sources: Vec<String> = arg
                    .unwrap_or("")
                    .split(',')
                    .map(|p| p.trim().to_string())
                    .collect();
                if sources.is_empty()
                    || sources.iter().any(|p| p.is_empty())
                {
                    bail!("merge spec needs a non-empty comma-separated \
                           source list (merge:a,b,...)");
                }
                EngineKind::Merge { sources }
            }
            other => anyhow::bail!("unknown engine kind {other:?}"),
        })
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineKind::Bp { aggregation } => write!(f, "bp:{aggregation}"),
            EngineKind::Sst { transport } => write!(f, "sst:{transport}"),
            EngineKind::Json => write!(f, "json"),
            EngineKind::Shards { index } => write!(f, "shards:{index}"),
            EngineKind::Merge { sources } => {
                write!(f, "merge:{}", sources.join(","))
            }
        }
    }
}

/// Helpers to view/copy typed slices as bytes (little-endian, host order —
/// the formats are not portable across endianness, as with real BP files
/// written without conversion).
///
/// One macro generates the pairs for every element type; the
/// bytes-to-values direction returns `Result` instead of panicking on
/// misaligned byte lengths.
pub mod cast {
    use super::Bytes;
    use anyhow::Result;
    use std::sync::Arc;

    macro_rules! impl_cast {
        ($($ty:ty => $to:ident, $from:ident);+ $(;)?) => {$(
            pub fn $to(xs: &[$ty]) -> Bytes {
                let mut v = Vec::with_capacity(std::mem::size_of_val(xs));
                for x in xs {
                    v.extend_from_slice(&x.to_le_bytes());
                }
                Arc::new(v)
            }

            pub fn $from(b: &[u8]) -> Result<Vec<$ty>> {
                const WIDTH: usize = std::mem::size_of::<$ty>();
                if b.len() % WIDTH != 0 {
                    anyhow::bail!(
                        "{}: {} bytes is not a multiple of the element \
                         width {}",
                        stringify!($from), b.len(), WIDTH
                    );
                }
                Ok(b.chunks_exact(WIDTH)
                    .map(|c| <$ty>::from_le_bytes(c.try_into().unwrap()))
                    .collect())
            }
        )+};
    }

    impl_cast! {
        f32 => f32_to_bytes, bytes_to_f32;
        f64 => f64_to_bytes, bytes_to_f64;
        u32 => u32_to_bytes, bytes_to_u32;
        u64 => u64_to_bytes, bytes_to_u64;
        i64 => i64_to_bytes, bytes_to_i64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parsing() {
        assert_eq!(EngineKind::parse("bp").unwrap(),
                   EngineKind::Bp { aggregation: 1 });
        assert_eq!(EngineKind::parse("bp:6").unwrap(),
                   EngineKind::Bp { aggregation: 6 });
        assert_eq!(EngineKind::parse("sst").unwrap(),
                   EngineKind::Sst { transport: "inproc".into() });
        assert_eq!(EngineKind::parse("sst:tcp").unwrap(),
                   EngineKind::Sst { transport: "tcp".into() });
        assert_eq!(EngineKind::parse("json").unwrap(), EngineKind::Json);
        assert!(EngineKind::parse("hdf5").is_err());
    }

    #[test]
    fn multiplex_engine_kinds_parse() {
        assert_eq!(
            EngineKind::parse("shards:out/run.bp.index.json").unwrap(),
            EngineKind::Shards { index: "out/run.bp.index.json".into() }
        );
        assert_eq!(
            EngineKind::parse("merge:a.bp, b-json ,c.bp").unwrap(),
            EngineKind::Merge {
                sources: vec!["a.bp".into(), "b-json".into(),
                              "c.bp".into()],
            }
        );
        // Degenerate specs are parse errors, not latent open failures.
        assert!(EngineKind::parse("shards").is_err());
        assert!(EngineKind::parse("shards:").is_err());
        assert!(EngineKind::parse("merge").is_err());
        assert!(EngineKind::parse("merge:").is_err());
        assert!(EngineKind::parse("merge:a,,b").is_err());
    }

    #[test]
    fn degenerate_engine_kinds_rejected() {
        // bp:0 would make node-level aggregation divide by zero.
        assert!(EngineKind::parse("bp:0").is_err());
        // Empty SST transport names can never resolve.
        assert!(EngineKind::parse("sst:").is_err());
        // Garbage aggregation counts are parse errors, not panics.
        assert!(EngineKind::parse("bp:many").is_err());
        assert!(EngineKind::parse("bp:-1").is_err());
    }

    #[test]
    fn engine_kind_display_round_trips() {
        for s in ["bp:6", "sst:tcp", "json", "shards:run.bp.index.json",
                  "merge:a.bp,b.bp"] {
            assert_eq!(EngineKind::parse(s).unwrap().to_string(), s);
        }
    }

    #[test]
    fn valid_kinds_survive_display_parse_display() {
        for s in ["bp", "bp:12", "sst", "sst:inproc", "sst:tcp", "json",
                  "shards:x.index.json", "merge:a,b,c"] {
            let kind = EngineKind::parse(s).unwrap();
            let rendered = kind.to_string();
            assert_eq!(EngineKind::parse(&rendered).unwrap(), kind,
                       "{s} -> {rendered} did not round-trip");
        }
    }

    #[test]
    fn cast_round_trips() {
        let xs = vec![1.0f32, -2.5, 3.25];
        assert_eq!(cast::bytes_to_f32(&cast::f32_to_bytes(&xs)).unwrap(),
                   xs);
        let ys = vec![1.0f64, -2.5];
        assert_eq!(cast::bytes_to_f64(&cast::f64_to_bytes(&ys)).unwrap(),
                   ys);
        let zs = vec![7u64, 8, 9];
        assert_eq!(cast::bytes_to_u64(&cast::u64_to_bytes(&zs)).unwrap(),
                   zs);
        let us = vec![1u32, 2];
        assert_eq!(cast::bytes_to_u32(&cast::u32_to_bytes(&us)).unwrap(),
                   us);
        let is = vec![-3i64, 4];
        assert_eq!(cast::bytes_to_i64(&cast::i64_to_bytes(&is)).unwrap(),
                   is);
    }

    #[test]
    fn cast_misaligned_lengths_are_errors_not_panics() {
        assert!(cast::bytes_to_f32(&[0u8; 5]).is_err());
        assert!(cast::bytes_to_f64(&[0u8; 4]).is_err());
        assert!(cast::bytes_to_u64(&[0u8; 9]).is_err());
        assert!(cast::bytes_to_u32(&[0u8; 3]).is_err());
        assert!(cast::bytes_to_i64(&[0u8; 1]).is_err());
    }

    #[test]
    fn put_queue_validates_once_per_definition() {
        let mut q = PutQueue::default();
        let decl = VarDecl::new("/x", Datatype::F32, vec![8]);
        let h1 = q.define(&decl).unwrap();
        let h2 = q.define(&decl).unwrap();
        assert_eq!(h1, h2);
        // Conflicting redefinition.
        let bad = VarDecl::new("/x", Datatype::F64, vec![8]);
        assert!(q.define(&bad).is_err());
        let bad2 = VarDecl::new("/x", Datatype::F32, vec![9]);
        assert!(q.define(&bad2).is_err());
    }

    #[test]
    fn put_queue_validates_operator_chains_at_definition() {
        use crate::adios::ops::OpChain;
        let mut q = PutQueue::default();
        // Lossy codec on an integer variable: typed error at define.
        let lossy = VarDecl::new("/ids", Datatype::U64, vec![8])
            .with_ops(OpChain::parse("zfp:10").unwrap());
        let err = q.define(&lossy).unwrap_err();
        assert!(format!("{err}").contains("lossy"), "{err}");
        // Integer codec on a float variable: typed error at define.
        let mismatch = VarDecl::new("/f", Datatype::F32, vec![8])
            .with_ops(OpChain::parse("delta").unwrap());
        assert!(q.define(&mismatch).is_err());
        // Valid chain defines; identical redefinition returns the same
        // handle; a different chain is a conflicting redeclaration.
        let chain = OpChain::parse("shuffle|rle").unwrap();
        let decl = VarDecl::new("/f", Datatype::F32, vec![8])
            .with_ops(chain.clone());
        let h1 = q.define(&decl).unwrap();
        assert_eq!(h1.ops(), &chain);
        let h2 = q.define(&decl).unwrap();
        assert_eq!(h1, h2);
        let other = VarDecl::new("/f", Datatype::F32, vec![8])
            .with_ops(OpChain::parse("rle").unwrap());
        assert!(q.define(&other).is_err());
        let plain = VarDecl::new("/f", Datatype::F32, vec![8]);
        assert!(q.define(&plain).is_err());
    }

    #[test]
    fn put_queue_rejects_bad_chunks() {
        let mut q = PutQueue::default();
        let h = q
            .define(&VarDecl::new("/x", Datatype::F32, vec![8]))
            .unwrap();
        // Wrong byte count.
        assert!(q
            .enqueue(&h, Chunk::new(vec![0], vec![4]),
                     Arc::new(vec![0u8; 15]))
            .is_err());
        // Out of bounds.
        assert!(q
            .enqueue(&h, Chunk::new(vec![6], vec![4]),
                     Arc::new(vec![0u8; 16]))
            .is_err());
        // Wrong rank.
        assert!(q
            .enqueue(&h, Chunk::new(vec![0, 0], vec![2, 2]),
                     Arc::new(vec![0u8; 16]))
            .is_err());
        // Valid.
        assert!(q
            .enqueue(&h, Chunk::new(vec![4], vec![4]),
                     Arc::new(vec![0u8; 16]))
            .is_ok());
        assert_eq!(q.pending_len(), 1);
    }

    #[test]
    fn put_queue_span_is_writable_and_drains() {
        let mut q = PutQueue::default();
        let h = q
            .define(&VarDecl::new("/x", Datatype::U64, vec![4]))
            .unwrap();
        {
            let span = q.span(&h, Chunk::whole(vec![4])).unwrap();
            assert_eq!(span.len(), 32);
            span[0] = 7;
        }
        let drained = q.drain();
        assert_eq!(drained.len(), 1);
        let bytes = match drained.into_iter().next().unwrap().data {
            PutPayload::Owned(v) => v,
            _ => panic!("span must be engine-owned"),
        };
        assert_eq!(bytes[0], 7);
        assert_eq!(q.pending_len(), 0);
    }

    #[test]
    fn get_queue_lifecycle() {
        let mut q = GetQueue::default();
        let h = q.defer("/x", Chunk::whole(vec![4]));
        // Not performed yet.
        assert!(q.take(h).is_err());
        let pending = q.drain_pending();
        assert_eq!(pending.len(), 1);
        q.complete(h, Arc::new(vec![1, 2, 3]));
        assert_eq!(*q.take(h).unwrap(), vec![1, 2, 3]);
        // Double-take fails.
        assert!(q.take(h).is_err());
    }

    #[test]
    fn poisoned_handles_report_the_batch_error() {
        let mut q = GetQueue::default();
        let h1 = q.defer("/x", Chunk::whole(vec![4]));
        let h2 = q.defer("/y", Chunk::whole(vec![4]));
        let drained = q.drain_pending();
        assert_eq!(drained.len(), 2);
        // The batch failed mid-flight: every drained handle poisoned.
        for g in &drained {
            q.poison(g.handle, "writer 3 replied garbage");
        }
        for h in [h1, h2] {
            let err = format!("{}", q.take(h).unwrap_err());
            assert!(err.contains("writer 3 replied garbage"), "{err}");
            assert!(!err.contains("unknown"), "{err}");
        }
        // Poison is consumed by take; afterwards the handle is unknown.
        assert!(format!("{}", q.take(h1).unwrap_err())
            .contains("unknown"));
        // reset() clears leftover poison.
        let h3 = q.defer("/z", Chunk::whole(vec![2]));
        q.drain_pending();
        q.poison(h3, "stale");
        q.reset();
        assert!(format!("{}", q.take(h3).unwrap_err())
            .contains("unknown"));
    }

    #[test]
    fn engine_trait_objects_are_send() {
        // The staged pipe moves engines (as `&mut dyn Engine`) into a
        // fetch thread; this pins the `Engine: Send` supertrait so the
        // capability cannot silently regress.
        fn assert_send<T: Send + ?Sized>() {}
        assert_send::<dyn Engine>();
        assert_send::<Box<dyn Engine>>();
        assert_send::<&mut dyn Engine>();
    }
}
