//! The step-oriented engine abstraction.
//!
//! Mirrors the ADIOS2 programming model the paper relies on: an engine is
//! opened in write or read mode; IO happens in *steps* (here: one openPMD
//! iteration per step); within a step the writer `put`s chunks of named
//! variables and attributes, the reader inspects available variables /
//! chunks and `get`s selections. `begin_step` on the read side reports
//! whether a step is available, and on the write side may *discard* the
//! step under backpressure (SST's `QueueFullPolicy=Discard`, the mechanism
//! behind the paper's "outputs are dropped as soon as the IO time cannot
//! be hidden" behaviour).

use std::sync::Arc;

use anyhow::Result;

use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};
use crate::openpmd::types::Datatype;
use crate::openpmd::Attribute;

/// Reference-counted, immutable data buffer.
///
/// Chunk payloads are handed between pipeline stages as `Bytes`; the
/// in-process transport forwards the `Arc` itself (zero-copy — the
/// property RDMA buys on real fabric).
pub type Bytes = Arc<Vec<u8>>;

/// Open mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Write,
    Read,
}

/// Result of `begin_step`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepStatus {
    /// Step is open; proceed with put/get.
    Ok,
    /// (read) No step available yet — poll again later.
    NotReady,
    /// (write, Discard policy) Writer queue full: the step was discarded
    /// before any data movement; the producer continues unblocked.
    Discarded,
    /// Stream ended: writer closed (read) / engine closed (write).
    EndOfStream,
}

/// Variable declaration for `put`.
#[derive(Clone, Debug, PartialEq)]
pub struct VarDecl {
    pub name: String,
    pub dtype: Datatype,
    /// Global dataset extent.
    pub shape: Vec<u64>,
}

impl VarDecl {
    pub fn new(name: impl Into<String>, dtype: Datatype,
               shape: Vec<u64>) -> Self {
        VarDecl { name: name.into(), dtype, shape }
    }
}

/// Variable metadata visible on the read side.
#[derive(Clone, Debug, PartialEq)]
pub struct VarInfo {
    pub name: String,
    pub dtype: Datatype,
    pub shape: Vec<u64>,
}

/// The engine trait. One instance per parallel rank and stream.
///
/// Engines are `Send` so ranks can run on their own threads; they are not
/// `Sync` — concurrency between ranks, not within one.
pub trait Engine: Send {
    /// Engine family name, e.g. `"bp"`, `"sst"`, `"json"`.
    fn engine_type(&self) -> &'static str;

    fn mode(&self) -> Mode;

    /// Open the next step.
    fn begin_step(&mut self) -> Result<StepStatus>;

    /// (write) Declare-and-write one chunk of a variable.
    fn put(&mut self, var: &VarDecl, chunk: Chunk, data: Bytes) -> Result<()>;

    /// (write) Attach an attribute to the current step.
    fn put_attribute(&mut self, name: &str, value: Attribute) -> Result<()>;

    /// (read) Variables visible in the current step.
    fn available_variables(&self) -> Vec<VarInfo>;

    /// (read) Chunk table of a variable in the current step — the input to
    /// the §3 distribution strategies.
    fn available_chunks(&self, var: &str) -> Vec<WrittenChunkInfo>;

    /// (read) Attributes of the current step.
    fn attribute(&self, name: &str) -> Option<Attribute>;

    /// (read) All attribute names in the current step.
    fn attribute_names(&self) -> Vec<String>;

    /// (read) Load a selection. Blocking; returns densely packed bytes in
    /// row-major order of the selection.
    fn get(&mut self, var: &str, selection: Chunk) -> Result<Bytes>;

    /// Close the current step. On the write side this *publishes* the step
    /// (file flush / stream delivery).
    fn end_step(&mut self) -> Result<()>;

    /// Close the engine (writer: signals end-of-stream to readers).
    fn close(&mut self) -> Result<()>;
}

/// Runtime-selectable engine kind — the *flexibility* property: which
/// backend moves the bytes is a config value, not code.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// BP file engine; value = number of writer ranks per aggregate file.
    Bp { aggregation: usize },
    /// SST staging engine over the named transport ("inproc" | "tcp").
    Sst { transport: String },
    /// Serial JSON files.
    Json,
}

impl EngineKind {
    /// Parse `"bp"`, `"bp:6"`, `"sst"`, `"sst:tcp"`, `"json"`.
    pub fn parse(s: &str) -> Result<EngineKind> {
        let (kind, arg) = match s.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (s, None),
        };
        Ok(match kind.to_ascii_lowercase().as_str() {
            "bp" => EngineKind::Bp {
                aggregation: arg.map(|a| a.parse()).transpose()?.unwrap_or(1),
            },
            "sst" => EngineKind::Sst {
                transport: arg.unwrap_or("inproc").to_string(),
            },
            "json" => EngineKind::Json,
            other => anyhow::bail!("unknown engine kind {other:?}"),
        })
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineKind::Bp { aggregation } => write!(f, "bp:{aggregation}"),
            EngineKind::Sst { transport } => write!(f, "sst:{transport}"),
            EngineKind::Json => write!(f, "json"),
        }
    }
}

/// Helpers to view/copy typed slices as bytes (little-endian, host order —
/// the formats are not portable across endianness, as with real BP files
/// written without conversion).
pub mod cast {
    use super::Bytes;
    use std::sync::Arc;

    pub fn f32_to_bytes(xs: &[f32]) -> Bytes {
        let mut v = Vec::with_capacity(xs.len() * 4);
        for x in xs {
            v.extend_from_slice(&x.to_le_bytes());
        }
        Arc::new(v)
    }

    pub fn bytes_to_f32(b: &[u8]) -> Vec<f32> {
        assert_eq!(b.len() % 4, 0);
        b.chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn f64_to_bytes(xs: &[f64]) -> Bytes {
        let mut v = Vec::with_capacity(xs.len() * 8);
        for x in xs {
            v.extend_from_slice(&x.to_le_bytes());
        }
        Arc::new(v)
    }

    pub fn bytes_to_f64(b: &[u8]) -> Vec<f64> {
        assert_eq!(b.len() % 8, 0);
        b.chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn u64_to_bytes(xs: &[u64]) -> Bytes {
        let mut v = Vec::with_capacity(xs.len() * 8);
        for x in xs {
            v.extend_from_slice(&x.to_le_bytes());
        }
        Arc::new(v)
    }

    pub fn bytes_to_u64(b: &[u8]) -> Vec<u64> {
        assert_eq!(b.len() % 8, 0);
        b.chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parsing() {
        assert_eq!(EngineKind::parse("bp").unwrap(),
                   EngineKind::Bp { aggregation: 1 });
        assert_eq!(EngineKind::parse("bp:6").unwrap(),
                   EngineKind::Bp { aggregation: 6 });
        assert_eq!(EngineKind::parse("sst").unwrap(),
                   EngineKind::Sst { transport: "inproc".into() });
        assert_eq!(EngineKind::parse("sst:tcp").unwrap(),
                   EngineKind::Sst { transport: "tcp".into() });
        assert_eq!(EngineKind::parse("json").unwrap(), EngineKind::Json);
        assert!(EngineKind::parse("hdf5").is_err());
    }

    #[test]
    fn engine_kind_display_round_trips() {
        for s in ["bp:6", "sst:tcp", "json"] {
            assert_eq!(EngineKind::parse(s).unwrap().to_string(), s);
        }
    }

    #[test]
    fn cast_round_trips() {
        let xs = vec![1.0f32, -2.5, 3.25];
        assert_eq!(cast::bytes_to_f32(&cast::f32_to_bytes(&xs)), xs);
        let ys = vec![1.0f64, -2.5];
        assert_eq!(cast::bytes_to_f64(&cast::f64_to_bytes(&ys)), ys);
        let zs = vec![7u64, 8, 9];
        assert_eq!(cast::bytes_to_u64(&cast::u64_to_bytes(&zs)), zs);
    }
}
