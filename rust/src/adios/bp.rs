//! BP — the binary-pack *file* engine (S3): the paper's baseline.
//!
//! A stripped-down cousin of ADIOS2's BP4: steps are appended
//! sequentially to a single file — metadata block first, then the chunk
//! payloads — so the file can be both written and read in streaming
//! fashion (no random access needed to make progress, matching how BP
//! files behave under `adios2::Mode::Read` streaming).
//!
//! Data is kept organized *as written* (one payload record per put), which
//! is what gives the §3 *alignment* property its meaning: a read that
//! matches a written chunk is one contiguous file read; a misaligned read
//! touches many records.
//!
//! Node-level aggregation (Fig. 5: "each node creates only one file")
//! arises in this codebase by composition — N producers stream via SST to
//! one `openpmd-pipe` which owns one `BpWriter` — exactly the paper's
//! SST+BP setup. The `aggregation` parameter of `EngineKind::Bp` is a
//! modeling knob for the simulated benchmarks.
//!
//! This module is a `pallas-lint` hardened zone: a corrupt or
//! truncated BP file must surface as a typed [`BpError`] the caller
//! (or the multiplex barrier) can report — never a panic.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use once_cell::sync::Lazy;

use super::engine::{
    Bytes, Engine, GetHandle, GetQueue, Mode, PutQueue, StepStatus,
    VarDecl, VarHandle, VarInfo,
};
use super::ops::{self, OpChain, OpsReport};
use super::region;
use super::wire::{Reader as WireReader, StepMeta, VarMeta};
use crate::obs::metrics::{counter, Counter};
use crate::obs::trace;
use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};
use crate::openpmd::Attribute;
use crate::util::pool;

static BP_PUT_CHUNKS: Lazy<&'static Counter> =
    Lazy::new(|| counter("bp.put_chunks"));
static BP_PUT_BYTES: Lazy<&'static Counter> =
    Lazy::new(|| counter("bp.put_bytes"));
static BP_GET_SWEEPS: Lazy<&'static Counter> =
    Lazy::new(|| counter("bp.get_sweeps"));
static BP_GET_BYTES: Lazy<&'static Counter> =
    Lazy::new(|| counter("bp.get_bytes"));

// BP02: variable metadata carries an operator chain and payload records
// of operated variables are stored operator-framed (compressed on disk).
// 03: chunk metadata grew the staged payload size (encoded_bytes) used
// by cost-aware distribution strategies.
const MAGIC: &[u8; 8] = b"OPMDBP03";
const STEP_MARKER: u64 = 0x0053_5445_5000_0000; // "STEP"-ish sentinel

/// Typed reader-side errors for corrupt or truncated BP files.
///
/// These surface through `anyhow::Result` as error *sources*, so
/// callers that care (the multiplex barrier, `openpmd-pipe`) can
/// `downcast_ref::<BpError>()` and report which file is damaged and
/// how, while everyone else just propagates. Every variant replaces a
/// code path that could previously allocate unboundedly or panic on
/// malformed input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BpError {
    /// The file does not start with the current `MAGIC` bytes.
    BadMagic { found: [u8; 8] },
    /// A step boundary did not carry the step sentinel — the file is
    /// damaged or was written by a different layout.
    BadStepMarker { found: u64 },
    /// A length/count field exceeds its plausibility bound; reading on
    /// would allocate or seek absurdly.
    ImplausibleLength { what: &'static str, len: u64, max: u64 },
    /// A payload record's offset/extent ranks disagree.
    RankMismatch { offset: usize, extent: usize },
    /// EOF in the middle of a step (file truncated mid-write).
    Truncated { what: &'static str },
}

impl std::fmt::Display for BpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BpError::BadMagic { found } => write!(
                f,
                "not a BP file: bad magic {:?} (expected {:?})",
                String::from_utf8_lossy(found),
                String::from_utf8_lossy(MAGIC),
            ),
            BpError::BadStepMarker { found } => {
                write!(f, "corrupt BP file: bad step marker {found:#x}")
            }
            BpError::ImplausibleLength { what, len, max } => write!(
                f,
                "corrupt BP file: implausible {what} of {len} \
                 (limit {max})"
            ),
            BpError::RankMismatch { offset, extent } => write!(
                f,
                "corrupt BP payload record: offset rank {offset} != \
                 extent rank {extent}"
            ),
            BpError::Truncated { what } => {
                write!(f, "truncated BP file: EOF while reading {what}")
            }
        }
    }
}

impl std::error::Error for BpError {}

/// Plausibility bound + typed error for a length/count field read from
/// the file, applied *before* any allocation sized by it.
fn bounded(len: u64, max: u64, what: &'static str) -> Result<usize> {
    if len > max {
        return Err(BpError::ImplausibleLength { what, len, max }.into());
    }
    Ok(len as usize)
}

/// Writer context: rank + hostname recorded into every chunk's metadata.
#[derive(Clone, Debug)]
pub struct WriterCtx {
    pub rank: usize,
    pub hostname: String,
}

impl Default for WriterCtx {
    fn default() -> Self {
        WriterCtx { rank: 0, hostname: "localhost".into() }
    }
}

// ======================================================================
// Writer
// ======================================================================

/// Append-only BP file writer.
pub struct BpWriter {
    path: PathBuf,
    file: BufWriter<File>,
    ctx: WriterCtx,
    step: u64,
    current: Option<(StepMeta, Vec<(String, Chunk, Bytes)>)>,
    /// Variable registry + deferred-put queue (two-phase API).
    puts: PutQueue,
    /// Encode-side operator accounting.
    ops_stats: OpsReport,
    pub bytes_written: u64,
}

impl BpWriter {
    pub fn create(path: impl AsRef<Path>, ctx: WriterCtx) -> Result<BpWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut file = BufWriter::new(
            File::create(&path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        file.write_all(MAGIC)?;
        Ok(BpWriter {
            path,
            file,
            ctx,
            step: 0,
            current: None,
            puts: PutQueue::default(),
            ops_stats: OpsReport::default(),
            bytes_written: MAGIC.len() as u64,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Engine for BpWriter {
    fn engine_type(&self) -> &'static str {
        "bp"
    }

    fn mode(&self) -> Mode {
        Mode::Write
    }

    fn begin_step(&mut self) -> Result<StepStatus> {
        if self.current.is_some() {
            bail!("begin_step while a step is open");
        }
        self.current = Some((StepMeta::default(), Vec::new()));
        Ok(StepStatus::Ok)
    }

    fn define_variable(&mut self, decl: &VarDecl) -> Result<VarHandle> {
        self.puts.define(decl)
    }

    fn put_deferred(&mut self, var: &VarHandle, chunk: Chunk, data: Bytes)
        -> Result<()>
    {
        if self.current.is_none() {
            bail!("put outside step");
        }
        self.puts.enqueue(var, chunk, data)
    }

    fn put_span(&mut self, var: &VarHandle, chunk: Chunk)
        -> Result<&mut [u8]>
    {
        if self.current.is_none() {
            bail!("put_span outside step");
        }
        self.puts.span(var, chunk)
    }

    fn perform_puts(&mut self) -> Result<()> {
        let pending = self.puts.drain();
        if pending.is_empty() {
            return Ok(());
        }
        let mut sp = trace::span("bp.perform_puts")
            .with("step", self.step)
            .with("chunks", pending.len());
        let mut put_bytes = 0u64;
        BP_PUT_CHUNKS.add(pending.len() as u64);
        let (meta, payloads) = self
            .current
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("perform_puts outside step"))?;
        for p in pending {
            // The operator chain is applied here, in the deferred core:
            // payload records of operated variables land on disk
            // operator-framed (compressed), never raw.
            let data = ops::encode_put(&p.var, &p.chunk, p.data,
                                       &mut self.ops_stats)?;
            // The stored size rides in the chunk metadata so readers
            // (and cost-aware distribution strategies) know the real
            // byte footprint without opening the record.
            let info = WrittenChunkInfo::new(p.chunk.clone(),
                                             self.ctx.rank,
                                             self.ctx.hostname.clone())
                .with_encoded_bytes(data.len() as u64);
            match meta.vars.iter_mut().find(|v| v.name == p.var.name()) {
                Some(vm) => vm.chunks.push(info),
                None => meta.vars.push(VarMeta {
                    name: p.var.name().to_string(),
                    dtype: p.var.dtype(),
                    shape: p.var.shape().to_vec(),
                    ops: p.var.ops().clone(),
                    chunks: vec![info],
                }),
            }
            put_bytes += data.len() as u64;
            payloads.push((p.var.name().to_string(), p.chunk, data));
        }
        BP_PUT_BYTES.add(put_bytes);
        sp.set("bytes", put_bytes);
        Ok(())
    }

    fn put_attribute(&mut self, name: &str, value: Attribute) -> Result<()> {
        let (meta, _) = self
            .current
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("put_attribute outside step"))?;
        meta.attributes.insert(name.to_string(), value);
        Ok(())
    }

    fn available_variables(&self) -> Vec<VarInfo> {
        Vec::new()
    }

    fn available_chunks(&self, _var: &str) -> Vec<WrittenChunkInfo> {
        Vec::new()
    }

    fn attribute(&self, _name: &str) -> Option<Attribute> {
        None
    }

    fn attribute_names(&self) -> Vec<String> {
        Vec::new()
    }

    fn get_deferred(&mut self, _var: &str, _selection: Chunk)
        -> Result<GetHandle>
    {
        bail!("get on a write-mode BP engine")
    }

    fn perform_gets(&mut self) -> Result<()> {
        bail!("perform_gets on a write-mode BP engine")
    }

    fn take_get(&mut self, _handle: GetHandle) -> Result<Bytes> {
        bail!("take_get on a write-mode BP engine")
    }

    fn end_step(&mut self) -> Result<()> {
        self.perform_puts()?;
        let mut sp = trace::span("bp.write_sweep").with("step", self.step);
        let (meta, payloads) = self
            .current
            .take()
            .ok_or_else(|| anyhow::anyhow!("end_step without begin_step"))?;
        let mut head = Vec::with_capacity(256);
        head.extend_from_slice(&STEP_MARKER.to_le_bytes());
        head.extend_from_slice(&self.step.to_le_bytes());
        let mut meta_buf = Vec::with_capacity(1024);
        meta.encode(&mut meta_buf);
        head.extend_from_slice(&(meta_buf.len() as u64).to_le_bytes());
        self.file.write_all(&head)?;
        self.file.write_all(&meta_buf)?;
        self.file
            .write_all(&(payloads.len() as u64).to_le_bytes())?;
        let mut written = head.len() as u64 + meta_buf.len() as u64 + 8;
        for (name, chunk, data) in &payloads {
            let mut rec = Vec::with_capacity(64);
            rec.extend_from_slice(&(name.len() as u64).to_le_bytes());
            rec.extend_from_slice(name.as_bytes());
            rec.extend_from_slice(&(chunk.offset.len() as u64).to_le_bytes());
            for x in &chunk.offset {
                rec.extend_from_slice(&x.to_le_bytes());
            }
            rec.extend_from_slice(&(chunk.extent.len() as u64).to_le_bytes());
            for x in &chunk.extent {
                rec.extend_from_slice(&x.to_le_bytes());
            }
            rec.extend_from_slice(&(data.len() as u64).to_le_bytes());
            self.file.write_all(&rec)?;
            self.file.write_all(data)?;
            written += rec.len() as u64 + data.len() as u64;
        }
        self.file.flush()?;
        sp.set("bytes", written);
        self.bytes_written += written;
        self.step += 1;
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        if self.current.is_some() {
            self.end_step()?;
        }
        self.file.flush()?;
        Ok(())
    }

    fn ops_report(&self) -> OpsReport {
        self.ops_stats
    }
}

impl Drop for BpWriter {
    fn drop(&mut self) {
        let _ = self.close();
    }
}

// ======================================================================
// Reader
// ======================================================================

struct PayloadIndex {
    chunk: Chunk,
    file_offset: u64,
    len: u64,
}

/// Streaming BP file reader.
pub struct BpReader {
    file: BufReader<File>,
    /// Current step metadata.
    meta: Option<(u64, StepMeta)>,
    /// var -> payload records of the current step.
    index: BTreeMap<String, Vec<PayloadIndex>>,
    /// Deferred-get queue (two-phase API).
    gets: GetQueue,
    /// Decode-side operator accounting.
    ops_stats: OpsReport,
    open_step: bool,
}

impl BpReader {
    pub fn open(path: impl AsRef<Path>) -> Result<BpReader> {
        let path = path.as_ref();
        let mut file = BufReader::new(
            File::open(path)
                .with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        file.read_exact(&mut magic).context("reading BP magic")?;
        if &magic != MAGIC {
            return Err(BpError::BadMagic { found: magic })
                .with_context(|| path.display().to_string());
        }
        Ok(BpReader {
            file,
            meta: None,
            index: BTreeMap::new(),
            gets: GetQueue::default(),
            ops_stats: OpsReport::default(),
            open_step: false,
        })
    }

    fn read_u64(&mut self) -> Result<Option<u64>> {
        let mut b = [0u8; 8];
        match self.file.read_exact(&mut b) {
            Ok(()) => Ok(Some(u64::from_le_bytes(b))),
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                Ok(None)
            }
            Err(e) => Err(e.into()),
        }
    }

    fn read_exact_u64(&mut self, what: &'static str) -> Result<u64> {
        self.read_u64()?
            .ok_or_else(|| BpError::Truncated { what }.into())
    }
}

impl Engine for BpReader {
    fn engine_type(&self) -> &'static str {
        "bp"
    }

    fn mode(&self) -> Mode {
        Mode::Read
    }

    fn begin_step(&mut self) -> Result<StepStatus> {
        if self.open_step {
            bail!("begin_step while a step is open");
        }
        let marker = match self.read_u64()? {
            None => return Ok(StepStatus::EndOfStream),
            Some(m) => m,
        };
        if marker != STEP_MARKER {
            return Err(BpError::BadStepMarker { found: marker }.into());
        }
        let step = self.read_exact_u64("step number")?;
        let meta_len = bounded(
            self.read_exact_u64("metadata length")?,
            1 << 30,
            "metadata block",
        )?;
        let mut meta_buf = vec![0u8; meta_len];
        self.file
            .read_exact(&mut meta_buf)
            .map_err(|_| BpError::Truncated { what: "metadata block" })?;
        let meta = StepMeta::decode(&mut WireReader::new(&meta_buf))?;

        let n_payloads = bounded(
            self.read_exact_u64("payload count")?,
            1 << 24,
            "payload count",
        )?;
        self.index.clear();
        for _ in 0..n_payloads {
            let name_len = bounded(
                self.read_exact_u64("variable name length")?,
                1 << 24,
                "variable name",
            )?;
            let mut name = vec![0u8; name_len];
            self.file
                .read_exact(&mut name)
                .map_err(|_| BpError::Truncated {
                    what: "variable name",
                })?;
            let name = String::from_utf8_lossy(&name).into_owned();
            let nd = bounded(
                self.read_exact_u64("offset rank")?,
                1 << 16,
                "offset rank",
            )?;
            let mut offset = Vec::with_capacity(nd);
            for _ in 0..nd {
                offset.push(self.read_exact_u64("chunk offset")?);
            }
            let nd2 = bounded(
                self.read_exact_u64("extent rank")?,
                1 << 16,
                "extent rank",
            )?;
            if nd != nd2 {
                return Err(BpError::RankMismatch {
                    offset: nd,
                    extent: nd2,
                }
                .into());
            }
            let mut extent = Vec::with_capacity(nd2);
            for _ in 0..nd2 {
                extent.push(self.read_exact_u64("chunk extent")?);
            }
            let len = self.read_exact_u64("payload length")?;
            let delta = i64::try_from(len).map_err(|_| {
                BpError::ImplausibleLength {
                    what: "payload record",
                    len,
                    max: i64::MAX as u64,
                }
            })?;
            let file_offset = self.file.stream_position()?;
            self.file.seek(SeekFrom::Current(delta))?;
            self.index
                .entry(name)
                .or_default()
                .push(PayloadIndex {
                    chunk: Chunk { offset, extent },
                    file_offset,
                    len,
                });
        }
        self.meta = Some((step, meta));
        self.open_step = true;
        Ok(StepStatus::Ok)
    }

    fn define_variable(&mut self, _decl: &VarDecl) -> Result<VarHandle> {
        bail!("define_variable on a read-mode BP engine")
    }

    fn put_deferred(&mut self, _var: &VarHandle, _chunk: Chunk,
                    _data: Bytes) -> Result<()> {
        bail!("put on a read-mode BP engine")
    }

    fn put_span(&mut self, _var: &VarHandle, _chunk: Chunk)
        -> Result<&mut [u8]>
    {
        bail!("put_span on a read-mode BP engine")
    }

    fn perform_puts(&mut self) -> Result<()> {
        bail!("perform_puts on a read-mode BP engine")
    }

    fn put_attribute(&mut self, _name: &str, _value: Attribute) -> Result<()> {
        bail!("put_attribute on a read-mode BP engine")
    }

    fn available_variables(&self) -> Vec<VarInfo> {
        self.meta
            .as_ref()
            .map(|(_, m)| {
                m.vars
                    .iter()
                    .map(|v| VarInfo {
                        name: v.name.clone(),
                        dtype: v.dtype,
                        shape: v.shape.clone(),
                        ops: v.ops.clone(),
                    })
                    .collect()
            })
            .unwrap_or_default()
    }

    fn available_chunks(&self, var: &str) -> Vec<WrittenChunkInfo> {
        self.meta
            .as_ref()
            .and_then(|(_, m)| {
                m.vars
                    .iter()
                    .find(|v| v.name == var)
                    .map(|v| v.chunks.clone())
            })
            .unwrap_or_default()
    }

    fn attribute(&self, name: &str) -> Option<Attribute> {
        self.meta
            .as_ref()
            .and_then(|(_, m)| m.attributes.get(name).cloned())
    }

    fn attribute_names(&self) -> Vec<String> {
        self.meta
            .as_ref()
            .map(|(_, m)| m.attributes.keys().cloned().collect())
            .unwrap_or_default()
    }

    fn get_deferred(&mut self, var: &str, selection: Chunk)
        -> Result<GetHandle>
    {
        if !self.open_step {
            bail!("get outside step");
        }
        if !self.index.contains_key(var) {
            // Distinguish unknown vs data-less variables, as eager get
            // did.
            if !self.available_variables().iter().any(|v| v.name == var) {
                bail!("unknown variable {var:?}");
            }
            bail!("no payloads for {var:?}");
        }
        Ok(self.gets.defer(var, selection))
    }

    fn perform_gets(&mut self) -> Result<()> {
        let mut pending = self.gets.drain_pending();
        if pending.is_empty() {
            return Ok(());
        }
        if !self.open_step {
            bail!("perform_gets outside step");
        }
        // Batched file IO: serve the batch in ascending file-offset
        // order so a deferred batch turns into one forward sweep over
        // the step's payload region instead of random seeks.
        let first_offset = |g: &super::engine::DeferredGet| {
            self.index
                .get(&g.var)
                .into_iter()
                .flatten()
                .filter(|p| p.chunk.intersect(&g.selection).is_some())
                .map(|p| p.file_offset)
                .min()
                .unwrap_or(u64::MAX)
        };
        pending.sort_by_key(first_offset);
        let mut sp = trace::span("bp.get_sweep").with("gets", pending.len());
        let mut got_bytes = 0u64;
        let mut failure = None;
        for g in &pending {
            match self.fetch(&g.var, &g.selection) {
                Ok(data) => {
                    got_bytes += data.len() as u64;
                    self.gets.complete(g.handle, data);
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        BP_GET_SWEEPS.inc();
        BP_GET_BYTES.add(got_bytes);
        sp.set("bytes", got_bytes);
        if let Some(e) = failure {
            // Mid-sweep IO failure (truncated/corrupt file): poison the
            // whole drained batch so take_get reports this error, not
            // "unknown handle".
            self.gets.fail_batch(&pending, &e);
            return Err(e);
        }
        Ok(())
    }

    fn take_get(&mut self, handle: GetHandle) -> Result<Bytes> {
        self.gets.take(handle)
    }

    fn end_step(&mut self) -> Result<()> {
        if !self.open_step {
            bail!("end_step without begin_step");
        }
        // Deferred gets that were never performed are dropped: their
        // handles die with the step, so fetching them here would read
        // bytes nobody can redeem.
        self.gets.reset();
        // Position the cursor after the last payload of this step: get()
        // may have seeked around. The payload index knows the end.
        let end = self
            .index
            .values()
            .flatten()
            .map(|p| p.file_offset + p.len)
            .max();
        if let Some(end) = end {
            self.file.seek(SeekFrom::Start(end))?;
        }
        self.open_step = false;
        self.meta = None;
        self.index.clear();
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        self.gets.reset();
        self.open_step = false;
        Ok(())
    }

    fn ops_report(&self) -> OpsReport {
        self.ops_stats
    }
}

/// Current step index (reader side) + internal batch servicing.
impl BpReader {
    pub fn current_step(&self) -> Option<u64> {
        self.meta.as_ref().map(|(s, _)| *s)
    }

    /// Load one selection from the current step's payload records,
    /// reversing the variable's operator chain on each record read.
    fn fetch(&mut self, var: &str, selection: &Chunk) -> Result<Bytes> {
        let (dtype, chain): (_, OpChain) = self
            .meta
            .as_ref()
            .and_then(|(_, m)| m.vars.iter().find(|v| v.name == var))
            .map(|v| (v.dtype, v.ops.clone()))
            .ok_or_else(|| anyhow::anyhow!("unknown variable {var:?}"))?;
        let elem = dtype.size();
        let records: Vec<(Chunk, u64, u64)> = self
            .index
            .get(var)
            .ok_or_else(|| anyhow::anyhow!("no payloads for {var:?}"))?
            .iter()
            .map(|p| (p.chunk.clone(), p.file_offset, p.len))
            .collect();

        // Fast path: the selection IS a written chunk (perfect alignment,
        // the property §3.1 rewards) — one contiguous read; an operated
        // record additionally pays exactly one decode.
        for (chunk, file_offset, len) in &records {
            if chunk == selection {
                self.file.seek(SeekFrom::Start(*file_offset))?;
                let mut data = pool::acquire_buf(*len as usize);
                self.ops_stats.allocations += data.fresh() as u64;
                let read = (&mut self.file)
                    .take(*len)
                    .read_to_end(&mut data)?;
                if read as u64 != *len {
                    bail!("short read for {var:?}");
                }
                if chain.is_identity() {
                    return Ok(Arc::new(data.detach()));
                }
                // `data` is scratch here: it recycles on drop, even
                // when the decode errors out.
                return ops::decode_get(&chain, dtype, chunk, &data,
                                       &mut self.ops_stats)
                    .map_err(|e| anyhow::anyhow!("{var}: {e}"));
            }
        }

        let mut out =
            pool::acquire_zeroed(selection.num_elements() as usize * elem);
        self.ops_stats.allocations += out.fresh() as u64;
        let mut covered = 0u64;
        for (chunk, file_offset, len) in records {
            if chunk.intersect(selection).is_none() {
                continue;
            }
            self.file.seek(SeekFrom::Start(file_offset))?;
            let mut data = pool::acquire_buf(len as usize);
            self.ops_stats.allocations += data.fresh() as u64;
            let read =
                (&mut self.file).take(len).read_to_end(&mut data)?;
            if read as u64 != len {
                bail!("short read for {var:?}");
            }
            let raw: Bytes = if chain.is_identity() {
                Arc::new(data.detach())
            } else {
                ops::decode_get(&chain, dtype, &chunk, &data,
                                &mut self.ops_stats)
                    .map_err(|e| anyhow::anyhow!("{var}: {e}"))?
            };
            covered += region::copy_region(&chunk, &raw, selection,
                                           &mut out, elem);
            // Record scratch is dead after the copy: send the buffer
            // straight back to the pool for the next record.
            pool::reclaim_bytes(raw);
        }
        if covered < selection.num_elements() {
            bail!(
                "selection of {var:?} only partially covered \
                 ({covered}/{} elements)",
                selection.num_elements()
            );
        }
        Ok(Arc::new(out.detach()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::engine::cast;
    use crate::openpmd::types::Datatype;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("openpmd-stream-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}.bp", std::process::id()))
    }

    fn write_two_steps(path: &Path) {
        let mut w = BpWriter::create(path, WriterCtx {
            rank: 3,
            hostname: "node01".into(),
        })
        .unwrap();
        for step in 0..2u64 {
            assert_eq!(w.begin_step().unwrap(), StepStatus::Ok);
            w.put_attribute("/data/time", Attribute::F64(step as f64 * 0.5))
                .unwrap();
            let var = VarDecl::new("/data/x", Datatype::F32, vec![8]);
            let lo: Vec<f32> = (0..4).map(|i| (step * 10 + i) as f32).collect();
            let hi: Vec<f32> =
                (4..8).map(|i| (step * 10 + i) as f32).collect();
            w.put(&var, Chunk::new(vec![0], vec![4]), cast::f32_to_bytes(&lo))
                .unwrap();
            w.put(&var, Chunk::new(vec![4], vec![4]), cast::f32_to_bytes(&hi))
                .unwrap();
            w.end_step().unwrap();
        }
        w.close().unwrap();
    }

    #[test]
    fn round_trip_two_steps() {
        let path = tmp("round-trip");
        write_two_steps(&path);
        let mut r = BpReader::open(&path).unwrap();
        for step in 0..2u64 {
            assert_eq!(r.begin_step().unwrap(), StepStatus::Ok);
            assert_eq!(r.current_step(), Some(step));
            assert_eq!(
                r.attribute("/data/time").unwrap().as_f64().unwrap(),
                step as f64 * 0.5
            );
            let vars = r.available_variables();
            assert_eq!(vars.len(), 1);
            assert_eq!(vars[0].shape, vec![8]);
            let chunks = r.available_chunks("/data/x");
            assert_eq!(chunks.len(), 2);
            assert_eq!(chunks[0].source_rank, 3);
            assert_eq!(chunks[0].hostname, "node01");
            let all = r.get("/data/x", Chunk::whole(vec![8])).unwrap();
            let want: Vec<f32> =
                (0..8).map(|i| (step * 10 + i) as f32).collect();
            assert_eq!(cast::bytes_to_f32(&all).unwrap(), want);
            r.end_step().unwrap();
        }
        assert_eq!(r.begin_step().unwrap(), StepStatus::EndOfStream);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn partial_selection_spanning_chunks() {
        let path = tmp("partial");
        write_two_steps(&path);
        let mut r = BpReader::open(&path).unwrap();
        r.begin_step().unwrap();
        let sel = Chunk::new(vec![2], vec![4]); // spans both written chunks
        let got =
            cast::bytes_to_f32(&r.get("/data/x", sel).unwrap()).unwrap();
        assert_eq!(got, vec![2.0, 3.0, 4.0, 5.0]);
        r.end_step().unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sequential_scan_not_disturbed_by_gets() {
        let path = tmp("scan");
        write_two_steps(&path);
        let mut r = BpReader::open(&path).unwrap();
        r.begin_step().unwrap();
        // Read only a sub-selection (leaves the cursor mid-step)...
        r.get("/data/x", Chunk::new(vec![0], vec![2])).unwrap();
        r.end_step().unwrap();
        // ...the next step must still parse.
        assert_eq!(r.begin_step().unwrap(), StepStatus::Ok);
        assert_eq!(r.current_step(), Some(1));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("bad-magic");
        std::fs::write(&path, b"NOTABP!!").unwrap();
        let err = BpReader::open(&path).unwrap_err();
        assert_eq!(
            err.downcast_ref::<BpError>(),
            Some(&BpError::BadMagic { found: *b"NOTABP!!" })
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_step_marker_is_a_typed_error() {
        let path = tmp("bad-marker");
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&0xdead_beefu64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let mut r = BpReader::open(&path).unwrap();
        let err = r.begin_step().unwrap_err();
        assert_eq!(
            err.downcast_ref::<BpError>(),
            Some(&BpError::BadStepMarker { found: 0xdead_beef })
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn implausible_length_is_bounded_before_allocation() {
        // MAGIC + step marker + step number + an absurd metadata
        // length: must be a typed error, not a 2^60-byte allocation.
        let path = tmp("absurd-len");
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&STEP_MARKER.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        bytes.extend_from_slice(&(1u64 << 60).to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let mut r = BpReader::open(&path).unwrap();
        let err = r.begin_step().unwrap_err();
        match err.downcast_ref::<BpError>() {
            Some(BpError::ImplausibleLength { what, len, .. }) => {
                assert_eq!(*what, "metadata block");
                assert_eq!(*len, 1 << 60);
            }
            other => panic!("expected ImplausibleLength, got {other:?}"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_is_error_not_panic() {
        let path = tmp("trunc");
        write_two_steps(&path);
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        let mut r = BpReader::open(&path).unwrap();
        // First step may or may not parse depending on cut point; it must
        // not panic, and eventually errors or ends.
        for _ in 0..3 {
            match r.begin_step() {
                Ok(StepStatus::Ok) => {
                    let _ = r.get("/data/x", Chunk::whole(vec![8]));
                    let _ = r.end_step();
                }
                Ok(StepStatus::EndOfStream) => break,
                Ok(_) => break,
                Err(_) => break,
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn operated_variable_shrinks_the_file_and_self_describes() {
        let chain = OpChain::parse("shuffle|rle").unwrap();
        let xs = vec![1.25f32; 4096];
        let write = |path: &Path, ops: OpChain| {
            let mut w =
                BpWriter::create(path, WriterCtx::default()).unwrap();
            w.begin_step().unwrap();
            let decl = VarDecl::new("/data/0/x", Datatype::F32,
                                    vec![4096])
                .with_ops(ops);
            let h = w.define_variable(&decl).unwrap();
            w.put_deferred(&h, Chunk::whole(vec![4096]),
                           cast::f32_to_bytes(&xs))
                .unwrap();
            w.end_step().unwrap();
            let report = w.ops_report();
            w.close().unwrap();
            report
        };
        let plain = tmp("ops-plain");
        let coded = tmp("ops-coded");
        let plain_report = write(&plain, OpChain::identity());
        let coded_report = write(&coded, chain.clone());
        assert!(plain_report.is_empty());
        assert!(coded_report.ratio() > 10.0,
                "constant payload must collapse: {coded_report:?}");
        let plain_size = std::fs::metadata(&plain).unwrap().len();
        let coded_size = std::fs::metadata(&coded).unwrap().len();
        assert!(coded_size < plain_size / 4,
                "coded {coded_size} vs plain {plain_size}");

        // The file self-describes its chain, and reads decode.
        let mut r = BpReader::open(&coded).unwrap();
        assert_eq!(r.begin_step().unwrap(), StepStatus::Ok);
        let vars = r.available_variables();
        assert_eq!(vars[0].ops, chain);
        // Aligned (fast-path) read.
        let whole = r.get("/data/0/x", Chunk::whole(vec![4096])).unwrap();
        assert_eq!(cast::bytes_to_f32(&whole).unwrap(), xs);
        // Misaligned read decodes then assembles.
        let part = r
            .get("/data/0/x", Chunk::new(vec![7], vec![9]))
            .unwrap();
        assert_eq!(cast::bytes_to_f32(&part).unwrap(), vec![1.25f32; 9]);
        assert!(r.ops_report().chunks_decoded >= 2);
        r.end_step().unwrap();
        std::fs::remove_file(&plain).ok();
        std::fs::remove_file(&coded).ok();
    }

    #[test]
    fn wrong_payload_size_rejected_at_put() {
        let path = tmp("badput");
        let mut w = BpWriter::create(&path, WriterCtx::default()).unwrap();
        w.begin_step().unwrap();
        let var = VarDecl::new("/x", Datatype::F32, vec![4]);
        let err = w.put(&var, Chunk::new(vec![0], vec![4]),
                        Arc::new(vec![0u8; 15]));
        assert!(err.is_err());
        w.end_step().unwrap();
        std::fs::remove_file(&path).ok();
    }
}
