//! Typed endpoint specs: the unified grammar behind `--in` / `--out`.
//!
//! Every CLI mode (`pipe`, `produce`, the fleet path, and the `serve`
//! daemon) resolves its endpoints through ONE constructor pair instead
//! of ad-hoc string matching scattered across `main.rs` and
//! [`super::multiplex`]:
//!
//! * [`SourceSpec::parse`] — input specs: `sst+ADDR[,ADDR...]`,
//!   `serve+ADDR` (subscribe to a fan-out daemon), `shards:<index>`,
//!   `merge:a,b,...` (children typed and validated, nesting rejected),
//!   or a bare series path (BP file, JSON step directory, or a
//!   `*.index.json` shard family).
//! * [`SinkSpec::parse`] — output specs: `bp:PATH` (or a bare path),
//!   `json:PATH`, `sst+ADDR` (stage steps for SST subscribers), and
//!   `serve+ADDR` (the fan-out daemon's downstream listen endpoint,
//!   consumed by the `serve` subcommand).
//!
//! Both types round-trip: `parse(display(x)) == x` for every
//! parse-constructed value, so specs can be logged, stored in shard
//! indexes, and replayed verbatim. Degenerate specs (`merge:` inside
//! `merge:`, a stream inside a merge, mixed SST transports, empty
//! lists, unknown sink engines) are typed [`SpecError`]s at *parse*
//! time, not opaque failures at open time.
//!
//! **Rank-awareness is explicit.** The legacy
//! [`super::multiplex::open_source`] accepted a `rank` it silently
//! ignored for every non-SST spec. [`SourceSpec::open`] instead takes
//! a [`ReaderSlot`] (rank within a fleet of N readers, validated at
//! construction) and documents the contract via
//! [`SourceSpec::rank_aware`]: only the streaming specs (`sst+`,
//! `serve+`) transmit the rank (in the SST `Hello` handshake, where
//! the writer uses it for per-peer diagnostics and topology-aware
//! distribution); file-backed specs open one *independent* reader per
//! slot and ignore the rank by design — each fleet worker re-reads the
//! shared table and keeps only its assigned slices.

use std::fmt;

use anyhow::{Context, Result};

use super::engine::Engine;
use super::multiplex::MultiplexReader;
use super::sst::{
    SstReader, SstReaderOptions, SstWriter, SstWriterOptions,
};

/// A malformed or degenerate endpoint spec. Every variant names the
/// exact grammar rule violated, so CLI errors read as documentation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpecError {
    /// The spec string (or one list element) was empty.
    Empty { what: &'static str },
    /// `sst+` writer lists must use one transport for all addresses.
    MixedTransports { tcp: usize, total: usize },
    /// `serve+` names exactly one daemon endpoint, never a list.
    ServeIsOneEndpoint { got: usize },
    /// `shards:` without an index path.
    MissingShardIndex,
    /// `merge:` inside `merge:` — flatten the source list instead.
    NestedMerge,
    /// A streaming child (`sst+`/`serve+`) inside `merge:`: merge
    /// children must be replayable series sources, because the
    /// alignment barrier may park a child's step across polls.
    StreamInMerge { child: String },
    /// Unknown `--engine` name for a sink.
    UnknownSinkEngine { engine: String },
    /// A reader slot with `rank >= readers` (or zero readers).
    BadSlot { rank: usize, readers: usize },
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::Empty { what } => {
                write!(f, "empty {what} in endpoint spec")
            }
            SpecError::MixedTransports { tcp, total } => write!(
                f,
                "mixed SST transports: {tcp} of {total} writer \
                 address(es) are tcp:// — use one transport for all \
                 writers"
            ),
            SpecError::ServeIsOneEndpoint { got } => write!(
                f,
                "serve+ names exactly one daemon endpoint, got {got} \
                 comma-separated addresses"
            ),
            SpecError::MissingShardIndex => write!(
                f,
                "shards spec needs an index path \
                 (shards:<out>.index.json)"
            ),
            SpecError::NestedMerge => write!(
                f,
                "merge: inside merge: — flatten the source list into \
                 one merge:a,b,... spec"
            ),
            SpecError::StreamInMerge { child } => write!(
                f,
                "merge child {child:?} is a streaming endpoint; merge \
                 children must be series sources (BP, JSON dir, or a \
                 shard index)"
            ),
            SpecError::UnknownSinkEngine { engine } => {
                write!(f, "unknown output engine {engine:?}")
            }
            SpecError::BadSlot { rank, readers } => write!(
                f,
                "reader slot rank {rank} out of range for {readers} \
                 reader(s)"
            ),
        }
    }
}

impl std::error::Error for SpecError {}

/// This consumer's position within a fleet of `readers` parallel
/// readers. Validated at construction so `SourceSpec::open` cannot be
/// handed an out-of-range rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReaderSlot {
    rank: usize,
    readers: usize,
}

impl ReaderSlot {
    /// The single-reader slot (rank 0 of 1).
    pub fn solo() -> ReaderSlot {
        ReaderSlot { rank: 0, readers: 1 }
    }

    /// Slot `rank` of `readers`; rejects `rank >= readers`.
    pub fn of(rank: usize, readers: usize)
        -> Result<ReaderSlot, SpecError>
    {
        if readers == 0 || rank >= readers {
            return Err(SpecError::BadSlot { rank, readers });
        }
        Ok(ReaderSlot { rank, readers })
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn readers(&self) -> usize {
        self.readers
    }
}

/// One transport shared by a connection set, derived from the address
/// forms themselves (`tcp://…` ⇒ tcp, anything else ⇒ inproc) so a
/// spec needs no side-channel transport flag and Display stays the
/// exact inverse of parse.
fn transport_of(addrs: &[String]) -> Result<&'static str, SpecError> {
    let tcp = addrs.iter().filter(|a| a.starts_with("tcp://")).count();
    if tcp == addrs.len() {
        Ok("tcp")
    } else if tcp == 0 {
        Ok("inproc")
    } else {
        Err(SpecError::MixedTransports { tcp, total: addrs.len() })
    }
}

/// A typed pipe/serve *input* endpoint. See the module docs for the
/// grammar; construct with [`SourceSpec::parse`], open with
/// [`SourceSpec::open`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SourceSpec {
    /// Subscribe to every listed SST writer rank (`sst+ADDR[,ADDR...]`,
    /// all addresses on one transport).
    Sst { writers: Vec<String> },
    /// Subscribe to a `serve` fan-out daemon (`serve+ADDR`). Wire- and
    /// engine-compatible with [`SourceSpec::Sst`] over one address; the
    /// distinct form documents intent and lets tooling tell a daemon
    /// subscription from a direct producer subscription.
    Serve { addr: String },
    /// Reassemble a fleet's shard family via its merged index
    /// (`shards:<out>.index.json`) as ONE logical series.
    Shards { index: String },
    /// Multiplex series sources (`merge:a,b,...`); children are
    /// restricted to [`SourceSpec::Series`] / [`SourceSpec::Shards`].
    Merge { children: Vec<SourceSpec> },
    /// A concrete series path: a `*.index.json` shard family, a JSON
    /// step directory, or a BP file.
    Series { path: String },
}

impl SourceSpec {
    /// Parse an input spec (see the module docs for the grammar).
    pub fn parse(spec: &str) -> Result<SourceSpec, SpecError> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err(SpecError::Empty { what: "input spec" });
        }
        if let Some(rest) = spec.strip_prefix("sst+") {
            let writers: Vec<String> =
                rest.split(',').map(|a| a.trim().to_string()).collect();
            if writers.iter().any(|a| a.is_empty()) {
                return Err(SpecError::Empty {
                    what: "sst+ writer address",
                });
            }
            transport_of(&writers)?;
            return Ok(SourceSpec::Sst { writers });
        }
        if let Some(rest) = spec.strip_prefix("serve+") {
            let addrs: Vec<&str> =
                rest.split(',').map(|a| a.trim()).collect();
            if addrs.len() != 1 {
                return Err(SpecError::ServeIsOneEndpoint {
                    got: addrs.len(),
                });
            }
            if addrs[0].is_empty() {
                return Err(SpecError::Empty {
                    what: "serve+ daemon address",
                });
            }
            return Ok(SourceSpec::Serve { addr: addrs[0].to_string() });
        }
        if let Some(index) = spec.strip_prefix("shards:") {
            if index.trim().is_empty() {
                return Err(SpecError::MissingShardIndex);
            }
            return Ok(SourceSpec::Shards {
                index: index.trim().to_string(),
            });
        }
        if let Some(rest) = spec.strip_prefix("merge:") {
            let mut children = Vec::new();
            for part in rest.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    return Err(SpecError::Empty {
                        what: "merge source",
                    });
                }
                let child = SourceSpec::parse(part)?;
                match &child {
                    SourceSpec::Merge { .. } => {
                        return Err(SpecError::NestedMerge);
                    }
                    SourceSpec::Sst { .. }
                    | SourceSpec::Serve { .. } => {
                        return Err(SpecError::StreamInMerge {
                            child: part.to_string(),
                        });
                    }
                    SourceSpec::Shards { .. }
                    | SourceSpec::Series { .. } => {}
                }
                children.push(child);
            }
            if children.is_empty() {
                return Err(SpecError::Empty { what: "merge list" });
            }
            return Ok(SourceSpec::Merge { children });
        }
        Ok(SourceSpec::Series { path: spec.to_string() })
    }

    /// Whether this spec *transmits* the [`ReaderSlot`] rank. Only the
    /// streaming specs do (the rank rides in the SST `Hello`
    /// handshake); file-backed specs open an independent reader per
    /// slot and ignore the rank **by contract** — the fleet's shared
    /// plan, not the source, partitions the work.
    pub fn rank_aware(&self) -> bool {
        matches!(self,
                 SourceSpec::Sst { .. } | SourceSpec::Serve { .. })
    }

    /// Open this source as a read engine for `slot`.
    pub fn open(&self, slot: ReaderSlot) -> Result<Box<dyn Engine>> {
        match self {
            SourceSpec::Sst { writers } => {
                let transport = transport_of(writers)?;
                Ok(Box::new(SstReader::open(SstReaderOptions {
                    writers: writers.clone(),
                    transport: transport.into(),
                    rank: slot.rank,
                    ..Default::default()
                })?))
            }
            SourceSpec::Serve { addr } => {
                let writers = vec![addr.clone()];
                let transport = transport_of(&writers)?;
                Ok(Box::new(SstReader::open(SstReaderOptions {
                    writers,
                    transport: transport.into(),
                    rank: slot.rank,
                    ..Default::default()
                })?))
            }
            SourceSpec::Shards { index } => Ok(Box::new(
                crate::openpmd::series::open_shard_family(index)?,
            )),
            SourceSpec::Merge { children } => {
                let mut names = Vec::with_capacity(children.len());
                let mut engines = Vec::with_capacity(children.len());
                for child in children {
                    let name = child.to_string();
                    engines.push(child.open(slot).with_context(|| {
                        format!("opening merge source {name}")
                    })?);
                    names.push(name);
                }
                Ok(Box::new(MultiplexReader::over_named(
                    names, engines,
                )?))
            }
            SourceSpec::Series { path } => open_series_path(path),
        }
    }
}

impl fmt::Display for SourceSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SourceSpec::Sst { writers } => {
                write!(f, "sst+{}", writers.join(","))
            }
            SourceSpec::Serve { addr } => write!(f, "serve+{addr}"),
            SourceSpec::Shards { index } => write!(f, "shards:{index}"),
            SourceSpec::Merge { children } => {
                write!(f, "merge:")?;
                for (i, child) in children.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{child}")?;
                }
                Ok(())
            }
            SourceSpec::Series { path } => write!(f, "{path}"),
        }
    }
}

/// Open one concrete series path: a `*.index.json` shard family, a
/// directory (JSON step series), anything else a BP file. The open
/// half of [`SourceSpec::Series`], shared with the shard-family opener
/// (whose children recurse through the same resolution).
pub fn open_series_path(
    path: impl AsRef<std::path::Path>,
) -> Result<Box<dyn Engine>> {
    let path = path.as_ref();
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or_default();
    if name.ends_with(".index.json") {
        return Ok(Box::new(
            crate::openpmd::series::open_shard_family(path)?,
        ));
    }
    if path.is_dir() {
        return Ok(Box::new(super::json::JsonReader::open(path)?));
    }
    Ok(Box::new(super::bp::BpReader::open(path)?))
}

/// A typed *output* endpoint. Construct with [`SinkSpec::parse`] (or
/// [`SinkSpec::from_parts`] for the legacy `--engine KIND --out PATH`
/// flag pair), open with [`SinkSpec::open_writer`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SinkSpec {
    /// BP file (`bp:PATH`, or a bare path).
    Bp { path: String },
    /// JSON step directory (`json:PATH`).
    Json { path: String },
    /// SST staging stream listening on `listen` (`sst+ADDR`;
    /// `tcp://host:port` addresses select the TCP transport).
    Sst { listen: String },
    /// A `serve` fan-out daemon's downstream listen endpoint
    /// (`serve+ADDR`). Not directly openable as a write engine — the
    /// `serve` subcommand consumes it (the daemon is a subscriber hub,
    /// not a step writer).
    Serve { listen: String },
}

impl SinkSpec {
    /// Parse an output spec (see the module docs for the grammar). A
    /// bare path is a BP file, matching the CLI's historic default.
    pub fn parse(spec: &str) -> Result<SinkSpec, SpecError> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err(SpecError::Empty { what: "output spec" });
        }
        if let Some(listen) = spec.strip_prefix("sst+") {
            if listen.is_empty() {
                return Err(SpecError::Empty {
                    what: "sst+ listen address",
                });
            }
            return Ok(SinkSpec::Sst { listen: listen.to_string() });
        }
        if let Some(listen) = spec.strip_prefix("serve+") {
            if listen.is_empty() {
                return Err(SpecError::Empty {
                    what: "serve+ listen address",
                });
            }
            return Ok(SinkSpec::Serve { listen: listen.to_string() });
        }
        if let Some(path) = spec.strip_prefix("bp:") {
            if path.is_empty() {
                return Err(SpecError::Empty { what: "bp: path" });
            }
            return Ok(SinkSpec::Bp { path: path.to_string() });
        }
        if let Some(path) = spec.strip_prefix("json:") {
            if path.is_empty() {
                return Err(SpecError::Empty { what: "json: path" });
            }
            return Ok(SinkSpec::Json { path: path.to_string() });
        }
        Ok(SinkSpec::Bp { path: spec.to_string() })
    }

    /// Resolve the legacy `--engine KIND --out VALUE` flag pair into a
    /// typed sink. `sst:tcp` normalizes the listen address to the
    /// `tcp://` form so the resulting spec round-trips through
    /// [`SinkSpec::parse`].
    pub fn from_parts(engine: &str, out: &str)
        -> Result<SinkSpec, SpecError>
    {
        if out.trim().is_empty() {
            return Err(SpecError::Empty { what: "output spec" });
        }
        match engine {
            "bp" => Ok(SinkSpec::Bp { path: out.to_string() }),
            "json" => Ok(SinkSpec::Json { path: out.to_string() }),
            "sst" => Ok(SinkSpec::Sst { listen: out.to_string() }),
            "sst:tcp" => {
                let listen = if out.starts_with("tcp://") {
                    out.to_string()
                } else {
                    format!("tcp://{out}")
                };
                Ok(SinkSpec::Sst { listen })
            }
            "serve" => Ok(SinkSpec::Serve { listen: out.to_string() }),
            other => Err(SpecError::UnknownSinkEngine {
                engine: other.to_string(),
            }),
        }
    }

    /// The transport the listen address selects (`tcp://…` ⇒ tcp,
    /// anything else ⇒ inproc). Meaningful for the streaming sinks;
    /// file sinks report inproc vacuously.
    pub fn transport(&self) -> &'static str {
        let listen = match self {
            SinkSpec::Sst { listen } | SinkSpec::Serve { listen } => {
                listen
            }
            SinkSpec::Bp { .. } | SinkSpec::Json { .. } => return "inproc",
        };
        if listen.starts_with("tcp://") {
            "tcp"
        } else {
            "inproc"
        }
    }

    /// Open this sink as a write engine for `slot`. File sinks shard
    /// the path per slot (`out.r<i>ofM.bp` for `readers > 1`, the
    /// fleet convention); the SST sink supports only solo slots (a
    /// sharded staging output would need per-shard addresses);
    /// [`SinkSpec::Serve`] is not a write engine — run the `serve`
    /// subcommand instead.
    pub fn open_writer(&self, slot: ReaderSlot)
        -> Result<Box<dyn Engine>>
    {
        use super::bp::{BpWriter, WriterCtx};
        use super::json::JsonWriter;
        match self {
            SinkSpec::Bp { path } => {
                let shard = crate::openpmd::series::shard_path(
                    path, slot.rank, slot.readers,
                );
                Ok(Box::new(BpWriter::create(&shard, WriterCtx {
                    rank: slot.rank,
                    hostname: "localhost".into(),
                })?))
            }
            SinkSpec::Json { path } => {
                let shard = crate::openpmd::series::shard_path(
                    path, slot.rank, slot.readers,
                );
                Ok(Box::new(JsonWriter::create(
                    &shard, slot.rank, "localhost",
                )?))
            }
            SinkSpec::Sst { listen } => {
                if slot.readers > 1 {
                    anyhow::bail!(
                        "sst+ output cannot shard across {} pipe \
                         workers — run one pipe per staging stream",
                        slot.readers
                    );
                }
                Ok(Box::new(SstWriter::open(SstWriterOptions {
                    listen: listen.clone(),
                    transport: self.transport().into(),
                    rank: slot.rank,
                    ..Default::default()
                })?))
            }
            SinkSpec::Serve { .. } => anyhow::bail!(
                "{self} is a serve daemon endpoint, not a write \
                 engine — use the serve subcommand"
            ),
        }
    }
}

impl fmt::Display for SinkSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SinkSpec::Bp { path } => write!(f, "bp:{path}"),
            SinkSpec::Json { path } => write!(f, "json:{path}"),
            SinkSpec::Sst { listen } => write!(f, "sst+{listen}"),
            SinkSpec::Serve { listen } => write!(f, "serve+{listen}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(s: &str) -> SourceSpec {
        SourceSpec::parse(s).unwrap()
    }

    #[test]
    fn source_grammar_resolves_every_form() {
        assert_eq!(src("sst+a,b"), SourceSpec::Sst {
            writers: vec!["a".into(), "b".into()],
        });
        assert_eq!(src("serve+tcp://h:9"), SourceSpec::Serve {
            addr: "tcp://h:9".into(),
        });
        assert_eq!(src("shards:out.index.json"), SourceSpec::Shards {
            index: "out.index.json".into(),
        });
        assert_eq!(src("merge:a.bp,shards:x.index.json"),
                   SourceSpec::Merge {
                       children: vec![
                           SourceSpec::Series { path: "a.bp".into() },
                           SourceSpec::Shards {
                               index: "x.index.json".into(),
                           },
                       ],
                   });
        assert_eq!(src("plain.bp"),
                   SourceSpec::Series { path: "plain.bp".into() });
    }

    #[test]
    fn degenerate_sources_are_typed_errors() {
        assert_eq!(SourceSpec::parse(""),
                   Err(SpecError::Empty { what: "input spec" }));
        assert_eq!(SourceSpec::parse("sst+a,"),
                   Err(SpecError::Empty {
                       what: "sst+ writer address",
                   }));
        assert_eq!(SourceSpec::parse("sst+tcp://h:1,inprocname"),
                   Err(SpecError::MixedTransports { tcp: 1, total: 2 }));
        assert_eq!(SourceSpec::parse("serve+a,b"),
                   Err(SpecError::ServeIsOneEndpoint { got: 2 }));
        assert_eq!(SourceSpec::parse("shards:"),
                   Err(SpecError::MissingShardIndex));
        assert_eq!(SourceSpec::parse("merge:a,merge:b,c"),
                   Err(SpecError::NestedMerge));
        assert_eq!(SourceSpec::parse("merge:a,sst+b"),
                   Err(SpecError::StreamInMerge {
                       child: "sst+b".into(),
                   }));
    }

    #[test]
    fn sink_grammar_and_legacy_flag_pair_agree() {
        assert_eq!(SinkSpec::parse("out.bp").unwrap(),
                   SinkSpec::Bp { path: "out.bp".into() });
        assert_eq!(SinkSpec::parse("bp:out.bp").unwrap(),
                   SinkSpec::Bp { path: "out.bp".into() });
        assert_eq!(SinkSpec::parse("json:dir").unwrap(),
                   SinkSpec::Json { path: "dir".into() });
        assert_eq!(SinkSpec::parse("sst+tcp://h:1").unwrap(),
                   SinkSpec::Sst { listen: "tcp://h:1".into() });
        assert_eq!(SinkSpec::from_parts("sst:tcp", "h:1").unwrap(),
                   SinkSpec::Sst { listen: "tcp://h:1".into() });
        assert_eq!(SinkSpec::from_parts("json", "dir").unwrap(),
                   SinkSpec::Json { path: "dir".into() });
        assert_eq!(SinkSpec::from_parts("flac", "x"),
                   Err(SpecError::UnknownSinkEngine {
                       engine: "flac".into(),
                   }));
        assert_eq!(SinkSpec::parse("serve+hub").unwrap().transport(),
                   "inproc");
        assert_eq!(SinkSpec::parse("sst+tcp://h:1")
                       .unwrap()
                       .transport(),
                   "tcp");
    }

    #[test]
    fn display_round_trips() {
        for s in [
            "sst+a,b",
            "sst+tcp://h:1,tcp://h:2",
            "serve+tcp://h:9",
            "shards:out.index.json",
            "merge:a.bp,shards:x.index.json,dir",
            "plain.bp",
        ] {
            let spec = src(s);
            assert_eq!(SourceSpec::parse(&spec.to_string()).unwrap(),
                       spec);
        }
        for s in ["bp:out.bp", "json:dir", "sst+addr", "serve+hub"] {
            let spec = SinkSpec::parse(s).unwrap();
            assert_eq!(spec.to_string(), s);
            assert_eq!(SinkSpec::parse(&spec.to_string()).unwrap(),
                       spec);
        }
    }

    #[test]
    fn slots_validate_rank_against_width() {
        assert!(ReaderSlot::of(0, 1).is_ok());
        assert!(ReaderSlot::of(3, 4).is_ok());
        assert_eq!(ReaderSlot::of(4, 4),
                   Err(SpecError::BadSlot { rank: 4, readers: 4 }));
        assert_eq!(ReaderSlot::of(0, 0),
                   Err(SpecError::BadSlot { rank: 0, readers: 0 }));
    }

    #[test]
    fn only_streaming_specs_are_rank_aware() {
        assert!(src("sst+a").rank_aware());
        assert!(src("serve+a").rank_aware());
        assert!(!src("shards:x.index.json").rank_aware());
        assert!(!src("merge:a,b").rank_aware());
        assert!(!src("plain.bp").rank_aware());
    }
}
