//! Data transports under the SST engine (S5).
//!
//! SST picks its data plane at runtime (§2.3): on Summit the paper uses the
//! libfabric **RDMA** transport, with **TCP sockets** as the fallback
//! (evaluated in Fig. 8 as "WAN"). This build has no Infiniband, so:
//!
//! * [`InProcTransport`] — the RDMA *functional* analog: connections are
//!   in-memory channels; `Bytes` payloads are passed as `Arc`s without any
//!   copy or serialization, which is precisely the property RDMA buys on
//!   real fabric (the performance analog is modeled in
//!   [`crate::cluster::network`]).
//! * [`TcpTransport`] — real network sockets with the wire framing from
//!   [`super::wire`]; usable across processes and hosts.
//!
//! Addresses: `inproc://name` and `tcp://host:port` (port 0 = ephemeral).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{bail, Context, Result};
use once_cell::sync::Lazy;

use super::wire::{decode_msg, encode_msg, GetReply, Msg};
use crate::obs::metrics::{counter, Counter};
use crate::util::pool;
use crate::util::sync::{classes, OrderedMutex};

// Frame counters are observation-only: the wire layout is untouched.
// Frames count on both transports; byte counters only on TCP, where
// bytes actually cross a socket (inproc hands `Arc`s over, no copy).
static FRAMES_SENT: Lazy<&'static Counter> =
    Lazy::new(|| counter("wire.frames_sent"));
static FRAMES_RECV: Lazy<&'static Counter> =
    Lazy::new(|| counter("wire.frames_recv"));
static WIRE_BYTES_SENT: Lazy<&'static Counter> =
    Lazy::new(|| counter("wire.bytes_sent"));
static WIRE_BYTES_RECV: Lazy<&'static Counter> =
    Lazy::new(|| counter("wire.bytes_recv"));

/// Receive outcome for the non-blocking path.
pub enum Recv {
    Msg(Msg),
    TimedOut,
    Closed,
}

/// A bidirectional, message-oriented connection.
pub trait Conn: Send {
    fn send(&mut self, msg: Msg) -> Result<()>;
    /// Blocking receive. `Recv::Closed` when the peer is gone.
    fn recv(&mut self) -> Result<Recv>;
    /// Receive with timeout.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Recv>;
    /// Human-readable peer description for diagnostics.
    fn peer(&self) -> String;
    /// Split into independently-owned send/receive halves, so a service
    /// thread can block on `recv` while another thread pushes
    /// announcements — the SST writer needs this.
    fn split(self: Box<Self>) -> Result<(Box<dyn ConnTx>, Box<dyn ConnRx>)>;
}

/// Send half of a split connection.
pub trait ConnTx: Send {
    fn send(&mut self, msg: Msg) -> Result<()>;
}

/// Receive half of a split connection.
pub trait ConnRx: Send {
    fn recv(&mut self) -> Result<Recv>;
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Recv>;
}

/// A listening endpoint accepting connections.
pub trait Listener: Send {
    /// The address readers should dial (resolved, e.g. with a real port).
    fn address(&self) -> String;
    /// Accept the next connection, with timeout.
    fn accept_timeout(&mut self, timeout: Duration)
        -> Result<Option<Box<dyn Conn>>>;
}

/// Transport factory: create listeners and dial addresses.
pub trait Transport: Send + Sync {
    fn name(&self) -> &'static str;
    fn listen(&self, hint: &str) -> Result<Box<dyn Listener>>;
    fn dial(&self, address: &str) -> Result<Box<dyn Conn>>;
}

/// Resolve a transport by name ("inproc" | "tcp").
pub fn by_name(name: &str) -> Result<Arc<dyn Transport>> {
    Ok(match name {
        "inproc" => Arc::new(InProcTransport),
        "tcp" => Arc::new(TcpTransport),
        other => bail!("unknown transport {other:?}"),
    })
}

// ======================================================================
// In-process transport
// ======================================================================

/// Pair of unbounded channels. `Bytes` inside `Msg` travel by `Arc` —
/// zero-copy hand-off between threads.
struct InProcConn {
    tx: Sender<Msg>,
    rx: Receiver<Msg>,
    peer: String,
}

impl Conn for InProcConn {
    fn send(&mut self, msg: Msg) -> Result<()> {
        self.tx
            .send(msg)
            .map_err(|_| anyhow::anyhow!("inproc peer {} gone", self.peer))?;
        FRAMES_SENT.inc();
        Ok(())
    }

    fn recv(&mut self) -> Result<Recv> {
        match self.rx.recv() {
            Ok(m) => {
                FRAMES_RECV.inc();
                Ok(Recv::Msg(m))
            }
            Err(_) => Ok(Recv::Closed),
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Recv> {
        match self.rx.recv_timeout(timeout) {
            Ok(m) => {
                FRAMES_RECV.inc();
                Ok(Recv::Msg(m))
            }
            Err(RecvTimeoutError::Timeout) => Ok(Recv::TimedOut),
            Err(RecvTimeoutError::Disconnected) => Ok(Recv::Closed),
        }
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn ConnTx>, Box<dyn ConnRx>)> {
        Ok((
            Box::new(InProcTx { tx: self.tx, peer: self.peer.clone() }),
            Box::new(InProcRx { rx: self.rx }),
        ))
    }
}

struct InProcTx {
    tx: Sender<Msg>,
    peer: String,
}

impl ConnTx for InProcTx {
    fn send(&mut self, msg: Msg) -> Result<()> {
        self.tx
            .send(msg)
            .map_err(|_| anyhow::anyhow!("inproc peer {} gone", self.peer))?;
        FRAMES_SENT.inc();
        Ok(())
    }
}

struct InProcRx {
    rx: Receiver<Msg>,
}

impl ConnRx for InProcRx {
    fn recv(&mut self) -> Result<Recv> {
        match self.rx.recv() {
            Ok(m) => {
                FRAMES_RECV.inc();
                Ok(Recv::Msg(m))
            }
            Err(_) => Ok(Recv::Closed),
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Recv> {
        match self.rx.recv_timeout(timeout) {
            Ok(m) => {
                FRAMES_RECV.inc();
                Ok(Recv::Msg(m))
            }
            Err(RecvTimeoutError::Timeout) => Ok(Recv::TimedOut),
            Err(RecvTimeoutError::Disconnected) => Ok(Recv::Closed),
        }
    }
}

/// Global registry of in-process listening endpoints.
static INPROC_REGISTRY: Lazy<
    OrderedMutex<HashMap<String, SyncSender<Box<dyn Conn>>>>,
> = Lazy::new(
    || OrderedMutex::new(&classes::INPROC_REGISTRY, HashMap::new()),
);

struct InProcListener {
    address: String,
    incoming: Receiver<Box<dyn Conn>>,
}

impl Listener for InProcListener {
    fn address(&self) -> String {
        self.address.clone()
    }

    fn accept_timeout(&mut self, timeout: Duration)
        -> Result<Option<Box<dyn Conn>>>
    {
        match self.incoming.recv_timeout(timeout) {
            Ok(c) => Ok(Some(c)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                bail!("inproc listener channel closed")
            }
        }
    }
}

impl Drop for InProcListener {
    fn drop(&mut self) {
        // Poisoned registry on teardown: skip the unregister rather
        // than panic inside drop (which would abort).
        if let Ok(mut reg) = INPROC_REGISTRY.lock() {
            reg.remove(&self.address);
        }
    }
}

/// The in-process transport (see module docs).
pub struct InProcTransport;

impl Transport for InProcTransport {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn listen(&self, hint: &str) -> Result<Box<dyn Listener>> {
        let address = if hint.starts_with("inproc://") {
            hint.to_string()
        } else {
            format!("inproc://{hint}")
        };
        let (tx, rx) = mpsc::sync_channel(64);
        let mut reg = INPROC_REGISTRY.lock()?;
        if reg.contains_key(&address) {
            bail!("inproc address {address:?} already in use");
        }
        reg.insert(address.clone(), tx);
        Ok(Box::new(InProcListener { address, incoming: rx }))
    }

    fn dial(&self, address: &str) -> Result<Box<dyn Conn>> {
        let acceptor = {
            let reg = INPROC_REGISTRY.lock()?;
            reg.get(address)
                .cloned()
                .with_context(|| format!("no inproc listener at {address:?}"))?
        };
        let (tx_a, rx_b) = mpsc::channel();
        let (tx_b, rx_a) = mpsc::channel();
        let ours = InProcConn {
            tx: tx_a,
            rx: rx_a,
            peer: address.to_string(),
        };
        let theirs = InProcConn {
            tx: tx_b,
            rx: rx_b,
            peer: format!("{address}#client"),
        };
        acceptor
            .send(Box::new(theirs))
            .map_err(|_| anyhow::anyhow!("listener at {address:?} gone"))?;
        Ok(Box::new(ours))
    }
}

// ======================================================================
// TCP transport
// ======================================================================

/// Length-framed messages over a TCP stream.
struct TcpConn {
    stream: TcpStream,
    peer: String,
    /// Reusable receive buffer — the hot path does not allocate per frame
    /// beyond the payload itself.
    buf: Vec<u8>,
}

/// Enlarge kernel socket buffers: bulk scientific payloads want MiBs of
/// in-flight data, not the distro default.
fn set_socket_buffers(stream: &TcpStream, bytes: i32) {
    use std::os::unix::io::AsRawFd;
    let fd = stream.as_raw_fd();
    unsafe {
        for opt in [libc::SO_SNDBUF, libc::SO_RCVBUF] {
            libc::setsockopt(
                fd,
                libc::SOL_SOCKET,
                opt,
                &bytes as *const i32 as *const libc::c_void,
                std::mem::size_of::<i32>() as libc::socklen_t,
            );
        }
    }
}

impl TcpConn {
    fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).ok();
        set_socket_buffers(&stream, 4 << 20);
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into());
        Ok(TcpConn { stream, peer, buf: Vec::new() })
    }
}

fn tcp_write_frame(stream: &mut TcpStream, msg: &Msg) -> Result<()> {
    // Fast path for the data plane: stream each payload directly from
    // its Arc instead of copying the whole batch into an encode buffer
    // first. The wire format is identical to encode_msg's
    // (tag, req_id, count, then per item: flag + len + bytes).
    if let Msg::GetBatchReply { req_id, items } = msg {
        let mut body_len = 1u64 + 8 + 8;
        for item in items {
            body_len += 9;
            body_len += match item {
                GetReply::Data(d) => d.len() as u64,
                GetReply::Encoded(d) => d.len() as u64,
                GetReply::Error(e) => e.len() as u64,
            };
        }
        // Coalesce the frame header, item headers, error strings and
        // small payloads into one buffer (NODELAY sockets would
        // otherwise emit a tiny segment per 9-byte item header); only
        // large payloads are streamed directly from their Arc.
        const STREAM_THRESHOLD: usize = 64 << 10;
        // Pool-recycled scratch: the coalescing buffer returns its
        // capacity when this frame is flushed (drop at return).
        let mut coalesced = pool::acquire_buf(256);
        coalesced.extend_from_slice(&body_len.to_le_bytes());
        coalesced.push(5); // GetBatchReply tag
        coalesced.extend_from_slice(&req_id.to_le_bytes());
        coalesced.extend_from_slice(&(items.len() as u64).to_le_bytes());
        for item in items {
            match item {
                GetReply::Data(d) | GetReply::Encoded(d) => {
                    coalesced.push(match item {
                        GetReply::Data(_) => 1,
                        _ => 2,
                    });
                    coalesced
                        .extend_from_slice(&(d.len() as u64).to_le_bytes());
                    if d.len() < STREAM_THRESHOLD {
                        coalesced.extend_from_slice(d);
                    } else {
                        stream.write_all(&coalesced)?;
                        coalesced.clear();
                        stream.write_all(d)?;
                    }
                }
                GetReply::Error(e) => {
                    coalesced.push(0);
                    coalesced
                        .extend_from_slice(&(e.len() as u64).to_le_bytes());
                    coalesced.extend_from_slice(e.as_bytes());
                }
            }
        }
        if !coalesced.is_empty() {
            stream.write_all(&coalesced)?;
        }
        FRAMES_SENT.inc();
        WIRE_BYTES_SENT.add(8 + body_len);
        return Ok(());
    }
    let body = encode_msg(msg);
    let len = (body.len() as u64).to_le_bytes();
    stream.write_all(&len)?;
    stream.write_all(&body)?;
    FRAMES_SENT.inc();
    WIRE_BYTES_SENT.add(8 + body.len() as u64);
    pool::recycle_vec(body);
    Ok(())
}

fn tcp_read_frame(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<Recv> {
    let mut len_buf = [0u8; 8];
    match stream.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Ok(Recv::Closed)
        }
        Err(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            return Ok(Recv::TimedOut)
        }
        Err(e)
            if e.kind() == std::io::ErrorKind::ConnectionReset
                || e.kind() == std::io::ErrorKind::BrokenPipe =>
        {
            return Ok(Recv::Closed)
        }
        Err(e) => return Err(e.into()),
    }
    let len = u64::from_le_bytes(len_buf) as usize;
    if len > 1 << 34 {
        bail!("implausible frame length {len}");
    }
    // After the header arrives, finish the frame even if a read timeout is
    // set: a partial frame would corrupt the stream.
    stream.set_read_timeout(None)?;

    // Fast path for the data plane: route each payload of a batched
    // reply straight into its own allocation — no intermediate frame
    // buffer, no zero-fill, no decode copy. (Read the 1-byte tag first
    // to dispatch.)
    let mut tag_buf = [0u8; 1];
    stream.read_exact(&mut tag_buf)?;
    let [tag] = tag_buf;
    if tag == 5 && len >= 17 {
        let mut req_id_buf = [0u8; 8];
        let mut count_buf = [0u8; 8];
        stream.read_exact(&mut req_id_buf)?;
        stream.read_exact(&mut count_buf)?;
        let req_id = u64::from_le_bytes(req_id_buf);
        let n = u64::from_le_bytes(count_buf) as usize;
        // Each item carries at least a 9-byte header; bounding n by the
        // frame length keeps a corrupt count from pre-allocating
        // gigabytes before the first item read fails.
        if n > 1 << 24 || n > (len - 17) / 9 + 1 {
            bail!("implausible batch item count {n}");
        }
        let mut consumed = 17u64; // tag + req_id + count
        let mut items = Vec::with_capacity(n);
        for _ in 0..n {
            let mut item_head = [0u8; 9];
            stream.read_exact(&mut item_head)?;
            let [flag, len_bytes @ ..] = item_head;
            let item_len = u64::from_le_bytes(len_bytes) as usize;
            consumed += 9 + item_len as u64;
            if consumed > len as u64 {
                bail!("batch reply overruns its frame");
            }
            if flag == 1 || flag == 2 {
                // Recycled payload buffer; on the short-read and error
                // returns below it goes back to the pool on drop.
                let mut data = pool::acquire_buf(item_len);
                let read = (&mut *stream)
                    .take(item_len as u64)
                    .read_to_end(&mut data)?;
                if read != item_len {
                    return Ok(Recv::Closed);
                }
                let data = data.detach();
                items.push(if flag == 1 {
                    GetReply::Data(Arc::new(data))
                } else {
                    GetReply::Encoded(Arc::new(data))
                });
            } else if flag == 0 {
                let mut err = vec![0u8; item_len];
                stream.read_exact(&mut err)?;
                items.push(GetReply::Error(
                    String::from_utf8_lossy(&err).into_owned(),
                ));
            } else {
                // Match decode_msg: unknown flags are protocol errors,
                // not garbage Error items.
                bail!("bad batch-reply flag {flag}");
            }
        }
        if consumed != len as u64 {
            bail!("batch reply length mismatch: {consumed} vs {len}");
        }
        FRAMES_RECV.inc();
        WIRE_BYTES_RECV.add(8 + len as u64);
        return Ok(Recv::Msg(Msg::GetBatchReply { req_id, items }));
    }
    buf.clear();
    buf.reserve(len);
    buf.push(tag);
    buf.resize(len, 0);
    stream.read_exact(&mut buf[1..])?;
    let msg = decode_msg(buf)?;
    FRAMES_RECV.inc();
    WIRE_BYTES_RECV.add(8 + len as u64);
    Ok(Recv::Msg(msg))
}

impl Conn for TcpConn {
    fn send(&mut self, msg: Msg) -> Result<()> {
        tcp_write_frame(&mut self.stream, &msg)
    }

    fn recv(&mut self) -> Result<Recv> {
        self.stream.set_read_timeout(None)?;
        let mut buf = std::mem::take(&mut self.buf);
        let r = tcp_read_frame(&mut self.stream, &mut buf);
        self.buf = buf;
        r
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Recv> {
        self.stream.set_read_timeout(Some(timeout))?;
        let mut buf = std::mem::take(&mut self.buf);
        let r = tcp_read_frame(&mut self.stream, &mut buf);
        self.buf = buf;
        self.stream.set_read_timeout(None).ok();
        r
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }

    fn split(self: Box<Self>) -> Result<(Box<dyn ConnTx>, Box<dyn ConnRx>)> {
        let tx_stream = self.stream.try_clone()?;
        Ok((
            Box::new(TcpTx { stream: tx_stream }),
            Box::new(TcpRx { stream: self.stream, buf: self.buf }),
        ))
    }
}

struct TcpTx {
    stream: TcpStream,
}

impl ConnTx for TcpTx {
    fn send(&mut self, msg: Msg) -> Result<()> {
        tcp_write_frame(&mut self.stream, &msg)
    }
}

struct TcpRx {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl ConnRx for TcpRx {
    fn recv(&mut self) -> Result<Recv> {
        self.stream.set_read_timeout(None)?;
        let mut buf = std::mem::take(&mut self.buf);
        let r = tcp_read_frame(&mut self.stream, &mut buf);
        self.buf = buf;
        r
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Recv> {
        self.stream.set_read_timeout(Some(timeout))?;
        let mut buf = std::mem::take(&mut self.buf);
        let r = tcp_read_frame(&mut self.stream, &mut buf);
        self.buf = buf;
        self.stream.set_read_timeout(None).ok();
        r
    }
}

struct TcpListenerWrap {
    listener: TcpListener,
    address: String,
}

impl Listener for TcpListenerWrap {
    fn address(&self) -> String {
        self.address.clone()
    }

    fn accept_timeout(&mut self, timeout: Duration)
        -> Result<Option<Box<dyn Conn>>>
    {
        self.listener.set_nonblocking(true)?;
        let deadline = std::time::Instant::now() + timeout;
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    return Ok(Some(Box::new(TcpConn::new(stream)?)));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if std::time::Instant::now() >= deadline {
                        return Ok(None);
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

/// The TCP sockets transport (the paper's "WAN" data plane).
pub struct TcpTransport;

impl Transport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn listen(&self, hint: &str) -> Result<Box<dyn Listener>> {
        let bind = hint
            .strip_prefix("tcp://")
            .unwrap_or(if hint.is_empty() { "127.0.0.1:0" } else { hint });
        let bind = if bind.contains(':') {
            bind.to_string()
        } else {
            "127.0.0.1:0".to_string()
        };
        let listener = TcpListener::bind(&bind)
            .with_context(|| format!("binding {bind:?}"))?;
        let address = format!("tcp://{}", listener.local_addr()?);
        Ok(Box::new(TcpListenerWrap { listener, address }))
    }

    fn dial(&self, address: &str) -> Result<Box<dyn Conn>> {
        let addr = address
            .strip_prefix("tcp://")
            .context("tcp address must start with tcp://")?;
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting {addr:?}"))?;
        Ok(Box::new(TcpConn::new(stream)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ping_pong(transport: &dyn Transport, hint: &str) {
        let mut listener = transport.listen(hint).unwrap();
        let addr = listener.address();
        let t = std::thread::spawn({
            let transport_name = transport.name().to_string();
            move || {
                let transport = by_name(&transport_name).unwrap();
                let mut c = transport.dial(&addr).unwrap();
                c.send(Msg::Hello {
                    reader_rank: 1,
                    hostname: "h1".into(),
                    codecs: vec!["shuffle".into()],
                })
                .unwrap();
                match c.recv().unwrap() {
                    Recv::Msg(Msg::HelloAck { writer_rank, .. }) => {
                        assert_eq!(writer_rank, 0)
                    }
                    other => panic!("wrong reply: {:?}",
                                    matches!(other, Recv::Closed)),
                }
            }
        });
        let mut server = listener
            .accept_timeout(Duration::from_secs(5))
            .unwrap()
            .expect("no connection");
        match server.recv().unwrap() {
            Recv::Msg(Msg::Hello { reader_rank, hostname, codecs }) => {
                assert_eq!(reader_rank, 1);
                assert_eq!(hostname, "h1");
                assert_eq!(codecs, vec!["shuffle"]);
            }
            _ => panic!("expected Hello"),
        }
        server
            .send(Msg::HelloAck { writer_rank: 0, hostname: "h0".into() })
            .unwrap();
        t.join().unwrap();
    }

    #[test]
    fn inproc_ping_pong() {
        ping_pong(&InProcTransport, "test-ping");
    }

    #[test]
    fn tcp_ping_pong() {
        ping_pong(&TcpTransport, "127.0.0.1:0");
    }

    #[test]
    fn inproc_dial_unknown_fails() {
        assert!(InProcTransport.dial("inproc://nope").is_err());
    }

    #[test]
    fn inproc_duplicate_listen_fails() {
        let _l = InProcTransport.listen("dup").unwrap();
        assert!(InProcTransport.listen("dup").is_err());
    }

    #[test]
    fn inproc_address_freed_on_drop() {
        {
            let _l = InProcTransport.listen("transient").unwrap();
        }
        let _l2 = InProcTransport.listen("transient").unwrap();
    }

    #[test]
    fn accept_timeout_returns_none() {
        let mut l = TcpTransport.listen("127.0.0.1:0").unwrap();
        assert!(l
            .accept_timeout(Duration::from_millis(20))
            .unwrap()
            .is_none());
    }

    #[test]
    fn recv_timeout_times_out() {
        let mut l = InProcTransport.listen("timeout-test").unwrap();
        let addr = l.address();
        let _client = InProcTransport.dial(&addr).unwrap();
        let mut server = l
            .accept_timeout(Duration::from_secs(1))
            .unwrap()
            .unwrap();
        match server.recv_timeout(Duration::from_millis(20)).unwrap() {
            Recv::TimedOut => {}
            _ => panic!("expected timeout"),
        }
    }

    #[test]
    fn large_batched_payload_over_tcp() {
        let mut l = TcpTransport.listen("127.0.0.1:0").unwrap();
        let addr = l.address();
        let payload = Arc::new((0..2_000_000u32)
            .flat_map(|x| x.to_le_bytes())
            .collect::<Vec<u8>>());
        let p2 = payload.clone();
        let t = std::thread::spawn(move || {
            let mut c = TcpTransport.dial(&addr).unwrap();
            c.send(Msg::GetBatchReply {
                req_id: 7,
                items: vec![
                    GetReply::Data(p2),
                    GetReply::Error("second item failed".into()),
                    GetReply::Data(Arc::new(vec![9u8; 3])),
                    GetReply::Encoded(Arc::new(vec![5u8; 40])),
                ],
            })
            .unwrap();
        });
        let mut server = l
            .accept_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();
        match server.recv().unwrap() {
            Recv::Msg(Msg::GetBatchReply { req_id, items }) => {
                assert_eq!(req_id, 7);
                assert_eq!(items.len(), 4);
                match &items[0] {
                    GetReply::Data(d) => assert_eq!(**d, *payload),
                    other => panic!("wrong item 0: {other:?}"),
                }
                match &items[1] {
                    GetReply::Error(e) => {
                        assert_eq!(e, "second item failed")
                    }
                    other => panic!("wrong item 1: {other:?}"),
                }
                match &items[2] {
                    GetReply::Data(d) => assert_eq!(**d, vec![9u8; 3]),
                    other => panic!("wrong item 2: {other:?}"),
                }
                match &items[3] {
                    GetReply::Encoded(d) => assert_eq!(**d, vec![5u8; 40]),
                    other => panic!("wrong item 3: {other:?}"),
                }
            }
            _ => panic!("expected GetBatchReply"),
        }
        t.join().unwrap();
    }
}
