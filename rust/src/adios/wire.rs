//! SST wire protocol: message types and their binary encoding.
//!
//! The same `Msg` enum flows over every transport. The in-process
//! transport passes it by value (`Bytes` payloads are `Arc`s — zero-copy,
//! the RDMA analogy); the TCP transport encodes it with the framing in
//! this module. The BP file engine reuses [`StepMeta`]'s encoding for its
//! per-step metadata blocks, so there is exactly one serialization of
//! variable/chunk metadata in the codebase.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::engine::Bytes;
use super::ops::OpChain;
use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};
use crate::openpmd::types::Datatype;
use crate::openpmd::Attribute;

/// Wire-format version tag. Bump this whenever the frame layout of
/// [`encode_msg`]/[`StepMeta::encode`] or the [`Msg`] tag map changes —
/// `pallas-lint`'s `format-fingerprint` rule compares the structural
/// fingerprint of those bodies against the committed manifest and fails
/// when the layout drifts while this string stays put.
pub const WIRE_FORMAT: &str = "SSTWIRE01";

/// Per-variable metadata within a step announcement.
#[derive(Clone, Debug, PartialEq)]
pub struct VarMeta {
    pub name: String,
    pub dtype: Datatype,
    pub shape: Vec<u64>,
    /// Operator chain the writer applied to this variable's payloads
    /// (identity = none). Travels in every step announcement and BP
    /// metadata block, so streams and files self-describe their
    /// encoding.
    pub ops: OpChain,
    /// Chunks contributed by the announcing writer rank.
    pub chunks: Vec<WrittenChunkInfo>,
}

/// Metadata of one published step from one writer rank.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepMeta {
    pub attributes: BTreeMap<String, Attribute>,
    pub vars: Vec<VarMeta>,
}

/// One selection within a batched get request.
#[derive(Clone, Debug, PartialEq)]
pub struct GetItem {
    pub var: String,
    pub sel: Chunk,
}

/// Per-item outcome within a batched get reply.
#[derive(Clone, Debug)]
pub enum GetReply {
    /// Dense row-major bytes for the requested selection.
    Data(Bytes),
    /// Operator-framed bytes: decode with the chain announced in the
    /// variable's [`VarMeta::ops`]. Sent only to readers whose `Hello`
    /// advertised every codec of that chain.
    Encoded(Bytes),
    /// The item failed; the rest of the batch is still valid.
    Error(String),
}

/// Protocol messages.
#[derive(Clone, Debug)]
pub enum Msg {
    /// Reader -> writer: subscribe to the stream. `codecs` lists the
    /// operator codecs this reader can decode (operator negotiation):
    /// a writer serves chains outside this set as decoded raw bytes.
    Hello { reader_rank: usize, hostname: String, codecs: Vec<String> },
    /// Writer -> reader: identify.
    HelloAck { writer_rank: usize, hostname: String },
    /// Writer -> reader: a step is available.
    StepAnnounce { step: u64, meta: StepMeta },
    /// Reader -> writer: one batched request covering every deferred
    /// selection this reader wants from this writer for `step` — the
    /// two-phase API's `perform_gets` sends exactly one of these per
    /// writer per step instead of one message per chunk.
    GetBatch { req_id: u64, step: u64, items: Vec<GetItem> },
    /// Writer -> reader: the batched reply, one entry per request item,
    /// in request order. `Bytes` payloads travel as `Arc`s over the
    /// in-process transport (zero-copy) and are streamed without an
    /// intermediate buffer over TCP.
    GetBatchReply { req_id: u64, items: Vec<GetReply> },
    /// Reader -> writer: finished reading a step (lets the writer
    /// retire it from the staging queue).
    StepDone { step: u64 },
    /// Writer -> reader: stream ends; no more steps.
    CloseStream,
    /// Reader -> writer: unsubscribe.
    ReaderBye,
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::HelloAck { .. } => 2,
            Msg::StepAnnounce { .. } => 3,
            Msg::GetBatch { .. } => 4,
            Msg::GetBatchReply { .. } => 5,
            Msg::StepDone { .. } => 7,
            Msg::CloseStream => 8,
            Msg::ReaderBye => 9,
        }
    }
}

// -- primitive encoders ------------------------------------------------

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn put_vec_u64(out: &mut Vec<u8>, v: &[u64]) {
    put_u64(out, v.len() as u64);
    for x in v {
        put_u64(out, *x);
    }
}

pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // Checked arithmetic: a corrupted length field near usize::MAX
        // must be a decode error, not a wrapping-add panic.
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "wire decode overrun: need {n} at {} of {}",
                    self.pos,
                    self.buf.len()
                )
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b: [u8; 8] = self
            .take(8)?
            .try_into()
            .map_err(|_| anyhow::anyhow!("wire decode: short u64"))?;
        Ok(u64::from_le_bytes(b))
    }

    pub fn u8(&mut self) -> Result<u8> {
        self.take(1)?
            .first()
            .copied()
            .ok_or_else(|| anyhow::anyhow!("wire decode: short u8"))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u64()? as usize;
        if n > 1 << 24 {
            bail!("implausible string length {n}");
        }
        Ok(String::from_utf8_lossy(self.take(n)?).into_owned())
    }

    pub fn vec_u64(&mut self) -> Result<Vec<u64>> {
        let n = self.u64()? as usize;
        if n > 64 {
            bail!("implausible dimensionality {n}");
        }
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u64()? as usize;
        Ok(self.take(n)?.to_vec())
    }
}

fn put_chunk(out: &mut Vec<u8>, c: &Chunk) {
    put_vec_u64(out, &c.offset);
    put_vec_u64(out, &c.extent);
}

fn get_chunk(r: &mut Reader) -> Result<Chunk> {
    let offset = r.vec_u64()?;
    let extent = r.vec_u64()?;
    if offset.len() != extent.len() {
        bail!("chunk rank mismatch {} vs {}", offset.len(), extent.len());
    }
    Ok(Chunk { offset, extent })
}

// -- StepMeta ----------------------------------------------------------

impl StepMeta {
    pub fn encode(&self, out: &mut Vec<u8>) {
        put_u64(out, self.attributes.len() as u64);
        for (k, v) in &self.attributes {
            put_str(out, k);
            v.encode(out);
        }
        put_u64(out, self.vars.len() as u64);
        for v in &self.vars {
            put_str(out, &v.name);
            out.push(v.dtype.tag());
            put_str(out, &v.ops.to_string());
            put_vec_u64(out, &v.shape);
            put_u64(out, v.chunks.len() as u64);
            for ci in &v.chunks {
                put_chunk(out, &ci.chunk);
                put_u64(out, ci.source_rank as u64);
                put_str(out, &ci.hostname);
                // Staged payload size; u64::MAX = unknown (the value
                // itself can never be a real size, the buffer could not
                // exist).
                put_u64(out, ci.encoded_bytes.unwrap_or(u64::MAX));
            }
        }
    }

    pub fn decode(r: &mut Reader) -> Result<StepMeta> {
        let n_attr = r.u64()? as usize;
        let mut attributes = BTreeMap::new();
        for _ in 0..n_attr {
            let k = r.str()?;
            let mut pos = r.pos;
            let v = Attribute::decode(r.buf, &mut pos)
                .map_err(|e| anyhow::anyhow!(e))?;
            r.pos = pos;
            attributes.insert(k, v);
        }
        let n_vars = r.u64()? as usize;
        if n_vars > 1 << 20 {
            bail!("implausible variable count {n_vars}");
        }
        // Pre-allocation bounded by the remaining buffer so a corrupt
        // count cannot allocate far beyond what could ever decode.
        let mut vars = Vec::with_capacity(n_vars.min(r.remaining() / 8));
        for _ in 0..n_vars {
            let name = r.str()?;
            let dtype = Datatype::from_tag(r.u8()?)
                .ok_or_else(|| anyhow::anyhow!("bad dtype tag"))?;
            let ops = OpChain::parse(&r.str()?)
                .map_err(|e| anyhow::anyhow!("bad operator chain: {e}"))?;
            let shape = r.vec_u64()?;
            let n_chunks = r.u64()? as usize;
            if n_chunks > 1 << 24 {
                bail!("implausible chunk count {n_chunks}");
            }
            let mut chunks =
                Vec::with_capacity(n_chunks.min(r.remaining() / 8));
            for _ in 0..n_chunks {
                let chunk = get_chunk(r)?;
                let source_rank = r.u64()? as usize;
                let hostname = r.str()?;
                let encoded_bytes = match r.u64()? {
                    u64::MAX => None,
                    b => Some(b),
                };
                chunks.push(WrittenChunkInfo {
                    chunk,
                    source_rank,
                    hostname,
                    encoded_bytes,
                    // Multiplex provenance is a reader-side annotation;
                    // it is never encoded, so decoding yields None.
                    source_id: None,
                });
            }
            vars.push(VarMeta { name, dtype, shape, ops, chunks });
        }
        Ok(StepMeta { attributes, vars })
    }
}

// -- Msg framing ---------------------------------------------------------

/// Encode a message body (without the outer length frame).
pub fn encode_msg(msg: &Msg) -> Vec<u8> {
    // Pool-backed scratch: TCP senders recycle the returned frame
    // after the write, so steady-state control traffic allocates
    // nothing. The wire layout is unchanged.
    let mut out = crate::util::pool::acquire_buf(64).detach();
    out.push(msg.tag());
    match msg {
        Msg::Hello { reader_rank, hostname, codecs } => {
            put_u64(&mut out, *reader_rank as u64);
            put_str(&mut out, hostname);
            put_u64(&mut out, codecs.len() as u64);
            for c in codecs {
                put_str(&mut out, c);
            }
        }
        Msg::HelloAck { writer_rank, hostname } => {
            put_u64(&mut out, *writer_rank as u64);
            put_str(&mut out, hostname);
        }
        Msg::StepAnnounce { step, meta } => {
            put_u64(&mut out, *step);
            meta.encode(&mut out);
        }
        Msg::GetBatch { req_id, step, items } => {
            put_u64(&mut out, *req_id);
            put_u64(&mut out, *step);
            put_u64(&mut out, items.len() as u64);
            for item in items {
                put_str(&mut out, &item.var);
                put_chunk(&mut out, &item.sel);
            }
        }
        Msg::GetBatchReply { req_id, items } => {
            put_u64(&mut out, *req_id);
            put_u64(&mut out, items.len() as u64);
            for item in items {
                match item {
                    GetReply::Data(data) => {
                        out.push(1);
                        put_u64(&mut out, data.len() as u64);
                        out.extend_from_slice(data);
                    }
                    GetReply::Encoded(data) => {
                        out.push(2);
                        put_u64(&mut out, data.len() as u64);
                        out.extend_from_slice(data);
                    }
                    GetReply::Error(error) => {
                        out.push(0);
                        put_str(&mut out, error);
                    }
                }
            }
        }
        Msg::StepDone { step } => put_u64(&mut out, *step),
        Msg::CloseStream | Msg::ReaderBye => {}
    }
    out
}

/// Decode a message body produced by [`encode_msg`].
pub fn decode_msg(buf: &[u8]) -> Result<Msg> {
    let mut r = Reader::new(buf);
    let tag = r.u8()?;
    let msg = match tag {
        1 => {
            let reader_rank = r.u64()? as usize;
            let hostname = r.str()?;
            let n = r.u64()? as usize;
            if n > 256 {
                bail!("implausible codec count {n}");
            }
            let codecs =
                (0..n).map(|_| r.str()).collect::<Result<Vec<_>>>()?;
            Msg::Hello { reader_rank, hostname, codecs }
        }
        2 => Msg::HelloAck {
            writer_rank: r.u64()? as usize,
            hostname: r.str()?,
        },
        3 => Msg::StepAnnounce { step: r.u64()?, meta: StepMeta::decode(&mut r)? },
        4 => {
            let req_id = r.u64()?;
            let step = r.u64()?;
            let n = r.u64()? as usize;
            // Every encoded item is at least 24 bytes (name len + two
            // chunk-vec lens); bounding n by the remaining buffer keeps
            // a corrupt count from pre-allocating gigabytes.
            if n > 1 << 24 || n > r.remaining() / 24 + 1 {
                bail!("implausible batch item count {n}");
            }
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                let var = r.str()?;
                let sel = get_chunk(&mut r)?;
                items.push(GetItem { var, sel });
            }
            Msg::GetBatch { req_id, step, items }
        }
        5 => {
            let req_id = r.u64()?;
            let n = r.u64()? as usize;
            // Every encoded item is at least 9 bytes (flag + length);
            // see the tag-4 arm for why the count is bounded by the
            // buffer before allocating.
            if n > 1 << 24 || n > r.remaining() / 9 + 1 {
                bail!("implausible batch item count {n}");
            }
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                items.push(match r.u8()? {
                    1 => GetReply::Data(std::sync::Arc::new(r.bytes()?)),
                    2 => GetReply::Encoded(
                        std::sync::Arc::new(r.bytes()?),
                    ),
                    0 => GetReply::Error(r.str()?),
                    other => bail!("bad batch-reply flag {other}"),
                });
            }
            Msg::GetBatchReply { req_id, items }
        }
        7 => Msg::StepDone { step: r.u64()? },
        8 => Msg::CloseStream,
        9 => Msg::ReaderBye,
        other => bail!("unknown message tag {other}"),
    };
    if r.remaining() != 0 {
        bail!("trailing {} bytes after message tag {tag}", r.remaining());
    }
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn round_trip(msg: Msg) -> Msg {
        decode_msg(&encode_msg(&msg)).unwrap()
    }

    fn sample_meta() -> StepMeta {
        let mut attributes = BTreeMap::new();
        attributes.insert("openPMD".into(), Attribute::Str("1.1.0".into()));
        attributes.insert("/data/3/time".into(), Attribute::F64(1.5));
        StepMeta {
            attributes,
            vars: vec![
                VarMeta {
                    name: "/data/3/particles/e/position/x".into(),
                    dtype: Datatype::F32,
                    shape: vec![1000],
                    ops: OpChain::identity(),
                    chunks: vec![WrittenChunkInfo::new(
                        Chunk::new(vec![0], vec![500]),
                        2,
                        "node07",
                    )
                    .with_encoded_bytes(2000)],
                },
                VarMeta {
                    name: "/data/3/particles/e/position/y".into(),
                    dtype: Datatype::F32,
                    shape: vec![1000],
                    ops: OpChain::parse("zfp:14|shuffle|rle").unwrap(),
                    chunks: vec![WrittenChunkInfo::new(
                        Chunk::new(vec![500], vec![500]),
                        3,
                        "node08",
                    )],
                },
            ],
        }
    }

    #[test]
    fn step_announce_round_trips() {
        match round_trip(Msg::StepAnnounce { step: 3, meta: sample_meta() }) {
            Msg::StepAnnounce { step, meta } => {
                assert_eq!(step, 3);
                assert_eq!(meta, sample_meta());
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn get_batch_round_trips() {
        let items = vec![
            GetItem { var: "v".into(),
                      sel: Chunk::new(vec![5, 0], vec![10, 3]) },
            GetItem { var: "w".into(), sel: Chunk::new(vec![0], vec![7]) },
        ];
        match round_trip(Msg::GetBatch {
            req_id: 9,
            step: 1,
            items: items.clone(),
        }) {
            Msg::GetBatch { req_id, step, items: got } => {
                assert_eq!((req_id, step), (9, 1));
                assert_eq!(got, items);
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn get_batch_reply_round_trips() {
        let data = Arc::new(vec![1u8, 2, 3, 4, 5]);
        let framed = Arc::new(vec![9u8; 24]);
        match round_trip(Msg::GetBatchReply {
            req_id: 1,
            items: vec![
                GetReply::Data(data.clone()),
                GetReply::Error("nope".into()),
                GetReply::Data(Arc::new(Vec::new())),
                GetReply::Encoded(framed.clone()),
            ],
        }) {
            Msg::GetBatchReply { req_id, items } => {
                assert_eq!(req_id, 1);
                assert_eq!(items.len(), 4);
                match &items[0] {
                    GetReply::Data(d) => assert_eq!(**d, *data),
                    other => panic!("wrong item {other:?}"),
                }
                match &items[1] {
                    GetReply::Error(e) => assert_eq!(e, "nope"),
                    other => panic!("wrong item {other:?}"),
                }
                match &items[2] {
                    GetReply::Data(d) => assert!(d.is_empty()),
                    other => panic!("wrong item {other:?}"),
                }
                match &items[3] {
                    GetReply::Encoded(d) => assert_eq!(**d, *framed),
                    other => panic!("wrong item {other:?}"),
                }
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn empty_batches_round_trip() {
        assert!(matches!(
            round_trip(Msg::GetBatch { req_id: 3, step: 0,
                                       items: Vec::new() }),
            Msg::GetBatch { req_id: 3, items, .. } if items.is_empty()
        ));
        assert!(matches!(
            round_trip(Msg::GetBatchReply { req_id: 4,
                                            items: Vec::new() }),
            Msg::GetBatchReply { req_id: 4, items } if items.is_empty()
        ));
    }

    #[test]
    fn control_messages_round_trip() {
        assert!(matches!(round_trip(Msg::CloseStream), Msg::CloseStream));
        assert!(matches!(round_trip(Msg::ReaderBye), Msg::ReaderBye));
        assert!(matches!(round_trip(Msg::StepDone { step: 7 }),
                         Msg::StepDone { step: 7 }));
        match round_trip(Msg::Hello {
            reader_rank: 4,
            hostname: "h".into(),
            codecs: vec!["shuffle".into(), "rle".into()],
        }) {
            Msg::Hello { reader_rank, hostname, codecs } => {
                assert_eq!(reader_rank, 4);
                assert_eq!(hostname, "h");
                assert_eq!(codecs, vec!["shuffle", "rle"]);
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn corrupt_buffers_are_errors_not_panics() {
        let mut buf = encode_msg(&Msg::StepAnnounce {
            step: 3,
            meta: sample_meta(),
        });
        buf.truncate(buf.len() / 2);
        assert!(decode_msg(&buf).is_err());
        assert!(decode_msg(&[42]).is_err());
        assert!(decode_msg(&[]).is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut buf = encode_msg(&Msg::CloseStream);
        buf.push(0);
        assert!(decode_msg(&buf).is_err());
    }
}
