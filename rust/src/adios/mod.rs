//! The adaptable IO layer (S2–S6): one step-oriented engine API,
//! interchangeable backends, runtime selection — the ADIOS2 role in the
//! paper's software stack (Fig. 3).
//!
//! Backends:
//!
//! * [`bp`] — the **BP** binary-pack *file* engine: persistent storage with
//!   node-level aggregation (one file per aggregator), the paper's
//!   "BP-only" baseline.
//! * [`sst`] — the **SST** *staging* engine: publish/subscribe loose
//!   coupling entirely in memory/network, bypassing the filesystem; the
//!   paper's focus. Rides on a pluggable [`transport`].
//! * [`json`] — a serial JSON backend for prototyping and debugging
//!   (bottom of Fig. 3), trading performance for `cat`-ability.
//! * [`multiplex`] — the multiplexing *virtual* read engine: an
//!   arbitrary set of child readers (a fleet's shard family, or any
//!   `merge:` composition of sources, backends mixed freely) presented
//!   as ONE logical series behind the same [`Engine`] contract —
//!   step-aligned with a discard-consistent barrier, tables merged
//!   with per-child provenance, gets routed to the owning child and
//!   batched one perform per child per step.
//! * [`spec`] — the typed endpoint grammar ([`SourceSpec`] /
//!   [`SinkSpec`]) every CLI mode resolves `--in`/`--out` through:
//!   parse ↔ Display round-tripping specs, typed rejection of
//!   degenerate forms, and explicit rank-awareness.
//!
//! Cross-cutting, [`ops`] is the per-variable *operator* layer (ADIOS2's
//! `AddOperation`): compression/precision-reduction chains declared per
//! variable, applied transparently inside `perform_puts`/`perform_gets`
//! by every backend, negotiated over the SST wire and persisted in BP
//! metadata.
//!
//! The *reusability* property (§2.1): application code is written against
//! [`Engine`] + [`EngineKind`] and switches between file IO and streaming
//! by changing a runtime parameter, not code.

pub mod engine;
pub mod bp;
pub mod json;
pub mod multiplex;
pub mod ops;
pub mod region;
pub mod spec;
pub mod sst;
pub mod transport;
pub mod wire;

pub use engine::{
    Bytes, Engine, EngineKind, GetHandle, Mode, StepStatus, VarDecl,
    VarHandle, VarInfo,
};
pub use multiplex::MultiplexReader;
pub use ops::{OpChain, Operator, OpsError, OpsReport};
pub use spec::{ReaderSlot, SinkSpec, SourceSpec, SpecError};
