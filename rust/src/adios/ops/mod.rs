//! `adios::ops` — per-variable operators: data transforms (compression,
//! precision reduction) applied transparently at put/get time.
//!
//! Mirrors ADIOS2's `AddOperation`: a variable declared with an operator
//! chain has every chunk payload pushed through the chain inside
//! `perform_puts` (write side) and reversed on the read side, so
//! application code keeps exchanging raw dense bytes while every byte
//! that crosses a wire, a staging queue or a file is transformed. The
//! streaming throughput the paper measures is ultimately bound by bytes
//! moved per step; operators are the lever once the network — not the
//! filesystem — is the bottleneck (Eisenhauer et al. 2024).
//!
//! * A chain is declared as a parseable spec string, e.g. `"shuffle|rle"`
//!   or `"zfp:14|shuffle|rle"`, attached to a [`crate::adios::VarDecl`]
//!   via `with_ops` and carried by the resulting `VarHandle`. Validation
//!   ([`OpChain::validate_for`]) happens once at `define_variable` time:
//!   unknown codecs, empty chain segments and lossy-codec-on-integer
//!   declarations are typed [`OpsError`]s.
//! * On the wire and in BP files, the chain travels inside the variable
//!   metadata (`wire::VarMeta`), so streams and files self-describe;
//!   encoded payloads are wrapped in a small frame
//!   (`[raw_len][encoded_len][bytes]`) whose lengths are validated on
//!   decode — a corrupted length field is an error, not a panic or an
//!   allocation bomb.
//! * SST readers advertise the codecs they understand in the `Hello`
//!   handshake (operator negotiation); a writer serves readers lacking a
//!   codec with decoded raw payloads instead of failing the stream.
//! * Every encode/decode is accounted in an [`OpsReport`] (ratio,
//!   encode/decode time, bytes saved), exposed per engine via
//!   [`crate::adios::Engine::ops_report`] and merged into
//!   `pipeline::PipeReport` by the pipe.

pub mod codec;

use std::fmt;
use std::sync::Arc;
use std::time::Instant;

use crate::adios::engine::Bytes;
use crate::openpmd::types::Datatype;

pub use codec::{Delta, Rle, Shuffle, ZfpLite};

/// Codec names understood by this build — what SST readers advertise in
/// the wire handshake (operator negotiation).
pub const CODEC_NAMES: [&str; 4] = ["shuffle", "rle", "delta", "zfp"];

/// The advertised codec list, owned (for the `Hello` message).
pub fn supported_codecs() -> Vec<String> {
    CODEC_NAMES.iter().map(|s| s.to_string()).collect()
}

// ---------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------

/// Typed errors of the operator subsystem.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum OpsError {
    /// Spec names a codec this build does not know.
    UnknownCodec(String),
    /// Spec contains an empty chain segment (e.g. `"shuffle||rle"`).
    EmptySegment(String),
    /// Codec parameter failed to parse or is out of range.
    BadParam { codec: &'static str, param: String },
    /// A lossy codec was attached to an integer variable.
    LossyOnInteger { codec: &'static str, dtype: &'static str },
    /// Codec cannot operate on this element type (e.g. `delta` on f32).
    DtypeUnsupported { codec: &'static str, dtype: &'static str },
    /// Encoded payload failed structural validation.
    Corrupt(String),
    /// Decoded size does not match the declared/expected size.
    LengthMismatch { expected: usize, got: usize },
}

impl fmt::Display for OpsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OpsError::UnknownCodec(name) => {
                write!(f, "unknown codec {name:?} (known: {})",
                       CODEC_NAMES.join(", "))
            }
            OpsError::EmptySegment(spec) => {
                write!(f, "empty chain segment in operator spec {spec:?}")
            }
            OpsError::BadParam { codec, param } => {
                write!(f, "bad parameter {param:?} for codec {codec}")
            }
            OpsError::LossyOnInteger { codec, dtype } => {
                write!(f, "lossy codec {codec} cannot be applied to \
                           integer variable type {dtype}")
            }
            OpsError::DtypeUnsupported { codec, dtype } => {
                write!(f, "codec {codec} does not support element type \
                           {dtype}")
            }
            OpsError::Corrupt(why) => {
                write!(f, "corrupt operator payload: {why}")
            }
            OpsError::LengthMismatch { expected, got } => {
                write!(f, "operator payload size mismatch: expected \
                           {expected} bytes, got {got}")
            }
        }
    }
}

impl std::error::Error for OpsError {}

// ---------------------------------------------------------------------
// Operator trait + specs
// ---------------------------------------------------------------------

/// Type/shape metadata a codec may consult.
#[derive(Clone, Copy, Debug)]
pub struct OpCtx<'a> {
    pub dtype: Datatype,
    /// Extent of the chunk being transformed (element counts per dim).
    pub extent: &'a [u64],
}

/// One data transform. `apply` runs at put time, `reverse` at get time.
///
/// `reverse` receives `want` (the exact output size, when the position
/// in the chain makes it knowable) and `cap` (a hard output bound that
/// keeps a corrupt stream from decoding into unbounded memory).
pub trait Operator: Send + Sync {
    fn spec(&self) -> OpSpec;

    /// Whether `reverse(apply(x)) == x` for all valid inputs.
    fn lossless(&self) -> bool {
        true
    }

    fn apply(&self, data: &[u8], ctx: &OpCtx) -> Result<Vec<u8>, OpsError>;

    fn reverse(
        &self,
        data: &[u8],
        ctx: &OpCtx,
        want: Option<usize>,
        cap: usize,
    ) -> Result<Vec<u8>, OpsError>;
}

/// Parsed form of one chain segment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpSpec {
    Shuffle,
    Rle,
    Delta,
    ZfpLite { keep_bits: u8 },
}

/// Default mantissa bits kept by a bare `"zfp"` segment.
pub const ZFP_DEFAULT_KEEP_BITS: u8 = 12;

impl OpSpec {
    pub fn name(self) -> &'static str {
        match self {
            OpSpec::Shuffle => "shuffle",
            OpSpec::Rle => "rle",
            OpSpec::Delta => "delta",
            OpSpec::ZfpLite { .. } => "zfp",
        }
    }

    /// Whether `apply` preserves the byte length (used to propagate the
    /// exact expected size backwards through a chain on decode).
    fn preserves_len(self) -> bool {
        matches!(self, OpSpec::Shuffle | OpSpec::ZfpLite { .. })
    }

    fn lossless(self) -> bool {
        !matches!(self, OpSpec::ZfpLite { .. })
    }

    /// Materialize the codec.
    pub fn operator(self) -> Box<dyn Operator> {
        match self {
            OpSpec::Shuffle => Box::new(Shuffle),
            OpSpec::Rle => Box::new(Rle),
            OpSpec::Delta => Box::new(Delta),
            OpSpec::ZfpLite { keep_bits } => {
                Box::new(ZfpLite { keep_bits })
            }
        }
    }

    /// Parse one `name` or `name:param` segment.
    fn parse(seg: &str) -> Result<OpSpec, OpsError> {
        let (name, param) = match seg.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (seg, None),
        };
        match name.to_ascii_lowercase().as_str() {
            "shuffle" => match param {
                None => Ok(OpSpec::Shuffle),
                Some(p) => Err(OpsError::BadParam {
                    codec: "shuffle",
                    param: p.to_string(),
                }),
            },
            "rle" => match param {
                None => Ok(OpSpec::Rle),
                Some(p) => Err(OpsError::BadParam {
                    codec: "rle",
                    param: p.to_string(),
                }),
            },
            "delta" => match param {
                None => Ok(OpSpec::Delta),
                Some(p) => Err(OpsError::BadParam {
                    codec: "delta",
                    param: p.to_string(),
                }),
            },
            "zfp" => {
                let keep_bits = match param {
                    None => ZFP_DEFAULT_KEEP_BITS,
                    Some(p) => match p.parse::<u8>() {
                        Ok(b) if (1..=52).contains(&b) => b,
                        _ => {
                            return Err(OpsError::BadParam {
                                codec: "zfp",
                                param: p.to_string(),
                            })
                        }
                    },
                };
                Ok(OpSpec::ZfpLite { keep_bits })
            }
            _ => Err(OpsError::UnknownCodec(name.to_string())),
        }
    }
}

// `zfp` always renders its parameter so specs round-trip through
// parse ↔ display (like `EngineKind`'s `bp:N`).
impl fmt::Display for OpSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OpSpec::ZfpLite { keep_bits } => write!(f, "zfp:{keep_bits}"),
            other => write!(f, "{}", other.name()),
        }
    }
}

// ---------------------------------------------------------------------
// Chains
// ---------------------------------------------------------------------

/// An ordered operator chain attached to one variable. The empty chain
/// is the identity (no transform) and is the default everywhere.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct OpChain {
    specs: Vec<OpSpec>,
}

impl OpChain {
    /// The no-op chain.
    pub fn identity() -> OpChain {
        OpChain::default()
    }

    pub fn from_specs(specs: Vec<OpSpec>) -> OpChain {
        OpChain { specs }
    }

    /// Parse a `"shuffle|rle"`-style spec. The empty string (and the
    /// aliases `"identity"`/`"none"`) parse to the identity chain;
    /// empty segments (`"shuffle||rle"`) and unknown codec names are
    /// typed errors.
    pub fn parse(spec: &str) -> Result<OpChain, OpsError> {
        let trimmed = spec.trim();
        if trimmed.is_empty()
            || trimmed.eq_ignore_ascii_case("identity")
            || trimmed.eq_ignore_ascii_case("none")
        {
            return Ok(OpChain::identity());
        }
        let mut specs = Vec::new();
        for seg in trimmed.split('|') {
            let seg = seg.trim();
            if seg.is_empty() {
                return Err(OpsError::EmptySegment(spec.to_string()));
            }
            specs.push(OpSpec::parse(seg)?);
        }
        Ok(OpChain { specs })
    }

    pub fn is_identity(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn specs(&self) -> &[OpSpec] {
        &self.specs
    }

    pub fn is_lossless(&self) -> bool {
        self.specs.iter().all(|s| s.lossless())
    }

    /// Validate the chain against a variable's element type — the
    /// `define_variable`-time check. Lossy codecs on integer variables
    /// and integer codecs on floats are typed errors.
    pub fn validate_for(&self, dtype: Datatype) -> Result<(), OpsError> {
        for spec in &self.specs {
            match spec {
                OpSpec::ZfpLite { .. } => match dtype {
                    Datatype::F32 | Datatype::F64 => {}
                    other => {
                        return Err(OpsError::LossyOnInteger {
                            codec: "zfp",
                            dtype: other.name(),
                        })
                    }
                },
                OpSpec::Delta => match dtype {
                    Datatype::I32
                    | Datatype::I64
                    | Datatype::U32
                    | Datatype::U64 => {}
                    other => {
                        return Err(OpsError::DtypeUnsupported {
                            codec: "delta",
                            dtype: other.name(),
                        })
                    }
                },
                OpSpec::Shuffle | OpSpec::Rle => {}
            }
        }
        Ok(())
    }

    /// Distinct codec names used by this chain.
    pub fn codec_names(&self) -> Vec<&'static str> {
        let mut names: Vec<&'static str> =
            self.specs.iter().map(|s| s.name()).collect();
        names.dedup();
        names.sort_unstable();
        names.dedup();
        names
    }

    /// Whether a peer advertising `codecs` can decode this chain.
    pub fn supported_by(&self, codecs: &[String]) -> bool {
        self.specs
            .iter()
            .all(|s| codecs.iter().any(|c| c == s.name()))
    }
}

impl fmt::Display for OpChain {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for spec in &self.specs {
            if !first {
                write!(f, "|")?;
            }
            write!(f, "{spec}")?;
            first = false;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Payload framing
// ---------------------------------------------------------------------

/// Bytes of the operator frame header: `[raw_len u64][encoded_len u64]`.
pub const FRAME_HEAD: usize = 16;

/// Apply `chain` to a raw dense payload and wrap the result in the
/// operator frame. The frame records the raw size so every decoder can
/// validate its output before handing bytes to the application.
pub fn encode_payload(
    chain: &OpChain,
    ctx: &OpCtx,
    raw: &[u8],
) -> Result<Vec<u8>, OpsError> {
    Ok(encode_framed(chain, ctx, raw)?.0)
}

/// [`encode_payload`] with allocation accounting: the frame buffer is
/// checked out of [`util::pool`](crate::util::pool) and codec
/// intermediates are recycled back into it. Returns the frame plus the
/// number of fresh heap allocations performed (codec outputs + frame
/// pool misses) — what `OpsReport.allocations` charges, so the metric
/// goes flat once the pool warms on identity-free steady state.
pub(crate) fn encode_framed(
    chain: &OpChain,
    ctx: &OpCtx,
    raw: &[u8],
) -> Result<(Vec<u8>, u64), OpsError> {
    let mut fresh = 0u64;
    let mut cur: Option<Vec<u8>> = None;
    for spec in chain.specs() {
        let op = spec.operator();
        let next = match &cur {
            Some(v) => op.apply(v, ctx)?,
            None => op.apply(raw, ctx)?,
        };
        // Codecs allocate their own outputs; the retired predecessor's
        // capacity goes back to the pool for the frame below.
        fresh += 1;
        if let Some(prev) = cur.take() {
            crate::util::pool::recycle_vec(prev);
        }
        cur = Some(next);
    }
    let encoded: &[u8] = match &cur {
        Some(v) => v,
        None => raw,
    };
    let mut out = crate::util::pool::acquire_buf(FRAME_HEAD + encoded.len());
    fresh += out.fresh() as u64;
    out.extend_from_slice(&(raw.len() as u64).to_le_bytes());
    out.extend_from_slice(&(encoded.len() as u64).to_le_bytes());
    out.extend_from_slice(encoded);
    if let Some(last) = cur.take() {
        crate::util::pool::recycle_vec(last);
    }
    Ok((out.detach(), fresh))
}

/// Validate an operator frame and reverse the chain. `expect_len` is
/// the raw byte count the caller independently knows the payload must
/// decode to (chunk elements × element width) — a frame disagreeing
/// with it, or whose length fields disagree with the buffer, is
/// rejected before any decoding work.
pub fn decode_payload(
    chain: &OpChain,
    ctx: &OpCtx,
    framed: &[u8],
    expect_len: usize,
) -> Result<Vec<u8>, OpsError> {
    Ok(decode_framed(chain, ctx, framed, expect_len)?.0)
}

/// [`decode_payload`] with allocation accounting; see
/// [`encode_framed`] for the counting convention.
pub(crate) fn decode_framed(
    chain: &OpChain,
    ctx: &OpCtx,
    framed: &[u8],
    expect_len: usize,
) -> Result<(Vec<u8>, u64), OpsError> {
    if framed.len() < FRAME_HEAD {
        return Err(OpsError::Corrupt(format!(
            "frame of {} bytes is shorter than its {FRAME_HEAD}-byte \
             header",
            framed.len()
        )));
    }
    let raw_len =
        u64::from_le_bytes(framed[..8].try_into().unwrap()) as usize;
    let enc_len =
        u64::from_le_bytes(framed[8..16].try_into().unwrap()) as usize;
    if enc_len != framed.len() - FRAME_HEAD {
        return Err(OpsError::Corrupt(format!(
            "encoded-length field says {enc_len}, frame carries {}",
            framed.len() - FRAME_HEAD
        )));
    }
    if raw_len != expect_len {
        return Err(OpsError::LengthMismatch {
            expected: expect_len,
            got: raw_len,
        });
    }
    let body = &framed[FRAME_HEAD..];
    // Propagate the exact output size backwards through the chain: the
    // size entering codec i is known whenever every earlier codec
    // preserves length.
    let specs = chain.specs();
    let mut known: Vec<Option<usize>> = Vec::with_capacity(specs.len());
    let mut k = Some(expect_len);
    for spec in specs {
        known.push(k);
        if !spec.preserves_len() {
            k = None;
        }
    }
    let cap = expect_len.saturating_mul(2) + 1024;
    let mut fresh = 0u64;
    let mut cur: Option<Vec<u8>> = None;
    for (i, spec) in specs.iter().enumerate().rev() {
        let op = spec.operator();
        let next = match &cur {
            Some(v) => op.reverse(v, ctx, known[i], cap)?,
            None => op.reverse(body, ctx, known[i], cap)?,
        };
        fresh += 1;
        if let Some(prev) = cur.take() {
            crate::util::pool::recycle_vec(prev);
        }
        cur = Some(next);
    }
    let out = match cur {
        Some(v) => v,
        None => {
            let mut o = crate::util::pool::acquire_buf(body.len());
            fresh += o.fresh() as u64;
            o.extend_from_slice(body);
            o.detach()
        }
    };
    if out.len() != expect_len {
        let got = out.len();
        crate::util::pool::recycle_vec(out);
        return Err(OpsError::LengthMismatch {
            expected: expect_len,
            got,
        });
    }
    Ok((out, fresh))
}

// ---------------------------------------------------------------------
// Accounting
// ---------------------------------------------------------------------

/// Cumulative operator statistics: encode side (writers), decode side
/// (readers). Cheap to copy; merge across engines with [`absorb`].
///
/// [`absorb`]: OpsReport::absorb
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpsReport {
    pub chunks_encoded: u64,
    pub chunks_decoded: u64,
    /// Raw bytes entering encode.
    pub raw_bytes_in: u64,
    /// Framed bytes leaving encode.
    pub encoded_bytes_out: u64,
    /// Framed bytes entering decode.
    pub encoded_bytes_in: u64,
    /// Raw bytes leaving decode.
    pub raw_bytes_out: u64,
    pub encode_ns: u64,
    pub decode_ns: u64,
    /// Heap buffers allocated on the data path (codec output buffers,
    /// reader reassembly buffers). Steady-state pipelines should see
    /// this stop growing once passthrough/identity paths are in effect;
    /// `benches/micro_runtime.rs` asserts exactly that.
    pub allocations: u64,
}

impl OpsReport {
    pub fn absorb(&mut self, o: OpsReport) {
        self.chunks_encoded += o.chunks_encoded;
        self.chunks_decoded += o.chunks_decoded;
        self.raw_bytes_in += o.raw_bytes_in;
        self.encoded_bytes_out += o.encoded_bytes_out;
        self.encoded_bytes_in += o.encoded_bytes_in;
        self.raw_bytes_out += o.raw_bytes_out;
        self.encode_ns += o.encode_ns;
        self.decode_ns += o.decode_ns;
        self.allocations += o.allocations;
    }

    pub fn is_empty(&self) -> bool {
        self.chunks_encoded == 0 && self.chunks_decoded == 0
    }

    /// Compression ratio (raw / encoded), from whichever side this
    /// report saw traffic on. 1.0 when nothing was transformed.
    pub fn ratio(&self) -> f64 {
        if self.encoded_bytes_out > 0 {
            self.raw_bytes_in as f64 / self.encoded_bytes_out as f64
        } else if self.encoded_bytes_in > 0 {
            self.raw_bytes_out as f64 / self.encoded_bytes_in as f64
        } else {
            1.0
        }
    }

    /// Bytes the encode side kept off the wire/disk (can be negative
    /// when a codec expands incompressible data).
    pub fn bytes_saved(&self) -> i64 {
        self.raw_bytes_in as i64 - self.encoded_bytes_out as i64
    }

    /// Encode throughput over raw bytes, bytes/s.
    pub fn encode_rate(&self) -> f64 {
        if self.encode_ns == 0 {
            0.0
        } else {
            self.raw_bytes_in as f64 / (self.encode_ns as f64 * 1e-9)
        }
    }

    /// Decode throughput over raw bytes, bytes/s.
    pub fn decode_rate(&self) -> f64 {
        if self.decode_ns == 0 {
            0.0
        } else {
            self.raw_bytes_out as f64 / (self.decode_ns as f64 * 1e-9)
        }
    }
}

/// Timed, accounted encode: the write-side hook used by every backend's
/// `perform_puts`.
pub fn encode_bytes(
    chain: &OpChain,
    ctx: &OpCtx,
    raw: &[u8],
    report: &mut OpsReport,
) -> Result<Bytes, OpsError> {
    let started = Instant::now();
    let (framed, allocs) = encode_framed(chain, ctx, raw)?;
    report.encode_ns += started.elapsed().as_nanos() as u64;
    report.chunks_encoded += 1;
    report.raw_bytes_in += raw.len() as u64;
    report.encoded_bytes_out += framed.len() as u64;
    report.allocations += allocs;
    Ok(Arc::new(framed))
}

/// Timed, accounted decode: the read-side hook used by every backend's
/// `perform_gets` (and the SST writer when it must assemble a partial
/// selection from encoded staged chunks).
pub fn decode_bytes(
    chain: &OpChain,
    ctx: &OpCtx,
    framed: &[u8],
    expect_len: usize,
    report: &mut OpsReport,
) -> Result<Bytes, OpsError> {
    let started = Instant::now();
    let (raw, allocs) = decode_framed(chain, ctx, framed, expect_len)?;
    report.decode_ns += started.elapsed().as_nanos() as u64;
    report.chunks_decoded += 1;
    report.encoded_bytes_in += framed.len() as u64;
    report.raw_bytes_out += raw.len() as u64;
    report.allocations += allocs;
    Ok(Arc::new(raw))
}

/// The write-side hook shared by every backend's `perform_puts`: an
/// identity-chain payload passes through untouched (no copy), anything
/// else is encoded through the variable's chain, timed and accounted.
pub fn encode_put(
    var: &crate::adios::engine::VarHandle,
    chunk: &crate::openpmd::chunk::Chunk,
    data: crate::adios::engine::PutPayload,
    report: &mut OpsReport,
) -> anyhow::Result<Bytes> {
    if var.ops().is_identity() {
        return Ok(data.into_bytes());
    }
    let ctx = OpCtx { dtype: var.dtype(), extent: &chunk.extent };
    encode_bytes(var.ops(), &ctx, data.as_slice(), report).map_err(|e| {
        anyhow::anyhow!("{}: operator encode: {e}", var.name())
    })
}

/// The read-side hook shared by every backend: reverse `chain` over one
/// framed chunk payload; `chunk` supplies the raw size the frame must
/// decode to. Callers handle identity chains themselves (their raw data
/// needs no copy).
pub fn decode_get(
    chain: &OpChain,
    dtype: Datatype,
    chunk: &crate::openpmd::chunk::Chunk,
    framed: &[u8],
    report: &mut OpsReport,
) -> anyhow::Result<Bytes> {
    let ctx = OpCtx { dtype, extent: &chunk.extent };
    let expect = chunk.num_elements() as usize * dtype.size();
    decode_bytes(chain, &ctx, framed, expect, report)
        .map_err(|e| anyhow::anyhow!("operator decode: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fctx() -> OpCtx<'static> {
        OpCtx { dtype: Datatype::F32, extent: &[] }
    }

    #[test]
    fn chain_spec_parsing_round_trips() {
        for s in ["shuffle", "rle", "shuffle|rle", "delta",
                  "zfp:14|shuffle|rle", "delta|rle"] {
            let chain = OpChain::parse(s).unwrap();
            assert_eq!(chain.to_string(), s, "display must round-trip");
            assert_eq!(OpChain::parse(&chain.to_string()).unwrap(), chain);
        }
        // Bare zfp renders its default parameter; re-parse agrees.
        let z = OpChain::parse("zfp").unwrap();
        assert_eq!(z.to_string(),
                   format!("zfp:{ZFP_DEFAULT_KEEP_BITS}"));
        assert_eq!(OpChain::parse(&z.to_string()).unwrap(), z);
        // Identity spellings.
        for s in ["", "  ", "identity", "none"] {
            assert!(OpChain::parse(s).unwrap().is_identity(), "{s:?}");
        }
        // Case-insensitive names.
        assert_eq!(OpChain::parse("SHUFFLE|Rle").unwrap(),
                   OpChain::parse("shuffle|rle").unwrap());
    }

    #[test]
    fn chain_spec_typed_errors() {
        assert!(matches!(OpChain::parse("gzip").unwrap_err(),
                         OpsError::UnknownCodec(n) if n == "gzip"));
        assert!(matches!(OpChain::parse("shuffle||rle").unwrap_err(),
                         OpsError::EmptySegment(_)));
        assert!(matches!(OpChain::parse("|shuffle").unwrap_err(),
                         OpsError::EmptySegment(_)));
        assert!(matches!(OpChain::parse("zfp:0").unwrap_err(),
                         OpsError::BadParam { codec: "zfp", .. }));
        assert!(matches!(OpChain::parse("zfp:99").unwrap_err(),
                         OpsError::BadParam { codec: "zfp", .. }));
        assert!(matches!(OpChain::parse("rle:4").unwrap_err(),
                         OpsError::BadParam { codec: "rle", .. }));
    }

    #[test]
    fn chain_dtype_validation() {
        let lossy = OpChain::parse("zfp:10").unwrap();
        assert!(lossy.validate_for(Datatype::F32).is_ok());
        assert!(lossy.validate_for(Datatype::F64).is_ok());
        assert!(matches!(
            lossy.validate_for(Datatype::U64).unwrap_err(),
            OpsError::LossyOnInteger { codec: "zfp", .. }
        ));
        let delta = OpChain::parse("delta").unwrap();
        assert!(delta.validate_for(Datatype::U64).is_ok());
        assert!(delta.validate_for(Datatype::I32).is_ok());
        assert!(matches!(
            delta.validate_for(Datatype::F32).unwrap_err(),
            OpsError::DtypeUnsupported { codec: "delta", .. }
        ));
        assert!(matches!(
            delta.validate_for(Datatype::U8).unwrap_err(),
            OpsError::DtypeUnsupported { codec: "delta", .. }
        ));
        assert!(OpChain::parse("shuffle|rle")
            .unwrap()
            .validate_for(Datatype::U8)
            .is_ok());
    }

    #[test]
    fn losslessness_and_negotiation_queries() {
        assert!(OpChain::parse("shuffle|rle").unwrap().is_lossless());
        assert!(!OpChain::parse("zfp|shuffle").unwrap().is_lossless());
        let chain = OpChain::parse("zfp:9|shuffle|rle").unwrap();
        assert_eq!(chain.codec_names(), vec!["rle", "shuffle", "zfp"]);
        assert!(chain.supported_by(&supported_codecs()));
        assert!(!chain
            .supported_by(&["shuffle".to_string(), "rle".to_string()]));
        assert!(OpChain::identity().supported_by(&[]));
    }

    #[test]
    fn framed_round_trip_all_chains() {
        let raw: Vec<u8> = (0..640u32)
            .flat_map(|i| ((i as f32) * 0.21).to_le_bytes())
            .collect();
        for spec in ["shuffle", "rle", "shuffle|rle"] {
            let chain = OpChain::parse(spec).unwrap();
            let framed =
                encode_payload(&chain, &fctx(), &raw).unwrap();
            let back =
                decode_payload(&chain, &fctx(), &framed, raw.len())
                    .unwrap();
            assert_eq!(back, raw, "chain {spec}");
        }
        // Identity chain frames too (raw passes through the frame).
        let id = OpChain::identity();
        let framed = encode_payload(&id, &fctx(), &raw).unwrap();
        assert_eq!(framed.len(), raw.len() + FRAME_HEAD);
        assert_eq!(decode_payload(&id, &fctx(), &framed, raw.len())
                       .unwrap(),
                   raw);
        // Zero-byte payloads round-trip.
        for spec in ["shuffle|rle", ""] {
            let chain = OpChain::parse(spec).unwrap();
            let framed = encode_payload(&chain, &fctx(), &[]).unwrap();
            assert!(decode_payload(&chain, &fctx(), &framed, 0)
                .unwrap()
                .is_empty());
        }
    }

    #[test]
    fn frame_validation_rejects_corruption() {
        let raw = vec![7u8; 256];
        let chain = OpChain::parse("shuffle|rle").unwrap();
        let ctx = OpCtx { dtype: Datatype::U8, extent: &[] };
        let framed = encode_payload(&chain, &ctx, &raw).unwrap();
        // Happy path.
        assert_eq!(decode_payload(&chain, &ctx, &framed, 256).unwrap(),
                   raw);
        // Truncated below the header.
        assert!(decode_payload(&chain, &ctx, &framed[..8], 256).is_err());
        // Corrupted raw-length field.
        let mut bad = framed.clone();
        bad[..8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            decode_payload(&chain, &ctx, &bad, 256).unwrap_err(),
            OpsError::LengthMismatch { .. }
        ));
        // Corrupted encoded-length field.
        let mut bad = framed.clone();
        bad[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            decode_payload(&chain, &ctx, &bad, 256).unwrap_err(),
            OpsError::Corrupt(_)
        ));
        // Truncated body.
        let cut = framed.len() - 1;
        assert!(decode_payload(&chain, &ctx, &framed[..cut], 256)
            .is_err());
        // Wrong expected size.
        assert!(decode_payload(&chain, &ctx, &framed, 255).is_err());
    }

    #[test]
    fn report_math_and_absorb() {
        assert!(OpsReport::default().is_empty());
        assert_eq!(OpsReport::default().ratio(), 1.0);
        let mut a = OpsReport {
            chunks_encoded: 2,
            raw_bytes_in: 400,
            encoded_bytes_out: 100,
            encode_ns: 1_000_000_000,
            ..Default::default()
        };
        assert!((a.ratio() - 4.0).abs() < 1e-12);
        assert_eq!(a.bytes_saved(), 300);
        assert!((a.encode_rate() - 400.0).abs() < 1e-9);
        let b = OpsReport {
            chunks_decoded: 1,
            encoded_bytes_in: 50,
            raw_bytes_out: 200,
            decode_ns: 500_000_000,
            ..Default::default()
        };
        assert!((b.ratio() - 4.0).abs() < 1e-12);
        assert!((b.decode_rate() - 400.0).abs() < 1e-9);
        a.absorb(b);
        assert_eq!(a.chunks_decoded, 1);
        assert_eq!(a.raw_bytes_out, 200);
        assert!(!a.is_empty());
    }

    #[test]
    fn timed_helpers_fill_the_report() {
        let raw = vec![3u8; 4096];
        let chain = OpChain::parse("rle").unwrap();
        let ctx = OpCtx { dtype: Datatype::U8, extent: &[4096] };
        let mut rep = OpsReport::default();
        let framed = encode_bytes(&chain, &ctx, &raw, &mut rep).unwrap();
        assert_eq!(rep.chunks_encoded, 1);
        assert_eq!(rep.raw_bytes_in, 4096);
        assert_eq!(rep.encoded_bytes_out, framed.len() as u64);
        assert!(rep.ratio() > 10.0, "constant bytes must collapse");
        let back =
            decode_bytes(&chain, &ctx, &framed, 4096, &mut rep).unwrap();
        assert_eq!(*back, raw);
        assert_eq!(rep.chunks_decoded, 1);
        assert_eq!(rep.raw_bytes_out, 4096);
    }
}
