//! The four built-in codecs of the operator subsystem.
//!
//! All four are hand-rolled and dependency-free (this environment builds
//! fully offline); each is small enough to audit yet representative of
//! the real ADIOS2 operator families:
//!
//! * [`Shuffle`] — byte transposition by element width (Blosc-style):
//!   groups the i-th byte of every element into one plane, so the
//!   near-constant sign/exponent bytes of real float data form long runs
//!   for a downstream RLE. Length-preserving, lossless.
//! * [`Rle`] — PackBits-style byte run-length coding with literal runs,
//!   so incompressible stretches cost ~0.8% instead of doubling.
//! * [`Delta`] — per-element delta + zigzag + LEB128 varint for integer
//!   and index data; monotone sequences (ids, offsets) collapse to one
//!   or two bytes per element. Integer dtypes only.
//! * [`ZfpLite`] — the lossy member: zeroes the low mantissa bits of
//!   f32/f64 elements, keeping `keep_bits` of precision. Length-
//!   preserving on its own (ratio 1.0); its value is making the
//!   mantissa planes compressible for a downstream `shuffle|rle`,
//!   mirroring how fixed-precision ZFP/SZ modes are deployed.

use super::{OpCtx, OpSpec, Operator, OpsError};

// ---------------------------------------------------------------------
// shuffle
// ---------------------------------------------------------------------

/// Byte-shuffle by element width. `[a0 a1 a2 a3, b0 b1 b2 b3, ...]`
/// becomes `[a0 b0 ..., a1 b1 ..., a2 b2 ..., a3 b3 ...]`.
pub struct Shuffle;

impl Operator for Shuffle {
    fn spec(&self) -> OpSpec {
        OpSpec::Shuffle
    }

    fn apply(&self, data: &[u8], ctx: &OpCtx) -> Result<Vec<u8>, OpsError> {
        let w = ctx.dtype.size();
        if w <= 1 {
            return Ok(data.to_vec());
        }
        if data.len() % w != 0 {
            return Err(OpsError::Corrupt(format!(
                "shuffle: {} bytes is not a multiple of element width {w}",
                data.len()
            )));
        }
        let n = data.len() / w;
        let mut out = vec![0u8; data.len()];
        for i in 0..n {
            for b in 0..w {
                out[b * n + i] = data[i * w + b];
            }
        }
        Ok(out)
    }

    fn reverse(
        &self,
        data: &[u8],
        ctx: &OpCtx,
        want: Option<usize>,
        _cap: usize,
    ) -> Result<Vec<u8>, OpsError> {
        let w = ctx.dtype.size();
        if let Some(want) = want {
            if want != data.len() {
                return Err(OpsError::LengthMismatch {
                    expected: want,
                    got: data.len(),
                });
            }
        }
        if w <= 1 {
            return Ok(data.to_vec());
        }
        if data.len() % w != 0 {
            return Err(OpsError::Corrupt(format!(
                "unshuffle: {} bytes is not a multiple of element width {w}",
                data.len()
            )));
        }
        let n = data.len() / w;
        let mut out = vec![0u8; data.len()];
        for i in 0..n {
            for b in 0..w {
                out[i * w + b] = data[b * n + i];
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------
// rle
// ---------------------------------------------------------------------

/// PackBits-style byte RLE. Control byte `c`:
/// `0..=127` — a literal run of `c + 1` bytes follows;
/// `128..=255` — the next byte repeats `c - 125` (3..=130) times.
pub struct Rle;

const RLE_MAX_LIT: usize = 128;
const RLE_MAX_RUN: usize = 130;
const RLE_MIN_RUN: usize = 3;

impl Operator for Rle {
    fn spec(&self) -> OpSpec {
        OpSpec::Rle
    }

    fn apply(&self, data: &[u8], _ctx: &OpCtx) -> Result<Vec<u8>, OpsError> {
        let mut out = Vec::with_capacity(data.len() / 2 + 8);
        let mut i = 0usize;
        while i < data.len() {
            // Measure the run starting at i.
            let mut j = i;
            while j + 1 < data.len()
                && data[j + 1] == data[i]
                && j + 1 - i < RLE_MAX_RUN
            {
                j += 1;
            }
            let run = j - i + 1;
            if run >= RLE_MIN_RUN {
                out.push((128 + (run - RLE_MIN_RUN)) as u8);
                out.push(data[i]);
                i += run;
                continue;
            }
            // Literal run: scan until a worthwhile repeat starts.
            let start = i;
            while i < data.len() && i - start < RLE_MAX_LIT {
                if i + 2 < data.len()
                    && data[i] == data[i + 1]
                    && data[i] == data[i + 2]
                {
                    break;
                }
                i += 1;
            }
            let lit = i - start;
            out.push((lit - 1) as u8);
            out.extend_from_slice(&data[start..i]);
        }
        Ok(out)
    }

    fn reverse(
        &self,
        data: &[u8],
        _ctx: &OpCtx,
        want: Option<usize>,
        cap: usize,
    ) -> Result<Vec<u8>, OpsError> {
        let mut out = Vec::with_capacity(want.unwrap_or(data.len()));
        let mut i = 0usize;
        while i < data.len() {
            let ctrl = data[i];
            i += 1;
            if ctrl < 128 {
                let lit = ctrl as usize + 1;
                if i + lit > data.len() {
                    return Err(OpsError::Corrupt(
                        "rle: literal run overruns the input".into(),
                    ));
                }
                if out.len() + lit > cap {
                    return Err(OpsError::Corrupt(
                        "rle: output exceeds the declared size bound".into(),
                    ));
                }
                out.extend_from_slice(&data[i..i + lit]);
                i += lit;
            } else {
                let run = ctrl as usize - 125;
                if i >= data.len() {
                    return Err(OpsError::Corrupt(
                        "rle: repeat run missing its value byte".into(),
                    ));
                }
                if out.len() + run > cap {
                    return Err(OpsError::Corrupt(
                        "rle: output exceeds the declared size bound".into(),
                    ));
                }
                let v = data[i];
                i += 1;
                out.resize(out.len() + run, v);
            }
        }
        if let Some(want) = want {
            if out.len() != want {
                return Err(OpsError::LengthMismatch {
                    expected: want,
                    got: out.len(),
                });
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------
// delta
// ---------------------------------------------------------------------

/// Per-element delta + zigzag + LEB128 varint for integer dtypes.
pub struct Delta;

fn zigzag(x: i64) -> u64 {
    ((x << 1) ^ (x >> 63)) as u64
}

fn unzigzag(x: u64) -> i64 {
    ((x >> 1) as i64) ^ -((x & 1) as i64)
}

fn put_varint(out: &mut Vec<u8>, mut x: u64) {
    loop {
        let b = (x & 0x7f) as u8;
        x >>= 7;
        if x == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn get_varint(data: &[u8], i: &mut usize) -> Result<u64, OpsError> {
    let mut x = 0u64;
    let mut shift = 0u32;
    loop {
        if *i >= data.len() {
            return Err(OpsError::Corrupt(
                "delta: varint overruns the input".into(),
            ));
        }
        if shift >= 64 {
            return Err(OpsError::Corrupt("delta: varint too long".into()));
        }
        let b = data[*i];
        *i += 1;
        x |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(x);
        }
        shift += 7;
    }
}

impl Operator for Delta {
    fn spec(&self) -> OpSpec {
        OpSpec::Delta
    }

    fn apply(&self, data: &[u8], ctx: &OpCtx) -> Result<Vec<u8>, OpsError> {
        let w = ctx.dtype.size();
        if w != 4 && w != 8 {
            return Err(OpsError::DtypeUnsupported {
                codec: "delta",
                dtype: ctx.dtype.name(),
            });
        }
        if data.len() % w != 0 {
            return Err(OpsError::Corrupt(format!(
                "delta: {} bytes is not a multiple of element width {w}",
                data.len()
            )));
        }
        let mut out = Vec::with_capacity(data.len() / 2 + 8);
        let mut prev = 0i64;
        if w == 4 {
            for c in data.chunks_exact(4) {
                let v = u32::from_le_bytes(c.try_into().unwrap()) as i64;
                put_varint(&mut out, zigzag(v.wrapping_sub(prev)));
                prev = v;
            }
        } else {
            for c in data.chunks_exact(8) {
                let v = u64::from_le_bytes(c.try_into().unwrap()) as i64;
                put_varint(&mut out, zigzag(v.wrapping_sub(prev)));
                prev = v;
            }
        }
        Ok(out)
    }

    fn reverse(
        &self,
        data: &[u8],
        ctx: &OpCtx,
        want: Option<usize>,
        cap: usize,
    ) -> Result<Vec<u8>, OpsError> {
        let w = ctx.dtype.size();
        if w != 4 && w != 8 {
            return Err(OpsError::DtypeUnsupported {
                codec: "delta",
                dtype: ctx.dtype.name(),
            });
        }
        let mut out = Vec::with_capacity(want.unwrap_or(data.len()));
        let mut prev = 0i64;
        let mut i = 0usize;
        while i < data.len() {
            let d = unzigzag(get_varint(data, &mut i)?);
            let v = prev.wrapping_add(d);
            prev = v;
            if out.len() + w > cap {
                return Err(OpsError::Corrupt(
                    "delta: output exceeds the declared size bound".into(),
                ));
            }
            if w == 4 {
                out.extend_from_slice(&(v as u32).to_le_bytes());
            } else {
                out.extend_from_slice(&(v as u64).to_le_bytes());
            }
        }
        if let Some(want) = want {
            if out.len() != want {
                return Err(OpsError::LengthMismatch {
                    expected: want,
                    got: out.len(),
                });
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------
// zfp-lite
// ---------------------------------------------------------------------

/// Lossy precision truncation: keep `keep_bits` mantissa bits of every
/// f32/f64 element, zeroing the rest. Reverse is the identity (the
/// truncation is irreversible — that is what "lossy" means here).
pub struct ZfpLite {
    pub keep_bits: u8,
}

impl Operator for ZfpLite {
    fn spec(&self) -> OpSpec {
        OpSpec::ZfpLite { keep_bits: self.keep_bits }
    }

    fn lossless(&self) -> bool {
        false
    }

    fn apply(&self, data: &[u8], ctx: &OpCtx) -> Result<Vec<u8>, OpsError> {
        use crate::openpmd::types::Datatype;
        match ctx.dtype {
            Datatype::F32 => {
                if data.len() % 4 != 0 {
                    return Err(OpsError::Corrupt(format!(
                        "zfp: {} bytes is not a multiple of 4",
                        data.len()
                    )));
                }
                let drop = 23u32.saturating_sub(self.keep_bits as u32);
                let mask: u32 = !((1u32 << drop) - 1);
                let mut out = Vec::with_capacity(data.len());
                for c in data.chunks_exact(4) {
                    let bits =
                        u32::from_le_bytes(c.try_into().unwrap()) & mask;
                    out.extend_from_slice(&bits.to_le_bytes());
                }
                Ok(out)
            }
            Datatype::F64 => {
                if data.len() % 8 != 0 {
                    return Err(OpsError::Corrupt(format!(
                        "zfp: {} bytes is not a multiple of 8",
                        data.len()
                    )));
                }
                let drop = 52u32.saturating_sub(self.keep_bits as u32);
                let mask: u64 = !((1u64 << drop) - 1);
                let mut out = Vec::with_capacity(data.len());
                for c in data.chunks_exact(8) {
                    let bits =
                        u64::from_le_bytes(c.try_into().unwrap()) & mask;
                    out.extend_from_slice(&bits.to_le_bytes());
                }
                Ok(out)
            }
            other => Err(OpsError::LossyOnInteger {
                codec: "zfp",
                dtype: other.name(),
            }),
        }
    }

    fn reverse(
        &self,
        data: &[u8],
        ctx: &OpCtx,
        want: Option<usize>,
        _cap: usize,
    ) -> Result<Vec<u8>, OpsError> {
        let w = ctx.dtype.size();
        if data.len() % w != 0 {
            return Err(OpsError::Corrupt(format!(
                "zfp: {} bytes is not a multiple of element width {w}",
                data.len()
            )));
        }
        if let Some(want) = want {
            if want != data.len() {
                return Err(OpsError::LengthMismatch {
                    expected: want,
                    got: data.len(),
                });
            }
        }
        Ok(data.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::openpmd::types::Datatype;

    fn ctx(dtype: Datatype) -> OpCtx<'static> {
        OpCtx { dtype, extent: &[] }
    }

    fn round_trip(op: &dyn Operator, data: &[u8], dtype: Datatype) {
        let c = ctx(dtype);
        let enc = op.apply(data, &c).unwrap();
        let dec = op
            .reverse(&enc, &c, Some(data.len()),
                     data.len() * 2 + 1024)
            .unwrap();
        assert_eq!(dec, data, "codec {:?}", op.spec());
    }

    #[test]
    fn shuffle_round_trips_and_transposes() {
        let data: Vec<u8> = (0..32).collect();
        round_trip(&Shuffle, &data, Datatype::F32);
        let enc = Shuffle.apply(&data, &ctx(Datatype::F32)).unwrap();
        // Plane 0 holds every element's byte 0: 0, 4, 8, ...
        assert_eq!(&enc[..8], &[0, 4, 8, 12, 16, 20, 24, 28]);
        // u8: pass-through.
        let enc8 = Shuffle.apply(&data, &ctx(Datatype::U8)).unwrap();
        assert_eq!(enc8, data);
    }

    #[test]
    fn shuffle_rejects_misaligned_input() {
        assert!(Shuffle.apply(&[0u8; 5], &ctx(Datatype::F32)).is_err());
        assert!(Shuffle
            .reverse(&[0u8; 7], &ctx(Datatype::F64), None, 1024)
            .is_err());
    }

    #[test]
    fn rle_round_trips_mixed_content() {
        let mut data = vec![7u8; 500];
        data.extend((0..=255u8).cycle().take(300));
        data.extend(vec![0u8; 2]); // short run stays literal
        round_trip(&Rle, &data, Datatype::U8);
        let enc = Rle.apply(&data, &ctx(Datatype::U8)).unwrap();
        assert!(enc.len() < data.len(), "rle failed to compress runs");
    }

    #[test]
    fn rle_handles_empty_and_expands_random_only_slightly() {
        round_trip(&Rle, &[], Datatype::U8);
        let random: Vec<u8> =
            (0..1000u32).map(|i| (i.wrapping_mul(2654435761) >> 13) as u8)
                .collect();
        let enc = Rle.apply(&random, &ctx(Datatype::U8)).unwrap();
        assert!(enc.len() <= random.len() + random.len() / 64 + 8,
                "worst-case expansion too large: {}", enc.len());
        round_trip(&Rle, &random, Datatype::U8);
    }

    #[test]
    fn rle_decode_rejects_truncation_and_bombs() {
        let enc = Rle.apply(&vec![9u8; 100], &ctx(Datatype::U8)).unwrap();
        // Truncated repeat (ctrl without value byte).
        assert!(Rle
            .reverse(&enc[..1], &ctx(Datatype::U8), None, 1024)
            .is_err());
        // Output bound enforced.
        assert!(Rle
            .reverse(&enc, &ctx(Datatype::U8), None, 10)
            .is_err());
        // Wrong final size.
        assert!(Rle
            .reverse(&enc, &ctx(Datatype::U8), Some(99), 1024)
            .is_err());
    }

    #[test]
    fn delta_round_trips_and_compresses_monotone() {
        let xs: Vec<u64> = (0..1000u64).map(|i| 1_000_000 + i * 3).collect();
        let mut data = Vec::new();
        for x in &xs {
            data.extend_from_slice(&x.to_le_bytes());
        }
        round_trip(&Delta, &data, Datatype::U64);
        let enc = Delta.apply(&data, &ctx(Datatype::U64)).unwrap();
        assert!(enc.len() < data.len() / 4,
                "monotone u64s should collapse: {}", enc.len());
        // u32, including wrap-around.
        let ys = [5u32, u32::MAX, 0, 17];
        let mut d32 = Vec::new();
        for y in ys {
            d32.extend_from_slice(&y.to_le_bytes());
        }
        round_trip(&Delta, &d32, Datatype::U32);
        // i64 negative values.
        let zs = [-5i64, 4, -4_000_000_000];
        let mut d64 = Vec::new();
        for z in zs {
            d64.extend_from_slice(&z.to_le_bytes());
        }
        round_trip(&Delta, &d64, Datatype::I64);
    }

    #[test]
    fn delta_rejects_floats_and_truncation() {
        assert!(Delta.apply(&[0u8; 8], &ctx(Datatype::F64)).is_err());
        let enc = Delta
            .apply(&42u64.to_le_bytes(), &ctx(Datatype::U64))
            .unwrap();
        // Dangling continuation bit.
        let bad = vec![0x80u8];
        assert!(Delta
            .reverse(&bad, &ctx(Datatype::U64), None, 1024)
            .is_err());
        assert!(Delta
            .reverse(&enc, &ctx(Datatype::U64), Some(16), 1024)
            .is_err());
    }

    #[test]
    fn zfp_truncates_within_tolerance_and_is_idempotent() {
        let op = ZfpLite { keep_bits: 16 };
        let xs: Vec<f32> = (0..100).map(|i| (i as f32) * 0.37 + 0.1).collect();
        let mut data = Vec::new();
        for x in &xs {
            data.extend_from_slice(&x.to_le_bytes());
        }
        let enc = op.apply(&data, &ctx(Datatype::F32)).unwrap();
        assert_eq!(enc.len(), data.len());
        // Idempotent: truncating twice changes nothing.
        assert_eq!(op.apply(&enc, &ctx(Datatype::F32)).unwrap(), enc);
        let eps = 2.0f32.powi(1 - 16);
        for (c, want) in enc.chunks_exact(4).zip(&xs) {
            let got = f32::from_le_bytes(c.try_into().unwrap());
            assert!((got - want).abs() <= want.abs() * eps,
                    "{got} vs {want}");
        }
        // Reverse is the identity.
        let dec = op
            .reverse(&enc, &ctx(Datatype::F32), Some(enc.len()), enc.len())
            .unwrap();
        assert_eq!(dec, enc);
    }

    #[test]
    fn zfp_rejects_integer_dtypes() {
        let op = ZfpLite { keep_bits: 12 };
        let err = op.apply(&[0u8; 8], &ctx(Datatype::U64)).unwrap_err();
        assert!(matches!(err, OpsError::LossyOnInteger { .. }), "{err}");
    }
}
