//! Engine-conformance suite for the two-phase (deferred) engine API.
//!
//! Every backend — BP, JSON, SST over any transport — must satisfy the
//! same contract:
//!
//! 1. **Ordering**: `define_variable` works outside steps; `put_deferred`
//!    / `put_span` require an open step; double `begin_step` fails;
//!    `take_get` before `perform_gets` fails; handles die at step end.
//! 2. **Perform-before-end equivalence**: a step whose puts were
//!    performed explicitly is byte-identical to one relying on
//!    `end_step`'s implicit perform.
//! 3. **Deferred == eager, byte for byte**: for any selection, the
//!    `get_deferred` + `perform_gets` + `take_get` batch returns exactly
//!    what the eager `get` returns.
//! 4. **Span == shared payload**: data serialized through `put_span`
//!    reads back identically to data handed in by `Arc`.
//! 5. **Validation is `Result`, not panic**: wrong payload sizes,
//!    out-of-bounds chunks and conflicting redeclarations are errors
//!    that leave the engine usable.
//!
//! Drive it from an integration test with one factory per backend; the
//! writer is closed on a background thread because SST's `close` lingers
//! until subscribed readers drain.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::adios::engine::{cast, Engine, StepStatus, VarDecl};
use crate::openpmd::chunk::Chunk;
use crate::openpmd::types::Datatype;
use crate::openpmd::Attribute;

/// A writer plus a way to open a reader onto what it wrote. The reader
/// factory is invoked after all steps are written but *before* the
/// writer is closed (SST readers must subscribe while the stream lives;
/// file readers do not care).
pub struct ConformancePair {
    pub writer: Box<dyn Engine>,
    pub open_reader: Box<dyn FnOnce() -> Result<Box<dyn Engine>>>,
}

const N: u64 = 16;
const VAR_A: &str = "/data/0/conformance/a";
const VAR_B: &str = "/data/0/conformance/b";

/// Deterministic per-step payload pattern.
fn pattern(step: u64, offset: u64, len: u64) -> Vec<f32> {
    (0..len)
        .map(|i| (step * 1000 + offset + i) as f32 * 0.5)
        .collect()
}

fn lo_chunk() -> Chunk {
    Chunk::new(vec![0], vec![N / 2])
}

fn hi_chunk() -> Chunk {
    Chunk::new(vec![N / 2], vec![N / 2])
}

/// Selections exercised against every backend: aligned-whole, aligned
/// chunk, misaligned spanning both chunks, tail.
fn selections() -> Vec<Chunk> {
    vec![
        Chunk::whole(vec![N]),
        lo_chunk(),
        Chunk::new(vec![2], vec![10]),
        hi_chunk(),
    ]
}

/// Run the whole suite against one backend.
pub fn run_conformance(
    name: &str,
    make: impl FnOnce() -> Result<ConformancePair>,
) -> Result<()> {
    let pair = make().with_context(|| format!("[{name}] opening pair"))?;
    let mut writer = pair.writer;

    write_phase(name, writer.as_mut())
        .with_context(|| format!("[{name}] write phase"))?;

    let mut reader = (pair.open_reader)()
        .with_context(|| format!("[{name}] opening reader"))?;

    // SST's close blocks until subscribed readers drain the staged
    // steps, so it runs concurrently with the read phase.
    let close_thread = std::thread::spawn(move || -> Result<()> {
        writer.close()
    });

    let read_result = read_phase(name, reader.as_mut())
        .with_context(|| format!("[{name}] read phase"));
    reader.close().ok();
    close_thread
        .join()
        .map_err(|_| anyhow::anyhow!("[{name}] writer close panicked"))?
        .with_context(|| format!("[{name}] writer close"))?;
    read_result
}

fn write_phase(name: &str, w: &mut dyn Engine) -> Result<()> {
    let decl_a = VarDecl::new(VAR_A, Datatype::F32, vec![N]);
    let decl_b = VarDecl::new(VAR_B, Datatype::F32, vec![N]);

    // 1. define works outside a step; puts do not.
    let ha = w.define_variable(&decl_a)?;
    if w.put_deferred(&ha, lo_chunk(),
                      cast::f32_to_bytes(&pattern(0, 0, N / 2)))
        .is_ok()
    {
        bail!("put_deferred outside a step must fail");
    }
    if w.put_span(&ha, lo_chunk()).is_ok() {
        bail!("put_span outside a step must fail");
    }

    // 5. conflicting redeclaration is an error; identical one is not.
    if w.define_variable(&VarDecl::new(VAR_A, Datatype::F64, vec![N]))
        .is_ok()
    {
        bail!("conflicting dtype redeclaration must fail");
    }
    if w.define_variable(&VarDecl::new(VAR_A, Datatype::F32, vec![N + 1]))
        .is_ok()
    {
        bail!("conflicting shape redeclaration must fail");
    }
    let ha2 = w.define_variable(&decl_a)?;
    if ha2 != ha {
        bail!("redefinition with identical decl must return same handle");
    }

    // ---- step 0: deferred puts + EXPLICIT perform -------------------
    if w.begin_step()? != StepStatus::Ok {
        bail!("writer begin_step must be Ok");
    }
    if w.begin_step().is_ok() {
        bail!("begin_step while a step is open must fail");
    }
    w.put_attribute("/conformance/step", Attribute::F64(0.0))?;

    // 5. wrong payload size / out-of-bounds chunk: errors, engine lives.
    if w.put_deferred(&ha, lo_chunk(), Arc::new(vec![0u8; 13])).is_ok() {
        bail!("wrong-size payload must fail");
    }
    if w.put_deferred(&ha, Chunk::new(vec![N - 2], vec![4]),
                      cast::f32_to_bytes(&[0.0; 4]))
        .is_ok()
    {
        bail!("out-of-bounds chunk must fail");
    }

    w.put_deferred(&ha, lo_chunk(),
                   cast::f32_to_bytes(&pattern(0, 0, N / 2)))?;
    w.put_deferred(&ha, hi_chunk(),
                   cast::f32_to_bytes(&pattern(0, N / 2, N / 2)))?;
    w.perform_puts()?; // explicit
    w.end_step()?;

    // ---- step 1: deferred puts + IMPLICIT perform, plus a span var --
    if w.begin_step()? != StepStatus::Ok {
        bail!("writer begin_step must be Ok");
    }
    w.put_attribute("/conformance/step", Attribute::F64(1.0))?;
    // Same payload as step 0 for A (shifted pattern would also do; equal
    // data makes the perform-before-end equivalence check direct).
    w.put_deferred(&ha, lo_chunk(),
                   cast::f32_to_bytes(&pattern(0, 0, N / 2)))?;
    w.put_deferred(&ha, hi_chunk(),
                   cast::f32_to_bytes(&pattern(0, N / 2, N / 2)))?;
    // 4. B is serialized in place through a span.
    let hb = w.define_variable(&decl_b)?;
    {
        let span = w.put_span(&hb, Chunk::whole(vec![N]))?;
        let want = pattern(7, 0, N);
        for (slot, v) in span.chunks_exact_mut(4).zip(&want) {
            slot.copy_from_slice(&v.to_le_bytes());
        }
    }
    w.end_step()?; // implicit perform
    let _ = name;
    Ok(())
}

fn read_phase(name: &str, r: &mut dyn Engine) -> Result<()> {
    // ---- step 0 ------------------------------------------------------
    wait_step(r)?;
    let vars = r.available_variables();
    if !vars.iter().any(|v| v.name == VAR_A) {
        bail!("step 0 must expose {VAR_A}, got {vars:?}");
    }
    let chunks = r.available_chunks(VAR_A);
    if chunks.len() != 2 {
        bail!("step 0 must expose 2 written chunks, got {}", chunks.len());
    }
    match r.attribute("/conformance/step") {
        Some(a) if a.as_f64() == Some(0.0) => {}
        other => bail!("step attribute wrong: {other:?}"),
    }

    // Unknown variable: error, engine stays usable.
    if r.get_deferred("/nope", Chunk::whole(vec![N])).is_ok() {
        bail!("get_deferred of unknown variable must fail");
    }

    // 3. eager first, then the same selections as one deferred batch.
    let mut eager = Vec::new();
    for sel in selections() {
        eager.push(r.get(VAR_A, sel)?);
    }
    let handles: Vec<_> = selections()
        .into_iter()
        .map(|sel| r.get_deferred(VAR_A, sel))
        .collect::<Result<_>>()?;
    // take before perform must fail.
    if r.take_get(handles[0]).is_ok() {
        bail!("take_get before perform_gets must fail");
    }
    r.perform_gets()?;
    let mut step0_whole = None;
    for (i, h) in handles.iter().enumerate() {
        let deferred = r.take_get(*h)?;
        if *deferred != *eager[i] {
            bail!(
                "[{name}] deferred batch result {i} differs from eager \
                 get ({} vs {} bytes)",
                deferred.len(),
                eager[i].len()
            );
        }
        if i == 0 {
            step0_whole = Some(deferred.clone());
        }
        // Each handle is single-redemption.
        if r.take_get(*h).is_ok() {
            bail!("double take_get must fail");
        }
    }
    let step0_whole = step0_whole.unwrap();
    // Content check against the ground-truth pattern.
    if cast::bytes_to_f32(&step0_whole)? != pattern(0, 0, N) {
        bail!("step 0 payload does not match the written pattern");
    }
    let stale = handles[0];
    r.end_step()?;

    // ---- step 1 ------------------------------------------------------
    wait_step(r)?;
    // 1. handles do not survive step boundaries.
    if r.take_get(stale).is_ok() {
        bail!("get handle must not survive end_step");
    }
    // 2. perform-before-end equivalence: step 1's A (implicit perform)
    // equals step 0's A (explicit perform), byte for byte.
    let a1 = r.get(VAR_A, Chunk::whole(vec![N]))?;
    if *a1 != *step0_whole {
        bail!(
            "[{name}] implicit-perform step differs from explicit-perform \
             step"
        );
    }
    // 4. the span-written variable reads back exactly.
    let b1 = r.get(VAR_B, Chunk::whole(vec![N]))?;
    if cast::bytes_to_f32(&b1)? != pattern(7, 0, N) {
        bail!("span-serialized payload does not match");
    }
    r.end_step()?;

    // ---- end of stream ----------------------------------------------
    match r.begin_step()? {
        StepStatus::EndOfStream => Ok(()),
        other => bail!("expected EndOfStream after 2 steps, got {other:?}"),
    }
}

/// `begin_step` with NotReady tolerance (SST readers may need to poll).
fn wait_step(r: &mut dyn Engine) -> Result<()> {
    for _ in 0..200 {
        match r.begin_step()? {
            StepStatus::Ok => return Ok(()),
            StepStatus::NotReady => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            other => bail!("expected a step, got {other:?}"),
        }
    }
    bail!("timed out waiting for a step")
}
