//! Engine-conformance suite for the two-phase (deferred) engine API.
//!
//! Every backend — BP, JSON, SST over any transport — must satisfy the
//! same contract:
//!
//! 1. **Ordering**: `define_variable` works outside steps; `put_deferred`
//!    / `put_span` require an open step; double `begin_step` fails;
//!    `take_get` before `perform_gets` fails; handles die at step end.
//! 2. **Perform-before-end equivalence**: a step whose puts were
//!    performed explicitly is byte-identical to one relying on
//!    `end_step`'s implicit perform.
//! 3. **Deferred == eager, byte for byte**: for any selection, the
//!    `get_deferred` + `perform_gets` + `take_get` batch returns exactly
//!    what the eager `get` returns.
//! 4. **Span == shared payload**: data serialized through `put_span`
//!    reads back identically to data handed in by `Arc`.
//! 5. **Validation is `Result`, not panic**: wrong payload sizes,
//!    out-of-bounds chunks and conflicting redeclarations are errors
//!    that leave the engine usable.
//!
//! Drive it from an integration test with one factory per backend; the
//! writer is closed on a background thread because SST's `close` lingers
//! until subscribed readers drain.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::adios::engine::{cast, Engine, StepStatus, VarDecl};
use crate::adios::ops::{OpChain, OpSpec};
use crate::openpmd::chunk::Chunk;
use crate::openpmd::types::Datatype;
use crate::openpmd::Attribute;

/// A writer plus a way to open a reader onto what it wrote. The reader
/// factory is invoked after all steps are written but *before* the
/// writer is closed (SST readers must subscribe while the stream lives;
/// file readers do not care).
pub struct ConformancePair {
    pub writer: Box<dyn Engine>,
    pub open_reader: Box<dyn FnOnce() -> Result<Box<dyn Engine>>>,
}

const N: u64 = 16;
const VAR_A: &str = "/data/0/conformance/a";
const VAR_B: &str = "/data/0/conformance/b";

/// Deterministic per-step payload pattern.
fn pattern(step: u64, offset: u64, len: u64) -> Vec<f32> {
    (0..len)
        .map(|i| (step * 1000 + offset + i) as f32 * 0.5)
        .collect()
}

fn lo_chunk() -> Chunk {
    Chunk::new(vec![0], vec![N / 2])
}

fn hi_chunk() -> Chunk {
    Chunk::new(vec![N / 2], vec![N / 2])
}

/// Selections exercised against every backend: aligned-whole, aligned
/// chunk, misaligned spanning both chunks, tail.
fn selections() -> Vec<Chunk> {
    vec![
        Chunk::whole(vec![N]),
        lo_chunk(),
        Chunk::new(vec![2], vec![10]),
        hi_chunk(),
    ]
}

/// Run the whole suite against one backend.
pub fn run_conformance(
    name: &str,
    make: impl FnOnce() -> Result<ConformancePair>,
) -> Result<()> {
    let pair = make().with_context(|| format!("[{name}] opening pair"))?;
    let mut writer = pair.writer;

    write_phase(name, writer.as_mut())
        .with_context(|| format!("[{name}] write phase"))?;

    let mut reader = (pair.open_reader)()
        .with_context(|| format!("[{name}] opening reader"))?;

    // SST's close blocks until subscribed readers drain the staged
    // steps, so it runs concurrently with the read phase.
    let close_thread = std::thread::spawn(move || -> Result<()> {
        writer.close()
    });

    let read_result = read_phase(name, reader.as_mut())
        .with_context(|| format!("[{name}] read phase"));
    reader.close().ok();
    close_thread
        .join()
        .map_err(|_| anyhow::anyhow!("[{name}] writer close panicked"))?
        .with_context(|| format!("[{name}] writer close"))?;
    read_result
}

fn write_phase(name: &str, w: &mut dyn Engine) -> Result<()> {
    let decl_a = VarDecl::new(VAR_A, Datatype::F32, vec![N]);
    let decl_b = VarDecl::new(VAR_B, Datatype::F32, vec![N]);

    // 1. define works outside a step; puts do not.
    let ha = w.define_variable(&decl_a)?;
    if w.put_deferred(&ha, lo_chunk(),
                      cast::f32_to_bytes(&pattern(0, 0, N / 2)))
        .is_ok()
    {
        bail!("put_deferred outside a step must fail");
    }
    if w.put_span(&ha, lo_chunk()).is_ok() {
        bail!("put_span outside a step must fail");
    }

    // 5. conflicting redeclaration is an error; identical one is not.
    if w.define_variable(&VarDecl::new(VAR_A, Datatype::F64, vec![N]))
        .is_ok()
    {
        bail!("conflicting dtype redeclaration must fail");
    }
    if w.define_variable(&VarDecl::new(VAR_A, Datatype::F32, vec![N + 1]))
        .is_ok()
    {
        bail!("conflicting shape redeclaration must fail");
    }
    let ha2 = w.define_variable(&decl_a)?;
    if ha2 != ha {
        bail!("redefinition with identical decl must return same handle");
    }

    // ---- step 0: deferred puts + EXPLICIT perform -------------------
    if w.begin_step()? != StepStatus::Ok {
        bail!("writer begin_step must be Ok");
    }
    if w.begin_step().is_ok() {
        bail!("begin_step while a step is open must fail");
    }
    w.put_attribute("/conformance/step", Attribute::F64(0.0))?;

    // 5. wrong payload size / out-of-bounds chunk: errors, engine lives.
    if w.put_deferred(&ha, lo_chunk(), Arc::new(vec![0u8; 13])).is_ok() {
        bail!("wrong-size payload must fail");
    }
    if w.put_deferred(&ha, Chunk::new(vec![N - 2], vec![4]),
                      cast::f32_to_bytes(&[0.0; 4]))
        .is_ok()
    {
        bail!("out-of-bounds chunk must fail");
    }

    w.put_deferred(&ha, lo_chunk(),
                   cast::f32_to_bytes(&pattern(0, 0, N / 2)))?;
    w.put_deferred(&ha, hi_chunk(),
                   cast::f32_to_bytes(&pattern(0, N / 2, N / 2)))?;
    w.perform_puts()?; // explicit
    w.end_step()?;

    // ---- step 1: deferred puts + IMPLICIT perform, plus a span var --
    if w.begin_step()? != StepStatus::Ok {
        bail!("writer begin_step must be Ok");
    }
    w.put_attribute("/conformance/step", Attribute::F64(1.0))?;
    // Same payload as step 0 for A (shifted pattern would also do; equal
    // data makes the perform-before-end equivalence check direct).
    w.put_deferred(&ha, lo_chunk(),
                   cast::f32_to_bytes(&pattern(0, 0, N / 2)))?;
    w.put_deferred(&ha, hi_chunk(),
                   cast::f32_to_bytes(&pattern(0, N / 2, N / 2)))?;
    // 4. B is serialized in place through a span.
    let hb = w.define_variable(&decl_b)?;
    {
        let span = w.put_span(&hb, Chunk::whole(vec![N]))?;
        let want = pattern(7, 0, N);
        for (slot, v) in span.chunks_exact_mut(4).zip(&want) {
            slot.copy_from_slice(&v.to_le_bytes());
        }
    }
    w.end_step()?; // implicit perform
    let _ = name;
    Ok(())
}

fn read_phase(name: &str, r: &mut dyn Engine) -> Result<()> {
    // ---- step 0 ------------------------------------------------------
    wait_step(r)?;
    let vars = r.available_variables();
    if !vars.iter().any(|v| v.name == VAR_A) {
        bail!("step 0 must expose {VAR_A}, got {vars:?}");
    }
    let chunks = r.available_chunks(VAR_A);
    if chunks.len() != 2 {
        bail!("step 0 must expose 2 written chunks, got {}", chunks.len());
    }
    match r.attribute("/conformance/step") {
        Some(a) if a.as_f64() == Some(0.0) => {}
        other => bail!("step attribute wrong: {other:?}"),
    }

    // Unknown variable: error, engine stays usable.
    if r.get_deferred("/nope", Chunk::whole(vec![N])).is_ok() {
        bail!("get_deferred of unknown variable must fail");
    }

    // 3. eager first, then the same selections as one deferred batch.
    let mut eager = Vec::new();
    for sel in selections() {
        eager.push(r.get(VAR_A, sel)?);
    }
    let handles: Vec<_> = selections()
        .into_iter()
        .map(|sel| r.get_deferred(VAR_A, sel))
        .collect::<Result<_>>()?;
    // take before perform must fail.
    if r.take_get(handles[0]).is_ok() {
        bail!("take_get before perform_gets must fail");
    }
    r.perform_gets()?;
    let mut step0_whole = None;
    for (i, h) in handles.iter().enumerate() {
        let deferred = r.take_get(*h)?;
        if *deferred != *eager[i] {
            bail!(
                "[{name}] deferred batch result {i} differs from eager \
                 get ({} vs {} bytes)",
                deferred.len(),
                eager[i].len()
            );
        }
        if i == 0 {
            step0_whole = Some(deferred.clone());
        }
        // Each handle is single-redemption.
        if r.take_get(*h).is_ok() {
            bail!("double take_get must fail");
        }
    }
    let step0_whole = step0_whole.unwrap();
    // Content check against the ground-truth pattern.
    if cast::bytes_to_f32(&step0_whole)? != pattern(0, 0, N) {
        bail!("step 0 payload does not match the written pattern");
    }
    let stale = handles[0];
    r.end_step()?;

    // ---- step 1 ------------------------------------------------------
    wait_step(r)?;
    // 1. handles do not survive step boundaries.
    if r.take_get(stale).is_ok() {
        bail!("get handle must not survive end_step");
    }
    // 2. perform-before-end equivalence: step 1's A (implicit perform)
    // equals step 0's A (explicit perform), byte for byte.
    let a1 = r.get(VAR_A, Chunk::whole(vec![N]))?;
    if *a1 != *step0_whole {
        bail!(
            "[{name}] implicit-perform step differs from explicit-perform \
             step"
        );
    }
    // 4. the span-written variable reads back exactly.
    let b1 = r.get(VAR_B, Chunk::whole(vec![N]))?;
    if cast::bytes_to_f32(&b1)? != pattern(7, 0, N) {
        bail!("span-serialized payload does not match");
    }
    r.end_step()?;

    // ---- end of stream ----------------------------------------------
    match r.begin_step()? {
        StepStatus::EndOfStream => Ok(()),
        other => bail!("expected EndOfStream after 2 steps, got {other:?}"),
    }
}

// =====================================================================
// Operator axis
// =====================================================================

const VAR_PLAIN: &str = "/data/0/ops/plain";
const VAR_CODED: &str = "/data/0/ops/coded";

/// Operator-chain conformance, run per (chain × backend): the same
/// payload is written twice in one step — once through an identity
/// chain, once through `spec` — as two chunks each (so exact-match
/// passthrough AND decode/assemble/re-encode service paths both run).
/// The read side loads whole, aligned and misaligned selections from
/// both variables; a lossless chain must be **byte-identical** to the
/// identity variable, a zfp-lite chain must agree within its
/// `keep_bits` tolerance. Integer chains (`delta`) run the same
/// contract on a u64 variable with monotone content.
pub fn run_operator_conformance(
    name: &str,
    spec: &str,
    make: impl FnOnce() -> Result<ConformancePair>,
) -> Result<()> {
    let chain = OpChain::parse(spec)
        .map_err(|e| anyhow::anyhow!("[{name}] spec {spec:?}: {e}"))?;
    // Chains rejected for f32 (delta) run on the integer variable.
    let integer = chain.validate_for(Datatype::F32).is_err();
    if integer {
        chain
            .validate_for(Datatype::U64)
            .map_err(|e| anyhow::anyhow!("[{name}] spec {spec:?}: {e}"))?;
    }
    // Per-element relative tolerance: 0 for lossless chains.
    let mut tol = 0.0f32;
    for s in chain.specs() {
        if let OpSpec::ZfpLite { keep_bits } = s {
            tol = tol.max(2.0f32.powi(1 - *keep_bits as i32));
        }
    }

    let pair = make()
        .with_context(|| format!("[{name}] {spec}: opening pair"))?;
    let mut writer = pair.writer;
    ops_write_phase(writer.as_mut(), &chain, integer)
        .with_context(|| format!("[{name}] {spec}: write phase"))?;

    let mut reader = (pair.open_reader)()
        .with_context(|| format!("[{name}] {spec}: opening reader"))?;
    let close_thread = std::thread::spawn(move || -> Result<()> {
        writer.close()
    });
    let read_result =
        ops_read_phase(reader.as_mut(), &chain, integer, tol)
            .with_context(|| format!("[{name}] {spec}: read phase"));
    reader.close().ok();
    close_thread
        .join()
        .map_err(|_| anyhow::anyhow!("[{name}] writer close panicked"))?
        .with_context(|| format!("[{name}] {spec}: writer close"))?;
    read_result
}

fn ops_payload_int(offset: u64, len: u64) -> Vec<u64> {
    (0..len).map(|i| 1_000_000 + (offset + i) * 7).collect()
}

fn ops_write_phase(
    w: &mut dyn Engine,
    chain: &OpChain,
    integer: bool,
) -> Result<()> {
    let dtype = if integer { Datatype::U64 } else { Datatype::F32 };
    let plain = VarDecl::new(VAR_PLAIN, dtype, vec![N]);
    let coded = VarDecl::new(VAR_CODED, dtype, vec![N])
        .with_ops(chain.clone());
    let hp = w.define_variable(&plain)?;
    let hc = w.define_variable(&coded)?;
    if w.begin_step()? != StepStatus::Ok {
        bail!("writer begin_step must be Ok");
    }
    for (chunk, offset) in [(lo_chunk(), 0u64), (hi_chunk(), N / 2)] {
        let bytes = if integer {
            cast::u64_to_bytes(&ops_payload_int(offset, N / 2))
        } else {
            cast::f32_to_bytes(&pattern(3, offset, N / 2))
        };
        w.put_deferred(&hp, chunk.clone(), bytes.clone())?;
        w.put_deferred(&hc, chunk, bytes)?;
    }
    w.end_step()?;
    Ok(())
}

fn ops_read_phase(
    r: &mut dyn Engine,
    chain: &OpChain,
    integer: bool,
    tol: f32,
) -> Result<()> {
    wait_step(r)?;
    // The stream/file self-describes the chain.
    let vars = r.available_variables();
    let coded_info = vars
        .iter()
        .find(|v| v.name == VAR_CODED)
        .ok_or_else(|| anyhow::anyhow!("coded variable not announced"))?;
    if &coded_info.ops != chain {
        bail!(
            "announced chain {:?} != declared {:?}",
            coded_info.ops.to_string(),
            chain.to_string()
        );
    }
    let plain_info = vars
        .iter()
        .find(|v| v.name == VAR_PLAIN)
        .ok_or_else(|| anyhow::anyhow!("plain variable not announced"))?;
    if !plain_info.ops.is_identity() {
        bail!("identity variable grew a chain: {:?}",
              plain_info.ops.to_string());
    }

    // Whole (spans chunks), aligned (exact chunk), misaligned.
    for sel in selections() {
        let want = r.get(VAR_PLAIN, sel.clone())?;
        let got = r.get(VAR_CODED, sel.clone())?;
        if chain.is_lossless() {
            if *got != *want {
                bail!(
                    "lossless chain output differs from identity on \
                     selection {:?}+{:?} ({} vs {} bytes)",
                    sel.offset, sel.extent, got.len(), want.len()
                );
            }
        } else if integer {
            bail!("lossy chains are float-only (validation hole)");
        } else {
            let want = cast::bytes_to_f32(&want)?;
            let got = cast::bytes_to_f32(&got)?;
            if want.len() != got.len() {
                bail!("lossy chain changed the element count");
            }
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                if (a - b).abs() > a.abs() * tol + 1e-6 {
                    bail!(
                        "element {i} outside zfp tolerance: {a} vs {b} \
                         (tol {tol})"
                    );
                }
            }
        }
    }
    r.end_step()?;
    match r.begin_step()? {
        StepStatus::EndOfStream => Ok(()),
        other => bail!("expected EndOfStream, got {other:?}"),
    }
}

/// `begin_step` with NotReady tolerance (SST readers may need to poll).
fn wait_step(r: &mut dyn Engine) -> Result<()> {
    for _ in 0..200 {
        match r.begin_step()? {
            StepStatus::Ok => return Ok(()),
            StepStatus::NotReady => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            other => bail!("expected a step, got {other:?}"),
        }
    }
    bail!("timed out waiting for a step")
}
