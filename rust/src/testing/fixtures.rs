//! Shared data fixtures for tests and benches, so the "write a chunked
//! BP source" helper exists once instead of per test file.

use std::path::Path;

use crate::adios::bp::{BpWriter, WriterCtx};
use crate::adios::engine::{cast, Engine, VarDecl};
use crate::openpmd::chunk::Chunk;
use crate::openpmd::types::Datatype;
use crate::openpmd::Attribute;

/// Write a BP source of `steps` steps, each carrying one f32 variable
/// `/data/x` of extent `extent` split into `chunks_per_step` equal
/// chunks, plus a `/data/time` attribute holding the step index.
/// Element at global index `g` of step `s` holds `(s * 100 + g) as
/// f32` — a formula tests can assert against.
pub fn write_chunked_bp(
    path: impl AsRef<Path>,
    steps: u64,
    extent: u64,
    chunks_per_step: u64,
) {
    assert!(
        chunks_per_step > 0 && extent % chunks_per_step == 0,
        "extent must split evenly into chunks"
    );
    let mut w = BpWriter::create(path, WriterCtx {
        rank: 0,
        hostname: "src".into(),
    })
    .expect("create BP fixture");
    let decl = VarDecl::new("/data/x", Datatype::F32, vec![extent]);
    let per_chunk = extent / chunks_per_step;
    for s in 0..steps {
        w.begin_step().unwrap();
        w.put_attribute("/data/time", Attribute::F64(s as f64))
            .unwrap();
        let h = w.define_variable(&decl).unwrap();
        for c in 0..chunks_per_step {
            let off = c * per_chunk;
            let xs: Vec<f32> = (0..per_chunk)
                .map(|i| (s * 100 + off + i) as f32)
                .collect();
            w.put_deferred(&h, Chunk::new(vec![off], vec![per_chunk]),
                           cast::f32_to_bytes(&xs))
                .unwrap();
        }
        w.end_step().unwrap();
    }
    w.close().unwrap();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::bp::BpReader;
    use crate::adios::engine::StepStatus;

    #[test]
    fn fixture_writes_the_documented_formula() {
        let path = std::env::temp_dir()
            .join(format!("opmd-fixture-{}.bp", std::process::id()));
        write_chunked_bp(&path, 2, 8, 2);
        let mut r = BpReader::open(&path).unwrap();
        for s in 0..2u64 {
            assert_eq!(r.begin_step().unwrap(), StepStatus::Ok);
            assert_eq!(
                r.attribute("/data/time").unwrap().as_f64(),
                Some(s as f64)
            );
            assert_eq!(r.available_chunks("/data/x").len(), 2);
            let data = r.get("/data/x", Chunk::whole(vec![8])).unwrap();
            let xs = cast::bytes_to_f32(&data).unwrap();
            for (g, &x) in xs.iter().enumerate() {
                assert_eq!(x, (s * 100 + g as u64) as f32);
            }
            r.end_step().unwrap();
        }
        assert_eq!(r.begin_step().unwrap(), StepStatus::EndOfStream);
        std::fs::remove_file(&path).ok();
    }
}
