//! Fleet-conformance harness: for any strategy and fleet width M, the
//! union of the fleet's output shards must be **byte-identical** to
//! what the serial single pipe forwards from the same stream — every
//! element present exactly once (complete AND disjoint), with the
//! same values, for every step.
//!
//! Shape mirrors [`super::engine_conformance`]: the library owns the
//! machinery, `tests/fleet_conformance.rs` drives it across the
//! (strategy × M) matrix. Each run builds a fresh N=2-writer SST
//! stream with a skewed chunk table (one 8x chunk per writer — the
//! shape that separates cost-aware from blind strategies), consumes
//! it once through the serial pipe and once through [`run_fleet`],
//! and compares the assembled step payloads element by element.
//!
//! **Reassembly conformance** closes the chain the other way:
//! [`fleet_into_shards`] runs a fleet into real BP shards plus the
//! merged `<out>.index.json`, [`reassembled_union`] opens that family
//! via [`crate::openpmd::series::open_shard_family`] (one multiplexed
//! logical series) and forwards it through ANOTHER serial pipe — so
//! `tests/reassembly_conformance.rs` proves
//! `produce → fleet(M) → reassemble → pipe` byte-identical to
//! `produce → pipe` for every strategy × M, with per-worker staged
//! read-ahead on top.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::adios::bp::{BpReader, BpWriter, WriterCtx};
use crate::adios::engine::{cast, Engine, StepStatus, VarDecl};
use crate::adios::sst::{
    QueueConfig, QueueFullPolicy, SstReader, SstReaderOptions, SstWriter,
    SstWriterOptions, WriterGroup,
};
use crate::distribution::{by_name, Strategy};
use crate::openpmd::chunk::Chunk;
use crate::openpmd::series::shard_path;
use crate::openpmd::types::Datatype;
use crate::pipeline::fleet::{run_fleet, FleetOptions};
use crate::pipeline::pipe::{run_pipe, PipeOptions};

const WRITERS: usize = 2;
/// Per-writer chunk sizes in units of [`K`] elements: skewed so blind
/// and cost-aware strategies produce different (but equally complete)
/// assignments.
const SKEW: [u64; 4] = [8, 1, 2, 1];
const K: u64 = 16;
const STEPS: u64 = 3;
const VAR: &str = "/data/0/fleet/x";

fn per_writer_elems() -> u64 {
    SKEW.iter().sum::<u64>() * K
}

fn total_elems() -> u64 {
    WRITERS as u64 * per_writer_elems()
}

/// Ground-truth value of global element `g` in step `s` — what every
/// writer spawned by [`spawn_skewed_sst_writers`] emits.
pub fn formula(step: u64, g: u64) -> f32 {
    (step * 1000 + g) as f32
}

/// Spawn `writers` skewed SST writer ranks (collective discard group,
/// blocking queue so nothing is dropped): writer `w` contributes the
/// chunk sizes in `sizes` (elements) at base offset `w * sum(sizes)`
/// of variable `var` (f32, shape `writers * sum(sizes)`), each element
/// holding [`formula`]. Returns dial addresses + producer threads to
/// join after the stream is drained. Shared by this harness and
/// `benches/fig_fleet.rs`, so the bench and the conformance suite
/// always exercise the same staging contract.
pub fn spawn_skewed_sst_writers(
    tag: &str,
    writers: usize,
    steps: u64,
    sizes: Vec<u64>,
    var: &'static str,
) -> Result<(Vec<String>, Vec<JoinHandle<()>>)> {
    let group = WriterGroup::new();
    let per_writer: u64 = sizes.iter().sum();
    let total = writers as u64 * per_writer;
    let mut addrs = Vec::new();
    let mut threads = Vec::new();
    for w in 0..writers {
        let mut writer = SstWriter::open(SstWriterOptions {
            listen: format!("fleet-skew-{tag}-w{w}-{}",
                            std::process::id()),
            transport: "inproc".into(),
            rank: w,
            hostname: format!("node{w:04}"),
            queue: QueueConfig {
                policy: QueueFullPolicy::Block,
                limit: 4,
            },
            group: Some(group.clone()),
            ..Default::default()
        })
        .with_context(|| format!("opening writer {w}"))?;
        addrs.push(writer.address());
        let sizes = sizes.clone();
        threads.push(std::thread::spawn(move || {
            let decl = VarDecl::new(var, Datatype::F32, vec![total]);
            let base = w as u64 * per_writer;
            for step in 0..steps {
                assert_eq!(writer.begin_step().unwrap(), StepStatus::Ok);
                let h = writer.define_variable(&decl).unwrap();
                let mut off = base;
                for &n in &sizes {
                    let xs: Vec<f32> =
                        (0..n).map(|i| formula(step, off + i)).collect();
                    writer
                        .put_deferred(
                            &h,
                            Chunk::new(vec![off], vec![n]),
                            cast::f32_to_bytes(&xs),
                        )
                        .unwrap();
                    off += n;
                }
                writer.end_step().unwrap();
            }
            writer.close().unwrap();
        }));
    }
    Ok((addrs, threads))
}

/// The harness's fixed fixture: N=2 writers over the [`SKEW`] table.
fn spawn_writers(tag: &str)
    -> Result<(Vec<String>, Vec<JoinHandle<()>>)>
{
    spawn_skewed_sst_writers(
        tag,
        WRITERS,
        STEPS,
        SKEW.iter().map(|f| f * K).collect(),
        VAR,
    )
}

fn open_reader(addrs: &[String], rank: usize) -> Result<SstReader> {
    SstReader::open(SstReaderOptions {
        writers: addrs.to_vec(),
        transport: "inproc".into(),
        rank,
        hostname: "localhost".into(),
        begin_step_timeout: Duration::from_secs(20),
        codecs: None,
    })
    .with_context(|| format!("opening fleet reader {rank}"))
}

/// Assemble each step's full payload from a set of output shards,
/// proving along the way that the shards' chunks cover every element
/// of every step **exactly once**.
fn assemble_union(shards: &[PathBuf]) -> Result<Vec<Vec<f32>>> {
    let n = total_elems() as usize;
    let mut readers = Vec::with_capacity(shards.len());
    for path in shards {
        readers.push(
            BpReader::open(path)
                .with_context(|| format!("opening shard {path:?}"))?,
        );
    }
    let mut steps_out = Vec::new();
    for step in 0..STEPS {
        let mut coverage = vec![0u32; n];
        let mut data = vec![0f32; n];
        for (shard, reader) in readers.iter_mut().enumerate() {
            match reader.begin_step()? {
                StepStatus::Ok => {}
                other => bail!(
                    "shard {shard} step {step}: begin_step {other:?}"
                ),
            }
            for info in reader.available_chunks(VAR) {
                let bytes = reader.get(VAR, info.chunk.clone())?;
                let xs = cast::bytes_to_f32(&bytes)?;
                let off = info.chunk.offset[0] as usize;
                for (i, &x) in xs.iter().enumerate() {
                    data[off + i] = x;
                    coverage[off + i] += 1;
                }
            }
            reader.end_step()?;
        }
        for (g, &c) in coverage.iter().enumerate() {
            if c != 1 {
                bail!(
                    "step {step}: element {g} covered {c} times across \
                     {} shard(s) — union not complete+disjoint",
                    shards.len()
                );
            }
        }
        steps_out.push(data);
    }
    for (shard, reader) in readers.iter_mut().enumerate() {
        match reader.begin_step()? {
            StepStatus::EndOfStream => {}
            other => {
                bail!("shard {shard}: trailing step status {other:?}")
            }
        }
    }
    Ok(steps_out)
}

fn tmp(tag: &str, name: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "opmd-fleet-conf-{tag}-{name}-{}",
        std::process::id()
    ))
}

/// The serial single pipe's output for one fresh stream — the
/// reference every fleet configuration must union to. Validated
/// against the writers' [`formula`] before it is returned, so callers
/// can reuse one reference across every (strategy, M) cell.
pub fn serial_reference(tag: &str) -> Result<Vec<Vec<f32>>> {
    let (addrs, producers) = spawn_writers(&format!("{tag}-serial"))?;
    let mut input = open_reader(&addrs, 0)?;
    let dst = tmp(tag, "serial.bp");
    let mut output = BpWriter::create(&dst, WriterCtx::default())?;
    let mut opts = PipeOptions::solo();
    opts.idle_timeout = Duration::from_secs(20);
    let report = run_pipe(&mut input, &mut output, opts)?;
    for t in producers {
        t.join().map_err(|_| anyhow::anyhow!("producer panicked"))?;
    }
    if report.steps != STEPS {
        bail!("serial pipe forwarded {} of {STEPS} steps", report.steps);
    }
    let result = assemble_union(std::slice::from_ref(&dst));
    std::fs::remove_file(&dst).ok();
    let serial = result?;
    for (step, data) in serial.iter().enumerate() {
        for (g, &x) in data.iter().enumerate() {
            if x != formula(step as u64, g as u64) {
                bail!(
                    "serial reference step {step} element {g}: {x} != \
                     formula {}",
                    formula(step as u64, g as u64)
                );
            }
        }
    }
    Ok(serial)
}

/// Run the fleet at width `readers` with `strategy_name` over a fresh
/// stream and return the union of its shards (validated complete +
/// disjoint), deleting the shards afterwards.
pub fn fleet_union(
    tag: &str,
    strategy_name: &str,
    readers: usize,
) -> Result<Vec<Vec<f32>>> {
    fleet_union_at_depth(tag, strategy_name, readers, 0)
}

/// [`fleet_union`] with per-worker staged read-ahead (`depth > 0`
/// gives every worker its own fetch thread — the satellite the
/// ROADMAP called "fleet workers with staged read-ahead").
pub fn fleet_union_at_depth(
    tag: &str,
    strategy_name: &str,
    readers: usize,
    depth: usize,
) -> Result<Vec<Vec<f32>>> {
    let case = format!("{tag}-{strategy_name}-m{readers}-d{depth}");
    let (index, shards) =
        fleet_into_shards(&case, strategy_name, readers, depth)?;
    let result = assemble_union(&shards);
    cleanup_family(&index, &shards);
    result.with_context(|| format!("[{case}] shard union"))
}

/// Run a fleet into REAL BP shards plus the merged
/// `<out>.index.json`: the persistent artifact half of the
/// produce → fleet → reassemble chain. Returns the index path and the
/// shard paths (callers clean up with [`cleanup_family`]).
pub fn fleet_into_shards(
    case: &str,
    strategy_name: &str,
    readers: usize,
    depth: usize,
) -> Result<(PathBuf, Vec<PathBuf>)> {
    let (addrs, producers) = spawn_writers(case)?;
    let base = tmp(case, "out.bp");
    let mut inputs: Vec<Box<dyn Engine>> = Vec::with_capacity(readers);
    let mut outputs: Vec<Box<dyn Engine>> = Vec::with_capacity(readers);
    let mut shards = Vec::with_capacity(readers);
    for rank in 0..readers {
        inputs.push(Box::new(open_reader(&addrs, rank)?));
        let shard = shard_path(&base, rank, readers);
        outputs.push(Box::new(BpWriter::create(&shard, WriterCtx {
            rank,
            hostname: "localhost".into(),
        })?));
        shards.push(shard);
    }
    let strategy: Arc<dyn Strategy> = Arc::from(by_name(strategy_name)?);
    let mut opts = FleetOptions::local(readers, strategy)?;
    opts.idle_timeout = Duration::from_secs(20);
    opts.depth = depth;
    let report = run_fleet(inputs, outputs, opts)?;
    for t in producers {
        t.join().map_err(|_| anyhow::anyhow!("producer panicked"))?;
    }
    if report.steps() != STEPS {
        bail!(
            "[{case}] fleet forwarded {} of {STEPS} steps",
            report.steps()
        );
    }
    if report.total_bytes_in() != STEPS * total_elems() * 4 {
        bail!(
            "[{case}] fleet moved {} bytes, stream holds {}",
            report.total_bytes_in(),
            STEPS * total_elems() * 4
        );
    }
    let index = crate::openpmd::series::write_shard_index(
        &base, readers, report.steps(),
    )?;
    Ok((index, shards))
}

/// Delete a shard family and its index (paths from
/// [`fleet_into_shards`] — tests run in parallel threads, so only this
/// family's files are touched).
pub fn cleanup_family(index: &Path, shards: &[PathBuf]) {
    std::fs::remove_file(index).ok();
    for shard in shards {
        std::fs::remove_file(shard).ok();
    }
}

/// The reassembly half of the chain: open a shard family through the
/// merged index as ONE multiplexed logical series, forward it through
/// a fresh serial pipe (`shards → openpmd-pipe → single BP file`), and
/// return the assembled per-step payloads of that final output. This
/// is exactly what a downstream consumer of a fleet's output sees, so
/// comparing it against [`serial_reference`] proves the closed
/// produce → fleet(M) → reassemble → pipe chain byte-identical to
/// produce → pipe.
pub fn reassembled_union(case: &str, index: &Path)
    -> Result<Vec<Vec<f32>>>
{
    let mut input = crate::openpmd::series::open_shard_family(index)
        .with_context(|| format!("[{case}] opening shard family"))?;
    let dst = tmp(case, "reassembled.bp");
    let mut output = BpWriter::create(&dst, WriterCtx::default())?;
    let mut opts = PipeOptions::solo();
    opts.idle_timeout = Duration::from_secs(20);
    let report = run_pipe(&mut input, &mut output, opts)
        .with_context(|| format!("[{case}] reassembling pipe"))?;
    if report.steps != STEPS {
        std::fs::remove_file(&dst).ok();
        bail!(
            "[{case}] reassembling pipe forwarded {} of {STEPS} steps",
            report.steps
        );
    }
    let result = assemble_union(std::slice::from_ref(&dst));
    std::fs::remove_file(&dst).ok();
    result.with_context(|| format!("[{case}] reassembled output"))
}

/// One full produce → fleet(M) → reassemble → pipe cell, compared
/// against an already-validated serial reference.
pub fn assert_reassembly_matches(
    serial: &[Vec<f32>],
    tag: &str,
    strategy_name: &str,
    readers: usize,
    depth: usize,
) -> Result<()> {
    let case = format!("re-{tag}-{strategy_name}-m{readers}-d{depth}");
    let (index, shards) =
        fleet_into_shards(&case, strategy_name, readers, depth)?;
    let result = reassembled_union(&case, &index);
    cleanup_family(&index, &shards);
    let reassembled = result?;
    compare_step_payloads(
        &reassembled,
        serial,
        &format!("{strategy_name} M={readers} depth={depth} reassembled"),
    )
}

/// Element-exact comparison of two assembled step-payload sets with a
/// first-difference diagnostic.
pub fn compare_step_payloads(
    got: &[Vec<f32>],
    want: &[Vec<f32>],
    label: &str,
) -> Result<()> {
    if got == want {
        return Ok(());
    }
    for (step, (g, w)) in got.iter().zip(want).enumerate() {
        if g != w {
            let at = g
                .iter()
                .zip(w)
                .position(|(a, b)| a != b)
                .unwrap_or(0);
            bail!(
                "[{label}] step {step} differs from the serial pipe \
                 first at element {at}: {} != {}",
                g[at],
                w[at]
            );
        }
    }
    bail!(
        "[{label}] step counts disagree: {} vs {}",
        got.len(),
        want.len()
    )
}

/// Compare one (strategy, M) fleet cell against an already-validated
/// serial reference (from [`serial_reference`] — hoist it once per
/// strategy, the reference is independent of the cell).
pub fn assert_fleet_matches(
    serial: &[Vec<f32>],
    tag: &str,
    strategy_name: &str,
    readers: usize,
) -> Result<()> {
    let fleet = fleet_union(tag, strategy_name, readers)?;
    compare_step_payloads(
        &fleet,
        serial,
        &format!("{strategy_name} M={readers}"),
    )
}
