//! A delegating engine wrapper with one injected behavior per
//! constructor: latency, faults, or backpressure discards. It wraps
//! any [`Engine`] and passes every call through, so the wrapped
//! backend stays fully conformant while exactly one behavior is
//! altered — and there is a single delegation impl to keep in sync
//! with the trait.
//!
//! Used by the staged-pipe tests (error propagation, drop accounting)
//! and by `benches/fig8_pipeline.rs`, where [`InjectedEngine::slow`]
//! gives load and store measurable latencies so the serial-vs-staged
//! overlap is visible on any machine.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::adios::engine::{
    Bytes, Engine, GetHandle, Mode, PutQueue, StepStatus, VarDecl,
    VarHandle, VarInfo,
};
use crate::adios::ops::OpsReport;
use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};
use crate::openpmd::Attribute;

/// The error text injected by [`InjectedEngine::failing`]; tests match
/// on it.
pub const INJECTED_STORE_FAULT: &str = "injected store fault";

/// See the module docs. Construct with [`InjectedEngine::slow`],
/// [`InjectedEngine::failing`] or [`InjectedEngine::discarding`].
pub struct InjectedEngine<E: Engine> {
    inner: E,
    /// Sleep added before every `perform_gets` (read side).
    get_latency: Duration,
    /// Sleep added before every `end_step` publish (write side —
    /// charged once per step, where file engines flush).
    put_latency: Duration,
    /// 0-based step index from which every `perform_puts` fails.
    fail_puts_from_step: Option<u64>,
    /// `begin_step` returns `Discarded` for this many first offers.
    discard_first_steps: u64,
    steps_offered: u64,
    steps_ended: u64,
}

impl<E: Engine> InjectedEngine<E> {
    fn passthrough(inner: E) -> InjectedEngine<E> {
        InjectedEngine {
            inner,
            get_latency: Duration::ZERO,
            put_latency: Duration::ZERO,
            fail_puts_from_step: None,
            discard_first_steps: 0,
            steps_offered: 0,
            steps_ended: 0,
        }
    }

    /// Fixed latency per batch execution, simulating slow media or a
    /// long wire: `get_latency` before each `perform_gets`,
    /// `put_latency` before each `end_step` publish.
    pub fn slow(inner: E, get_latency: Duration, put_latency: Duration)
        -> InjectedEngine<E>
    {
        let mut e = Self::passthrough(inner);
        e.get_latency = get_latency;
        e.put_latency = put_latency;
        e
    }

    /// Write-mode fault injection: `perform_puts` starts failing from
    /// step index `fail_from_step` on — for error-propagation tests
    /// (e.g. the staged pipe must unwind and join its fetch thread
    /// when the store side dies, not deadlock it).
    pub fn failing(inner: E, fail_from_step: u64) -> InjectedEngine<E> {
        let mut e = Self::passthrough(inner);
        e.fail_puts_from_step = Some(fail_from_step);
        e
    }

    /// Write-mode backpressure injection: the first `n` steps are
    /// discarded at `begin_step` (queue-full backpressure without an
    /// SST queue), for drop-accounting tests.
    pub fn discarding(inner: E, n: u64) -> InjectedEngine<E> {
        let mut e = Self::passthrough(inner);
        e.discard_first_steps = n;
        e
    }

    pub fn into_inner(self) -> E {
        self.inner
    }
}

impl<E: Engine> Engine for InjectedEngine<E> {
    fn engine_type(&self) -> &'static str {
        self.inner.engine_type()
    }

    fn mode(&self) -> Mode {
        self.inner.mode()
    }

    fn begin_step(&mut self) -> Result<StepStatus> {
        self.steps_offered += 1;
        if self.steps_offered <= self.discard_first_steps {
            // Step discarded before any data movement; the inner step
            // is never opened.
            return Ok(StepStatus::Discarded);
        }
        self.inner.begin_step()
    }

    fn define_variable(&mut self, decl: &VarDecl) -> Result<VarHandle> {
        self.inner.define_variable(decl)
    }

    fn put_deferred(&mut self, var: &VarHandle, chunk: Chunk, data: Bytes)
        -> Result<()>
    {
        self.inner.put_deferred(var, chunk, data)
    }

    fn put_span(&mut self, var: &VarHandle, chunk: Chunk)
        -> Result<&mut [u8]>
    {
        self.inner.put_span(var, chunk)
    }

    fn perform_puts(&mut self) -> Result<()> {
        if let Some(from) = self.fail_puts_from_step {
            if self.steps_ended >= from {
                bail!("{INJECTED_STORE_FAULT} (step {})", self.steps_ended);
            }
        }
        self.inner.perform_puts()
    }

    fn put_attribute(&mut self, name: &str, value: Attribute) -> Result<()> {
        self.inner.put_attribute(name, value)
    }

    fn available_variables(&self) -> Vec<VarInfo> {
        self.inner.available_variables()
    }

    fn available_chunks(&self, var: &str) -> Vec<WrittenChunkInfo> {
        self.inner.available_chunks(var)
    }

    fn attribute(&self, name: &str) -> Option<Attribute> {
        self.inner.attribute(name)
    }

    fn attribute_names(&self) -> Vec<String> {
        self.inner.attribute_names()
    }

    fn get_deferred(&mut self, var: &str, selection: Chunk)
        -> Result<GetHandle>
    {
        self.inner.get_deferred(var, selection)
    }

    fn perform_gets(&mut self) -> Result<()> {
        std::thread::sleep(self.get_latency);
        self.inner.perform_gets()
    }

    fn take_get(&mut self, handle: GetHandle) -> Result<Bytes> {
        self.inner.take_get(handle)
    }

    fn end_step(&mut self) -> Result<()> {
        if self.inner.mode() == Mode::Write {
            std::thread::sleep(self.put_latency);
        }
        self.inner.end_step()?;
        self.steps_ended += 1;
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        self.inner.close()
    }

    fn ops_report(&self) -> OpsReport {
        self.inner.ops_report()
    }
}

/// A fully-validating write engine that stores nothing: every put goes
/// through the real two-phase queue (declaration checks, chunk bounds,
/// payload sizes) and is then counted and dropped. The measurement
/// sink for benches where a real output medium would dominate what is
/// being measured — `benches/fig_fleet.rs` points every fleet worker
/// at one so the sweep times the reader side, not disk writes.
#[derive(Default)]
pub struct CountingSink {
    puts: PutQueue,
    open: bool,
    pub steps: u64,
    pub bytes: u64,
    pub chunks: u64,
}

impl CountingSink {
    pub fn new() -> CountingSink {
        CountingSink::default()
    }
}

impl Engine for CountingSink {
    fn engine_type(&self) -> &'static str {
        "counting-sink"
    }

    fn mode(&self) -> Mode {
        Mode::Write
    }

    fn begin_step(&mut self) -> Result<StepStatus> {
        if self.open {
            bail!("begin_step while a step is open");
        }
        self.open = true;
        Ok(StepStatus::Ok)
    }

    fn define_variable(&mut self, decl: &VarDecl) -> Result<VarHandle> {
        self.puts.define(decl)
    }

    fn put_deferred(&mut self, var: &VarHandle, chunk: Chunk, data: Bytes)
        -> Result<()>
    {
        if !self.open {
            bail!("put outside step");
        }
        self.puts.enqueue(var, chunk, data)
    }

    fn put_span(&mut self, var: &VarHandle, chunk: Chunk)
        -> Result<&mut [u8]>
    {
        if !self.open {
            bail!("put_span outside step");
        }
        self.puts.span(var, chunk)
    }

    fn perform_puts(&mut self) -> Result<()> {
        for p in self.puts.drain() {
            self.bytes += p.data.len() as u64;
            self.chunks += 1;
        }
        Ok(())
    }

    fn put_attribute(&mut self, _name: &str, _value: Attribute)
        -> Result<()>
    {
        if !self.open {
            bail!("put_attribute outside step");
        }
        Ok(())
    }

    fn available_variables(&self) -> Vec<VarInfo> {
        Vec::new()
    }

    fn available_chunks(&self, _var: &str) -> Vec<WrittenChunkInfo> {
        Vec::new()
    }

    fn attribute(&self, _name: &str) -> Option<Attribute> {
        None
    }

    fn attribute_names(&self) -> Vec<String> {
        Vec::new()
    }

    fn get_deferred(&mut self, _var: &str, _selection: Chunk)
        -> Result<GetHandle>
    {
        bail!("get on a write-mode sink")
    }

    fn perform_gets(&mut self) -> Result<()> {
        bail!("perform_gets on a write-mode sink")
    }

    fn take_get(&mut self, _handle: GetHandle) -> Result<Bytes> {
        bail!("take_get on a write-mode sink")
    }

    fn end_step(&mut self) -> Result<()> {
        if !self.open {
            bail!("end_step without begin_step");
        }
        self.perform_puts()?;
        self.open = false;
        self.steps += 1;
        Ok(())
    }

    fn close(&mut self) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::bp::{BpReader, BpWriter, WriterCtx};
    use crate::adios::engine::cast;
    use crate::openpmd::types::Datatype;

    #[test]
    fn counting_sink_counts_and_validates() {
        let mut sink = CountingSink::new();
        let decl = VarDecl::new("/x", Datatype::F32, vec![8]);
        let h = sink.define_variable(&decl).unwrap();
        // Puts outside a step are errors, like every real backend.
        assert!(sink
            .put_deferred(&h, Chunk::whole(vec![8]),
                          cast::f32_to_bytes(&[0.0; 8]))
            .is_err());
        sink.begin_step().unwrap();
        sink.put_deferred(&h, Chunk::new(vec![0], vec![4]),
                          cast::f32_to_bytes(&[1.0; 4]))
            .unwrap();
        // Invalid chunks are still rejected.
        assert!(sink
            .put_deferred(&h, Chunk::new(vec![6], vec![4]),
                          cast::f32_to_bytes(&[1.0; 4]))
            .is_err());
        sink.end_step().unwrap();
        assert_eq!((sink.steps, sink.chunks, sink.bytes), (1, 1, 16));
    }

    #[test]
    fn slow_engine_round_trips_unchanged() {
        let path = std::env::temp_dir()
            .join(format!("opmd-slow-{}.bp", std::process::id()));
        let inner = BpWriter::create(&path, WriterCtx::default()).unwrap();
        let mut w = InjectedEngine::slow(
            inner, Duration::ZERO, Duration::from_millis(1));
        let var = VarDecl::new("/x", Datatype::F32, vec![4]);
        w.begin_step().unwrap();
        w.put(&var, Chunk::whole(vec![4]),
              cast::f32_to_bytes(&[1.0, 2.0, 3.0, 4.0]))
            .unwrap();
        w.end_step().unwrap();
        w.close().unwrap();

        let inner = BpReader::open(&path).unwrap();
        let mut r = InjectedEngine::slow(
            inner, Duration::from_millis(1), Duration::ZERO);
        assert_eq!(r.begin_step().unwrap(), StepStatus::Ok);
        let data = r.get("/x", Chunk::whole(vec![4])).unwrap();
        assert_eq!(cast::bytes_to_f32(&data).unwrap(),
                   vec![1.0, 2.0, 3.0, 4.0]);
        r.end_step().unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn failing_engine_fails_from_the_configured_step() {
        let path = std::env::temp_dir()
            .join(format!("opmd-failw-{}.bp", std::process::id()));
        let inner = BpWriter::create(&path, WriterCtx::default()).unwrap();
        let mut w = InjectedEngine::failing(inner, 1);
        let var = VarDecl::new("/x", Datatype::F32, vec![1]);
        // Step 0 succeeds.
        w.begin_step().unwrap();
        w.put(&var, Chunk::whole(vec![1]), cast::f32_to_bytes(&[0.0]))
            .unwrap();
        w.end_step().unwrap();
        // Step 1 fails at batch execution.
        w.begin_step().unwrap();
        let err = w
            .put(&var, Chunk::whole(vec![1]), cast::f32_to_bytes(&[1.0]))
            .unwrap_err();
        assert!(format!("{err}").contains(INJECTED_STORE_FAULT), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn discarding_engine_drops_then_delegates() {
        let path = std::env::temp_dir()
            .join(format!("opmd-discw-{}.bp", std::process::id()));
        let inner = BpWriter::create(&path, WriterCtx::default()).unwrap();
        let mut w = InjectedEngine::discarding(inner, 2);
        assert_eq!(w.begin_step().unwrap(), StepStatus::Discarded);
        assert_eq!(w.begin_step().unwrap(), StepStatus::Discarded);
        assert_eq!(w.begin_step().unwrap(), StepStatus::Ok);
        w.end_step().unwrap();
        w.close().unwrap();
        std::fs::remove_file(&path).ok();
    }
}
