//! Mini property-testing framework (S17) + the engine-conformance suite.
//!
//! proptest is not available offline, so the invariant tests for the
//! distribution strategies use this: deterministic seeded generation, a
//! configurable case count, and greedy input shrinking on failure. The
//! API is intentionally tiny — `check(cases, gen, prop)`.
//!
//! [`engine_conformance`] is the shared contract test for the two-phase
//! engine API, run against every backend from `tests/`;
//! [`fleet_conformance`] is its analog for the parallel reader fleet
//! (shard union == serial pipe, for any strategy × M). [`engines`]
//! provides a delegating engine wrapper with one injected behavior
//! (latency, faults, discards) plus a validating
//! [`engines::CountingSink`] for pipe tests and benches, and
//! [`fixtures`] the shared chunked-BP source generator they read.

pub mod engine_conformance;
pub mod engines;
pub mod fixtures;
pub mod fleet_conformance;

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Max shrink attempts after a failure.
    pub shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 200, seed: 0xC0FFEE, shrink_steps: 2000 }
    }
}

/// Outcome of a property over one input.
pub type PropResult = Result<(), String>;

/// A value generator plus a shrinker.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller inputs, most aggressive first. Default: none.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` over `config.cases` generated inputs; panic with the
/// (shrunk) counterexample on failure.
pub fn check_with<G: Gen>(
    config: Config,
    gen: &G,
    prop: impl Fn(&G::Value) -> PropResult,
) {
    let mut rng = Rng::new(config.seed);
    for case in 0..config.cases {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            // Shrink greedily: take the first failing candidate, repeat.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut budget = config.shrink_steps;
            'outer: while budget > 0 {
                for cand in gen.shrink(&best) {
                    budget = budget.saturating_sub(1);
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed at case {case} (seed {:#x}):\n  {}\n  \
                 counterexample: {:?}",
                config.seed, best_msg, best
            );
        }
    }
}

/// [`check_with`] under the default config.
pub fn check<G: Gen>(gen: &G, prop: impl Fn(&G::Value) -> PropResult) {
    check_with(Config::default(), gen, prop)
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

// ----------------------------------------------------------------------
// Stock generators
// ----------------------------------------------------------------------

/// Uniform usize in [lo, hi]; shrinks toward lo.
pub struct UsizeRange(pub usize, pub usize);

impl Gen for UsizeRange {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range(self.0, self.1 + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        // Geometric ladder from lo toward v (most aggressive first), so a
        // greedy first-failure walk converges to the boundary in
        // O(log^2) steps instead of descending linearly.
        let mut out = Vec::new();
        if *v <= self.0 {
            return out;
        }
        out.push(self.0);
        let k = *v - self.0;
        let mut step = k / 2;
        while step > 0 {
            out.push(v - step);
            step /= 2;
        }
        out.dedup();
        out
    }
}

/// Pair of independent generators; shrinks each side.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for a in self.0.shrink(&v.0) {
            out.push((a, v.1.clone()));
        }
        for b in self.1.shrink(&v.1) {
            out.push((v.0.clone(), b));
        }
        out
    }
}

/// Vec of values with random length in [0, max_len]; shrinks by halving
/// and element-dropping.
pub struct VecOf<G> {
    pub item: G,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.range(0, self.max_len + 1);
        (0..n).map(|_| self.item.generate(rng)).collect()
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.is_empty() {
            return out;
        }
        out.push(v[..v.len() / 2].to_vec());
        if v.len() > 1 {
            out.push(v[1..].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        // Shrink one element.
        for (i, item) in v.iter().enumerate().take(4) {
            for s in self.item.shrink(item) {
                let mut copy = v.clone();
                copy[i] = s;
                out.push(copy);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(&UsizeRange(1, 100), |&x| {
            prop_assert!(x >= 1 && x <= 100, "range violated: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "counterexample")]
    fn failing_property_panics_with_counterexample() {
        check(&UsizeRange(0, 1000), |&x| {
            prop_assert!(x < 500, "too big: {x}");
            Ok(())
        });
    }

    #[test]
    fn shrinking_reaches_minimal_case() {
        // Capture the panic message and verify the counterexample is the
        // boundary value 500, not an arbitrary large one.
        let result = std::panic::catch_unwind(|| {
            check(&UsizeRange(0, 100_000), |&x| {
                prop_assert!(x < 500, "too big: {x}");
                Ok(())
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("counterexample: 500"), "msg: {msg}");
    }

    #[test]
    fn vec_generator_respects_bounds() {
        check(&VecOf { item: UsizeRange(5, 9), max_len: 13 }, |v| {
            prop_assert!(v.len() <= 13, "len {}", v.len());
            prop_assert!(v.iter().all(|&x| (5..=9).contains(&x)),
                         "range violated: {v:?}");
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed| {
            let mut got = Vec::new();
            let mut rng = Rng::new(seed);
            let g = UsizeRange(0, 1 << 20);
            for _ in 0..20 {
                got.push(g.generate(&mut rng));
            }
            got
        };
        assert_eq!(collect(42), collect(42));
        assert_ne!(collect(42), collect(43));
    }
}
