//! Reusable buffer pool for the streaming hot path.
//!
//! Every hop in the pipeline — wire decode, operator encode/decode,
//! BP fetch, SST reassembly, serve staging — needs a scratch or output
//! `Vec<u8>` per chunk per step. Allocating those fresh each time makes
//! the allocator the steady-state bottleneck once the data path outruns
//! the filesystem. This module keeps a bounded, size-classed stash of
//! retired buffers and hands them back out, so a warmed-up pipe step
//! performs O(1) heap allocations regardless of chunk count.
//!
//! Design constraints, in order:
//!
//! - **Dependency-free and unwind-safe.** Capacity returns to the pool
//!   via [`PooledBuf`]'s `Drop`, so early returns, `?` propagation and
//!   panics all shelve the buffer instead of leaking pool budget.
//! - **Lock-graph leaf.** The shelves live behind one [`OrderedMutex`]
//!   under the dedicated `BUF_POOL` class. Nothing is ever called while
//!   that guard is held — counters are lock-free atomics bumped after
//!   the guard drops — so the pool adds zero lock-order edges.
//! - **Bounded.** Retained bytes never exceed the budget
//!   (`OPMD_POOL_BUDGET`, default 256 MiB); over-budget returns are
//!   simply freed and counted as `pool.trimmed_bytes`.
//! - **Bypassable.** `set_pooling_enabled(false)` (or
//!   `OPMD_POOL_DISABLE=1`) turns every acquire into a plain allocation
//!   and every return into a plain free, for A/B benchmarking
//!   (`benches/micro_alloc.rs`) and byte-identity conformance tests.
//!
//! Observability: `pool.hits`, `pool.misses`, `pool.recycled_bytes`,
//! `pool.trimmed_bytes` counters and the `pool.retained_bytes` gauge,
//! all registered in [`obs::metrics`](crate::obs::metrics).

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use once_cell::sync::Lazy;

use crate::obs::metrics::{counter, gauge, Counter, Gauge};
use crate::util::sync::{classes, OrderedMutex};

/// Smallest size class: buffers below this round up to 1 KiB.
const MIN_SHIFT: u32 = 10;
/// Largest size class: 64 MiB. Bigger requests are served exact-sized
/// and never retained (one stray huge buffer would evict everything).
const MAX_SHIFT: u32 = 26;
/// Number of power-of-two size classes (1 KiB ..= 64 MiB inclusive).
const NUM_CLASSES: usize = (MAX_SHIFT - MIN_SHIFT + 1) as usize;

/// Default retained-bytes budget when `OPMD_POOL_BUDGET` is unset.
const DEFAULT_BUDGET: usize = 256 << 20;

static POOL_HITS: Lazy<&'static Counter> =
    Lazy::new(|| counter("pool.hits"));
static POOL_MISSES: Lazy<&'static Counter> =
    Lazy::new(|| counter("pool.misses"));
static POOL_RECYCLED: Lazy<&'static Counter> =
    Lazy::new(|| counter("pool.recycled_bytes"));
static POOL_TRIMMED: Lazy<&'static Counter> =
    Lazy::new(|| counter("pool.trimmed_bytes"));
static POOL_RETAINED: Lazy<&'static Gauge> =
    Lazy::new(|| gauge("pool.retained_bytes"));

/// Size-classed free lists. Each entry stores the buffer alongside its
/// capacity at shelving time so the guard scope never needs to call
/// `Vec::capacity` — the critical section is pop/push + arithmetic
/// only, with no method calls that could grow the lock graph.
struct Shelves {
    classes: [Vec<(usize, Vec<u8>)>; NUM_CLASSES],
    retained: usize,
}

fn empty_shelves() -> Shelves {
    Shelves {
        classes: std::array::from_fn(|_| Vec::new()),
        retained: 0,
    }
}

/// A thread-safe, size-classed pool of reusable `Vec<u8>` buffers with
/// a bounded retained-bytes budget. One process-wide instance lives
/// behind the module-level free functions ([`acquire_buf`],
/// [`recycle_vec`], …); tests construct standalone pools with tight
/// budgets. The enable switch is per-instance, so a standalone test
/// pool can be toggled without perturbing the global one.
pub struct BufferPool {
    shelves: OrderedMutex<Shelves>,
    budget: usize,
    enabled: AtomicBool,
}

/// Map a requested minimum capacity to its size-class index, or `None`
/// when the request exceeds the largest retained class.
fn class_index(min: usize) -> Option<usize> {
    if min > (1usize << MAX_SHIFT) {
        return None;
    }
    let needed = min.max(1).next_power_of_two();
    let shift = needed.trailing_zeros().max(MIN_SHIFT);
    Some((shift - MIN_SHIFT) as usize)
}

/// Capacity (bytes) of size class `ci`.
fn class_bytes(ci: usize) -> usize {
    1usize << (ci as u32 + MIN_SHIFT)
}

impl BufferPool {
    /// A pool that will retain at most `budget` bytes of free capacity.
    pub fn new(budget: usize) -> Self {
        BufferPool {
            shelves: OrderedMutex::new(&classes::BUF_POOL, empty_shelves()),
            budget,
            enabled: AtomicBool::new(true),
        }
    }

    fn from_env() -> Self {
        let budget = std::env::var("OPMD_POOL_BUDGET")
            .ok()
            .and_then(|s| crate::util::bytes::parse_bytes(&s).ok())
            .map(|b| b as usize)
            .unwrap_or(DEFAULT_BUDGET);
        let pool = BufferPool::new(budget);
        if std::env::var("OPMD_POOL_DISABLE").is_ok_and(|v| v != "0") {
            pool.enabled.store(false, Ordering::Relaxed);
        }
        pool
    }

    /// Flip this pool's enable switch. Disabled means checkout = plain
    /// allocation and stash = plain free; already-shelved capacity
    /// stays until [`purge`](BufferPool::purge)d.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Whether this pool currently recycles.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Check out an empty buffer with at least `min` bytes of capacity.
    /// Pool hit when a shelved buffer of the right class exists; a miss
    /// allocates fresh at the full class size so capacities stay
    /// uniform across recycles.
    ///
    /// Note: the returned handle recycles into the **process-wide**
    /// pool on drop. Standalone pools (tests) see capacity come back
    /// only through explicit [`detach`](PooledBuf::detach) +
    /// [`stash_vec`](BufferPool::stash_vec).
    pub fn checkout(&self, min: usize) -> PooledBuf {
        if !self.enabled() {
            return PooledBuf {
                buf: Vec::with_capacity(min),
                recycle: false,
                fresh: true,
            };
        }
        let Some(ci) = class_index(min) else {
            // Oversize: exact allocation, never shelved.
            POOL_MISSES.inc();
            return PooledBuf {
                buf: Vec::with_capacity(min),
                recycle: false,
                fresh: true,
            };
        };
        let mut popped: Option<Vec<u8>> = None;
        let mut retained = None;
        if let Ok(mut sh) = self.shelves.lock() {
            if let Some((cap, v)) = sh.classes[ci].pop() {
                sh.retained -= cap;
                popped = Some(v);
            }
            retained = Some(sh.retained);
        }
        // Guard is dead: counters and allocation happen lock-free.
        if let Some(r) = retained {
            POOL_RETAINED.set(r as u64);
        }
        match popped {
            Some(buf) => {
                POOL_HITS.inc();
                PooledBuf { buf, recycle: true, fresh: false }
            }
            None => {
                POOL_MISSES.inc();
                PooledBuf {
                    buf: Vec::with_capacity(class_bytes(ci)),
                    recycle: true,
                    fresh: true,
                }
            }
        }
    }

    /// Check out a buffer of exactly `len` zeroed bytes — the pooled
    /// equivalent of `vec![0u8; len]`, for region-assembly scratch
    /// where uncovered holes must read as zero.
    pub fn checkout_zeroed(&self, len: usize) -> PooledBuf {
        let mut b = self.checkout(len);
        b.buf.clear();
        b.buf.resize(len, 0);
        b
    }

    /// Return a retired buffer's capacity to the pool. Contents are
    /// cleared; capacity beyond the budget (or outside the retained
    /// size classes) is freed and counted as trimmed.
    pub fn stash_vec(&self, mut v: Vec<u8>) {
        let cap = v.capacity();
        if cap == 0 {
            return;
        }
        if !self.enabled() {
            return; // dropped: plain free
        }
        v.clear();
        // Shelve under the largest class the capacity fully covers, so
        // a future hit always honours its class's capacity promise.
        let ci = match class_index(cap) {
            Some(ci) if cap >= class_bytes(ci) => Some(ci),
            Some(ci) if ci > 0 => Some(ci - 1),
            _ => None,
        };
        let mut kept = false;
        let mut retained = None;
        if let Some(ci) = ci {
            if let Ok(mut sh) = self.shelves.lock() {
                if sh.retained + cap <= self.budget {
                    sh.classes[ci].push((cap, v));
                    sh.retained += cap;
                    kept = true;
                }
                retained = Some(sh.retained);
            }
        }
        // Guard is dead. A buffer that wasn't shelved (over budget,
        // poisoned lock, or no covering class) frees here, lock-free.
        if let Some(r) = retained {
            POOL_RETAINED.set(r as u64);
        }
        if kept {
            POOL_RECYCLED.add(cap as u64);
        } else {
            POOL_TRIMMED.add(cap as u64);
        }
    }

    /// Free capacity currently shelved, in bytes.
    pub fn retained_bytes(&self) -> usize {
        match self.shelves.lock() {
            Ok(sh) => sh.retained,
            Err(_) => 0,
        }
    }

    /// The retained-bytes ceiling this pool was built with.
    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Drop every shelved buffer (tests; also lets a bench phase start
    /// cold). The freed buffers deallocate outside the guard.
    pub fn purge(&self) {
        let mut freed = empty_shelves();
        if let Ok(mut sh) = self.shelves.lock() {
            std::mem::swap(&mut *sh, &mut freed);
        }
        drop(freed);
        POOL_RETAINED.set(0);
    }
}

/// The process-wide pool all hot-path call sites share.
static GLOBAL: Lazy<BufferPool> = Lazy::new(BufferPool::from_env);

/// RAII handle to a checked-out buffer. Derefs to `Vec<u8>`; on drop
/// the capacity returns to the process-wide pool — including when the
/// drop happens on an error-return or panic-unwind path — unless
/// [`detach`](PooledBuf::detach)ed first.
pub struct PooledBuf {
    buf: Vec<u8>,
    recycle: bool,
    fresh: bool,
}

impl PooledBuf {
    /// Whether this checkout had to allocate (pool miss). Hot-path
    /// callers charge `OpsReport.allocations` with this, so the metric
    /// counts real heap allocations and goes flat once the pool warms.
    pub fn fresh(&self) -> bool {
        self.fresh
    }

    /// Surrender the buffer to the caller. The capacity leaves the
    /// pool's custody — typically to become a long-lived payload
    /// (`Arc<Vec<u8>>`) that [`reclaim_bytes`] returns later, at the
    /// payload's end of life.
    pub fn detach(mut self) -> Vec<u8> {
        std::mem::take(&mut self.buf)
    }
}

impl Deref for PooledBuf {
    type Target = Vec<u8>;
    fn deref(&self) -> &Vec<u8> {
        &self.buf
    }
}

impl DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if self.recycle && self.buf.capacity() > 0 {
            GLOBAL.stash_vec(std::mem::take(&mut self.buf));
        }
    }
}

/// Check out an empty buffer (≥ `min` capacity) from the global pool.
pub fn acquire_buf(min: usize) -> PooledBuf {
    GLOBAL.checkout(min)
}

/// Check out `len` zeroed bytes from the global pool.
pub fn acquire_zeroed(len: usize) -> PooledBuf {
    GLOBAL.checkout_zeroed(len)
}

/// Return a plain `Vec`'s capacity to the global pool (for buffers
/// that were detached, or never pool-managed in the first place).
pub fn recycle_vec(v: Vec<u8>) {
    GLOBAL.stash_vec(v);
}

/// Try to reclaim a payload's buffer at its end of life. Succeeds only
/// when this is the last `Arc` reference — a still-staged or
/// still-cached payload is left alone.
pub fn reclaim_bytes(b: Arc<Vec<u8>>) {
    if let Ok(v) = Arc::try_unwrap(b) {
        GLOBAL.stash_vec(v);
    }
}

/// Flip the process-wide pooling switch (A/B benchmarking and
/// conformance tests).
pub fn set_pooling_enabled(on: bool) {
    GLOBAL.set_enabled(on);
}

/// Whether the process-wide pool currently recycles.
pub fn pooling_enabled() -> bool {
    GLOBAL.enabled()
}

/// Free capacity currently shelved in the global pool.
pub fn retained_bytes() -> usize {
    GLOBAL.retained_bytes()
}

/// The global pool's retained-bytes ceiling.
pub fn pool_budget() -> usize {
    GLOBAL.budget_bytes()
}

/// Drop everything shelved in the global pool.
pub fn purge() {
    GLOBAL.purge()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_rounding_covers_requests() {
        assert_eq!(class_index(0), Some(0));
        assert_eq!(class_index(1), Some(0));
        assert_eq!(class_index(1024), Some(0));
        assert_eq!(class_index(1025), Some(1));
        assert_eq!(class_index(64 << 20), Some(NUM_CLASSES - 1));
        assert_eq!(class_index((64 << 20) + 1), None);
        for min in [1usize, 512, 4096, 70_000, 1 << 20] {
            let ci = class_index(min).unwrap();
            assert!(class_bytes(ci) >= min, "class too small for {min}");
        }
    }

    #[test]
    fn capacity_recycles_through_the_pool() {
        let pool = BufferPool::new(1 << 20);
        let mut a = pool.checkout(4096);
        assert!(a.fresh());
        assert!(a.capacity() >= 4096);
        a.extend_from_slice(&[7u8; 100]);
        let v = a.detach();
        pool.stash_vec(v);
        let b = pool.checkout(4096);
        assert!(!b.fresh(), "second checkout should hit the shelf");
        assert!(b.is_empty(), "recycled buffer must come back cleared");
        assert!(b.capacity() >= 4096);
    }

    #[test]
    fn zeroed_checkout_is_actually_zero() {
        let pool = BufferPool::new(1 << 20);
        // Dirty a buffer, return it, and make sure the zeroed path
        // scrubs the recycled contents.
        let mut v = Vec::with_capacity(2048);
        v.extend_from_slice(&[0xAAu8; 2048]);
        pool.stash_vec(v);
        let z = pool.checkout_zeroed(2048);
        assert_eq!(z.len(), 2048);
        assert!(z.iter().all(|&b| b == 0));
    }

    #[test]
    fn budget_bounds_retained_bytes() {
        let budget = 8 << 10; // two 4 KiB buffers
        let pool = BufferPool::new(budget);
        for _ in 0..10 {
            pool.stash_vec(Vec::with_capacity(4096));
        }
        assert_eq!(pool.retained_bytes(), 8 << 10);
        assert!(pool.retained_bytes() <= pool.budget_bytes());
    }

    #[test]
    fn oversize_and_undersize_are_never_retained() {
        let pool = BufferPool::new(usize::MAX >> 1);
        // Above the largest class: freed, not shelved.
        pool.stash_vec(Vec::with_capacity((64 << 20) + 4096));
        // Below the smallest class: can't honour class 0's promise.
        pool.stash_vec(Vec::with_capacity(16));
        assert_eq!(pool.retained_bytes(), 0);
        // Oversize checkout is exact-sized and marked non-recycling.
        let big = pool.checkout((64 << 20) + 1);
        assert!(big.fresh());
        assert!(!big.recycle);
    }

    #[test]
    fn detach_surrenders_capacity() {
        let pool = BufferPool::new(1 << 20);
        let mut a = pool.checkout(1024);
        a.extend_from_slice(b"payload");
        let v = a.detach();
        assert_eq!(&v[..], b"payload");
        // Nothing was shelved by the detach itself.
        assert_eq!(pool.retained_bytes(), 0);
    }

    #[test]
    fn disabled_pool_is_a_plain_allocator() {
        let pool = BufferPool::new(1 << 20);
        pool.set_enabled(false);
        let a = pool.checkout(4096);
        assert!(a.fresh());
        pool.stash_vec(Vec::with_capacity(4096));
        assert_eq!(pool.retained_bytes(), 0);
        pool.set_enabled(true);
        drop(a);
    }

    #[test]
    fn purge_empties_the_shelves() {
        let pool = BufferPool::new(1 << 20);
        pool.stash_vec(Vec::with_capacity(4096));
        assert!(pool.retained_bytes() > 0);
        pool.purge();
        assert_eq!(pool.retained_bytes(), 0);
        assert!(pool.checkout(4096).fresh());
    }

    #[test]
    fn reclaim_skips_shared_payloads() {
        let shared: Arc<Vec<u8>> = Arc::new(vec![1u8; 2048]);
        let clone = Arc::clone(&shared);
        reclaim_bytes(shared); // refcount 2: must not touch it
        assert_eq!(clone.len(), 2048);
    }

    #[test]
    fn concurrent_checkout_stash_smoke() {
        let pool = Arc::new(BufferPool::new(4 << 20));
        let mut handles = Vec::new();
        for t in 0..4 {
            let p = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                for i in 0..200usize {
                    let mut b = p.checkout(1024 + (i % 7) * 512);
                    b.push((t + i) as u8);
                    let v = b.detach();
                    p.stash_vec(v);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(pool.retained_bytes() <= pool.budget_bytes());
    }
}
