//! Lock helpers with typed poison propagation.
//!
//! `Mutex::lock().unwrap()` converts a poisoned lock — some other
//! thread panicked while holding it — into a second panic in the
//! current thread. In the streaming setups this crate targets that is
//! the worst possible reaction: a panicking peer tears down every
//! coupled engine mid-stream, and there is no filesystem to fall back
//! to. These helpers turn poison into an ordinary typed error
//! ([`PoisonedLock`], a `std::error::Error`, so `?` lifts it into
//! `anyhow::Result`) that the engine contract already knows how to
//! route: a failed `perform_gets` poisons its batch handles, a failed
//! `begin_step` surfaces to the pipe loop, and the multiplex barrier
//! reports it instead of dying.
//!
//! `pallas-lint` (the `lock-unwrap` rule) gates new `.lock().unwrap()`
//! sites crate-wide; this module is the sanctioned replacement.

//! The ordered half — [`LockClass`], [`OrderedMutex`],
//! [`OrderedCondvar`] — is the runtime side of the `pallas-lint`
//! concurrency pass: every long-lived `Mutex`/`Condvar` in the crate is
//! registered under a named class in [`classes`], the static analysis
//! builds the crate's lock-order graph over those classes
//! (`tools/lint/lock.graph.json`), and debug builds assert the same
//! order at runtime via a thread-local held-lock stack plus a
//! wait-timeout deadlock watchdog. Release builds compile the wrappers
//! down to the plain poison-typed lock above.

use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// A mutex (or condvar wait) observed poison: a thread panicked while
/// holding the lock. Carries a static description of what the lock
/// guards so the surfaced error names the subsystem, not just "lock".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoisonedLock {
    /// What the mutex guards (e.g. `"sst writer shared state"`).
    pub what: &'static str,
}

impl std::fmt::Display for PoisonedLock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} lock poisoned by a panicked thread",
            self.what
        )
    }
}

impl std::error::Error for PoisonedLock {}

/// Acquire `m`, propagating poison as a typed error instead of
/// panicking. The usual call shape is
/// `let mut sh = lock_or_poisoned(&self.shared, "sst writer shared")?;`
/// in `Result` contexts, or a `match` with an explicit recovery path
/// (log + break) inside service threads that cannot return errors.
pub fn lock_or_poisoned<'a, T>(
    m: &'a Mutex<T>,
    what: &'static str,
) -> Result<MutexGuard<'a, T>, PoisonedLock> {
    m.lock().map_err(|_| PoisonedLock { what })
}

/// [`Condvar::wait_timeout`] with typed poison propagation, matching
/// [`lock_or_poisoned`]. The guard is consumed and returned exactly as
/// with the std API.
pub fn wait_timeout_or_poisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
    what: &'static str,
) -> Result<(MutexGuard<'a, T>, WaitTimeoutResult), PoisonedLock> {
    cv.wait_timeout(guard, timeout)
        .map_err(|_| PoisonedLock { what })
}

/// A named lock class with a total acquisition rank. A thread may only
/// acquire a lock whose rank is strictly greater than every lock it
/// already holds, which makes lock-order inversion (and therefore
/// deadlock between classes) impossible by construction. The static
/// analysis and the debug-build runtime checker share this registry:
/// `pallas-lint` reads the class/rank table straight out of
/// [`classes`], so the blessed `tools/lint/lock.graph.json` and the
/// runtime assertions can never drift apart.
#[derive(Debug)]
pub struct LockClass {
    /// Stable name used in lint findings and the blessed lock graph.
    pub name: &'static str,
    /// Acquisition rank; higher ranks are acquired later.
    pub rank: u32,
}

/// The crate-wide lock-class registry. One entry per long-lived
/// `Mutex`/`Condvar`; ranks are spaced by 10 so a future class can
/// slot between two existing ones without renumbering the world.
///
/// `pallas-lint` parses this module (`static NAME: LockClass =
/// LockClass { name: …, rank: N };`) to learn the class table, then
/// maps every `OrderedMutex::new(&classes::X, …)` construction site to
/// the field or static that owns it. Adding a lock means adding a line
/// here — an unregistered `Mutex` in a lock zone is a finding.
pub mod classes {
    use super::LockClass;

    /// `adios::transport` in-proc listener registry (name → acceptor).
    pub static INPROC_REGISTRY: LockClass =
        LockClass { name: "inproc-registry", rank: 10 };
    /// `pipeline::fleet` shared per-step chunk-plan cache.
    pub static FLEET_PLANNER: LockClass =
        LockClass { name: "fleet-planner", rank: 20 };
    /// `runtime` PJRT executable serialization (not re-entrant).
    pub static RUNTIME_EXEC: LockClass =
        LockClass { name: "runtime-exec", rank: 30 };
    /// SST writer-group first-contact accept/reject decisions.
    pub static SST_GROUP_DECISIONS: LockClass =
        LockClass { name: "sst-group-decisions", rank: 40 };
    /// SST writer service-thread join registry.
    pub static SST_SERVICE_THREADS: LockClass =
        LockClass { name: "sst-service-threads", rank: 50 };
    /// `pipeline::serve` daemon service-thread join registry (accept
    /// loop + per-subscriber sender/receiver pairs).
    pub static SERVE_SERVICE_THREADS: LockClass =
        LockClass { name: "serve-service-threads", rank: 52 };
    /// `pipeline::serve` hub state: the shared step cache (last K
    /// staged steps) + subscriber registry. Never held across a
    /// blocking send — announces are queued into per-subscriber
    /// outboxes and sent by the owning sender thread.
    pub static SERVE_HUB: LockClass =
        LockClass { name: "serve-hub", rank: 54 };
    /// `pipeline::serve` per-subscriber outbox (queued announces +
    /// batch replies). Disjoint from [`SERVE_HUB`] by construction:
    /// hub and outbox are never held together, so fan-out adds no
    /// lock-order edges.
    pub static SERVE_SUBSCRIBER: LockClass =
        LockClass { name: "serve-subscriber", rank: 56 };
    /// SST writer shared state (reader registry + staged steps).
    pub static SST_WRITER_SHARED: LockClass =
        LockClass { name: "sst-writer-shared", rank: 60 };
    /// SST per-reader connection transmit half. Above
    /// [`SST_WRITER_SHARED`]: the backlog-replay critical section in
    /// `serve_reader` sends under the registration lock.
    pub static SST_PEER_TX: LockClass =
        LockClass { name: "sst-peer-tx", rank: 70 };
    /// `util::pool` buffer-pool shelves. A leaf in the lock graph: pool
    /// code never acquires any other class while holding it (counters
    /// are lock-free atomics updated after the guard drops), and its
    /// rank sits above every data-path class so a buffer can be
    /// checked out or shelved while any engine/transport lock is held.
    /// Only [`OBS`] ranks higher, keeping first-use counter interning
    /// legal even from inside pool callers.
    pub static BUF_POOL: LockClass =
        LockClass { name: "buf-pool", rank: 75 };
    /// `obs` trace-collector state (thread-buffer directory and the
    /// per-thread event buffers). Deliberately the HIGHEST rank in the
    /// registry: instrumentation records from inside any subsystem, so
    /// this class must be acquirable while every other lock is held —
    /// which under the strictly-increasing-rank rule means it sorts
    /// last. Obs code never acquires any other class while holding it,
    /// and never nests two obs locks (the drain clones the directory,
    /// drops the guard, then visits buffers one at a time).
    pub static OBS: LockClass = LockClass { name: "obs", rank: 80 };
}

/// Debug-build held-lock bookkeeping: a thread-local stack of the lock
/// classes this thread currently holds, in acquisition order.
#[cfg(debug_assertions)]
mod held {
    use super::LockClass;
    use std::cell::RefCell;

    thread_local! {
        static HELD: RefCell<Vec<&'static LockClass>> =
            const { RefCell::new(Vec::new()) };
    }

    /// Assert that acquiring `class` now respects the rank order.
    pub(super) fn check(class: &'static LockClass) {
        HELD.with(|h| {
            let h = h.borrow();
            if let Some(top) = h.last() {
                assert!(
                    class.rank > top.rank,
                    "lock-order violation: acquiring `{}` (rank {}) \
                     while holding `{}` (rank {}); held stack: {:?}",
                    class.name,
                    class.rank,
                    top.name,
                    top.rank,
                    names(&h),
                );
            }
        });
    }

    pub(super) fn push(class: &'static LockClass) {
        HELD.with(|h| h.borrow_mut().push(class));
    }

    /// Remove the most recent entry of `class`. Guards may drop out of
    /// acquisition order (a guard stored in a binding can outlive one
    /// acquired later), so this is not strict LIFO.
    pub(super) fn pop(class: &'static LockClass) {
        HELD.with(|h| {
            let mut h = h.borrow_mut();
            if let Some(idx) =
                h.iter().rposition(|c| std::ptr::eq(*c, class))
            {
                h.remove(idx);
            }
        });
    }

    /// Names of the held classes, innermost last, for diagnostics.
    pub(super) fn names(held: &[&'static LockClass]) -> Vec<&'static str> {
        held.iter().map(|c| c.name).collect()
    }

    pub(super) fn snapshot() -> Vec<&'static str> {
        HELD.with(|h| names(&h.borrow()))
    }
}

/// Debug-build bookkeeping token carried inside [`OrderedGuard`]; pops
/// the thread-local held stack when dropped. A zero-sized no-op in
/// release builds.
struct HeldEntry {
    #[cfg(debug_assertions)]
    class: &'static LockClass,
}

impl HeldEntry {
    fn acquired(class: &'static LockClass) -> Self {
        #[cfg(not(debug_assertions))]
        let _ = class;
        #[cfg(debug_assertions)]
        held::push(class);
        HeldEntry {
            #[cfg(debug_assertions)]
            class,
        }
    }
}

impl Drop for HeldEntry {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        held::pop(self.class);
    }
}

/// How long the debug-build watchdog waits on a contended lock before
/// declaring the process deadlocked and panicking with the held-stack
/// diagnostics. Generous enough for slow CI machines; a real inversion
/// deadlock never resolves, so any finite bound catches it.
#[cfg(debug_assertions)]
const WATCHDOG: Duration = Duration::from_secs(30);

/// A [`Mutex`] bound to a [`LockClass`]. `lock()` propagates poison as
/// the same typed [`PoisonedLock`] error as [`lock_or_poisoned`]
/// (the class name supplies the `what`); under `debug_assertions` it
/// additionally asserts the rank order against the thread's held-lock
/// stack and runs a deadlock watchdog while waiting.
pub struct OrderedMutex<T> {
    class: &'static LockClass,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    pub fn new(class: &'static LockClass, value: T) -> Self {
        OrderedMutex { class, inner: Mutex::new(value) }
    }

    pub fn class(&self) -> &'static LockClass {
        self.class
    }

    pub fn lock(&self) -> Result<OrderedGuard<'_, T>, PoisonedLock> {
        let guard = self.acquire()?;
        Ok(OrderedGuard {
            held: HeldEntry::acquired(self.class),
            guard,
        })
    }

    #[cfg(not(debug_assertions))]
    fn acquire(&self) -> Result<MutexGuard<'_, T>, PoisonedLock> {
        self.inner
            .lock()
            .map_err(|_| PoisonedLock { what: self.class.name })
    }

    /// Debug path: order check up front (a violation is a violation
    /// even when the lock happens to be free), then a watchdog loop so
    /// a genuine deadlock surfaces as a diagnostic panic instead of a
    /// silent hang.
    #[cfg(debug_assertions)]
    fn acquire(&self) -> Result<MutexGuard<'_, T>, PoisonedLock> {
        use std::sync::TryLockError;
        use std::time::Instant;

        held::check(self.class);
        let deadline = Instant::now() + WATCHDOG;
        loop {
            match self.inner.try_lock() {
                Ok(g) => return Ok(g),
                Err(TryLockError::Poisoned(_)) => {
                    return Err(PoisonedLock { what: self.class.name })
                }
                Err(TryLockError::WouldBlock) => {}
            }
            assert!(
                Instant::now() < deadline,
                "deadlock watchdog: waited {:?} for `{}` (rank {}); \
                 this thread holds {:?}",
                WATCHDOG,
                self.class.name,
                self.class.rank,
                held::snapshot(),
            );
            std::thread::sleep(Duration::from_micros(100));
        }
    }
}

/// Guard returned by [`OrderedMutex::lock`]. Derefs to the protected
/// value; dropping it releases the lock and (in debug builds) pops the
/// thread-local held stack.
pub struct OrderedGuard<'a, T> {
    // Declared before `guard` so the held-stack entry is retired
    // first on drop; both happen on the owning thread, so the order
    // is unobservable to other threads.
    held: HeldEntry,
    guard: MutexGuard<'a, T>,
}

impl<T> Deref for OrderedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> DerefMut for OrderedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

/// A [`Condvar`] bound to the [`LockClass`] of the mutex it pairs
/// with. Waiting with a guard of any other class is a bug (the wait
/// would release the wrong lock); debug builds assert the pairing,
/// and the static `condvar-class` rule checks it at lint time.
pub struct OrderedCondvar {
    class: &'static LockClass,
    cv: Condvar,
}

impl OrderedCondvar {
    pub fn new(class: &'static LockClass) -> Self {
        OrderedCondvar { class, cv: Condvar::new() }
    }

    pub fn class(&self) -> &'static LockClass {
        self.class
    }

    pub fn notify_one(&self) {
        self.cv.notify_one();
    }

    pub fn notify_all(&self) {
        self.cv.notify_all();
    }

    /// [`Condvar::wait_timeout`] over an [`OrderedGuard`], with typed
    /// poison propagation. The held-stack entry is kept across the
    /// wait: the thread is blocked and acquires nothing while parked,
    /// and on wake it holds the same lock again.
    pub fn wait_timeout<'a, T>(
        &self,
        guard: OrderedGuard<'a, T>,
        timeout: Duration,
    ) -> Result<(OrderedGuard<'a, T>, WaitTimeoutResult), PoisonedLock>
    {
        #[cfg(debug_assertions)]
        self.check_class(&guard);
        let OrderedGuard { held, guard } = guard;
        let (guard, res) = self
            .cv
            .wait_timeout(guard, timeout)
            .map_err(|_| PoisonedLock { what: self.class.name })?;
        Ok((OrderedGuard { held, guard }, res))
    }

    #[cfg(debug_assertions)]
    fn check_class<T>(&self, guard: &OrderedGuard<'_, T>) {
        assert!(
            std::ptr::eq(self.class, guard.held.class),
            "condvar-class violation: waiting on condvar of class \
             `{}` with a guard of class `{}`",
            self.class.name,
            guard.held.class.name,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn healthy_lock_passes_through() {
        let m = Mutex::new(7);
        *lock_or_poisoned(&m, "test").unwrap() += 1;
        assert_eq!(*lock_or_poisoned(&m, "test").unwrap(), 8);
    }

    #[test]
    fn poisoned_lock_is_a_typed_error() {
        let m = Arc::new(Mutex::new(0));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        let err = lock_or_poisoned(&m, "test counter").unwrap_err();
        assert_eq!(err, PoisonedLock { what: "test counter" });
        assert!(err.to_string().contains("test counter"));
        // And it lifts into anyhow::Result via `?`.
        let lifted: anyhow::Result<()> = (|| {
            lock_or_poisoned(&m, "test counter")?;
            Ok(())
        })();
        assert!(lifted.unwrap_err().to_string().contains("poisoned"));
    }

    #[test]
    fn wait_timeout_passes_guard_back() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_or_poisoned(&m, "test").unwrap();
        let (g, res) = wait_timeout_or_poisoned(
            &cv,
            g,
            Duration::from_millis(1),
            "test",
        )
        .unwrap();
        assert!(res.timed_out());
        drop(g);
    }

    #[test]
    fn ordered_mutex_locks_and_derefs() {
        let m = OrderedMutex::new(&classes::INPROC_REGISTRY, 7);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 8);
        assert_eq!(m.class().name, "inproc-registry");
    }

    #[test]
    fn ordered_mutex_allows_increasing_ranks() {
        let lo = OrderedMutex::new(&classes::INPROC_REGISTRY, ());
        let hi = OrderedMutex::new(&classes::FLEET_PLANNER, ());
        let a = lo.lock().unwrap();
        let b = hi.lock().unwrap();
        drop(b);
        drop(a);
        // Sequential re-acquisition at a lower rank is fine once the
        // higher guard is gone.
        let b = hi.lock().unwrap();
        drop(b);
        let a = lo.lock().unwrap();
        drop(a);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "lock-order violation")]
    fn ordered_mutex_panics_on_inversion_in_debug() {
        let lo = OrderedMutex::new(&classes::INPROC_REGISTRY, ());
        let hi = OrderedMutex::new(&classes::FLEET_PLANNER, ());
        let _b = hi.lock().unwrap();
        let _a = lo.lock().unwrap();
    }

    #[test]
    fn ordered_mutex_reports_poison_typed() {
        let m = Arc::new(OrderedMutex::new(&classes::RUNTIME_EXEC, 0));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        let err = m.lock().unwrap_err();
        assert_eq!(err, PoisonedLock { what: "runtime-exec" });
    }

    #[test]
    fn ordered_condvar_wait_returns_same_class_guard() {
        let m = OrderedMutex::new(&classes::SST_WRITER_SHARED, 0u32);
        let cv = OrderedCondvar::new(&classes::SST_WRITER_SHARED);
        let g = m.lock().unwrap();
        let (g, res) =
            cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
        assert!(res.timed_out());
        assert_eq!(*g, 0);
        drop(g);
        // The held stack unwound: a low-rank lock is acquirable again.
        let lo = OrderedMutex::new(&classes::INPROC_REGISTRY, ());
        drop(lo.lock().unwrap());
        cv.notify_all();
        cv.notify_one();
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "condvar-class violation")]
    fn ordered_condvar_panics_on_wrong_class_in_debug() {
        let m = OrderedMutex::new(&classes::SST_GROUP_DECISIONS, ());
        let cv = OrderedCondvar::new(&classes::SST_WRITER_SHARED);
        let g = m.lock().unwrap();
        let _ = cv.wait_timeout(g, Duration::from_millis(1));
    }

    #[cfg(debug_assertions)]
    #[test]
    fn watchdog_sees_cross_thread_contention_resolve() {
        // Not a deadlock: the other thread releases quickly, so the
        // watchdog loop exits on its try_lock path.
        let m = Arc::new(OrderedMutex::new(&classes::SST_PEER_TX, 0));
        let m2 = m.clone();
        let t = std::thread::spawn(move || {
            let mut g = m2.lock().unwrap();
            *g += 1;
            std::thread::sleep(Duration::from_millis(20));
        });
        std::thread::sleep(Duration::from_millis(5));
        let g = m.lock().unwrap();
        assert_eq!(*g, 1);
        drop(g);
        t.join().unwrap();
    }
}
