//! Lock helpers with typed poison propagation.
//!
//! `Mutex::lock().unwrap()` converts a poisoned lock — some other
//! thread panicked while holding it — into a second panic in the
//! current thread. In the streaming setups this crate targets that is
//! the worst possible reaction: a panicking peer tears down every
//! coupled engine mid-stream, and there is no filesystem to fall back
//! to. These helpers turn poison into an ordinary typed error
//! ([`PoisonedLock`], a `std::error::Error`, so `?` lifts it into
//! `anyhow::Result`) that the engine contract already knows how to
//! route: a failed `perform_gets` poisons its batch handles, a failed
//! `begin_step` surfaces to the pipe loop, and the multiplex barrier
//! reports it instead of dying.
//!
//! `pallas-lint` (the `lock-unwrap` rule) gates new `.lock().unwrap()`
//! sites crate-wide; this module is the sanctioned replacement.

use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// A mutex (or condvar wait) observed poison: a thread panicked while
/// holding the lock. Carries a static description of what the lock
/// guards so the surfaced error names the subsystem, not just "lock".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoisonedLock {
    /// What the mutex guards (e.g. `"sst writer shared state"`).
    pub what: &'static str,
}

impl std::fmt::Display for PoisonedLock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} lock poisoned by a panicked thread",
            self.what
        )
    }
}

impl std::error::Error for PoisonedLock {}

/// Acquire `m`, propagating poison as a typed error instead of
/// panicking. The usual call shape is
/// `let mut sh = lock_or_poisoned(&self.shared, "sst writer shared")?;`
/// in `Result` contexts, or a `match` with an explicit recovery path
/// (log + break) inside service threads that cannot return errors.
pub fn lock_or_poisoned<'a, T>(
    m: &'a Mutex<T>,
    what: &'static str,
) -> Result<MutexGuard<'a, T>, PoisonedLock> {
    m.lock().map_err(|_| PoisonedLock { what })
}

/// [`Condvar::wait_timeout`] with typed poison propagation, matching
/// [`lock_or_poisoned`]. The guard is consumed and returned exactly as
/// with the std API.
pub fn wait_timeout_or_poisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
    what: &'static str,
) -> Result<(MutexGuard<'a, T>, WaitTimeoutResult), PoisonedLock> {
    cv.wait_timeout(guard, timeout)
        .map_err(|_| PoisonedLock { what })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn healthy_lock_passes_through() {
        let m = Mutex::new(7);
        *lock_or_poisoned(&m, "test").unwrap() += 1;
        assert_eq!(*lock_or_poisoned(&m, "test").unwrap(), 8);
    }

    #[test]
    fn poisoned_lock_is_a_typed_error() {
        let m = Arc::new(Mutex::new(0));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        let err = lock_or_poisoned(&m, "test counter").unwrap_err();
        assert_eq!(err, PoisonedLock { what: "test counter" });
        assert!(err.to_string().contains("test counter"));
        // And it lifts into anyhow::Result via `?`.
        let lifted: anyhow::Result<()> = (|| {
            lock_or_poisoned(&m, "test counter")?;
            Ok(())
        })();
        assert!(lifted.unwrap_err().to_string().contains("poisoned"));
    }

    #[test]
    fn wait_timeout_passes_guard_back() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_or_poisoned(&m, "test").unwrap();
        let (g, res) = wait_timeout_or_poisoned(
            &cv,
            g,
            Duration::from_millis(1),
            "test",
        )
        .unwrap();
        assert!(res.timed_out());
        drop(g);
    }
}
