//! Deterministic pseudo-random numbers (xoshiro256**, SplitMix64 seeding).
//!
//! Used by the synthetic workload generators, the discrete-event
//! simulator's straggler model and the property-testing framework. All
//! consumers take an explicit seed so every benchmark and test is
//! reproducible bit-for-bit.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, tiny.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method (unbiased).
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with given median and sigma (of the underlying normal).
    /// This is the straggler model used for PFS write-time outliers: heavy
    /// right tail, never negative.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    /// Exponential with mean `mean`.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_has_roughly_unit_variance() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_is_positive_with_heavy_tail() {
        let mut r = Rng::new(6);
        let xs: Vec<f64> = (0..10_000).map(|_| r.lognormal(1.0, 0.5)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let above = xs.iter().filter(|&&x| x > 2.0).count();
        assert!(above > 50, "tail too light: {above}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
