//! Hand-rolled command-line parsing (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`,
//! positional arguments, typed accessors with defaults, and an
//! auto-generated `--help`. Enough for a launcher, deliberately not more.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative option specification for help text + validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub value_name: Option<&'static str>,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// A parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub program: String,
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

/// Parse error with a user-facing message.
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of arguments (excluding or including argv[0],
    /// controlled by `has_program`).
    pub fn parse_from<I, S>(args: I, has_subcommand: bool) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut it = args.into_iter().map(Into::into).peekable();
        let program = it.next().unwrap_or_else(|| "openpmd-stream".into());
        let mut out = Args { program, ..Default::default() };
        if has_subcommand {
            if let Some(next) = it.peek() {
                if !next.starts_with('-') {
                    out.subcommand = it.next();
                }
            }
        }
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional.
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if arg.starts_with('-') && arg.len() > 1 {
                return Err(CliError(format!(
                    "short options are not supported: {arg:?}"
                )));
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse the process command line.
    pub fn from_env(has_subcommand: bool) -> Result<Args, CliError> {
        Args::parse_from(std::env::args(), has_subcommand)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(
        &self,
        name: &str,
    ) -> Result<Option<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|e| {
                CliError(format!("invalid value for --{name}: {v:?} ({e})"))
            }),
        }
    }

    pub fn get_parse_or<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }

    /// Error on unknown options (call after all accesses are declared).
    pub fn reject_unknown(&self, known: &[&str]) -> Result<(), CliError> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                return Err(CliError(format!("unknown option --{k}")));
            }
        }
        Ok(())
    }
}

/// Render a help screen from option specs.
pub fn render_help(
    program: &str,
    about: &str,
    usage: &str,
    opts: &[OptSpec],
) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{program} — {about}\n");
    let _ = writeln!(s, "USAGE:\n    {usage}\n");
    if !opts.is_empty() {
        let _ = writeln!(s, "OPTIONS:");
        for o in opts {
            let left = match o.value_name {
                Some(v) => format!("--{} <{}>", o.name, v),
                None => format!("--{}", o.name),
            };
            let default = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "    {left:<28} {}{default}", o.help);
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], sub: bool) -> Args {
        Args::parse_from(v.iter().map(|s| s.to_string()), sub).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags_positional() {
        let a = parse(
            &["prog", "bench", "--nodes", "512", "--verbose",
              "--out=x.csv", "input.bp"],
            true,
        );
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get("nodes"), Some("512"));
        assert_eq!(a.get("out"), Some("x.csv"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["input.bp"]);
    }

    #[test]
    fn typed_access_and_defaults() {
        let a = parse(&["prog", "--nodes", "64"], false);
        assert_eq!(a.get_parse_or("nodes", 8usize).unwrap(), 64);
        assert_eq!(a.get_parse_or("gpus", 6usize).unwrap(), 6);
        assert!(a.get_parse::<usize>("missing").unwrap().is_none());
    }

    #[test]
    fn bad_typed_value_is_an_error() {
        let a = parse(&["prog", "--nodes", "lots"], false);
        assert!(a.get_parse::<usize>("nodes").is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse(&["prog", "--", "--not-an-option"], false);
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["prog", "--fast"], false);
        assert!(a.flag("fast"));
    }

    #[test]
    fn unknown_rejection() {
        let a = parse(&["prog", "--typo", "1"], false);
        assert!(a.reject_unknown(&["nodes"]).is_err());
        assert!(a.reject_unknown(&["typo"]).is_ok());
    }

    #[test]
    fn short_options_rejected() {
        assert!(Args::parse_from(
            ["prog", "-n"].iter().map(|s| s.to_string()), false).is_err());
    }

    #[test]
    fn help_rendering_contains_options() {
        let h = render_help(
            "openpmd-stream",
            "streaming pipelines",
            "openpmd-stream bench [OPTIONS]",
            &[OptSpec { name: "nodes", value_name: Some("N"),
                        default: Some("64"), help: "node count" }],
        );
        assert!(h.contains("--nodes <N>"));
        assert!(h.contains("[default: 64]"));
    }
}
