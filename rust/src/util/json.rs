//! Minimal JSON: value model, serializer, recursive-descent parser.
//!
//! Two consumers: the serial JSON engine (prototyping backend of Fig. 3)
//! and the PJRT runtime, which reads `artifacts/meta.json` written by the
//! python AOT step. No serde offline, so this is hand-rolled; it supports
//! the full JSON grammar minus exotic number forms.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `[1,2,3]` -> `vec![1,2,3]` if all entries are non-negative ints.
    pub fn as_u64_vec(&self) -> Option<Vec<u64>> {
        self.as_arr()?.iter().map(|v| v.as_u64()).collect()
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    let _ = write!(out, "{:width$}", "",
                                   width = (indent + 1) * 2);
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                let _ = write!(out, "{:width$}]", "", width = indent * 2);
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    let _ = write!(out, "{:width$}", "",
                                   width = (indent + 1) * 2);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                let _ = write!(out, "{:width$}}}", "", width = indent * 2);
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 9e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(format!("trailing content at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.pos
            )),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E'
                                                    | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        other => {
                            return Err(format!("bad escape {other:?}"))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| "invalid UTF-8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.pos,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.pos,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn round_trip_compact_and_pretty() {
        let src = r#"{"inputs":[[4096,3],[1,4096]],"doc":"SAXS \"x\""}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn escapes_round_trip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn errors_not_panics() {
        for bad in ["", "{", "[1,", "{\"a\"}", "tru", "1 2", "\"unterm",
                    "{\"a\":}"] {
            assert!(parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn u64_vec_helper() {
        let v = parse("[4096, 3]").unwrap();
        assert_eq!(v.as_u64_vec(), Some(vec![4096, 3]));
        assert_eq!(parse("[1.5]").unwrap().as_u64_vec(), None);
    }

    #[test]
    fn meta_json_shape() {
        // The exact structure aot.py emits.
        let doc = r#"{
          "saxs": {
            "inputs": [[4096, 3], [1, 4096], [3, 512]],
            "outputs": [[512]],
            "doc": "SAXS intensity"
          }
        }"#;
        let v = parse(doc).unwrap();
        let saxs = v.get("saxs").unwrap();
        let inputs = saxs.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].as_u64_vec(), Some(vec![4096, 3]));
        assert_eq!(
            saxs.get("outputs").unwrap().as_arr().unwrap()[0].as_u64_vec(),
            Some(vec![512])
        );
    }
}
