//! Minimal leveled logger writing to stderr.
//!
//! The `log` facade crate is vendored but no logger implementation is, so
//! the coordinator ships its own: a global level filter, per-component
//! prefixes and elapsed-time stamps. Deliberately tiny — it exists so the
//! SST wire protocol and the DES can be traced when debugging, not to be a
//! logging framework.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

/// Severity levels, ascending.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Trace => "TRACE",
            Level::Debug => "DEBUG",
            Level::Info => "INFO",
            Level::Warn => "WARN",
            Level::Error => "ERROR",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "trace" => Some(Level::Trace),
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: Lazy<Instant> = Lazy::new(Instant::now);

/// Set the global level. Also reads `OPENPMD_STREAM_LOG` at first use via
/// [`init_from_env`].
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Trace,
        1 => Level::Debug,
        2 => Level::Info,
        3 => Level::Warn,
        _ => Level::Error,
    }
}

/// Initialise from the `OPENPMD_STREAM_LOG` environment variable.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("OPENPMD_STREAM_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

/// Core log call; prefer the macros.
pub fn log(lvl: Level, component: &str, msg: std::fmt::Arguments<'_>) {
    if lvl >= level() {
        let t = START.elapsed().as_secs_f64();
        eprintln!("[{t:>10.4}s {:<5} {component}] {msg}", lvl.as_str());
    }
}

#[macro_export]
macro_rules! trace {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace,
                                   $component, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   $component, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   $component, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_log {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   $component, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($component:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error,
                                   $component, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn levels_are_ordered() {
        assert!(Level::Trace < Level::Debug);
        assert!(Level::Warn < Level::Error);
    }
}
