//! Byte-size parsing and formatting (binary units, as used throughout the
//! paper: GiB, TiB). Also rate formatting for throughput tables.

/// Binary unit constants.
pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;
pub const TIB: u64 = 1 << 40;
pub const PIB: u64 = 1 << 50;

/// Format a byte count with binary units, e.g. `9.14 GiB`.
pub fn fmt_bytes(bytes: u64) -> String {
    fmt_bytes_f(bytes as f64)
}

/// Float variant (for averaged values).
pub fn fmt_bytes_f(bytes: f64) -> String {
    let abs = bytes.abs();
    if abs >= PIB as f64 {
        format!("{:.2} PiB", bytes / PIB as f64)
    } else if abs >= TIB as f64 {
        format!("{:.2} TiB", bytes / TIB as f64)
    } else if abs >= GIB as f64 {
        format!("{:.2} GiB", bytes / GIB as f64)
    } else if abs >= MIB as f64 {
        format!("{:.2} MiB", bytes / MIB as f64)
    } else if abs >= KIB as f64 {
        format!("{:.2} KiB", bytes / KIB as f64)
    } else {
        format!("{} B", bytes as i64)
    }
}

/// Format a rate in bytes/second, e.g. `4.15 TiB/s`.
pub fn fmt_rate(bytes_per_s: f64) -> String {
    format!("{}/s", fmt_bytes_f(bytes_per_s))
}

/// Parse a human byte size: `"9.14GiB"`, `"512 MiB"`, `"1024"` (bytes),
/// `"2.5 TiB"`. Case-insensitive; accepts decimal (`GB`) as binary for
/// convenience since the paper uses binary units throughout.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let t = s.trim();
    let split = t
        .find(|c: char| c.is_ascii_alphabetic())
        .unwrap_or(t.len());
    let (num, unit) = t.split_at(split);
    let value: f64 = num
        .trim()
        .parse()
        .map_err(|e| format!("bad byte size {s:?}: {e}"))?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1,
        "k" | "kb" | "kib" => KIB,
        "m" | "mb" | "mib" => MIB,
        "g" | "gb" | "gib" => GIB,
        "t" | "tb" | "tib" => TIB,
        "p" | "pb" | "pib" => PIB,
        other => return Err(format!("unknown byte unit {other:?} in {s:?}")),
    };
    if value < 0.0 {
        return Err(format!("negative byte size {s:?}"));
    }
    Ok((value * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_round_trip_magnitudes() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * KIB), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * MIB), "3.00 MiB");
        assert_eq!(fmt_bytes(9 * GIB + 143 * MIB), "9.14 GiB");
        assert_eq!(fmt_bytes(2 * TIB + TIB / 2), "2.50 TiB");
        assert_eq!(fmt_bytes(250 * PIB), "250.00 PiB");
    }

    #[test]
    fn parses_units() {
        assert_eq!(parse_bytes("1024").unwrap(), 1024);
        assert_eq!(parse_bytes("2 KiB").unwrap(), 2048);
        assert_eq!(parse_bytes("9.14GiB").unwrap(),
                   (9.14 * GIB as f64).round() as u64);
        assert_eq!(parse_bytes("2.5 tib").unwrap(),
                   (2.5 * TIB as f64).round() as u64);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_bytes("lots").is_err());
        assert!(parse_bytes("12 XiB").is_err());
        assert!(parse_bytes("-3 GiB").is_err());
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate(4.15 * TIB as f64), "4.15 TiB/s");
    }
}
