//! Byte-size parsing and formatting (binary units, as used throughout the
//! paper: GiB, TiB). Also rate formatting for throughput tables.

/// Binary unit constants.
pub const KIB: u64 = 1 << 10;
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;
pub const TIB: u64 = 1 << 40;
pub const PIB: u64 = 1 << 50;

/// Format a byte count with binary units, e.g. `9.14 GiB`.
pub fn fmt_bytes(bytes: u64) -> String {
    fmt_bytes_f(bytes as f64)
}

/// Float variant (for averaged values).
pub fn fmt_bytes_f(bytes: f64) -> String {
    let abs = bytes.abs();
    if abs >= PIB as f64 {
        format!("{:.2} PiB", bytes / PIB as f64)
    } else if abs >= TIB as f64 {
        format!("{:.2} TiB", bytes / TIB as f64)
    } else if abs >= GIB as f64 {
        format!("{:.2} GiB", bytes / GIB as f64)
    } else if abs >= MIB as f64 {
        format!("{:.2} MiB", bytes / MIB as f64)
    } else if abs >= KIB as f64 {
        format!("{:.2} KiB", bytes / KIB as f64)
    } else {
        format!("{} B", bytes as i64)
    }
}

/// Format a rate in bytes/second, e.g. `4.15 TiB/s`.
pub fn fmt_rate(bytes_per_s: f64) -> String {
    format!("{}/s", fmt_bytes_f(bytes_per_s))
}

/// Parse a human byte size: `"9.14GiB"`, `"512 MiB"`, `"1024"` (bytes),
/// `"2.5 TiB"`. Case-insensitive; accepts decimal (`GB`) as binary for
/// convenience since the paper uses binary units throughout.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let t = s.trim();
    let split = t
        .find(|c: char| c.is_ascii_alphabetic())
        .unwrap_or(t.len());
    let (num, unit) = t.split_at(split);
    let value: f64 = num
        .trim()
        .parse()
        .map_err(|e| format!("bad byte size {s:?}: {e}"))?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1,
        "k" | "kb" | "kib" => KIB,
        "m" | "mb" | "mib" => MIB,
        "g" | "gb" | "gib" => GIB,
        "t" | "tb" | "tib" => TIB,
        "p" | "pb" | "pib" => PIB,
        other => return Err(format!("unknown byte unit {other:?} in {s:?}")),
    };
    if value < 0.0 {
        return Err(format!("negative byte size {s:?}"));
    }
    Ok((value * mult as f64).round() as u64)
}

// ---------------------------------------------------------------------
// base64 (standard alphabet, padded) — used by the JSON engine to store
// operator-compressed payloads; hand-rolled because this environment
// builds fully offline.
// ---------------------------------------------------------------------

const B64_ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Standard base64 with padding.
pub fn b64_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity((data.len() + 2) / 3 * 4);
    for group in data.chunks(3) {
        let b0 = group[0] as u32;
        let b1 = *group.get(1).unwrap_or(&0) as u32;
        let b2 = *group.get(2).unwrap_or(&0) as u32;
        let n = (b0 << 16) | (b1 << 8) | b2;
        out.push(B64_ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(B64_ALPHABET[(n >> 12) as usize & 63] as char);
        if group.len() > 1 {
            out.push(B64_ALPHABET[(n >> 6) as usize & 63] as char);
        } else {
            out.push('=');
        }
        if group.len() > 2 {
            out.push(B64_ALPHABET[n as usize & 63] as char);
        } else {
            out.push('=');
        }
    }
    out
}

fn b64_value(c: u8) -> Result<u32, String> {
    Ok(match c {
        b'A'..=b'Z' => (c - b'A') as u32,
        b'a'..=b'z' => (c - b'a') as u32 + 26,
        b'0'..=b'9' => (c - b'0') as u32 + 52,
        b'+' => 62,
        b'/' => 63,
        other => {
            return Err(format!("invalid base64 byte {:?}",
                               other as char))
        }
    })
}

/// Decode standard padded base64.
///
/// The output length is computed exactly from the input length and the
/// trailing padding, so the whole decode is a single buffer-pool
/// checkout with zero growth reallocations — these payloads sit on the
/// JSON engine's get path, where the old `len / 4 * 3` upper bound
/// wasted a fresh allocation per chunk.
pub fn b64_decode(s: &str) -> Result<Vec<u8>, String> {
    let bytes = s.as_bytes();
    if bytes.len() % 4 != 0 {
        return Err(format!(
            "base64 length {} is not a multiple of 4", bytes.len()
        ));
    }
    if bytes.is_empty() {
        return Ok(Vec::new());
    }
    let pad = bytes
        .iter()
        .rev()
        .take_while(|&&c| c == b'=')
        .take(2)
        .count();
    let exact_len = bytes.len() / 4 * 3 - pad;
    let mut out = crate::util::pool::acquire_buf(exact_len);
    for (gi, group) in bytes.chunks_exact(4).enumerate() {
        let last = gi == bytes.len() / 4 - 1;
        let pad = group.iter().rev().take_while(|&&c| c == b'=').count();
        if pad > 2 || (pad > 0 && !last) {
            return Err("misplaced base64 padding".into());
        }
        let mut n = 0u32;
        for &c in &group[..4 - pad] {
            n = (n << 6) | b64_value(c)?;
        }
        n <<= 6 * pad as u32;
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    debug_assert_eq!(out.len(), exact_len);
    Ok(out.detach())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_round_trip_magnitudes() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * KIB), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * MIB), "3.00 MiB");
        assert_eq!(fmt_bytes(9 * GIB + 143 * MIB), "9.14 GiB");
        assert_eq!(fmt_bytes(2 * TIB + TIB / 2), "2.50 TiB");
        assert_eq!(fmt_bytes(250 * PIB), "250.00 PiB");
    }

    #[test]
    fn parses_units() {
        assert_eq!(parse_bytes("1024").unwrap(), 1024);
        assert_eq!(parse_bytes("2 KiB").unwrap(), 2048);
        assert_eq!(parse_bytes("9.14GiB").unwrap(),
                   (9.14 * GIB as f64).round() as u64);
        assert_eq!(parse_bytes("2.5 tib").unwrap(),
                   (2.5 * TIB as f64).round() as u64);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_bytes("lots").is_err());
        assert!(parse_bytes("12 XiB").is_err());
        assert!(parse_bytes("-3 GiB").is_err());
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate(4.15 * TIB as f64), "4.15 TiB/s");
    }

    #[test]
    fn base64_known_vectors() {
        assert_eq!(b64_encode(b""), "");
        assert_eq!(b64_encode(b"f"), "Zg==");
        assert_eq!(b64_encode(b"fo"), "Zm8=");
        assert_eq!(b64_encode(b"foo"), "Zm9v");
        assert_eq!(b64_encode(b"foobar"), "Zm9vYmFy");
        assert_eq!(b64_decode("Zm9vYmFy").unwrap(), b"foobar");
        assert_eq!(b64_decode("").unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn base64_round_trips_all_byte_values() {
        for len in [0usize, 1, 2, 3, 4, 255, 256, 1000] {
            let data: Vec<u8> =
                (0..len).map(|i| (i * 37 % 256) as u8).collect();
            assert_eq!(b64_decode(&b64_encode(&data)).unwrap(), data,
                       "len {len}");
        }
    }

    #[test]
    fn base64_rejects_garbage() {
        assert!(b64_decode("Zm9").is_err()); // bad length
        assert!(b64_decode("Z###").is_err()); // bad alphabet
        assert!(b64_decode("Zg==Zg==").is_err()); // interior padding
        assert!(b64_decode("====").is_err()); // all padding
    }
}
