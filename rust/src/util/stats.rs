//! Descriptive statistics for the benchmark harness: quantiles, boxplot
//! summaries with the paper's whisker convention (Fig. 7/9: whiskers at the
//! furthest sample within 1.5·IQR of the quartiles, everything beyond is an
//! outlier), and streaming mean/min/max accumulators.

/// Five-number boxplot summary plus outliers, matching the paper's figures.
#[derive(Clone, Debug, PartialEq)]
pub struct BoxPlot {
    pub min: f64,
    pub lower_whisker: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub upper_whisker: f64,
    pub max: f64,
    /// Samples outside the whiskers, ascending.
    pub outliers: Vec<f64>,
    pub n: usize,
}

/// Linear-interpolation quantile (type 7, the numpy default).
/// `xs` must be sorted ascending and non-empty.
pub fn quantile_sorted(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let q = q.clamp(0.0, 1.0);
    let pos = q * (xs.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        let frac = pos - lo as f64;
        xs[lo] * (1.0 - frac) + xs[hi] * frac
    }
}

/// Compute a [`BoxPlot`] from unsorted samples.
pub fn boxplot(samples: &[f64]) -> BoxPlot {
    assert!(!samples.is_empty(), "boxplot of empty slice");
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
    let q1 = quantile_sorted(&xs, 0.25);
    let median = quantile_sorted(&xs, 0.50);
    let q3 = quantile_sorted(&xs, 0.75);
    let iqr = q3 - q1;
    let lo_fence = q1 - 1.5 * iqr;
    let hi_fence = q3 + 1.5 * iqr;
    // Whisker = furthest sample still inside the fence (paper's convention).
    let lower_whisker = *xs.iter().find(|&&x| x >= lo_fence).unwrap_or(&xs[0]);
    let upper_whisker = *xs
        .iter()
        .rev()
        .find(|&&x| x <= hi_fence)
        .unwrap_or(xs.last().unwrap());
    let outliers = xs
        .iter()
        .copied()
        .filter(|&x| x < lower_whisker || x > upper_whisker)
        .collect();
    BoxPlot {
        min: xs[0],
        lower_whisker,
        q1,
        median,
        q3,
        upper_whisker,
        max: *xs.last().unwrap(),
        outliers,
        n: xs.len(),
    }
}

impl BoxPlot {
    /// One-line rendering used by the bench tables.
    pub fn render(&self) -> String {
        format!(
            "n={:<5} min={:<8.3} w-={:<8.3} q1={:<8.3} med={:<8.3} q3={:<8.3} w+={:<8.3} max={:<8.3} outliers={}",
            self.n,
            self.min,
            self.lower_whisker,
            self.q1,
            self.median,
            self.q3,
            self.upper_whisker,
            self.max,
            self.outliers.len()
        )
    }
}

/// Streaming summary accumulator (no allocation per sample).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: usize,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, sum: 0.0, sum_sq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.sum / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum_sq / self.n as f64 - m * m).max(0.0)).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }
}

/// Mean of a slice; NaN if empty.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Median of an unsorted slice.
pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_match_numpy_type7() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile_sorted(&xs, 0.0), 1.0);
        assert_eq!(quantile_sorted(&xs, 1.0), 4.0);
        assert_eq!(quantile_sorted(&xs, 0.5), 2.5);
        assert!((quantile_sorted(&xs, 0.25) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn boxplot_without_outliers() {
        let xs: Vec<f64> = (1..=11).map(|i| i as f64).collect();
        let b = boxplot(&xs);
        assert_eq!(b.median, 6.0);
        assert_eq!(b.q1, 3.5);
        assert_eq!(b.q3, 8.5);
        assert_eq!(b.lower_whisker, 1.0);
        assert_eq!(b.upper_whisker, 11.0);
        assert!(b.outliers.is_empty());
    }

    #[test]
    fn boxplot_flags_outliers_beyond_1p5_iqr() {
        let mut xs: Vec<f64> = (1..=11).map(|i| i as f64).collect();
        xs.push(100.0);
        let b = boxplot(&xs);
        assert_eq!(b.outliers, vec![100.0]);
        assert!(b.upper_whisker <= 11.0);
        assert_eq!(b.max, 100.0);
    }

    #[test]
    fn boxplot_single_sample() {
        let b = boxplot(&[5.0]);
        assert_eq!(b.median, 5.0);
        assert_eq!(b.min, 5.0);
        assert_eq!(b.max, 5.0);
        assert!(b.outliers.is_empty());
    }

    #[test]
    fn summary_mean_std() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.mean(), 5.0);
        assert!((s.std() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.n(), 8);
    }

    #[test]
    fn median_of_even_and_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }
}
