//! Support substrates: RNG, statistics, CLI parsing, byte formatting,
//! logging. All hand-built — the build environment is offline, so the
//! usual crates (rand, clap, criterion) are not available.

pub mod bytes;
pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod sync;

/// Monotonic wall-clock helper used by metrics and benches.
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}

/// Format a `Duration` human-readably (`1.23 s`, `45.6 ms`, `789 µs`).
pub fn fmt_duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{:.2} s", s)
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1} µs", s * 1e6)
    } else {
        format!("{} ns", d.as_nanos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn durations_format_by_magnitude() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00 s");
        assert_eq!(fmt_duration(Duration::from_millis(12)), "12.00 ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.0 µs");
        assert_eq!(fmt_duration(Duration::from_nanos(42)), "42 ns");
    }
}
