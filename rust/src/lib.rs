//! # openpmd-stream
//!
//! A reproduction of *"Transitioning from file-based HPC workflows to
//! streaming data pipelines with openPMD and ADIOS2"* (Poeschel et al.,
//! CS.DC 2021) as a production-shaped Rust + JAX + Pallas three-layer stack.
//!
//! The crate provides, bottom-up:
//!
//! * [`openpmd`] — the openPMD data model: self-describing particle–mesh
//!   series (iterations, meshes, particle species, records, attributes,
//!   unit metadata) independent of any concrete IO backend.
//! * [`adios`] — the ADIOS2-like adaptable IO layer: one step-oriented
//!   [`adios::Engine`] API with interchangeable backends — the BP
//!   binary-pack file engine with node-level aggregation, the SST
//!   streaming/staging engine (publish/subscribe loose coupling) over
//!   pluggable data transports (in-process "RDMA"-analog, TCP sockets),
//!   and a serial JSON backend for prototyping. The API is **two-phase
//!   and handle-based** (engine v2), mirroring ADIOS2's deferred model:
//!   `define_variable` returns a typed [`adios::VarHandle`];
//!   `put_deferred`/`put_span` and `get_deferred` only enqueue;
//!   `perform_puts`/`perform_gets` execute a whole step's batch at once
//!   (`end_step` implies the final perform). Deferred batching is what
//!   lets a step's chunks travel as one staging exchange — one wire
//!   message per writer pair per step over SST — so IO overlaps compute
//!   instead of pacing it; `put_span` serializes producer data directly
//!   into the engine's staging buffer (zero-copy on the in-process
//!   transport). The eager `put`/`get` of engine v1 remain as provided
//!   conveniences built on the deferred core, and an engine-conformance
//!   suite ([`testing::engine_conformance`]) proves deferred and eager
//!   paths byte-identical for every backend. On top of the backends,
//!   [`adios::multiplex`] is the *virtual* read engine that closes the
//!   composition loop: an arbitrary set of child readers — a reader
//!   fleet's `out.r<i>ofM.bp` shard family opened through its merged
//!   `<out>.index.json` ([`openpmd::series::open_shard_family`]), or
//!   any ad-hoc `merge:a,b,...` of sources, backends mixed freely —
//!   presented as ONE logical series behind the same engine contract.
//!   Steps align across children under a discard-consistent barrier, a
//!   merged chunk table carries per-child provenance
//!   (`WrittenChunkInfo::source_id`, preserved through distribution
//!   assignments), deferred gets route to the owning child with one
//!   batched perform per child per step, and the engine-spec grammar
//!   grows `shards:<index.json>` / `merge:a,b,...` — so a fleet's
//!   output is consumable by the pipe, the analysis, or a second
//!   fleet stage exactly like the pre-fleet serial stream
//!   (byte-identical, proven by `tests/reassembly_conformance.rs`).
//! * [`adios::ops`] — the per-variable **operator** subsystem (ADIOS2's
//!   `AddOperation`): data transforms applied transparently at put/get
//!   time, because once the network rather than the filesystem is the
//!   bottleneck, bytes-per-step is the remaining lever. An
//!   [`adios::ops::Operator`] has `apply`/`reverse` over typed byte
//!   slices; four dependency-free codecs ship — `shuffle` (byte
//!   transposition by element width), `rle` (PackBits-style byte runs),
//!   `delta` (delta+zigzag+varint for integer/index data) and `zfp:N`
//!   (lossy mantissa truncation keeping `N` bits, f32/f64 only).
//!   Chains compose via a spec grammar attached at `define_variable`
//!   time:
//!
//!   ```text
//!   chain   := "" | "identity" | "none" | codec ("|" codec)*
//!   codec   := "shuffle" | "rle" | "delta" | "zfp" | "zfp:" bits
//!   bits    := 1..=52        (mantissa bits kept; default 12)
//!   ```
//!
//!   Validation is typed and up-front: unknown codecs, empty segments
//!   (`"shuffle||rle"`) and lossy-codec-on-integer declarations are
//!   [`adios::ops::OpsError`]s at definition, not failures mid-stream.
//!   The chain is applied inside `perform_puts` and reversed at
//!   `perform_gets` (the deferred core), so eager paths inherit it;
//!   encoded payloads travel in a length-validated frame; the SST wire
//!   negotiates codecs at handshake (readers lacking one are served
//!   raw); BP files persist the chain in variable metadata so they
//!   self-describe; JSON stores compressed payloads base64-encoded; and
//!   `pipeline::pipe` forwards chains end to end (or re-encodes with
//!   `--operators`). Every engine reports an [`adios::ops::OpsReport`]
//!   (ratio, bytes saved, encode/decode throughput), merged into the
//!   pipe report; `benches/fig_compression.rs` measures ratio vs.
//!   throughput per chain over real SST-TCP.
//! * [`distribution`] — the paper's §3 contribution: chunk-distribution
//!   strategies (round-robin, hyperslab slicing, binpacking, two-phase
//!   by-hostname, and cost-aware load-balanced LPT over the staged byte
//!   sizes writers announce per chunk) plus quality metrics (locality /
//!   balance / alignment).
//! * [`cluster`] — the simulated Summit substrate: node topology, fabric
//!   and parallel-filesystem models, and a max–min fair-share
//!   discrete-event simulator that regenerates the paper's 512-node
//!   figures on a laptop.
//! * [`pipeline`] — the L3 orchestrator: pipeline stages, the
//!   `openpmd-pipe` adaptor in its two execution modes (serial, and
//!   staged with bounded read-ahead so the store of step N overlaps the
//!   load of step N+1), backpressure/queue policies and metrics
//!   (including [`pipeline::OverlapReport`], which quantifies the IO
//!   time the staged pipe hides). [`pipeline::fleet`] scales the
//!   adaptor across readers: M workers over the N writer transports,
//!   coordinated by one shared per-step chunk plan (a complete +
//!   disjoint `Assignment` per step and variable), each storing into
//!   its own output shard — shard unions are byte-identical to the
//!   serial pipe for every strategy, and
//!   [`pipeline::FleetReport`] carries the straggler accounting
//!   (per-rank bytes/busy time, max/mean imbalance, aggregate rate).
//!   Fleet workers optionally stack staged read-ahead on top
//!   (`FleetOptions::depth`), and the chain composes end to end:
//!   produce → fleet(M) → reassemble (shard family as one multiplexed
//!   series) → pipe/analyze/second fleet.
//! * [`producer`] / [`analysis`] — the two pipeline endpoints: a
//!   PIConGPU-like Kelvin–Helmholtz particle producer and a GAPD-like
//!   SAXS diffraction consumer, both executing AOT-lowered JAX/Pallas
//!   artifacts through [`runtime`] (PJRT); python never runs at runtime.
//! * [`obs`] — the unified observability layer: scoped tracing spans
//!   (per-thread buffers, central collector, Chrome-trace/Perfetto and
//!   JSON-lines exporters with `pid`/`tid` mapped to fleet rank and
//!   pipeline stage) plus a process-wide registry of counters, gauges
//!   and log-bucketed histograms, threaded through the engine perform
//!   paths, the SST announce/serve loops, the wire layer, the staged
//!   pipe and the fleet. Surfaced on `produce`/`pipe` via `--trace`,
//!   `--metrics` and `--metrics-interval`; near-zero cost when
//!   disabled (gated by `benches/micro_obs.rs`).
//! * [`util`], [`config`], [`testing`], [`bench`] — support substrates
//!   built from scratch (no network access in this environment): CLI
//!   parsing, statistics, deterministic RNG, a TOML-subset config
//!   format, a mini property-testing framework, and a bench harness.
//!
//! The crate polices itself with [`analysis::lint`] — a
//! dependency-free static-analysis gate (`pallas-lint` in `tools/`,
//! run by CI and by `tests/lint_clean.rs`) enforcing panic-freedom in
//! the hardened wire/BP/SST/multiplex/pipeline modules, lock
//! discipline crate-wide (see [`util::sync::lock_or_poisoned`]),
//! engine-contract conformance, and a committed fingerprint of the
//! serialization layouts. Waivers are in-source
//! `// lint:allow(<rule>): <reason>` comments budgeted by the
//! shrink-only ledger `tools/lint/waivers.ledger`.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod adios;
pub mod analysis;
pub mod bench;
pub mod cluster;
pub mod config;
pub mod distribution;
pub mod obs;
pub mod openpmd;
pub mod pipeline;
pub mod producer;
pub mod runtime;
pub mod testing;
pub mod util;

pub use adios::{
    Engine, EngineKind, GetHandle, Mode, OpChain, OpsError, OpsReport,
    ReaderSlot, SinkSpec, SourceSpec, SpecError, StepStatus, VarDecl,
    VarHandle,
};
pub use distribution::{Assignment, ChunkTable, Strategy};
pub use openpmd::Series;
