//! Chunk-distribution strategies (S7) — the paper's §3 contribution.
//!
//! A writing application produces n-dimensional chunks that differ in
//! problem-domain location (offset/extent) and compute-domain location
//! (rank, hostname). The reading application's ranks must decide who loads
//! what. §3.1 names the properties a good distribution has:
//!
//! * **locality** — few, topologically-close communication partners;
//! * **balancing** — even data volume per reader;
//! * **alignment** — loaded chunks coincide with written chunks;
//! * **read constraints** — domain-imposed (out of scope here, §3.2).
//!
//! Each strategy in this module guarantees a *complete* distribution
//! (every written byte is assigned to exactly one reader) and trades the
//! properties differently; [`metrics`] quantifies the trade for any
//! assignment, and the property tests in `tests/` verify the guarantees.

pub mod binpacking;
pub mod by_hostname;
pub mod hyperslabs;
pub mod metrics;
pub mod round_robin;

pub use binpacking::Binpacking;
pub use by_hostname::ByHostname;
pub use hyperslabs::Hyperslabs;
pub use round_robin::RoundRobin;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};

/// A reader rank with its placement in the system topology.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReaderRank {
    pub rank: usize,
    pub hostname: String,
}

/// The reading application's parallel layout.
#[derive(Clone, Debug, Default)]
pub struct ReaderLayout {
    pub ranks: Vec<ReaderRank>,
}

impl ReaderLayout {
    /// `n` readers all on one host (the degenerate single-node case).
    pub fn local(n: usize) -> Self {
        ReaderLayout {
            ranks: (0..n)
                .map(|rank| ReaderRank { rank, hostname: "localhost".into() })
                .collect(),
        }
    }

    /// `per_node` readers on each of `nodes` hosts named `node<i>`,
    /// ranks numbered node-major (like `jsrun` round-robin placement).
    pub fn nodes(nodes: usize, per_node: usize) -> Self {
        let mut ranks = Vec::with_capacity(nodes * per_node);
        for node in 0..nodes {
            for slot in 0..per_node {
                ranks.push(ReaderRank {
                    rank: node * per_node + slot,
                    hostname: format!("node{node:04}"),
                });
            }
        }
        ReaderLayout { ranks }
    }

    pub fn len(&self) -> usize {
        self.ranks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranks.is_empty()
    }
}

/// The distribution problem input: one variable's written chunks + the
/// dataset extent they tile.
#[derive(Clone, Debug)]
pub struct ChunkTable {
    pub dataset_extent: Vec<u64>,
    pub chunks: Vec<WrittenChunkInfo>,
}

impl ChunkTable {
    pub fn total_elements(&self) -> u64 {
        self.chunks.iter().map(|c| c.chunk.num_elements()).sum()
    }
}

/// One piece of work for a reader: load `chunk` (possibly a sub-chunk of
/// a written chunk), remembering where the bytes live.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkSlice {
    pub chunk: Chunk,
    /// Writer rank holding the data.
    pub source_rank: usize,
    /// Writer hostname (for locality accounting).
    pub source_host: String,
}

impl ChunkSlice {
    pub fn of(info: &WrittenChunkInfo) -> Self {
        ChunkSlice {
            chunk: info.chunk.clone(),
            source_rank: info.source_rank,
            source_host: info.hostname.clone(),
        }
    }

    pub fn with_chunk(info: &WrittenChunkInfo, chunk: Chunk) -> Self {
        ChunkSlice {
            chunk,
            source_rank: info.source_rank,
            source_host: info.hostname.clone(),
        }
    }
}

/// The distribution result: reader rank -> slices to load.
#[derive(Clone, Debug, Default)]
pub struct Assignment {
    pub per_reader: BTreeMap<usize, Vec<ChunkSlice>>,
}

impl Assignment {
    pub fn slices(&self, reader: usize) -> &[ChunkSlice] {
        self.per_reader
            .get(&reader)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    pub fn elements_for(&self, reader: usize) -> u64 {
        self.slices(reader)
            .iter()
            .map(|s| s.chunk.num_elements())
            .sum()
    }

    pub fn total_elements(&self) -> u64 {
        self.per_reader.keys().map(|r| self.elements_for(*r)).sum()
    }

    pub fn total_slices(&self) -> usize {
        self.per_reader.values().map(|v| v.len()).sum()
    }

    fn push(&mut self, reader: usize, slice: ChunkSlice) {
        if slice.chunk.num_elements() > 0 {
            self.per_reader.entry(reader).or_default().push(slice);
        }
    }
}

/// A chunk-distribution strategy.
pub trait Strategy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Compute a complete assignment of `table` over `readers`.
    fn distribute(&self, table: &ChunkTable, readers: &ReaderLayout)
        -> Assignment;
}

/// Resolve a strategy by config name. `"hostname"` takes optional
/// secondary/fallback suffixes: `"hostname:binpacking:hyperslabs"`.
pub fn by_name(name: &str) -> Result<Box<dyn Strategy>> {
    let mut parts = name.split(':');
    let head = parts.next().unwrap_or("");
    Ok(match head {
        "roundrobin" | "round-robin" => Box::new(RoundRobin),
        "hyperslabs" | "slicing" => Box::new(Hyperslabs),
        "binpacking" => Box::new(Binpacking),
        "hostname" | "by-hostname" => {
            let secondary = parts.next().unwrap_or("binpacking");
            let fallback = parts.next().unwrap_or("binpacking");
            Box::new(ByHostname::new(by_name(secondary)?, by_name(fallback)?))
        }
        other => bail!("unknown distribution strategy {other:?}"),
    })
}

/// Verify that `assignment` is a complete, non-overlapping distribution
/// of `table` (every written element assigned exactly once). Returns a
/// description of the first violation.
pub fn verify_complete(table: &ChunkTable, assignment: &Assignment)
    -> Result<(), String>
{
    let want: u64 = table.total_elements();
    let got: u64 = assignment.total_elements();
    if want != got {
        return Err(format!(
            "assigned {got} elements, table has {want}"
        ));
    }
    // Each written chunk must be exactly tiled by the slices that
    // intersect it.
    for info in &table.chunks {
        let mut covered = 0u64;
        let mut pieces: Vec<&Chunk> = Vec::new();
        for slices in assignment.per_reader.values() {
            for s in slices {
                if s.source_rank != info.source_rank {
                    continue;
                }
                if let Some(inter) = s.chunk.intersect(&info.chunk) {
                    // A slice must not extend outside the chunk it came
                    // from if it names this source rank... it may though
                    // (two chunks from one rank). Count the overlap only.
                    covered += inter.num_elements();
                    pieces.push(&s.chunk);
                }
            }
        }
        if covered < info.chunk.num_elements() {
            return Err(format!(
                "chunk {:?}+{:?} (rank {}) covered {covered}/{} elements",
                info.chunk.offset,
                info.chunk.extent,
                info.source_rank,
                info.chunk.num_elements()
            ));
        }
        if covered > info.chunk.num_elements() {
            return Err(format!(
                "chunk {:?}+{:?} (rank {}) over-covered: {covered}/{}",
                info.chunk.offset,
                info.chunk.extent,
                info.source_rank,
                info.chunk.num_elements()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn table_1d(sizes: &[(u64, usize, &str)]) -> ChunkTable {
        // sizes: (extent, source_rank, hostname), laid out contiguously.
        let mut chunks = Vec::new();
        let mut off = 0u64;
        for (n, rank, host) in sizes {
            chunks.push(WrittenChunkInfo::new(
                Chunk::new(vec![off], vec![*n]),
                *rank,
                *host,
            ));
            off += n;
        }
        ChunkTable { dataset_extent: vec![off], chunks }
    }

    #[test]
    fn by_name_resolves_all() {
        for n in ["roundrobin", "hyperslabs", "binpacking", "hostname",
                  "hostname:roundrobin:hyperslabs"] {
            assert!(by_name(n).is_ok(), "{n}");
        }
        assert!(by_name("quantum").is_err());
    }

    #[test]
    fn verify_catches_gaps_and_overlaps() {
        let table = table_1d(&[(10, 0, "a")]);
        // Gap: only 5 of 10 assigned.
        let mut a = Assignment::default();
        a.push(0, ChunkSlice::with_chunk(&table.chunks[0],
                                         Chunk::new(vec![0], vec![5])));
        assert!(verify_complete(&table, &a).is_err());
        // Overlap: 15 of 10.
        let mut b = Assignment::default();
        b.push(0, ChunkSlice::of(&table.chunks[0]));
        b.push(1, ChunkSlice::with_chunk(&table.chunks[0],
                                         Chunk::new(vec![0], vec![5])));
        assert!(verify_complete(&table, &b).is_err());
        // Exact.
        let mut c = Assignment::default();
        c.push(0, ChunkSlice::of(&table.chunks[0]));
        assert!(verify_complete(&table, &c).is_ok());
    }

    #[test]
    fn layouts() {
        let l = ReaderLayout::nodes(2, 3);
        assert_eq!(l.len(), 6);
        assert_eq!(l.ranks[4].hostname, "node0001");
        assert_eq!(l.ranks[4].rank, 4);
        assert_eq!(ReaderLayout::local(2).ranks[1].hostname, "localhost");
    }
}
