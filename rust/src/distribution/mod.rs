//! Chunk-distribution strategies (S7) — the paper's §3 contribution.
//!
//! A writing application produces n-dimensional chunks that differ in
//! problem-domain location (offset/extent) and compute-domain location
//! (rank, hostname). The reading application's ranks must decide who loads
//! what. §3.1 names the properties a good distribution has:
//!
//! * **locality** — few, topologically-close communication partners;
//! * **balancing** — even data volume per reader;
//! * **alignment** — loaded chunks coincide with written chunks;
//! * **read constraints** — domain-imposed (out of scope here, §3.2).
//!
//! Each strategy in this module guarantees a *complete* distribution
//! (every written byte is assigned to exactly one reader) and trades the
//! properties differently; [`metrics`] quantifies the trade for any
//! assignment, and the property tests in `tests/` verify the guarantees.

pub mod binpacking;
pub mod by_hostname;
pub mod hyperslabs;
pub mod load_balanced;
pub mod metrics;
pub mod round_robin;

pub use binpacking::Binpacking;
pub use by_hostname::ByHostname;
pub use hyperslabs::Hyperslabs;
pub use load_balanced::LoadBalanced;
pub use round_robin::RoundRobin;

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};

/// A reader rank with its placement in the system topology.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReaderRank {
    pub rank: usize,
    pub hostname: String,
}

/// Typed error for degenerate reader layouts. A zero-rank layout would
/// make every [`Assignment`] vacuously "complete" (nothing assigned,
/// nothing checked), so the constructors reject it up front instead of
/// letting the hole surface as silently-dropped data downstream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayoutError {
    /// A layout with zero reader ranks was requested
    /// (`local(0)`, `nodes(0, _)` or `nodes(_, 0)`).
    Empty { nodes: usize, per_node: usize },
}

impl fmt::Display for LayoutError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LayoutError::Empty { nodes, per_node } => write!(
                f,
                "reader layout of {nodes} node(s) x {per_node} rank(s) \
                 has no readers; an empty layout would make every \
                 distribution vacuously complete"
            ),
        }
    }
}

impl std::error::Error for LayoutError {}

/// The reading application's parallel layout.
#[derive(Clone, Debug, Default)]
pub struct ReaderLayout {
    pub ranks: Vec<ReaderRank>,
}

impl ReaderLayout {
    /// `n` readers all on one host (the degenerate single-node case).
    /// `n == 0` is a typed error, not an empty layout.
    pub fn local(n: usize) -> std::result::Result<Self, LayoutError> {
        if n == 0 {
            return Err(LayoutError::Empty { nodes: 1, per_node: 0 });
        }
        Ok(ReaderLayout {
            ranks: (0..n)
                .map(|rank| ReaderRank { rank, hostname: "localhost".into() })
                .collect(),
        })
    }

    /// `per_node` readers on each of `nodes` hosts named `node<i>`,
    /// ranks numbered node-major (like `jsrun` round-robin placement).
    /// A zero node or per-node count is a typed error.
    pub fn nodes(nodes: usize, per_node: usize)
        -> std::result::Result<Self, LayoutError>
    {
        if nodes == 0 || per_node == 0 {
            return Err(LayoutError::Empty { nodes, per_node });
        }
        let mut ranks = Vec::with_capacity(nodes * per_node);
        for node in 0..nodes {
            for slot in 0..per_node {
                ranks.push(ReaderRank {
                    rank: node * per_node + slot,
                    hostname: format!("node{node:04}"),
                });
            }
        }
        Ok(ReaderLayout { ranks })
    }

    pub fn len(&self) -> usize {
        self.ranks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranks.is_empty()
    }
}

/// The distribution problem input: one variable's written chunks + the
/// dataset extent they tile.
#[derive(Clone, Debug)]
pub struct ChunkTable {
    pub dataset_extent: Vec<u64>,
    pub chunks: Vec<WrittenChunkInfo>,
}

impl ChunkTable {
    pub fn total_elements(&self) -> u64 {
        self.chunks.iter().map(|c| c.chunk.num_elements()).sum()
    }
}

/// One piece of work for a reader: load `chunk` (possibly a sub-chunk of
/// a written chunk), remembering where the bytes live.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkSlice {
    pub chunk: Chunk,
    /// Writer rank holding the data.
    pub source_rank: usize,
    /// Writer hostname (for locality accounting).
    pub source_host: String,
    /// Source engine of a multiplexed composition holding the data
    /// ([`WrittenChunkInfo::source_id`]): preserved through the
    /// assignment so a plan over a merged table still knows which
    /// child each slice routes to. `None` for single-engine tables.
    pub source_id: Option<usize>,
    /// Cost of moving this slice, for balancing: the source chunk's
    /// announced staged byte size ([`WrittenChunkInfo::encoded_bytes`],
    /// pro-rated for sub-chunks), or the element count when the writer
    /// did not announce sizes. Comparable *within* one chunk table —
    /// either every chunk of a variable carries announced sizes or none
    /// does — which is all a per-variable strategy needs.
    pub cost: u64,
}

impl ChunkSlice {
    pub fn of(info: &WrittenChunkInfo) -> Self {
        ChunkSlice {
            chunk: info.chunk.clone(),
            source_rank: info.source_rank,
            source_host: info.hostname.clone(),
            source_id: info.source_id,
            cost: info
                .encoded_bytes
                .unwrap_or_else(|| info.chunk.num_elements()),
        }
    }

    pub fn with_chunk(info: &WrittenChunkInfo, chunk: Chunk) -> Self {
        let sub = chunk.num_elements();
        let cost = match (info.encoded_bytes, info.chunk.num_elements()) {
            // Pro-rate the announced size by the sub-chunk's share; a
            // non-empty sub-slice keeps a nonzero cost.
            (Some(bytes), total) if total > 0 => {
                ((bytes as u128 * sub as u128 / total as u128) as u64)
                    .max(u64::from(sub > 0))
            }
            _ => sub,
        };
        ChunkSlice {
            chunk,
            source_rank: info.source_rank,
            source_host: info.hostname.clone(),
            source_id: info.source_id,
            cost,
        }
    }
}

/// The distribution result: reader rank -> slices to load.
#[derive(Clone, Debug, Default)]
pub struct Assignment {
    pub per_reader: BTreeMap<usize, Vec<ChunkSlice>>,
}

impl Assignment {
    pub fn slices(&self, reader: usize) -> &[ChunkSlice] {
        self.per_reader
            .get(&reader)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    pub fn elements_for(&self, reader: usize) -> u64 {
        self.slices(reader)
            .iter()
            .map(|s| s.chunk.num_elements())
            .sum()
    }

    /// Total [`ChunkSlice::cost`] assigned to `reader` — the byte load
    /// the cost-aware strategies balance.
    pub fn cost_for(&self, reader: usize) -> u64 {
        self.slices(reader).iter().map(|s| s.cost).sum()
    }

    /// Max per-reader cost over `readers` ranks (0 for an empty
    /// assignment) — the straggler bound a balanced strategy minimizes.
    pub fn max_cost(&self, readers: &ReaderLayout) -> u64 {
        readers
            .ranks
            .iter()
            .map(|r| self.cost_for(r.rank))
            .max()
            .unwrap_or(0)
    }

    pub fn total_elements(&self) -> u64 {
        self.per_reader.keys().map(|r| self.elements_for(*r)).sum()
    }

    pub fn total_slices(&self) -> usize {
        self.per_reader.values().map(|v| v.len()).sum()
    }

    fn push(&mut self, reader: usize, slice: ChunkSlice) {
        if slice.chunk.num_elements() > 0 {
            self.per_reader.entry(reader).or_default().push(slice);
        }
    }
}

/// A chunk-distribution strategy.
pub trait Strategy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Compute a complete assignment of `table` over `readers`.
    fn distribute(&self, table: &ChunkTable, readers: &ReaderLayout)
        -> Assignment;
}

/// The strategy names [`by_name`] resolves (canonical spellings).
pub const STRATEGY_NAMES: [&str; 5] =
    ["roundrobin", "hyperslabs", "binpacking", "loadbalanced", "hostname"];

/// Resolve a strategy by config name. `"hostname"` takes optional
/// secondary/fallback suffixes: `"hostname:binpacking:hyperslabs"`.
pub fn by_name(name: &str) -> Result<Box<dyn Strategy>> {
    let mut parts = name.split(':');
    let head = parts.next().unwrap_or("");
    Ok(match head {
        "roundrobin" | "round-robin" => Box::new(RoundRobin),
        "hyperslabs" | "slicing" => Box::new(Hyperslabs),
        "binpacking" => Box::new(Binpacking),
        "loadbalanced" | "load-balanced" | "lpt" => Box::new(LoadBalanced),
        "hostname" | "by-hostname" => {
            let secondary = parts.next().unwrap_or("binpacking");
            let fallback = parts.next().unwrap_or("binpacking");
            Box::new(ByHostname::new(by_name(secondary)?, by_name(fallback)?))
        }
        other => bail!(
            "unknown distribution strategy {other:?} (valid: {})",
            STRATEGY_NAMES.join(", ")
        ),
    })
}

/// Verify that `assignment` is a complete, non-overlapping distribution
/// of `table` (every written element assigned exactly once). Returns a
/// description of the first violation.
pub fn verify_complete(table: &ChunkTable, assignment: &Assignment)
    -> Result<(), String>
{
    let want: u64 = table.total_elements();
    let got: u64 = assignment.total_elements();
    if want != got {
        return Err(format!(
            "assigned {got} elements, table has {want}"
        ));
    }
    // Each written chunk must be exactly tiled by the slices that
    // intersect it.
    for info in &table.chunks {
        let mut covered = 0u64;
        let mut pieces: Vec<&Chunk> = Vec::new();
        for slices in assignment.per_reader.values() {
            for s in slices {
                if s.source_rank != info.source_rank {
                    continue;
                }
                if let Some(inter) = s.chunk.intersect(&info.chunk) {
                    // A slice must not extend outside the chunk it came
                    // from if it names this source rank... it may though
                    // (two chunks from one rank). Count the overlap only.
                    covered += inter.num_elements();
                    pieces.push(&s.chunk);
                }
            }
        }
        if covered < info.chunk.num_elements() {
            return Err(format!(
                "chunk {:?}+{:?} (rank {}) covered {covered}/{} elements",
                info.chunk.offset,
                info.chunk.extent,
                info.source_rank,
                info.chunk.num_elements()
            ));
        }
        if covered > info.chunk.num_elements() {
            return Err(format!(
                "chunk {:?}+{:?} (rank {}) over-covered: {covered}/{}",
                info.chunk.offset,
                info.chunk.extent,
                info.source_rank,
                info.chunk.num_elements()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn table_1d(sizes: &[(u64, usize, &str)]) -> ChunkTable {
        // sizes: (extent, source_rank, hostname), laid out contiguously.
        let mut chunks = Vec::new();
        let mut off = 0u64;
        for (n, rank, host) in sizes {
            chunks.push(WrittenChunkInfo::new(
                Chunk::new(vec![off], vec![*n]),
                *rank,
                *host,
            ));
            off += n;
        }
        ChunkTable { dataset_extent: vec![off], chunks }
    }

    #[test]
    fn by_name_resolves_all() {
        for n in ["roundrobin", "hyperslabs", "binpacking", "hostname",
                  "loadbalanced", "lpt",
                  "hostname:roundrobin:hyperslabs",
                  "hostname:loadbalanced:loadbalanced"] {
            assert!(by_name(n).is_ok(), "{n}");
        }
        assert!(by_name("quantum").is_err());
    }

    #[test]
    fn by_name_error_lists_valid_strategies() {
        let err = format!("{}", by_name("quantum").unwrap_err());
        for name in STRATEGY_NAMES {
            assert!(err.contains(name), "{err:?} lacks {name}");
        }
    }

    #[test]
    fn empty_layouts_are_typed_errors() {
        assert_eq!(ReaderLayout::local(0).unwrap_err(),
                   LayoutError::Empty { nodes: 1, per_node: 0 });
        assert_eq!(ReaderLayout::nodes(0, 3).unwrap_err(),
                   LayoutError::Empty { nodes: 0, per_node: 3 });
        assert_eq!(ReaderLayout::nodes(3, 0).unwrap_err(),
                   LayoutError::Empty { nodes: 3, per_node: 0 });
        let msg = format!("{}", ReaderLayout::local(0).unwrap_err());
        assert!(msg.contains("no readers"), "{msg}");
    }

    #[test]
    fn slice_costs_default_to_elements_and_prefer_announced_bytes() {
        let info = WrittenChunkInfo::new(
            Chunk::new(vec![0], vec![100]), 0, "a");
        assert_eq!(ChunkSlice::of(&info).cost, 100);
        let sized = info.clone().with_encoded_bytes(4000);
        assert_eq!(ChunkSlice::of(&sized).cost, 4000);
        // Sub-slices pro-rate the announced size.
        let half = ChunkSlice::with_chunk(
            &sized, Chunk::new(vec![0], vec![50]));
        assert_eq!(half.cost, 2000);
        // ...and never round a non-empty slice down to zero cost.
        let tiny = ChunkSlice::with_chunk(
            &info.clone().with_encoded_bytes(1),
            Chunk::new(vec![0], vec![1]));
        assert_eq!(tiny.cost, 1);
    }

    #[test]
    fn slices_preserve_multiplex_provenance() {
        // A merged (multiplexed) table stamps each chunk with its
        // source engine; slicing — whole or sub-chunk — must carry it
        // through to the Assignment.
        let info = WrittenChunkInfo::new(
            Chunk::new(vec![0], vec![100]), 2, "a")
            .with_source_id(3);
        assert_eq!(ChunkSlice::of(&info).source_id, Some(3));
        let sub = ChunkSlice::with_chunk(
            &info, Chunk::new(vec![10], vec![5]));
        assert_eq!(sub.source_id, Some(3));
        // Plain single-engine tables stay unstamped.
        let plain = WrittenChunkInfo::new(
            Chunk::new(vec![0], vec![4]), 0, "a");
        assert_eq!(ChunkSlice::of(&plain).source_id, None);
    }

    #[test]
    fn verify_catches_gaps_and_overlaps() {
        let table = table_1d(&[(10, 0, "a")]);
        // Gap: only 5 of 10 assigned.
        let mut a = Assignment::default();
        a.push(0, ChunkSlice::with_chunk(&table.chunks[0],
                                         Chunk::new(vec![0], vec![5])));
        assert!(verify_complete(&table, &a).is_err());
        // Overlap: 15 of 10.
        let mut b = Assignment::default();
        b.push(0, ChunkSlice::of(&table.chunks[0]));
        b.push(1, ChunkSlice::with_chunk(&table.chunks[0],
                                         Chunk::new(vec![0], vec![5])));
        assert!(verify_complete(&table, &b).is_err());
        // Exact.
        let mut c = Assignment::default();
        c.push(0, ChunkSlice::of(&table.chunks[0]));
        assert!(verify_complete(&table, &c).is_ok());
    }

    #[test]
    fn layouts() {
        let l = ReaderLayout::nodes(2, 3).unwrap();
        assert_eq!(l.len(), 6);
        assert_eq!(l.ranks[4].hostname, "node0001");
        assert_eq!(l.ranks[4].rank, 4);
        assert_eq!(ReaderLayout::local(2).unwrap().ranks[1].hostname,
                   "localhost");
    }
}
