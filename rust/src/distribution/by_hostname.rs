//! Distribution by hostname (§3.2, fourth algorithm; Fig. 4).
//!
//! Two phases:
//!
//! 1. **sort by node**: chunks written on a host that also runs readers
//!    are distributed *within that host* by a secondary strategy — all
//!    communication stays on-node;
//! 2. **fallback**: chunks from hosts without readers are distributed
//!    over *all* readers by a fallback strategy, ensuring completeness.
//!
//! The algorithm thereby "dynamically adapts to job scheduling" (§3.2):
//! co-scheduled writers and readers (the paper's 3+3 GPUs per node) get
//! perfect locality; disjoint scheduling automatically degrades to the
//! fallback. The hostname can stand for any topology layer (CPU socket,
//! host cohort) — here it is the literal hostname, as in the paper.

use std::collections::BTreeMap;

use super::{
    Assignment, ChunkTable, ReaderLayout, ReaderRank, Strategy,
};

/// See module docs.
pub struct ByHostname {
    secondary: Box<dyn Strategy>,
    fallback: Box<dyn Strategy>,
}

impl ByHostname {
    pub fn new(secondary: Box<dyn Strategy>, fallback: Box<dyn Strategy>)
        -> Self
    {
        ByHostname { secondary, fallback }
    }

    /// Paper configuration (1): Binpacking within the node, Binpacking
    /// as fallback.
    pub fn paper_default() -> Self {
        ByHostname::new(
            Box::new(super::Binpacking),
            Box::new(super::Binpacking),
        )
    }
}

impl Strategy for ByHostname {
    fn name(&self) -> &'static str {
        "hostname"
    }

    fn distribute(&self, table: &ChunkTable, readers: &ReaderLayout)
        -> Assignment
    {
        let mut out = Assignment::default();
        if readers.is_empty() {
            return out;
        }

        // Readers per host.
        let mut readers_by_host: BTreeMap<&str, Vec<ReaderRank>> =
            BTreeMap::new();
        for r in &readers.ranks {
            readers_by_host
                .entry(r.hostname.as_str())
                .or_default()
                .push(r.clone());
        }

        // Phase 1: split the chunk table by writer host.
        let mut local_tables: BTreeMap<&str, ChunkTable> = BTreeMap::new();
        let mut leftover = ChunkTable {
            dataset_extent: table.dataset_extent.clone(),
            chunks: Vec::new(),
        };
        for info in &table.chunks {
            if readers_by_host.contains_key(info.hostname.as_str()) {
                local_tables
                    .entry(info.hostname.as_str())
                    .or_insert_with(|| ChunkTable {
                        dataset_extent: table.dataset_extent.clone(),
                        chunks: Vec::new(),
                    })
                    .chunks
                    .push(info.clone());
            } else {
                leftover.chunks.push(info.clone());
            }
        }

        // Per-host secondary distribution.
        for (host, local_table) in &local_tables {
            let local_readers = ReaderLayout {
                ranks: readers_by_host[host].clone(),
            };
            let local = self.secondary.distribute(local_table,
                                                  &local_readers);
            for (reader, slices) in local.per_reader {
                out.per_reader.entry(reader).or_default().extend(slices);
            }
        }

        // Phase 2: fallback for hosts without readers.
        if !leftover.chunks.is_empty() {
            let fb = self.fallback.distribute(&leftover, readers);
            for (reader, slices) in fb.per_reader {
                out.per_reader.entry(reader).or_default().extend(slices);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::verify_complete;
    use super::*;
    use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};

    fn co_scheduled_table(nodes: usize, writers_per_node: usize,
                          chunk: u64) -> ChunkTable {
        // Writers on node0000..node000N, matching ReaderLayout::nodes.
        let mut chunks = Vec::new();
        let mut off = 0;
        for node in 0..nodes {
            for w in 0..writers_per_node {
                chunks.push(WrittenChunkInfo::new(
                    Chunk::new(vec![off], vec![chunk]),
                    node * writers_per_node + w,
                    format!("node{node:04}"),
                ));
                off += chunk;
            }
        }
        ChunkTable { dataset_extent: vec![off], chunks }
    }

    #[test]
    fn co_scheduled_communication_stays_local() {
        // 3 writers + 3 readers per node (the paper's §4.2 layout).
        let table = co_scheduled_table(4, 3, 100);
        let readers = ReaderLayout::nodes(4, 3).unwrap();
        let a = ByHostname::paper_default().distribute(&table, &readers);
        verify_complete(&table, &a).unwrap();
        // Every slice must be served by a writer on the reader's host.
        for (reader, slices) in &a.per_reader {
            let reader_host = &readers
                .ranks
                .iter()
                .find(|r| r.rank == *reader)
                .unwrap()
                .hostname;
            for s in slices {
                assert_eq!(&s.source_host, reader_host,
                           "off-node slice for reader {reader}");
            }
        }
    }

    #[test]
    fn writer_only_nodes_use_fallback() {
        // Writers on 4 nodes, readers only on the first 2.
        let table = co_scheduled_table(4, 2, 50);
        let readers = ReaderLayout::nodes(2, 2).unwrap();
        let a = ByHostname::paper_default().distribute(&table, &readers);
        verify_complete(&table, &a).unwrap();
        // All data still assigned, some of it off-node.
        let off_node: u64 = a
            .per_reader
            .iter()
            .flat_map(|(reader, slices)| {
                let host = readers
                    .ranks
                    .iter()
                    .find(|r| r.rank == *reader)
                    .unwrap()
                    .hostname
                    .clone();
                slices
                    .iter()
                    .filter(move |s| s.source_host != host)
                    .map(|s| s.chunk.num_elements())
            })
            .sum();
        assert_eq!(off_node, 2 * 2 * 50); // exactly the two readerless nodes
    }

    #[test]
    fn no_readers_anywhere_local_to_writers_falls_back_entirely() {
        // Readers on a disjoint set of hosts.
        let table = co_scheduled_table(2, 2, 10);
        let readers = ReaderLayout {
            ranks: (0..3)
                .map(|rank| ReaderRank {
                    rank,
                    hostname: format!("other{rank}"),
                })
                .collect(),
        };
        let a = ByHostname::paper_default().distribute(&table, &readers);
        verify_complete(&table, &a).unwrap();
    }

    #[test]
    fn respects_secondary_strategy_choice() {
        let table = co_scheduled_table(1, 4, 25);
        let readers = ReaderLayout::nodes(1, 2).unwrap();
        let strat = ByHostname::new(
            Box::new(super::super::RoundRobin),
            Box::new(super::super::Hyperslabs),
        );
        let a = strat.distribute(&table, &readers);
        verify_complete(&table, &a).unwrap();
        // Round-robin within the node: 2 chunks each, unsplit.
        assert_eq!(a.slices(0).len(), 2);
        assert_eq!(a.slices(1).len(), 2);
    }

    #[test]
    fn empty_table() {
        let table = ChunkTable { dataset_extent: vec![0], chunks: vec![] };
        let a = ByHostname::paper_default()
            .distribute(&table, &ReaderLayout::local(2).unwrap());
        assert_eq!(a.total_slices(), 0);
    }
}
