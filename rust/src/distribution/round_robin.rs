//! Round-Robin distribution (§3.2, first algorithm).
//!
//! Deals whole written chunks to readers cyclically. Optimizes only the
//! *alignment* property (chunks are never split), fully forgoing
//! *locality* and *balancing* — per the paper, "interesting only in
//! situations where its effects can be fully controlled by other means",
//! e.g. when the producer emits uniform chunks and reader count divides
//! writer count.

use super::{Assignment, ChunkSlice, ChunkTable, ReaderLayout, Strategy};

/// See module docs.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundRobin;

impl Strategy for RoundRobin {
    fn name(&self) -> &'static str {
        "roundrobin"
    }

    fn distribute(&self, table: &ChunkTable, readers: &ReaderLayout)
        -> Assignment
    {
        let mut out = Assignment::default();
        if readers.is_empty() {
            return out;
        }
        for (i, info) in table.chunks.iter().enumerate() {
            let reader = readers.ranks[i % readers.len()].rank;
            out.per_reader
                .entry(reader)
                .or_default()
                .push(ChunkSlice::of(info));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::table_1d;
    use super::super::verify_complete;
    use super::*;

    #[test]
    fn deals_cyclically_and_completely() {
        let table = table_1d(&[
            (10, 0, "a"), (10, 1, "a"), (10, 2, "b"), (10, 3, "b"),
            (10, 4, "c"),
        ]);
        let readers = ReaderLayout::local(2).unwrap();
        let a = RoundRobin.distribute(&table, &readers);
        verify_complete(&table, &a).unwrap();
        assert_eq!(a.slices(0).len(), 3); // chunks 0, 2, 4
        assert_eq!(a.slices(1).len(), 2); // chunks 1, 3
    }

    #[test]
    fn never_splits_chunks_perfect_alignment() {
        let table = table_1d(&[(7, 0, "a"), (13, 1, "a"), (29, 2, "b")]);
        let a =
            RoundRobin.distribute(&table, &ReaderLayout::local(2).unwrap());
        for slices in a.per_reader.values() {
            for s in slices {
                assert!(table
                    .chunks
                    .iter()
                    .any(|c| c.chunk == s.chunk && c.source_rank
                         == s.source_rank));
            }
        }
    }

    #[test]
    fn imbalance_with_uneven_chunks() {
        // One huge chunk lands on reader 0: balancing is forgone.
        let table = table_1d(&[(1000, 0, "a"), (1, 1, "a")]);
        let a =
            RoundRobin.distribute(&table, &ReaderLayout::local(2).unwrap());
        assert_eq!(a.elements_for(0), 1000);
        assert_eq!(a.elements_for(1), 1);
    }

    #[test]
    fn empty_readers_yield_empty_assignment() {
        let table = table_1d(&[(4, 0, "a")]);
        let a = RoundRobin.distribute(&table, &ReaderLayout::default());
        assert_eq!(a.total_slices(), 0);
    }

    #[test]
    fn more_readers_than_chunks() {
        let table = table_1d(&[(4, 0, "a"), (4, 1, "a")]);
        let a =
            RoundRobin.distribute(&table, &ReaderLayout::local(5).unwrap());
        verify_complete(&table, &a).unwrap();
        assert!(a.slices(2).is_empty());
    }
}
