//! Dataset-slicing distribution (§3.2, second algorithm).
//!
//! Pre-assigns contiguous hyperslabs of the dataset — cut along the
//! slowest dimension — to reader ranks, then intersects the written
//! chunks with each rank's slab. Optimizes *balancing* (slabs are equal
//! to within one row); *locality* falls out when the producer's rank
//! order correlates with the problem domain (true for PIConGPU without
//! load balancing, §4.3), and *alignment* is partially kept because only
//! `n_readers - 1` cuts are introduced.

use super::{Assignment, ChunkSlice, ChunkTable, ReaderLayout, Strategy};
use crate::openpmd::chunk::Chunk;

/// See module docs.
#[derive(Clone, Copy, Debug, Default)]
pub struct Hyperslabs;

impl Hyperslabs {
    /// The slab (offset, extent) along dim 0 for reader index `i` of `n`,
    /// over a dataset of `rows` rows: balanced to within one row.
    pub fn slab(rows: u64, n: u64, i: u64) -> (u64, u64) {
        let base = rows / n;
        let rem = rows % n;
        // First `rem` readers get one extra row.
        let start = i * base + i.min(rem);
        let len = base + u64::from(i < rem);
        (start, len)
    }
}

impl Strategy for Hyperslabs {
    fn name(&self) -> &'static str {
        "hyperslabs"
    }

    fn distribute(&self, table: &ChunkTable, readers: &ReaderLayout)
        -> Assignment
    {
        let mut out = Assignment::default();
        let n = readers.len() as u64;
        if n == 0 || table.dataset_extent.is_empty() {
            return out;
        }
        let rows = table.dataset_extent[0];
        for (i, reader) in readers.ranks.iter().enumerate() {
            let (start, len) = Self::slab(rows, n, i as u64);
            if len == 0 {
                continue;
            }
            let mut slab_off = vec![0u64; table.dataset_extent.len()];
            slab_off[0] = start;
            let mut slab_ext = table.dataset_extent.clone();
            slab_ext[0] = len;
            let slab = Chunk::new(slab_off, slab_ext);
            for info in &table.chunks {
                if let Some(inter) = info.chunk.intersect(&slab) {
                    out.per_reader
                        .entry(reader.rank)
                        .or_default()
                        .push(ChunkSlice::with_chunk(info, inter));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::table_1d;
    use super::super::verify_complete;
    use super::*;

    #[test]
    fn slabs_partition_rows() {
        for rows in [0u64, 1, 7, 100, 101, 4096] {
            for n in [1u64, 2, 3, 7, 64] {
                let mut next = 0;
                let mut total = 0;
                for i in 0..n {
                    let (start, len) = Hyperslabs::slab(rows, n, i);
                    assert_eq!(start, next, "rows={rows} n={n} i={i}");
                    next = start + len;
                    total += len;
                }
                assert_eq!(total, rows);
            }
        }
    }

    #[test]
    fn balanced_to_one_row() {
        let (_, a) = (0, Hyperslabs::slab(103, 4, 0).1);
        let (_, b) = (0, Hyperslabs::slab(103, 4, 3).1);
        assert!(a - b <= 1);
    }

    #[test]
    fn complete_and_balanced_on_uniform_chunks() {
        let table = table_1d(&[
            (100, 0, "a"), (100, 1, "a"), (100, 2, "b"), (100, 3, "b"),
        ]);
        let readers = ReaderLayout::local(4).unwrap();
        let a = Hyperslabs.distribute(&table, &readers);
        verify_complete(&table, &a).unwrap();
        for r in 0..4 {
            assert_eq!(a.elements_for(r), 100);
        }
        // Aligned case: cuts coincide with chunk boundaries -> 1 slice
        // per reader.
        assert_eq!(a.total_slices(), 4);
    }

    #[test]
    fn misaligned_cuts_split_chunks() {
        let table = table_1d(&[(10, 0, "a"), (10, 1, "a")]);
        let a =
            Hyperslabs.distribute(&table, &ReaderLayout::local(3).unwrap());
        verify_complete(&table, &a).unwrap();
        // 20 rows over 3 readers: 7, 7, 6.
        assert_eq!(a.elements_for(0), 7);
        assert_eq!(a.elements_for(1), 7);
        assert_eq!(a.elements_for(2), 6);
        // Reader 1's slab [7, 14) spans the chunk boundary at 10.
        assert_eq!(a.slices(1).len(), 2);
    }

    #[test]
    fn two_dim_slices_along_first_dim() {
        use crate::openpmd::chunk::WrittenChunkInfo;
        let table = ChunkTable {
            dataset_extent: vec![8, 16],
            chunks: vec![
                WrittenChunkInfo::new(
                    Chunk::new(vec![0, 0], vec![4, 16]), 0, "a"),
                WrittenChunkInfo::new(
                    Chunk::new(vec![4, 0], vec![4, 16]), 1, "a"),
            ],
        };
        let a =
            Hyperslabs.distribute(&table, &ReaderLayout::local(2).unwrap());
        verify_complete(&table, &a).unwrap();
        assert_eq!(a.elements_for(0), 64);
        assert_eq!(a.elements_for(1), 64);
        // Full rows: the second dimension is never cut.
        for slices in a.per_reader.values() {
            for s in slices {
                assert_eq!(s.chunk.extent[1], 16);
            }
        }
    }

    #[test]
    fn more_readers_than_rows() {
        let table = table_1d(&[(3, 0, "a")]);
        let a =
            Hyperslabs.distribute(&table, &ReaderLayout::local(5).unwrap());
        verify_complete(&table, &a).unwrap();
        let nonempty = (0..5).filter(|r| a.elements_for(*r) > 0).count();
        assert_eq!(nonempty, 3);
    }
}
