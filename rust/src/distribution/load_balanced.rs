//! Cost-aware load-balanced distribution: Longest-Processing-Time.
//!
//! Deals whole written chunks like Round-Robin (perfect *alignment*),
//! but greedily: chunks are sorted by descending byte cost and each is
//! assigned to the currently least-loaded reader — Graham's LPT
//! list-scheduling heuristic (1969), whose makespan is within 4/3 of
//! optimal. The cost of a chunk is its **announced staged byte size**
//! ([`crate::openpmd::chunk::WrittenChunkInfo::encoded_bytes`], set by
//! every writer after its operator chain ran), so when compression is
//! active the strategy balances the bytes that actually cross the wire,
//! not the pre-compression element counts; without announced sizes it
//! falls back to element counts.
//!
//! Compared to the paper's strategies: Binpacking bounds the worst
//! reader at 2x ideal but cuts chunks; Round-Robin never cuts but can
//! put every large chunk on one reader. LPT never cuts *and* tracks the
//! loaded sizes — the right default when writers emit skewed chunks
//! (load-balanced producers, §4.3) and readers must not straggle.

use super::{Assignment, ChunkSlice, ChunkTable, ReaderLayout, Strategy};

/// See module docs.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadBalanced;

impl Strategy for LoadBalanced {
    fn name(&self) -> &'static str {
        "loadbalanced"
    }

    fn distribute(&self, table: &ChunkTable, readers: &ReaderLayout)
        -> Assignment
    {
        let mut out = Assignment::default();
        if readers.is_empty() {
            return out;
        }
        // Whole chunks, largest first; ties broken by table order so
        // the assignment is deterministic for identical inputs (the
        // fleet's shared-plan contract).
        let mut order: Vec<(usize, ChunkSlice)> = table
            .chunks
            .iter()
            .map(ChunkSlice::of)
            .enumerate()
            .collect();
        order.sort_by_key(|(i, s)| (std::cmp::Reverse(s.cost), *i));
        // Least-loaded reader per chunk (linear scan: reader counts are
        // small; the table scan above dominates).
        let mut load = vec![0u64; readers.len()];
        for (_, slice) in order {
            let (idx, _) = load
                .iter()
                .enumerate()
                .min_by_key(|(i, l)| (**l, *i))
                .expect("non-empty layout checked above");
            load[idx] += slice.cost;
            out.per_reader
                .entry(readers.ranks[idx].rank)
                .or_default()
                .push(slice);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::table_1d;
    use super::super::{verify_complete, RoundRobin};
    use super::*;
    use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};

    #[test]
    fn complete_and_whole_chunks_only() {
        let table = table_1d(&[
            (37, 0, "a"), (91, 1, "a"), (5, 2, "b"), (128, 3, "b"),
            (64, 4, "c"),
        ]);
        let readers = ReaderLayout::local(3).unwrap();
        let a = LoadBalanced.distribute(&table, &readers);
        verify_complete(&table, &a).unwrap();
        // Perfect alignment: every slice is a written chunk.
        for slices in a.per_reader.values() {
            for s in slices {
                assert!(table.chunks.iter().any(
                    |c| c.chunk == s.chunk && c.source_rank == s.source_rank
                ));
            }
        }
    }

    #[test]
    fn beats_round_robin_on_skewed_chunks() {
        // One huge chunk plus many small ones: RoundRobin piles the big
        // chunk and half the small ones on reader 0; LPT gives the big
        // chunk a reader of its own.
        let table = table_1d(&[
            (1000, 0, "a"), (100, 1, "a"), (100, 2, "a"), (100, 3, "a"),
            (100, 4, "a"), (100, 5, "a"),
        ]);
        let readers = ReaderLayout::local(2).unwrap();
        let lpt = LoadBalanced.distribute(&table, &readers);
        let rr = RoundRobin.distribute(&table, &readers);
        verify_complete(&table, &lpt).unwrap();
        assert_eq!(lpt.max_cost(&readers), 1000);
        assert_eq!(rr.max_cost(&readers), 1000 + 2 * 100);
        assert!(lpt.max_cost(&readers) < rr.max_cost(&readers));
    }

    #[test]
    fn balances_announced_bytes_not_elements() {
        // Two chunks of equal element count but 8x different staged
        // sizes (one compressed well), plus two fillers. Balancing by
        // elements pairs the two equal-element chunks arbitrarily;
        // balancing by bytes must give the 8000-byte chunk its own
        // reader.
        let mk = |off: u64, n: u64, rank: usize, bytes: u64| {
            WrittenChunkInfo::new(Chunk::new(vec![off], vec![n]), rank, "h")
                .with_encoded_bytes(bytes)
        };
        let table = ChunkTable {
            dataset_extent: vec![400],
            chunks: vec![
                mk(0, 100, 0, 8000),
                mk(100, 100, 1, 1000),
                mk(200, 100, 2, 1000),
                mk(300, 100, 3, 1000),
            ],
        };
        let readers = ReaderLayout::local(2).unwrap();
        let a = LoadBalanced.distribute(&table, &readers);
        verify_complete(&table, &a).unwrap();
        assert_eq!(a.max_cost(&readers), 8000);
        // The three cheap chunks share the other reader.
        let loads: Vec<u64> =
            (0..2).map(|r| a.cost_for(r)).collect();
        assert!(loads.contains(&8000) && loads.contains(&3000),
                "{loads:?}");
    }

    #[test]
    fn deterministic_under_cost_ties() {
        let table = table_1d(&[
            (50, 0, "a"), (50, 1, "a"), (50, 2, "a"), (50, 3, "a"),
        ]);
        let readers = ReaderLayout::local(3).unwrap();
        let a = LoadBalanced.distribute(&table, &readers);
        let b = LoadBalanced.distribute(&table, &readers);
        for r in 0..3 {
            assert_eq!(a.slices(r), b.slices(r));
        }
    }

    #[test]
    fn empty_table_and_single_reader() {
        let empty = table_1d(&[]);
        let readers = ReaderLayout::local(2).unwrap();
        assert_eq!(
            LoadBalanced.distribute(&empty, &readers).total_slices(), 0);
        let table = table_1d(&[(10, 0, "a"), (20, 1, "b")]);
        let solo = ReaderLayout::local(1).unwrap();
        let a = LoadBalanced.distribute(&table, &solo);
        verify_complete(&table, &a).unwrap();
        assert_eq!(a.elements_for(0), 30);
    }

    #[test]
    fn empty_readers_yield_empty_assignment() {
        let table = table_1d(&[(4, 0, "a")]);
        let a = LoadBalanced.distribute(&table, &ReaderLayout::default());
        assert_eq!(a.total_slices(), 0);
    }
}
