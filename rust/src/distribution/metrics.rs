//! Assignment quality metrics: quantifying §3.1's properties for any
//! assignment, used by the ablation bench (`micro_distribution`) and the
//! DES cost model.

use std::collections::{BTreeMap, BTreeSet};

use super::{Assignment, ChunkTable, ReaderLayout};

/// Quality report for one assignment.
#[derive(Clone, Debug, PartialEq)]
pub struct Quality {
    /// max over readers of (assigned / ideal); 1.0 = perfectly balanced.
    /// The binpacking guarantee bounds this by 2.0.
    pub balance_factor: f64,
    /// Fraction of assigned elements whose writer is on the reader's host
    /// (1.0 = all communication node-local).
    pub locality_fraction: f64,
    /// Written chunks per assigned slice (≤ 1.0; 1.0 = no chunk was
    /// split). The paper's *alignment*.
    pub alignment: f64,
    /// Mean number of distinct writer partners per (non-idle) reader —
    /// the "number of communication partners" §4.3 identifies as the
    /// driver of strategy (2)'s poor performance.
    pub mean_partners: f64,
    /// Max writer partners over readers.
    pub max_partners: usize,
}

/// Compute the [`Quality`] of `assignment` for `table` and `readers`.
pub fn quality(
    table: &ChunkTable,
    readers: &ReaderLayout,
    assignment: &Assignment,
) -> Quality {
    let n = readers.len().max(1) as f64;
    let total: u64 = table.total_elements();
    let ideal = (total as f64 / n).max(1.0);

    let host_of: BTreeMap<usize, &str> = readers
        .ranks
        .iter()
        .map(|r| (r.rank, r.hostname.as_str()))
        .collect();

    let mut max_load = 0u64;
    let mut local_elems = 0u64;
    let mut partner_counts = Vec::new();
    for (reader, slices) in &assignment.per_reader {
        let load: u64 = slices.iter().map(|s| s.chunk.num_elements()).sum();
        max_load = max_load.max(load);
        let host = host_of.get(reader).copied().unwrap_or("");
        local_elems += slices
            .iter()
            .filter(|s| s.source_host == host)
            .map(|s| s.chunk.num_elements())
            .sum::<u64>();
        let partners: BTreeSet<usize> =
            slices.iter().map(|s| s.source_rank).collect();
        if !slices.is_empty() {
            partner_counts.push(partners.len());
        }
    }

    let slices = assignment.total_slices();
    Quality {
        balance_factor: if total == 0 {
            1.0
        } else {
            max_load as f64 / ideal
        },
        locality_fraction: if total == 0 {
            1.0
        } else {
            local_elems as f64 / total as f64
        },
        alignment: if slices == 0 {
            1.0
        } else {
            table.chunks.len() as f64 / slices as f64
        },
        mean_partners: if partner_counts.is_empty() {
            0.0
        } else {
            partner_counts.iter().sum::<usize>() as f64
                / partner_counts.len() as f64
        },
        max_partners: partner_counts.into_iter().max().unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::table_1d;
    use super::super::{
        Binpacking, ByHostname, Hyperslabs, ReaderLayout, RoundRobin,
        Strategy,
    };
    use super::*;
    use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};

    fn node_table(nodes: usize, per_node: usize, size: u64) -> ChunkTable {
        let mut chunks = Vec::new();
        let mut off = 0;
        for node in 0..nodes {
            for w in 0..per_node {
                chunks.push(WrittenChunkInfo::new(
                    Chunk::new(vec![off], vec![size]),
                    node * per_node + w,
                    format!("node{node:04}"),
                ));
                off += size;
            }
        }
        ChunkTable { dataset_extent: vec![off], chunks }
    }

    #[test]
    fn perfect_case_metrics() {
        let table = node_table(2, 2, 100);
        let readers = ReaderLayout::nodes(2, 2).unwrap();
        let a = ByHostname::paper_default().distribute(&table, &readers);
        let q = quality(&table, &readers, &a);
        assert!((q.balance_factor - 1.0).abs() < 1e-9, "{q:?}");
        assert_eq!(q.locality_fraction, 1.0);
        assert_eq!(q.alignment, 1.0);
        assert_eq!(q.max_partners, 1);
    }

    #[test]
    fn round_robin_alignment_one_but_poor_balance() {
        let table = table_1d(&[(1000, 0, "a"), (10, 1, "a"), (10, 2, "a")]);
        let readers = ReaderLayout::local(3).unwrap();
        let a = RoundRobin.distribute(&table, &readers);
        let q = quality(&table, &readers, &a);
        assert_eq!(q.alignment, 1.0);
        assert!(q.balance_factor > 2.0, "{q:?}");
    }

    #[test]
    fn hyperslabs_balance_near_one() {
        let table = node_table(4, 2, 128);
        let readers = ReaderLayout::nodes(4, 2).unwrap();
        let a = Hyperslabs.distribute(&table, &readers);
        let q = quality(&table, &readers, &a);
        assert!(q.balance_factor <= 1.01, "{q:?}");
    }

    #[test]
    fn binpacking_ignores_topology_many_partners() {
        // With chunk sizes misaligned to the ideal, binpacking crosses
        // node boundaries; by-hostname does not.
        let mut table = node_table(8, 3, 97);
        // Perturb sizes so bins straddle nodes.
        for (i, c) in table.chunks.iter_mut().enumerate() {
            c.chunk.extent[0] = 60 + ((i * 37) % 80) as u64;
        }
        let readers = ReaderLayout::nodes(8, 3).unwrap();
        let bp = quality(&table, &readers,
                         &Binpacking.distribute(&table, &readers));
        let bh = quality(
            &table,
            &readers,
            &ByHostname::paper_default().distribute(&table, &readers),
        );
        assert_eq!(bh.locality_fraction, 1.0);
        assert!(bp.locality_fraction < 1.0, "{bp:?}");
    }

    #[test]
    fn empty_assignment_quality_is_neutral() {
        let table = ChunkTable { dataset_extent: vec![0], chunks: vec![] };
        let readers = ReaderLayout::local(2).unwrap();
        let q = quality(&table, &readers, &Default::default());
        assert_eq!(q.balance_factor, 1.0);
        assert_eq!(q.locality_fraction, 1.0);
        assert_eq!(q.max_partners, 0);
    }
}
