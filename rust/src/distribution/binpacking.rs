//! Binpacking distribution (§3.2, third algorithm).
//!
//! Computes the ideal data volume per reader, slices incoming chunks so no
//! piece exceeds it, then packs the pieces with the **Next-Fit**
//! approximation (Johnson 1973): keep one open bin; if the next piece
//! does not fit, close the bin and open a new one. Next-Fit uses at most
//! twice the optimal number of bins; mapped onto readers (bin `b` →
//! reader `b mod n`) this yields the paper's guarantee that each reader
//! receives **at most double the ideal amount** — a worst case the
//! paper's Fig. 9 actually observes in practice, and that
//! `benches/fig9_loadtimes.rs` reproduces.
//!
//! Compared to Round-Robin it adds a balancing guarantee; compared to
//! Hyperslabs it never cuts a chunk below the piece size, keeping *some*
//! alignment. Both guarantees are the weakened forms discussed in §3.2.

use super::{Assignment, ChunkSlice, ChunkTable, ReaderLayout, Strategy};
use crate::openpmd::chunk::WrittenChunkInfo;

/// See module docs.
#[derive(Clone, Copy, Debug, Default)]
pub struct Binpacking;

impl Binpacking {
    /// Slice `info` into pieces of at most `ideal` elements, cutting only
    /// along dimension 0 (whole hyperplanes — matches how ADIOS chunks
    /// can be subset cheaply). A single row larger than `ideal` stays
    /// whole (cannot be cut at this granularity).
    fn slice_chunk(
        info: &WrittenChunkInfo,
        ideal: u64,
        out: &mut Vec<ChunkSlice>,
    ) {
        let total = info.chunk.num_elements();
        if total <= ideal {
            out.push(ChunkSlice::of(info));
            return;
        }
        let row: u64 = info.chunk.extent[1..].iter().product::<u64>().max(1);
        let rows_per_piece = (ideal / row).max(1);
        let mut rest = info.chunk.clone();
        loop {
            if rest.extent[0] <= rows_per_piece {
                out.push(ChunkSlice::with_chunk(info, rest));
                return;
            }
            let (piece, remainder) = rest
                .split_rows(rows_per_piece)
                .expect("rows_per_piece < extent checked above");
            out.push(ChunkSlice::with_chunk(info, piece));
            rest = remainder;
        }
    }
}

impl Strategy for Binpacking {
    fn name(&self) -> &'static str {
        "binpacking"
    }

    fn distribute(&self, table: &ChunkTable, readers: &ReaderLayout)
        -> Assignment
    {
        let mut out = Assignment::default();
        let n = readers.len() as u64;
        if n == 0 {
            return out;
        }
        let total = table.total_elements();
        if total == 0 {
            return out;
        }
        let ideal = total.div_ceil(n);

        // Phase 1: size-fit the chunks.
        let mut pieces = Vec::with_capacity(table.chunks.len());
        for info in &table.chunks {
            Self::slice_chunk(info, ideal, &mut pieces);
        }

        // Phase 2: Next-Fit into bins of capacity `ideal`.
        let mut bin = 0u64;
        let mut fill = 0u64;
        for piece in pieces {
            let size = piece.chunk.num_elements();
            if fill > 0 && fill + size > ideal {
                bin += 1;
                fill = 0;
            }
            fill += size;
            let reader = readers.ranks[(bin % n) as usize].rank;
            out.push(reader, piece);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::table_1d;
    use super::super::verify_complete;
    use super::*;

    #[test]
    fn complete_on_mixed_sizes() {
        let table = table_1d(&[
            (37, 0, "a"), (91, 1, "a"), (5, 2, "b"), (128, 3, "b"),
            (64, 4, "c"),
        ]);
        let readers = ReaderLayout::local(3).unwrap();
        let a = Binpacking.distribute(&table, &readers);
        verify_complete(&table, &a).unwrap();
    }

    #[test]
    fn two_x_ideal_guarantee() {
        let table = table_1d(&[
            (100, 0, "a"), (33, 1, "a"), (77, 2, "a"), (50, 3, "b"),
            (90, 4, "b"), (10, 5, "b"), (60, 6, "c"),
        ]);
        for n in 1..=7 {
            let readers = ReaderLayout::local(n).unwrap();
            let a = Binpacking.distribute(&table, &readers);
            verify_complete(&table, &a).unwrap();
            let ideal = table.total_elements().div_ceil(n as u64);
            for r in 0..n {
                assert!(
                    a.elements_for(r) <= 2 * ideal,
                    "reader {r} got {} > 2*ideal={} (n={n})",
                    a.elements_for(r),
                    2 * ideal
                );
            }
        }
    }

    #[test]
    fn pieces_never_exceed_ideal() {
        let table = table_1d(&[(1000, 0, "a")]);
        let readers = ReaderLayout::local(4).unwrap();
        let a = Binpacking.distribute(&table, &readers);
        let ideal = 1000u64.div_ceil(4);
        for slices in a.per_reader.values() {
            for s in slices {
                assert!(s.chunk.num_elements() <= ideal);
            }
        }
        verify_complete(&table, &a).unwrap();
    }

    #[test]
    fn small_chunks_stay_whole() {
        // alignment: chunks below ideal are never split.
        let table = table_1d(&[(10, 0, "a"), (20, 1, "a"), (15, 2, "b")]);
        let a =
            Binpacking.distribute(&table, &ReaderLayout::local(2).unwrap());
        verify_complete(&table, &a).unwrap();
        for slices in a.per_reader.values() {
            for s in slices {
                assert!(table.chunks.iter().any(
                    |c| c.chunk == s.chunk || c.chunk.contains(&s.chunk)
                ));
            }
        }
        // ideal = 23, so 20 and 15 stay whole; 10 stays whole trivially.
        assert_eq!(a.total_slices(), 3);
    }

    #[test]
    fn single_reader_takes_everything() {
        let table = table_1d(&[(10, 0, "a"), (20, 1, "b")]);
        let a =
            Binpacking.distribute(&table, &ReaderLayout::local(1).unwrap());
        verify_complete(&table, &a).unwrap();
        assert_eq!(a.elements_for(0), 30);
    }

    #[test]
    fn two_dim_splits_along_rows_only() {
        use crate::openpmd::chunk::{Chunk, WrittenChunkInfo};
        let table = ChunkTable {
            dataset_extent: vec![100, 8],
            chunks: vec![WrittenChunkInfo::new(
                Chunk::new(vec![0, 0], vec![100, 8]),
                0,
                "a",
            )],
        };
        let a =
            Binpacking.distribute(&table, &ReaderLayout::local(4).unwrap());
        verify_complete(&table, &a).unwrap();
        for slices in a.per_reader.values() {
            for s in slices {
                assert_eq!(s.chunk.extent[1], 8, "inner dim was cut");
            }
        }
    }

    #[test]
    fn empty_table_is_fine() {
        let table = table_1d(&[]);
        let a =
            Binpacking.distribute(&table, &ReaderLayout::local(3).unwrap());
        assert_eq!(a.total_slices(), 0);
    }
}
