//! PJRT runtime (S14): load and execute the AOT-lowered JAX/Pallas
//! artifacts from the rust hot path.
//!
//! `make artifacts` runs python exactly once, producing
//! `artifacts/<name>.hlo.txt` (HLO *text* — the interchange format the
//! bundled xla_extension 0.5.1 accepts, see `python/compile/aot.py`) plus
//! `artifacts/meta.json` with the fixed I/O shapes. This module compiles
//! each artifact on the PJRT CPU client at startup; after that the binary
//! is self-contained — python never runs at request time.
//!
//! Batching: the artifacts are lowered at fixed shapes (e.g. 4096 atoms).
//! [`Exec::run_f32_padded`] pads the last batch with zero-weight entries,
//! which is exact for every entry point (zero weight ⇒ zero contribution
//! to the kinematic sum / histogram; padding particles in `pic_step` are
//! simply discarded on output).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::util::json::{parse, Json};
use crate::util::sync::{classes, OrderedMutex};

/// Shape metadata of one artifact entry point (from meta.json).
#[derive(Clone, Debug, PartialEq)]
pub struct EntryMeta {
    pub name: String,
    pub inputs: Vec<Vec<u64>>,
    pub outputs: Vec<Vec<u64>>,
}

impl EntryMeta {
    fn from_json(name: &str, j: &Json) -> Result<EntryMeta> {
        let shapes = |key: &str| -> Result<Vec<Vec<u64>>> {
            j.get(key)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow::anyhow!("{name}: missing {key}"))?
                .iter()
                .map(|s| {
                    s.as_u64_vec().ok_or_else(|| {
                        anyhow::anyhow!("{name}: bad shape in {key}")
                    })
                })
                .collect()
        };
        Ok(EntryMeta {
            name: name.to_string(),
            inputs: shapes("inputs")?,
            outputs: shapes("outputs")?,
        })
    }

    /// Elements per input tensor.
    pub fn input_elems(&self, i: usize) -> usize {
        self.inputs[i].iter().product::<u64>() as usize
    }

    pub fn output_elems(&self, i: usize) -> usize {
        self.outputs[i].iter().product::<u64>() as usize
    }
}

/// One compiled artifact.
pub struct Exec {
    pub meta: EntryMeta,
    exe: xla::PjRtLoadedExecutable,
    /// PJRT executables are not re-entrant per instance; serialize calls.
    lock: OrderedMutex<()>,
}

impl Exec {
    /// Execute with f32 inputs matching the artifact's exact shapes.
    /// Returns the flattened f32 outputs.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: {} inputs given, artifact takes {}",
                self.meta.name,
                inputs.len(),
                self.meta.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, data) in inputs.iter().enumerate() {
            let want = self.meta.input_elems(i);
            if data.len() != want {
                bail!(
                    "{}: input {i} has {} elements, artifact wants {want}",
                    self.meta.name,
                    data.len()
                );
            }
            let dims: Vec<i64> =
                self.meta.inputs[i].iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let _guard = self.lock.lock()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        drop(_guard);
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: artifact returned {} outputs, meta says {}",
                self.meta.name,
                parts.len(),
                self.meta.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (i, p) in parts.into_iter().enumerate() {
            let v = p.to_vec::<f32>()?;
            if v.len() != self.meta.output_elems(i) {
                bail!("{}: output {i} has wrong size", self.meta.name);
            }
            out.push(v);
        }
        Ok(out)
    }
}

/// The artifact registry.
pub struct Runtime {
    /// Owns the PJRT device plugin; executables stay valid only while
    /// it lives, so the registry keeps it even though nothing reads it.
    _client: xla::PjRtClient,
    execs: HashMap<String, Arc<Exec>>,
    dir: PathBuf,
}

impl Runtime {
    /// Default artifacts directory: `$OPENPMD_STREAM_ARTIFACTS` or
    /// `artifacts/` relative to the working directory.
    pub fn default_dir() -> PathBuf {
        std::env::var("OPENPMD_STREAM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load and compile every artifact listed in `meta.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        if !meta_path.exists() {
            bail!(
                "no artifacts at {} — run `make artifacts` first \
                 (python AOT lowering)",
                dir.display()
            );
        }
        let meta_text = std::fs::read_to_string(&meta_path)?;
        let meta =
            parse(&meta_text).map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
        let obj = meta
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("meta.json is not an object"))?;

        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        let mut execs = HashMap::new();
        for (name, entry) in obj {
            let hlo = dir.join(format!("{name}.hlo.txt"));
            if !hlo.exists() {
                bail!("meta.json names {name} but {} is missing",
                      hlo.display());
            }
            let proto = xla::HloModuleProto::from_text_file(
                hlo.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| anyhow::anyhow!("parsing {name}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
            execs.insert(
                name.clone(),
                Arc::new(Exec {
                    meta: EntryMeta::from_json(name, entry)?,
                    exe,
                    lock: OrderedMutex::new(&classes::RUNTIME_EXEC, ()),
                }),
            );
        }
        Ok(Runtime { _client: client, execs, dir })
    }

    /// Load from the default directory.
    pub fn load_default() -> Result<Runtime> {
        Self::load(Self::default_dir())
    }

    pub fn get(&self, name: &str) -> Result<Arc<Exec>> {
        self.execs.get(name).cloned().ok_or_else(|| {
            anyhow::anyhow!(
                "artifact {name:?} not found in {} (have: {:?})",
                self.dir.display(),
                self.names()
            )
        })
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.execs.keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        // Tests run from the crate root; artifacts exist once
        // `make artifacts` ran. Skip (not fail) if absent so `cargo test`
        // works on a fresh checkout.
        let d = Runtime::default_dir();
        d.join("meta.json").exists().then_some(d)
    }

    #[test]
    fn missing_dir_gives_actionable_error() {
        match Runtime::load("/nonexistent-artifacts") {
            Err(err) => {
                assert!(format!("{err:#}").contains("make artifacts"))
            }
            Ok(_) => panic!("expected an error"),
        }
    }

    #[test]
    fn loads_all_artifacts() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load(dir).unwrap();
        let names = rt.names();
        for want in ["saxs", "pic_step", "binning"] {
            assert!(names.iter().any(|n| n == want), "{names:?}");
        }
    }

    #[test]
    fn saxs_artifact_runs_and_matches_physics() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load(dir).unwrap();
        let saxs = rt.get("saxs").unwrap();
        let n = saxs.meta.input_elems(1); // [1, N] weights
        let q = saxs.meta.output_elems(0);
        // One atom at the origin with weight 1, all others weight 0:
        // I(q) == 1 for every q.
        let pos = vec![0.0f32; n * 3];
        let mut w = vec![0.0f32; n];
        w[0] = 1.0;
        let mut q_t = vec![0.0f32; 3 * q];
        for (i, x) in q_t.iter_mut().enumerate() {
            *x = (i % 7) as f32 * 0.1;
        }
        let out = saxs.run_f32(&[&pos, &w, &q_t]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), q);
        for (i, &v) in out[0].iter().enumerate() {
            assert!((v - 1.0).abs() < 1e-4, "I(q[{i}]) = {v}");
        }
    }

    #[test]
    fn pic_step_artifact_conserves_momentum_without_fields() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load(dir).unwrap();
        let pic = rt.get("pic_step").unwrap();
        let n = pic.meta.inputs[0][0] as usize;
        let g = pic.meta.inputs[2][0] as usize;
        let pos: Vec<f32> = (0..n * 3).map(|i| (i % 64) as f32).collect();
        let mom: Vec<f32> =
            (0..n * 3).map(|i| ((i % 7) as f32 - 3.0) * 0.1).collect();
        let zeros = vec![0.0f32; g * g * 3];
        let out = pic.run_f32(&[&pos, &mom, &zeros, &zeros]).unwrap();
        assert_eq!(out.len(), 2);
        // Zero fields: momentum unchanged.
        for (a, b) in out[1].iter().zip(&mom) {
            assert!((a - b).abs() < 1e-5);
        }
        // Positions moved and stayed in the box.
        assert!(out[0].iter().all(|&x| (0.0..64.0).contains(&x)));
    }

    #[test]
    fn wrong_input_shapes_rejected() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::load(dir).unwrap();
        let saxs = rt.get("saxs").unwrap();
        assert!(saxs.run_f32(&[&[0.0], &[0.0], &[0.0]]).is_err());
        assert!(saxs.run_f32(&[&[0.0]]).is_err());
        assert!(rt.get("nope").is_err());
    }
}
