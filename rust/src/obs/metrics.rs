//! Process-wide registry of named counters, gauges and log-bucketed
//! histograms.
//!
//! Metrics are always on: an increment is one relaxed atomic add, so
//! report structs can read them without a "metrics enabled" mode.
//! Handles are interned — [`counter`]/[`gauge`]/[`histogram`] return
//! the same `&'static` instance for the same name — and call sites
//! cache the handle in a `Lazy` static so the registry lock is taken
//! once per site, never per increment:
//!
//! ```ignore
//! static FRAMES: Lazy<&'static Counter> =
//!     Lazy::new(|| metrics::counter("wire.frames_sent"));
//! FRAMES.add(1);
//! ```
//!
//! [`snapshot_metrics`] captures every registered instrument at once;
//! [`Snapshot::delta`] subtracts an earlier snapshot, which is how
//! per-run numbers are derived from process-wide totals (tests and
//! the pipe's `--metrics-interval` emission both rely on it).
//!
//! Histograms are log₂-bucketed: bucket `i` counts samples in
//! `[2^(i-1), 2^i)` (bucket 0 counts zeros), with exact `sum` and
//! `count` alongside — enough for the backoff/lock-wait/latency
//! distributions the exporters print without storing samples.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use once_cell::sync::Lazy;

use crate::util::sync::{classes, OrderedMutex};

/// Number of log₂ buckets; covers the full `u64` sample range.
pub const HIST_BUCKETS: usize = 65;

/// A monotonically increasing counter.
pub struct Counter {
    name: &'static str,
    v: AtomicU64,
}

impl Counter {
    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// A last-value-wins gauge (queue depths, current step).
pub struct Gauge {
    name: &'static str,
    v: AtomicU64,
}

impl Gauge {
    #[inline]
    pub fn set(&self, n: u64) {
        self.v.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

/// A log₂-bucketed histogram with exact sum and count.
pub struct Histogram {
    name: &'static str,
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    #[inline]
    pub fn record(&self, sample: u64) {
        let idx = (64 - sample.leading_zeros()) as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(sample, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            sum: self.sum.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of one histogram.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub sum: u64,
    pub count: u64,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper edge of the highest non-empty bucket (a cheap max bound).
    pub fn max_bound(&self) -> u64 {
        match self.buckets.iter().rposition(|&c| c > 0) {
            Some(0) | None => 0,
            Some(i) if i >= 64 => u64::MAX,
            Some(i) => 1u64 << i,
        }
    }

    fn delta(&self, earlier: &HistSnapshot) -> HistSnapshot {
        HistSnapshot {
            buckets: self
                .buckets
                .iter()
                .zip(earlier.buckets.iter().chain(std::iter::repeat(&0)))
                .map(|(now, then)| now.saturating_sub(*then))
                .collect(),
            sum: self.sum.saturating_sub(earlier.sum),
            count: self.count.saturating_sub(earlier.count),
        }
    }
}

/// The interned-instrument registry. One map per instrument kind,
/// each under the obs lock class; entries are leaked to `'static` so
/// handles can live in `Lazy` statics at call sites.
struct Registry {
    counters: OrderedMutex<BTreeMap<&'static str, &'static Counter>>,
    gauges: OrderedMutex<BTreeMap<&'static str, &'static Gauge>>,
    hists: OrderedMutex<BTreeMap<&'static str, &'static Histogram>>,
}

static REGISTRY: Lazy<Registry> = Lazy::new(|| Registry {
    counters: OrderedMutex::new(&classes::OBS, BTreeMap::new()),
    gauges: OrderedMutex::new(&classes::OBS, BTreeMap::new()),
    hists: OrderedMutex::new(&classes::OBS, BTreeMap::new()),
});

/// Intern the counter named `name`.
pub fn counter(name: &'static str) -> &'static Counter {
    let fallback: fn(&'static str) -> &'static Counter = |name| {
        Box::leak(Box::new(Counter { name, v: AtomicU64::new(0) }))
    };
    match REGISTRY.counters.lock() {
        Ok(mut m) => *m
            .entry(name)
            .or_insert_with(|| fallback(name)),
        // Poisoned registry: hand out an unregistered instrument so
        // the caller keeps working (it just won't export).
        Err(_) => fallback(name),
    }
}

/// Intern the gauge named `name`.
pub fn gauge(name: &'static str) -> &'static Gauge {
    let fallback: fn(&'static str) -> &'static Gauge = |name| {
        Box::leak(Box::new(Gauge { name, v: AtomicU64::new(0) }))
    };
    match REGISTRY.gauges.lock() {
        Ok(mut m) => *m
            .entry(name)
            .or_insert_with(|| fallback(name)),
        Err(_) => fallback(name),
    }
}

/// Intern the histogram named `name`.
pub fn histogram(name: &'static str) -> &'static Histogram {
    let fallback: fn(&'static str) -> &'static Histogram = |name| {
        Box::leak(Box::new(Histogram {
            name,
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }))
    };
    match REGISTRY.hists.lock() {
        Ok(mut m) => *m
            .entry(name)
            .or_insert_with(|| fallback(name)),
        Err(_) => fallback(name),
    }
}

/// Point-in-time copy of every registered instrument.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl Snapshot {
    /// Counter value, defaulting to zero for unregistered names.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// This snapshot minus an `earlier` one: counters and histogram
    /// contents subtract (saturating), gauges keep the later value.
    pub fn delta(&self, earlier: &Snapshot) -> Snapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| {
                let then = earlier.counters.get(k).copied().unwrap_or(0);
                (k.clone(), v.saturating_sub(then))
            })
            .collect();
        let hists = self
            .hists
            .iter()
            .map(|(k, h)| {
                let then = earlier.hists.get(k);
                let d = match then {
                    Some(t) => h.delta(t),
                    None => h.clone(),
                };
                (k.clone(), d)
            })
            .collect();
        Snapshot { counters, gauges: self.gauges.clone(), hists }
    }
}

/// Capture every registered instrument. The three registry maps are
/// locked one at a time (same class, never nested). (Named uniquely —
/// not `snapshot` — so the lint concurrency pass's name-based call
/// linking cannot confuse it with `util::sync`'s debug helper.)
pub fn snapshot_metrics() -> Snapshot {
    let mut snap = Snapshot::default();
    if let Ok(m) = REGISTRY.counters.lock() {
        snap.counters = m
            .iter()
            .map(|(k, c)| (k.to_string(), c.get()))
            .collect();
    }
    if let Ok(m) = REGISTRY.gauges.lock() {
        snap.gauges =
            m.iter().map(|(k, g)| (k.to_string(), g.get())).collect();
    }
    if let Ok(m) = REGISTRY.hists.lock() {
        snap.hists = m
            .iter()
            .map(|(k, h)| (k.to_string(), h.snapshot()))
            .collect();
    }
    snap
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; tests use names unique to this
    // module and delta-based assertions so parallel suites can't
    // interfere.

    #[test]
    fn counters_intern_and_accumulate() {
        let a = counter("test_metrics.counter_a");
        let b = counter("test_metrics.counter_a");
        assert!(std::ptr::eq(a, b), "same name -> same instrument");
        let before = a.get();
        a.inc();
        a.add(9);
        assert_eq!(a.get(), before + 10);
        assert_eq!(a.name(), "test_metrics.counter_a");
    }

    #[test]
    fn gauges_hold_last_value() {
        let g = gauge("test_metrics.gauge_a");
        g.set(42);
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn histogram_buckets_by_log2() {
        let h = histogram("test_metrics.hist_a");
        let before = h.snapshot();
        h.record(0); // bucket 0
        h.record(1); // bucket 1
        h.record(2); // bucket 2
        h.record(3); // bucket 2
        h.record(1024); // bucket 11
        let d = h.snapshot().delta(&before);
        assert_eq!(d.count, 5);
        assert_eq!(d.sum, 1030);
        assert_eq!(d.buckets[0], 1);
        assert_eq!(d.buckets[1], 1);
        assert_eq!(d.buckets[2], 2);
        assert_eq!(d.buckets[11], 1);
        assert!((d.mean() - 206.0).abs() < 1e-9);
        assert_eq!(d.max_bound(), 2048);
    }

    #[test]
    fn snapshot_delta_subtracts() {
        let c = counter("test_metrics.delta_c");
        let h = histogram("test_metrics.delta_h");
        let before = snapshot_metrics();
        c.add(3);
        h.record(5);
        let d = snapshot_metrics().delta(&before);
        assert_eq!(d.counter("test_metrics.delta_c"), 3);
        assert_eq!(d.hists["test_metrics.delta_h"].count, 1);
        assert_eq!(d.counter("test_metrics.never_registered"), 0);
    }
}
