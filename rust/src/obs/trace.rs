//! Scoped tracing spans with per-thread buffers and a central
//! collector.
//!
//! A [`Span`] measures one scoped region: it stamps a monotonic start
//! time at construction and records one complete event (start,
//! duration, structured fields) into the calling thread's buffer when
//! dropped. Buffers are bounded; overflow drops the newest events and
//! counts them, so a runaway producer degrades the trace instead of
//! memory. The collector keeps a directory of every thread buffer
//! ever registered (thread exit does not lose events) and
//! [`drain`] moves everything out for export.
//!
//! Cost model: tracing is off by default, and [`span`] checks one
//! relaxed atomic before doing anything else — the disabled path
//! allocates nothing and never takes a lock, so instrumentation stays
//! compiled into release hot paths. When enabled, a record is one
//! uncontended `OrderedMutex` acquisition on a thread-owned buffer.
//!
//! Thread attribution: buffers capture the OS thread name at
//! registration, and [`set_thread_identity`] lets pipeline stages
//! override it with a fleet rank + stage label — the exporter maps
//! rank to Chrome-trace `pid` and stage to the thread name, which is
//! what makes staged overlap and straggler structure visible as a
//! Perfetto timeline.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use once_cell::sync::Lazy;

use crate::util::sync::{classes, OrderedMutex};

/// Bound on buffered events per thread; overflow increments the
/// buffer's `dropped` count instead of growing without limit.
const BUFFER_CAP: usize = 1 << 16;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Monotonic epoch all span timestamps are relative to, forced at
/// [`enable`] so timestamps start near zero for the exported trace.
static EPOCH: Lazy<Instant> = Lazy::new(Instant::now);

/// One structured field value on a span.
#[derive(Clone, Debug, PartialEq)]
pub enum FieldValue {
    U64(u64),
    F64(f64),
    Str(String),
}

impl From<u64> for FieldValue {
    fn from(v: u64) -> FieldValue {
        FieldValue::U64(v)
    }
}

impl From<usize> for FieldValue {
    fn from(v: usize) -> FieldValue {
        FieldValue::U64(v as u64)
    }
}

impl From<f64> for FieldValue {
    fn from(v: f64) -> FieldValue {
        FieldValue::F64(v)
    }
}

impl From<&str> for FieldValue {
    fn from(v: &str) -> FieldValue {
        FieldValue::Str(v.to_string())
    }
}

impl From<String> for FieldValue {
    fn from(v: String) -> FieldValue {
        FieldValue::Str(v)
    }
}

/// One recorded span: a completed scoped region on one thread.
#[derive(Clone, Debug)]
pub struct Event {
    pub name: &'static str,
    /// Microseconds since the trace epoch.
    pub start_us: u64,
    pub dur_us: u64,
    pub fields: Vec<(&'static str, FieldValue)>,
}

/// The mutable half of a thread buffer, under one obs-class lock so a
/// record is a single acquisition.
#[derive(Default)]
struct BufferInner {
    /// Fleet rank / pipeline-stage identity, when a stage declared
    /// one via [`set_thread_identity`].
    rank: Option<usize>,
    stage: Option<String>,
    events: Vec<Event>,
    dropped: u64,
}

/// One thread's span buffer, shared between the owning thread (via
/// thread-local) and the collector directory.
pub struct ThreadBuffer {
    /// Registration sequence number; the exporter's `tid`.
    seq: u64,
    /// OS thread name at registration ("fleet-r0", "staged-fetch").
    thread_name: String,
    // Field named uniquely (not `inner`): the lint pass resolves lock
    // receivers by their last path segment, and `OrderedMutex` itself
    // wraps a raw mutex field called `inner`.
    ring: OrderedMutex<BufferInner>,
}

impl ThreadBuffer {
    // Named uniquely (not `record`) so the lint pass's name-based
    // call linking cannot attribute this OBS acquisition to the
    // crate's other `.record(..)` call sites.
    fn push_event(&self, ev: Event) {
        // Own-thread buffer: uncontended except against a drain.
        if let Ok(mut b) = self.ring.lock() {
            if b.events.len() < BUFFER_CAP {
                b.events.push(ev);
            } else {
                b.dropped += 1;
            }
        }
    }
}

/// Everything drained from one thread buffer, ready for export.
pub struct ThreadDump {
    pub tid: u64,
    pub thread_name: String,
    pub rank: Option<usize>,
    pub stage: Option<String>,
    pub events: Vec<Event>,
    pub dropped: u64,
}

/// Directory of every registered thread buffer. Guarded by the same
/// obs class as the buffers, but never while one of them is locked:
/// the drain clones the `Arc` list first, then releases.
static DIRECTORY: Lazy<OrderedMutex<Vec<Arc<ThreadBuffer>>>> =
    Lazy::new(|| OrderedMutex::new(&classes::OBS, Vec::new()));

static NEXT_SEQ: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static LOCAL: RefCell<Option<Arc<ThreadBuffer>>> =
        const { RefCell::new(None) };
}

/// This thread's buffer, registering it on first use.
fn local_buffer() -> Option<Arc<ThreadBuffer>> {
    LOCAL.with(|l| {
        if let Some(buf) = l.borrow().as_ref() {
            return Some(buf.clone());
        }
        let buf = Arc::new(ThreadBuffer {
            seq: NEXT_SEQ.fetch_add(1, Ordering::Relaxed),
            thread_name: std::thread::current()
                .name()
                .unwrap_or("?")
                .to_string(),
            ring: OrderedMutex::new(
                &classes::OBS,
                BufferInner::default(),
            ),
        });
        DIRECTORY.lock().ok()?.push(buf.clone());
        *l.borrow_mut() = Some(buf.clone());
        Some(buf)
    })
}

/// Turn span recording on. Forces the trace epoch so the first span's
/// timestamp is near zero.
pub fn enable() {
    Lazy::force(&EPOCH);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn span recording off. Already-buffered events stay until the
/// next [`drain`].
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Declare this thread's pipeline identity: fleet rank (Chrome-trace
/// `pid`) and stage label (thread name in the exported timeline).
/// Call once from a worker before its first span; a no-op while
/// tracing is disabled.
pub fn set_thread_identity(rank: usize, stage: &str) {
    if !enabled() {
        return;
    }
    if let Some(buf) = local_buffer() {
        if let Ok(mut b) = buf.ring.lock() {
            b.rank = Some(rank);
            b.stage = Some(stage.to_string());
        }
    }
}

/// Open a scoped span. The returned guard records one event into the
/// calling thread's buffer when dropped; with tracing disabled it is
/// inert (no clock read, no allocation, no lock).
#[inline]
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span { name, start: None, fields: Vec::new() };
    }
    Span { name, start: Some(Instant::now()), fields: Vec::new() }
}

/// A live scoped span; see [`span`].
pub struct Span {
    name: &'static str,
    /// `None` when tracing was disabled at construction — the drop
    /// path then does nothing.
    start: Option<Instant>,
    fields: Vec<(&'static str, FieldValue)>,
}

impl Span {
    /// Attach a structured field (builder form, at open).
    pub fn with(mut self, key: &'static str, v: impl Into<FieldValue>)
        -> Span
    {
        self.set(key, v);
        self
    }

    /// Attach a structured field mid-span (e.g. a byte count known
    /// only after the work ran).
    pub fn set(&mut self, key: &'static str, v: impl Into<FieldValue>) {
        if self.start.is_some() {
            self.fields.push((key, v.into()));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let epoch = *EPOCH;
        let start_us = start
            .checked_duration_since(epoch)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        let dur_us = start.elapsed().as_micros() as u64;
        if let Some(buf) = local_buffer() {
            buf.push_event(Event {
                name: self.name,
                start_us,
                dur_us,
                fields: std::mem::take(&mut self.fields),
            });
        }
    }
}

/// Move every buffered event out of every registered thread buffer.
/// Buffers stay registered (threads keep recording into them); the
/// dump is ordered by registration sequence. Returns an empty vec if
/// the directory lock is poisoned.
pub fn drain() -> Vec<ThreadDump> {
    let buffers: Vec<Arc<ThreadBuffer>> = match DIRECTORY.lock() {
        Ok(d) => d.clone(),
        Err(_) => return Vec::new(),
    };
    // Directory guard is released; buffers are visited one at a time
    // so two obs-class locks are never held together.
    let mut out = Vec::with_capacity(buffers.len());
    for buf in buffers {
        let Ok(mut b) = buf.ring.lock() else { continue };
        out.push(ThreadDump {
            tid: buf.seq,
            thread_name: buf.thread_name.clone(),
            rank: b.rank,
            stage: b.stage.clone(),
            events: std::mem::take(&mut b.events),
            dropped: std::mem::replace(&mut b.dropped, 0),
        });
    }
    out.sort_by_key(|d| d.tid);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // The collector is process-global and `cargo test` threads share
    // it, so assertions here filter to the current thread's dump and
    // to span names unique to each test.

    fn my_dump(dumps: Vec<ThreadDump>, name_prefix: &str)
        -> Vec<Event>
    {
        dumps
            .into_iter()
            .flat_map(|d| d.events)
            .filter(|e| e.name.starts_with(name_prefix))
            .collect()
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = crate::obs::testutil::serialize();
        disable();
        {
            let mut s = span("t_disabled.outer");
            s.set("bytes", 7u64);
        }
        let evs = my_dump(drain(), "t_disabled.");
        assert!(evs.is_empty());
    }

    #[test]
    fn nesting_orders_and_contains() {
        let _g = crate::obs::testutil::serialize();
        enable();
        {
            let _outer = span("t_nest.outer").with("step", 3u64);
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _inner = span("t_nest.inner");
                std::thread::sleep(
                    std::time::Duration::from_millis(1),
                );
            }
        }
        disable();
        let evs = my_dump(drain(), "t_nest.");
        // Inner drops first, so it is recorded first.
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].name, "t_nest.inner");
        assert_eq!(evs[1].name, "t_nest.outer");
        let (inner, outer) = (&evs[0], &evs[1]);
        // Time containment: outer started first, ended last.
        assert!(outer.start_us <= inner.start_us);
        assert!(
            outer.start_us + outer.dur_us
                >= inner.start_us + inner.dur_us
        );
        assert_eq!(
            outer.fields,
            vec![("step", FieldValue::U64(3))]
        );
    }

    #[test]
    fn threads_never_interleave_partial_records() {
        let _g = crate::obs::testutil::serialize();
        enable();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        let _s = span("t_interleave.work")
                            .with("thread", t as u64)
                            .with("i", i)
                            .with("check", t as u64 * 1000 + i);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        disable();
        // Every record is internally consistent (all three fields
        // from the same thread+iteration) and each thread's buffer
        // holds only its own records, in order.
        let mut seen = 0;
        for d in drain() {
            let mut last_i = None;
            let mut thread_of_buf = None;
            for e in d
                .events
                .iter()
                .filter(|e| e.name == "t_interleave.work")
            {
                let f: std::collections::BTreeMap<_, _> = e
                    .fields
                    .iter()
                    .map(|(k, v)| (*k, v.clone()))
                    .collect();
                let t = match f["thread"] {
                    FieldValue::U64(t) => t,
                    _ => panic!("bad field"),
                };
                let i = match f["i"] {
                    FieldValue::U64(i) => i,
                    _ => panic!("bad field"),
                };
                assert_eq!(
                    f["check"],
                    FieldValue::U64(t * 1000 + i),
                    "torn record: fields from different spans"
                );
                let owner = *thread_of_buf.get_or_insert(t);
                assert_eq!(owner, t, "foreign record in buffer");
                if let Some(prev) = last_i {
                    assert!(i > prev, "out-of-order in one thread");
                }
                last_i = Some(i);
                seen += 1;
            }
        }
        assert_eq!(seen, 4 * 200);
    }

    #[test]
    fn identity_is_attached_to_the_dump() {
        let _g = crate::obs::testutil::serialize();
        enable();
        let h = std::thread::Builder::new()
            .name("t-ident-worker".into())
            .spawn(|| {
                set_thread_identity(5, "fetch");
                let _s = span("t_ident.work");
            })
            .unwrap();
        h.join().unwrap();
        disable();
        let d = drain()
            .into_iter()
            .find(|d| {
                d.events.iter().any(|e| e.name == "t_ident.work")
            })
            .expect("worker dump present");
        assert_eq!(d.rank, Some(5));
        assert_eq!(d.stage.as_deref(), Some("fetch"));
        assert_eq!(d.thread_name, "t-ident-worker");
    }
}
