//! Unified observability: tracing spans + a process-wide metric
//! registry + exporters, threaded through every hot path of the
//! pipeline (engine perform paths, SST announce/serve, wire frames,
//! staged fetch/store, fleet workers, the multiplex barrier).
//!
//! Three submodules, all dependency-free:
//!
//! * [`trace`] — scoped [`trace::Span`]s with monotonic timestamps and
//!   structured key/value fields. Records land in per-thread bounded
//!   buffers registered with a central collector; nothing is written
//!   until a drain. Tracing is **off by default** and the disabled
//!   record path is a single relaxed atomic load, so instrumentation
//!   can stay compiled into release hot paths (gated by
//!   `benches/micro_obs.rs`).
//! * [`metrics`] — named counters, gauges and log-bucketed histograms
//!   interned in one process-wide registry. Increments are lock-free
//!   atomics and always on; call sites cache the interned handle in a
//!   `Lazy` static so the registry lock is touched once per site.
//! * [`export`] — serialization of a trace drain and a metric snapshot
//!   to JSON lines and to the Chrome trace-event format
//!   (`chrome://tracing` / Perfetto), with span `pid`/`tid` mapped to
//!   fleet rank / pipeline stage via [`trace::set_thread_identity`].
//!
//! Metric names are dotted `subsystem.quantity[_unit]` strings —
//! `wire.frames_sent`, `engine.put_bytes`, `pipe.backoff_us` — see
//! the "Tracing & metrics" section of `tools/README.md` for the full
//! scheme and the Perfetto workflow.
//!
//! Lock discipline: the collector's directory and each per-thread
//! buffer use [`crate::util::sync::OrderedMutex`] under
//! [`crate::util::sync::classes::OBS`], the highest-ranked class in
//! the registry, so recording is legal while *any* other lock is
//! held. Obs code never acquires another class while holding an obs
//! lock and never nests two obs locks.

pub mod export;
pub mod metrics;
pub mod trace;

pub use metrics::{snapshot_metrics, Counter, Gauge, Histogram, Snapshot};
pub use trace::{span, Span};

/// Tests that toggle the global tracing switch or drain the global
/// collector must not interleave; they serialize on this guard.
#[cfg(test)]
pub(crate) mod testutil {
    use std::sync::{Mutex, MutexGuard};

    use once_cell::sync::Lazy;

    static GUARD: Lazy<Mutex<()>> = Lazy::new(|| Mutex::new(()));

    pub fn serialize() -> MutexGuard<'static, ()> {
        GUARD.lock().unwrap_or_else(|e| e.into_inner())
    }
}
