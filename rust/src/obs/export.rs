//! Exporters: Chrome trace-event JSON and JSON lines.
//!
//! [`chrome_trace`] renders a collector drain as a Chrome trace-event
//! document (`{"traceEvents": [...]}`), directly loadable in
//! Perfetto / `chrome://tracing`. Every span becomes one complete
//! (`"ph": "X"`) event — balanced begin/end by construction — and the
//! `pid`/`tid` axes carry the pipeline topology:
//!
//! * `pid` = fleet rank (threads that declared one via
//!   [`crate::obs::trace::set_thread_identity`]; rank 0 otherwise),
//!   labelled by a
//!   `process_name` metadata event, so an M-rank fleet renders as M
//!   process lanes;
//! * `tid` = thread registration sequence, labelled with the stage
//!   name (or OS thread name) via `thread_name` metadata, so staged
//!   fetch/store overlap is visible as parallel tracks.
//!
//! [`trace_json_lines`] renders the same drain as one JSON object per
//! line (grep/jq-friendly); [`metrics_line`] renders a metric
//! [`Snapshot`] as a single line for the pipe's periodic
//! `--metrics <path>` emission.

use std::collections::BTreeMap;

use crate::obs::metrics::Snapshot;
use crate::obs::trace::{Event, FieldValue, ThreadDump};
use crate::util::json::Json;

fn field_json(v: &FieldValue) -> Json {
    match v {
        FieldValue::U64(n) => Json::Num(*n as f64),
        FieldValue::F64(x) => Json::Num(*x),
        FieldValue::Str(s) => Json::Str(s.clone()),
    }
}

fn args_json(fields: &[(&'static str, FieldValue)]) -> Json {
    Json::Obj(
        fields
            .iter()
            .map(|(k, v)| (k.to_string(), field_json(v)))
            .collect(),
    )
}

fn meta_event(name: &str, pid: u64, tid: u64, label: &str) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".into(), Json::Str(name.into()));
    o.insert("ph".into(), Json::Str("M".into()));
    o.insert("pid".into(), Json::Num(pid as f64));
    o.insert("tid".into(), Json::Num(tid as f64));
    let mut args = BTreeMap::new();
    args.insert("name".into(), Json::Str(label.into()));
    o.insert("args".into(), Json::Obj(args));
    Json::Obj(o)
}

fn span_event(pid: u64, tid: u64, e: &Event) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".into(), Json::Str(e.name.into()));
    o.insert("ph".into(), Json::Str("X".into()));
    o.insert("pid".into(), Json::Num(pid as f64));
    o.insert("tid".into(), Json::Num(tid as f64));
    o.insert("ts".into(), Json::Num(e.start_us as f64));
    o.insert("dur".into(), Json::Num(e.dur_us as f64));
    if !e.fields.is_empty() {
        o.insert("args".into(), args_json(&e.fields));
    }
    Json::Obj(o)
}

/// Label for a dump's process lane and thread track.
fn lane(dump: &ThreadDump) -> (u64, String, String) {
    let pid = dump.rank.unwrap_or(0) as u64;
    let process = match dump.rank {
        Some(r) => format!("rank {r}"),
        None => "rank 0".to_string(),
    };
    let thread = match &dump.stage {
        Some(s) => s.clone(),
        None => dump.thread_name.clone(),
    };
    (pid, process, thread)
}

/// Render a collector drain as a Chrome trace-event document.
pub fn chrome_trace(dumps: &[ThreadDump]) -> Json {
    let mut events = Vec::new();
    let mut named_pids: BTreeMap<u64, String> = BTreeMap::new();
    for d in dumps {
        if d.events.is_empty() {
            continue;
        }
        let (pid, process, thread) = lane(d);
        named_pids.entry(pid).or_insert(process);
        events.push(meta_event("thread_name", pid, d.tid, &thread));
        for e in &d.events {
            events.push(span_event(pid, d.tid, e));
        }
    }
    let mut all = Vec::with_capacity(events.len() + named_pids.len());
    for (pid, label) in &named_pids {
        all.push(meta_event("process_name", *pid, 0, label));
    }
    all.extend(events);
    let mut doc = BTreeMap::new();
    doc.insert("traceEvents".into(), Json::Arr(all));
    doc.insert(
        "displayTimeUnit".into(),
        Json::Str("ms".into()),
    );
    Json::Obj(doc)
}

/// Render a collector drain as JSON lines: one object per span, with
/// the owning lane's rank/stage denormalized onto every line.
pub fn trace_json_lines(dumps: &[ThreadDump]) -> String {
    let mut out = String::new();
    for d in dumps {
        let (pid, _, thread) = lane(d);
        for e in &d.events {
            let mut o = BTreeMap::new();
            o.insert("name".into(), Json::Str(e.name.into()));
            o.insert("rank".into(), Json::Num(pid as f64));
            o.insert("stage".into(), Json::Str(thread.clone()));
            o.insert("tid".into(), Json::Num(d.tid as f64));
            o.insert("ts_us".into(), Json::Num(e.start_us as f64));
            o.insert("dur_us".into(), Json::Num(e.dur_us as f64));
            if !e.fields.is_empty() {
                o.insert("args".into(), args_json(&e.fields));
            }
            out.push_str(&Json::Obj(o).to_string());
            out.push('\n');
        }
    }
    out
}

/// Render a metric snapshot as one JSON line, tagged with the pipe
/// step it was taken at (`step: null` for the final summary line).
pub fn metrics_line(step: Option<u64>, snap: &Snapshot) -> String {
    let mut o = BTreeMap::new();
    o.insert(
        "step".into(),
        match step {
            Some(s) => Json::Num(s as f64),
            None => Json::Null,
        },
    );
    o.insert(
        "counters".into(),
        Json::Obj(
            snap.counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                .collect(),
        ),
    );
    o.insert(
        "gauges".into(),
        Json::Obj(
            snap.gauges
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                .collect(),
        ),
    );
    o.insert(
        "histograms".into(),
        Json::Obj(
            snap.hists
                .iter()
                .map(|(k, h)| {
                    let mut ho = BTreeMap::new();
                    ho.insert(
                        "count".into(),
                        Json::Num(h.count as f64),
                    );
                    ho.insert("sum".into(), Json::Num(h.sum as f64));
                    ho.insert(
                        "mean".into(),
                        Json::Num(h.mean()),
                    );
                    ho.insert(
                        "max_bound".into(),
                        Json::Num(h.max_bound() as f64),
                    );
                    (k.clone(), Json::Obj(ho))
                })
                .collect(),
        ),
    );
    Json::Obj(o).to_string()
}

/// Drain the collector and write a Chrome-trace file.
pub fn write_chrome_trace(
    path: &std::path::Path,
) -> std::io::Result<()> {
    let dumps = crate::obs::trace::drain();
    std::fs::write(path, chrome_trace(&dumps).to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::HistSnapshot;

    /// A hand-built drain: rank-1 fetch stage with a nested pair,
    /// plus an anonymous main thread — exercises both lane mappings.
    fn fixture() -> Vec<ThreadDump> {
        vec![
            ThreadDump {
                tid: 1,
                thread_name: "main".into(),
                rank: None,
                stage: None,
                events: vec![Event {
                    name: "pipe.step",
                    start_us: 0,
                    dur_us: 300,
                    fields: vec![("step", FieldValue::U64(0))],
                }],
                dropped: 0,
            },
            ThreadDump {
                tid: 2,
                thread_name: "fleet-r1".into(),
                rank: Some(1),
                stage: Some("fetch".into()),
                events: vec![
                    Event {
                        name: "sst.get_batch",
                        start_us: 120,
                        dur_us: 80,
                        fields: vec![
                            ("bytes", FieldValue::U64(4096)),
                            ("writers", FieldValue::U64(2)),
                        ],
                    },
                    Event {
                        name: "pipe.fetch",
                        start_us: 100,
                        dur_us: 150,
                        fields: vec![],
                    },
                ],
                dropped: 0,
            },
        ]
    }

    #[test]
    fn chrome_trace_golden() {
        let doc = chrome_trace(&fixture());
        let expect = concat!(
            r#"{"displayTimeUnit":"ms","traceEvents":["#,
            r#"{"args":{"name":"rank 0"},"name":"process_name","#,
            r#""ph":"M","pid":0,"tid":0},"#,
            r#"{"args":{"name":"rank 1"},"name":"process_name","#,
            r#""ph":"M","pid":1,"tid":0},"#,
            r#"{"args":{"name":"main"},"name":"thread_name","#,
            r#""ph":"M","pid":0,"tid":1},"#,
            r#"{"args":{"step":0},"dur":300,"name":"pipe.step","#,
            r#""ph":"X","pid":0,"tid":1,"ts":0},"#,
            r#"{"args":{"name":"fetch"},"name":"thread_name","#,
            r#""ph":"M","pid":1,"tid":2},"#,
            r#"{"args":{"bytes":4096,"writers":2},"dur":80,"#,
            r#""name":"sst.get_batch","ph":"X","pid":1,"tid":2,"#,
            r#""ts":120},"#,
            r#"{"dur":150,"name":"pipe.fetch","ph":"X","pid":1,"#,
            r#""tid":2,"ts":100}]}"#,
        );
        assert_eq!(doc.to_string(), expect);
        // And it survives a parse round trip.
        let back = crate::util::json::parse(&doc.to_string()).unwrap();
        assert_eq!(
            back.get("traceEvents").unwrap().as_arr().unwrap().len(),
            7
        );
    }

    #[test]
    fn json_lines_golden() {
        let lines = trace_json_lines(&fixture());
        let expect = concat!(
            r#"{"args":{"step":0},"dur_us":300,"name":"pipe.step","#,
            r#""rank":0,"stage":"main","tid":1,"ts_us":0}"#,
            "\n",
            r#"{"args":{"bytes":4096,"writers":2},"dur_us":80,"#,
            r#""name":"sst.get_batch","rank":1,"stage":"fetch","#,
            r#""tid":2,"ts_us":120}"#,
            "\n",
            r#"{"dur_us":150,"name":"pipe.fetch","rank":1,"#,
            r#""stage":"fetch","tid":2,"ts_us":100}"#,
            "\n",
        );
        assert_eq!(lines, expect);
        for line in lines.lines() {
            crate::util::json::parse(line).unwrap();
        }
    }

    #[test]
    fn metrics_line_golden() {
        let mut snap = Snapshot::default();
        snap.counters.insert("wire.frames_sent".into(), 12);
        snap.gauges.insert("staged.queue_depth".into(), 3);
        snap.hists.insert(
            "pipe.backoff_us".into(),
            HistSnapshot {
                buckets: vec![0, 0, 1],
                sum: 2,
                count: 1,
            },
        );
        let line = metrics_line(Some(4), &snap);
        let expect = concat!(
            r#"{"counters":{"wire.frames_sent":12},"#,
            r#""gauges":{"staged.queue_depth":3},"#,
            r#""histograms":{"pipe.backoff_us":{"count":1,"#,
            r#""max_bound":4,"mean":2,"sum":2}},"step":4}"#,
        );
        assert_eq!(line, expect);
        let no_step = metrics_line(None, &Snapshot::default());
        assert!(no_step.starts_with(r#"{"counters":{}"#));
        assert!(no_step.contains(r#""step":null"#));
    }
}
