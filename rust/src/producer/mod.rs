//! Data producers (S12): the PIConGPU stand-ins.
//!
//! * [`kelvin_helmholtz`] — a real (small) particle-in-cell producer: a
//!   Kelvin–Helmholtz shear-flow particle population evolved by the
//!   AOT-compiled `pic_step` artifact (L1 Pallas Boris push inside),
//!   with a bit-compatible pure-rust fallback for artifact-less builds.
//!   Emits openPMD iterations exactly like PIConGPU's openPMD plugin.
//! * [`synthetic`] — a data-shape-only producer for IO benchmarks:
//!   emits correctly structured particle records of arbitrary size
//!   without computing physics (the IO layer cannot tell the
//!   difference, which is the point).

pub mod kelvin_helmholtz;
pub mod synthetic;

pub use kelvin_helmholtz::KhProducer;
pub use synthetic::SyntheticProducer;
