//! Kelvin–Helmholtz particle producer.
//!
//! PIConGPU's flagship weak-scaling case (Bussmann et al. 2013) is a
//! relativistic Kelvin–Helmholtz instability. This producer initializes
//! the classic KH setup — two counter-streaming shear layers with a
//! seeded velocity perturbation in a periodic box — and advances it with
//! the `pic_step` artifact (bilinear field gather + Boris push, lowered
//! from JAX/Pallas; see `python/compile/`).
//!
//! The physics constants (`DT`, `QM`, `BOX`, `GRID`) are baked into the
//! artifact at lowering time; the same values are mirrored here for the
//! pure-rust fallback, and a test asserts artifact ↔ fallback agreement
//! so the two can never drift apart silently.

use std::sync::Arc;

use anyhow::Result;

use crate::adios::engine::{cast, Engine, StepStatus};
use crate::adios::ops::OpChain;
use crate::openpmd::chunk::Chunk;
use crate::openpmd::record::ParticleSpecies;
use crate::openpmd::series::{Iteration, Series};
use crate::runtime::{Exec, Runtime};
use crate::util::rng::Rng;

/// Mirrors python/compile/model.py — keep in sync (tested).
pub const DT: f32 = 0.05;
pub const QM: f32 = -1.0;
pub const BOX: [f32; 3] = [64.0, 64.0, 64.0];
pub const GRID: usize = 64;
/// Artifact batch size (python/compile/aot.py PIC_PARTICLES).
pub const BATCH: usize = 16384;

/// The producer state of one parallel rank.
pub struct KhProducer {
    /// Particles on this rank.
    pub n: usize,
    /// Interleaved [n, 3] row-major.
    pub pos: Vec<f32>,
    pub mom: Vec<f32>,
    pub weights: Vec<f32>,
    e_grid: Vec<f32>,
    b_grid: Vec<f32>,
    exec: Option<Arc<Exec>>,
    pub rank: usize,
    pub hostname: String,
    /// This rank's offset in the global particle index space.
    pub global_offset: u64,
    /// Global particle count across all ranks.
    pub global_n: u64,
    /// Operator chain declared for every emitted record component
    /// (the `--operators` CLI knob).
    pub ops: OpChain,
    step_count: u64,
}

impl KhProducer {
    /// Initialize the KH state. `runtime` enables the PJRT path; without
    /// it the pure-rust fallback is used (identical math).
    pub fn new(
        rank: usize,
        hostname: &str,
        n: usize,
        global_offset: u64,
        global_n: u64,
        seed: u64,
        runtime: Option<&Runtime>,
    ) -> Result<KhProducer> {
        let mut rng = Rng::new(seed ^ (rank as u64).wrapping_mul(0x9E37));
        let mut pos = Vec::with_capacity(n * 3);
        let mut mom = Vec::with_capacity(n * 3);
        let weights = vec![1.0f32; n];
        for _ in 0..n {
            let x = rng.f32() * BOX[0];
            let y = rng.f32() * BOX[1];
            let z = rng.f32() * BOX[2];
            pos.extend_from_slice(&[x, y, z]);
            // Shear flow: +vx in the middle band, -vx outside, plus a
            // seeded sinusoidal vy perturbation (KH trigger) and thermal
            // jitter.
            let dir = if y > BOX[1] * 0.25 && y < BOX[1] * 0.75 {
                1.0
            } else {
                -1.0
            };
            let vx = dir * 0.5 + 0.02 * rng.normal() as f32;
            let vy = 0.05
                * (2.0 * std::f32::consts::PI * x / BOX[0] * 4.0).sin()
                + 0.02 * rng.normal() as f32;
            let vz = 0.02 * rng.normal() as f32;
            mom.extend_from_slice(&[vx, vy, vz]);
        }
        // Static fields: uniform B_z plus a weak sinusoidal E pattern on
        // the grid (PIConGPU's self-consistent field solve is out of
        // scope — the IO system cannot tell, see DESIGN.md §5).
        let g = GRID;
        let mut e_grid = vec![0.0f32; g * g * 3];
        let mut b_grid = vec![0.0f32; g * g * 3];
        for i in 0..g {
            for j in 0..g {
                let idx = (i * g + j) * 3;
                let x = i as f32 / g as f32;
                let y = j as f32 / g as f32;
                e_grid[idx] =
                    0.05 * (2.0 * std::f32::consts::PI * y).sin();
                e_grid[idx + 1] =
                    0.05 * (2.0 * std::f32::consts::PI * x).cos();
                b_grid[idx + 2] = 0.2;
            }
        }
        let exec = match runtime {
            Some(rt) => Some(rt.get("pic_step")?),
            None => None,
        };
        Ok(KhProducer {
            n,
            pos,
            mom,
            weights,
            e_grid,
            b_grid,
            exec,
            rank,
            hostname: hostname.to_string(),
            global_offset,
            global_n,
            ops: OpChain::identity(),
            step_count: 0,
        })
    }

    /// Declare every emitted record component with `ops` from now on.
    pub fn set_operators(&mut self, ops: OpChain) {
        self.ops = ops;
    }

    /// Advance one PIC step (through PJRT when available).
    pub fn step(&mut self) -> Result<()> {
        if let Some(exec) = self.exec.clone() {
            self.step_pjrt(&exec)?;
        } else {
            self.step_fallback();
        }
        self.step_count += 1;
        Ok(())
    }

    pub fn steps_taken(&self) -> u64 {
        self.step_count
    }

    /// PJRT path: run the artifact in `BATCH`-sized slices, padding the
    /// tail with particles parked at the origin with zero momentum
    /// (their outputs are discarded).
    fn step_pjrt(&mut self, exec: &Exec) -> Result<()> {
        let mut i = 0;
        while i < self.n {
            let take = (self.n - i).min(BATCH);
            let mut pos_b = vec![0.0f32; BATCH * 3];
            let mut mom_b = vec![0.0f32; BATCH * 3];
            pos_b[..take * 3]
                .copy_from_slice(&self.pos[i * 3..(i + take) * 3]);
            mom_b[..take * 3]
                .copy_from_slice(&self.mom[i * 3..(i + take) * 3]);
            let out = exec.run_f32(&[
                &pos_b,
                &mom_b,
                &self.e_grid,
                &self.b_grid,
            ])?;
            self.pos[i * 3..(i + take) * 3]
                .copy_from_slice(&out[0][..take * 3]);
            self.mom[i * 3..(i + take) * 3]
                .copy_from_slice(&out[1][..take * 3]);
            i += take;
        }
        Ok(())
    }

    /// Pure-rust fallback, bit-for-bit the same math as model.py.
    fn step_fallback(&mut self) {
        for p in 0..self.n {
            let (e_f, b_f) = (
                gather(&self.e_grid, &self.pos[p * 3..p * 3 + 3]),
                gather(&self.b_grid, &self.pos[p * 3..p * 3 + 3]),
            );
            let m = &mut self.mom[p * 3..p * 3 + 3];
            let h = 0.5 * QM * DT;
            let vm = [m[0] + h * e_f[0], m[1] + h * e_f[1],
                      m[2] + h * e_f[2]];
            let t = [h * b_f[0], h * b_f[1], h * b_f[2]];
            let t2 = t[0] * t[0] + t[1] * t[1] + t[2] * t[2];
            let s = [2.0 * t[0] / (1.0 + t2), 2.0 * t[1] / (1.0 + t2),
                     2.0 * t[2] / (1.0 + t2)];
            let vp = [
                vm[0] + vm[1] * t[2] - vm[2] * t[1],
                vm[1] + vm[2] * t[0] - vm[0] * t[2],
                vm[2] + vm[0] * t[1] - vm[1] * t[0],
            ];
            let vpl = [
                vm[0] + vp[1] * s[2] - vp[2] * s[1],
                vm[1] + vp[2] * s[0] - vp[0] * s[2],
                vm[2] + vp[0] * s[1] - vp[1] * s[0],
            ];
            m[0] = vpl[0] + h * e_f[0];
            m[1] = vpl[1] + h * e_f[1];
            m[2] = vpl[2] + h * e_f[2];
            for d in 0..3 {
                let x = self.pos[p * 3 + d] + DT * m[d];
                self.pos[p * 3 + d] = x - (x / BOX[d]).floor() * BOX[d];
            }
        }
    }

    /// Column `d` (0=x, 1=y, 2=z) of an interleaved [n,3] buffer.
    fn column(buf: &[f32], d: usize) -> Vec<f32> {
        buf.chunks_exact(3).map(|r| r[d]).collect()
    }

    /// Emit the current state as one openPMD iteration through `engine`.
    /// Mirrors PIConGPU's openPMD plugin: species "e" with position,
    /// momentum, weighting; one chunk per rank at this rank's offset.
    pub fn write_iteration(
        &self,
        series: &mut Series,
        engine: &mut dyn Engine,
        index: u64,
    ) -> Result<StepStatus> {
        let mut it = Iteration::new(self.step_count as f64 * DT as f64,
                                    DT as f64);
        let mut species = ParticleSpecies::pic_layout_with_ops(
            self.global_n, self.ops.clone());
        let my_chunk = Chunk::new(vec![self.global_offset],
                                  vec![self.n as u64]);
        for (record, data) in [
            ("position", &self.pos),
            ("momentum", &self.mom),
        ] {
            let rec = species.records.get_mut(record).unwrap();
            for (d, comp) in ["x", "y", "z"].iter().enumerate() {
                rec.component_mut(comp)
                    .unwrap()
                    .store_chunk(
                        my_chunk.clone(),
                        cast::f32_to_bytes(&Self::column(data, d)),
                    )
                    .map_err(|e| anyhow::anyhow!(e))?;
            }
        }
        species
            .records
            .get_mut("weighting")
            .unwrap()
            .components
            .values_mut()
            .next()
            .unwrap()
            .store_chunk(my_chunk, cast::f32_to_bytes(&self.weights))
            .map_err(|e| anyhow::anyhow!(e))?;
        it.particles.insert("e".into(), species);
        series.write_iteration(engine, index, &mut it)
    }

    /// Total kinetic energy (diagnostic; conserved without E-fields).
    pub fn kinetic_energy(&self) -> f64 {
        self.mom
            .chunks_exact(3)
            .map(|m| {
                0.5 * (m[0] * m[0] + m[1] * m[1] + m[2] * m[2]) as f64
            })
            .sum()
    }
}

/// Bilinear periodic gather on the [GRID, GRID, 3] x-y field —
/// the rust mirror of model.py's `gather_fields`.
fn gather(grid: &[f32], pos: &[f32]) -> [f32; 3] {
    let g = GRID;
    let u = pos[0] / BOX[0] * g as f32;
    let v = pos[1] / BOX[1] * g as f32;
    let u0f = u.floor();
    let v0f = v.floor();
    let fu = u - u0f;
    let fv = v - v0f;
    let u0 = (u0f as i64).rem_euclid(g as i64) as usize;
    let v0 = (v0f as i64).rem_euclid(g as i64) as usize;
    let u1 = (u0 + 1) % g;
    let v1 = (v0 + 1) % g;
    let at = |i: usize, j: usize, d: usize| grid[(i * g + j) * 3 + d];
    let mut out = [0.0f32; 3];
    for (d, o) in out.iter_mut().enumerate() {
        *o = (1.0 - fu) * (1.0 - fv) * at(u0, v0, d)
            + (1.0 - fu) * fv * at(u0, v1, d)
            + fu * (1.0 - fv) * at(u1, v0, d)
            + fu * fv * at(u1, v1, d);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn producer(n: usize) -> KhProducer {
        KhProducer::new(0, "test", n, 0, n as u64, 42, None).unwrap()
    }

    #[test]
    fn initial_state_is_in_box_with_shear() {
        let p = producer(1000);
        assert!(p.pos.iter().enumerate().all(|(i, &x)| {
            x >= 0.0 && x < BOX[i % 3]
        }));
        // Mean |vx| must reflect the +-0.5 shear.
        let mean_abs_vx: f32 = p
            .mom
            .chunks_exact(3)
            .map(|m| m[0].abs())
            .sum::<f32>()
            / 1000.0;
        assert!((mean_abs_vx - 0.5).abs() < 0.05, "{mean_abs_vx}");
    }

    #[test]
    fn fallback_step_keeps_particles_in_box() {
        let mut p = producer(500);
        for _ in 0..20 {
            p.step().unwrap();
        }
        assert_eq!(p.steps_taken(), 20);
        assert!(p.pos.iter().enumerate().all(|(i, &x)| {
            x >= 0.0 && x < BOX[i % 3]
        }));
    }

    #[test]
    fn pure_magnetic_fallback_conserves_energy() {
        let mut p = producer(200);
        p.e_grid.iter_mut().for_each(|x| *x = 0.0);
        let e0 = p.kinetic_energy();
        for _ in 0..50 {
            p.step().unwrap();
        }
        let e1 = p.kinetic_energy();
        assert!((e1 - e0).abs() / e0 < 1e-4, "{e0} -> {e1}");
    }

    #[test]
    fn artifact_and_fallback_agree() {
        // The critical cross-layer test: PJRT artifact == rust fallback.
        let dir = Runtime::default_dir();
        if !dir.join("meta.json").exists() {
            return; // artifacts not built in this checkout
        }
        let rt = Runtime::load(dir).unwrap();
        let mut a =
            KhProducer::new(0, "t", 300, 0, 300, 7, Some(&rt)).unwrap();
        let mut b = KhProducer::new(0, "t", 300, 0, 300, 7, None).unwrap();
        assert_eq!(a.pos, b.pos);
        for _ in 0..5 {
            a.step().unwrap();
            b.step().unwrap();
        }
        for (x, y) in a.pos.iter().zip(&b.pos) {
            assert!((x - y).abs() < 2e-3, "pos {x} vs {y}");
        }
        for (x, y) in a.mom.iter().zip(&b.mom) {
            assert!((x - y).abs() < 2e-3, "mom {x} vs {y}");
        }
    }

    #[test]
    fn writes_valid_openpmd_iteration() {
        use crate::adios::bp::{BpReader, BpWriter, WriterCtx};
        let path = std::env::temp_dir()
            .join(format!("kh-write-{}.bp", std::process::id()));
        let p = producer(128);
        let mut series = Series::new("test", "openpmd-stream");
        let mut w = BpWriter::create(&path, WriterCtx {
            rank: 0,
            hostname: "test".into(),
        })
        .unwrap();
        p.write_iteration(&mut series, &mut w, 0).unwrap();
        w.close().unwrap();

        let mut r = BpReader::open(&path).unwrap();
        let (status, parsed) = Series::read_iteration(&mut r).unwrap();
        assert_eq!(status, StepStatus::Ok);
        let (idx, it) = parsed.unwrap();
        assert_eq!(idx, 0);
        let sp = &it.particles["e"];
        assert_eq!(sp.records.len(), 3);
        assert_eq!(
            sp.records["position"].components["x"].dataset.extent,
            vec![128]
        );
        // Validator agrees.
        let findings =
            crate::openpmd::validate::validate_iteration(0, &it);
        assert!(crate::openpmd::validate::is_conformant(&findings),
                "{findings:?}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn gather_constant_field() {
        let grid = vec![2.0f32; GRID * GRID * 3];
        let got = gather(&grid, &[13.7, 44.1, 0.0]);
        for d in 0..3 {
            assert!((got[d] - 2.0).abs() < 1e-6);
        }
    }
}
