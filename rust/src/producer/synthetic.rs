//! Synthetic producer: the data *shape* of PIConGPU without the physics.
//!
//! IO benchmarks (micro_transport, the real-engine parts of the
//! examples) need realistic openPMD step structure at arbitrary sizes
//! without paying for particle pushes. The synthetic producer emits the
//! same species layout (`position`/`momentum`/`weighting`, one chunk per
//! rank) with deterministic pseudo-random payloads — serialized straight
//! into the engine's staging buffer via `put_span`, so the hot path
//! performs zero intermediate copies.

use anyhow::Result;

use crate::adios::engine::{Engine, StepStatus, VarDecl};
use crate::openpmd::chunk::Chunk;
use crate::openpmd::series::var_name;
use crate::openpmd::types::Datatype;
use crate::openpmd::record::SCALAR;
use crate::openpmd::Attribute;
use crate::util::rng::Rng;

/// Synthetic producer for one rank.
pub struct SyntheticProducer {
    pub rank: usize,
    /// Particles this rank contributes per step.
    pub n: usize,
    pub global_offset: u64,
    pub global_n: u64,
    rng: Rng,
    step: u64,
}

impl SyntheticProducer {
    pub fn new(rank: usize, n: usize, global_offset: u64, global_n: u64,
               seed: u64) -> Self {
        SyntheticProducer {
            rank,
            n,
            global_offset,
            global_n,
            rng: Rng::new(seed ^ rank as u64),
            step: 0,
        }
    }

    /// Producer sized by bytes per step (7 f32 components per particle:
    /// 3 position + 3 momentum + 1 weighting).
    pub fn with_bytes_per_step(rank: usize, bytes: u64, ranks: usize,
                               seed: u64) -> Self {
        let n = (bytes / (7 * 4)).max(1) as usize;
        let global_n = (n * ranks) as u64;
        Self::new(rank, n, (rank * n) as u64, global_n, seed)
    }

    /// Bytes this producer writes per step.
    pub fn bytes_per_step(&self) -> u64 {
        self.n as u64 * 7 * 4
    }

    /// Serialize one component's pseudo-random payload directly into an
    /// engine staging span (no intermediate buffer).
    fn fill_span(&mut self, scale: f32, span: &mut [u8]) {
        for slot in span.chunks_exact_mut(4) {
            let v = self.rng.f32() * scale;
            slot.copy_from_slice(&v.to_le_bytes());
        }
    }

    /// Write one step of openPMD-shaped particle data through the
    /// two-phase API: every component is declared, serialized into a
    /// `put_span` staging buffer, and the whole step is performed by
    /// `end_step` as one batch.
    /// Returns the step status from the engine (discards propagate).
    pub fn write_step(&mut self, engine: &mut dyn Engine)
        -> Result<StepStatus>
    {
        match engine.begin_step()? {
            StepStatus::Ok => {}
            other => {
                if other == StepStatus::Discarded {
                    self.step += 1;
                }
                return Ok(other);
            }
        }
        let idx = self.step;
        engine.put_attribute(
            &format!("/data/{idx}/time"),
            Attribute::F64(idx as f64),
        )?;
        let chunk = Chunk::new(vec![self.global_offset],
                               vec![self.n as u64]);
        for record in ["position", "momentum"] {
            for comp in ["x", "y", "z"] {
                let decl = VarDecl::new(
                    var_name(idx, "e", record, comp),
                    Datatype::F32,
                    vec![self.global_n],
                );
                let handle = engine.define_variable(&decl)?;
                let span = engine.put_span(&handle, chunk.clone())?;
                self.fill_span(64.0, span);
            }
        }
        let decl = VarDecl::new(
            var_name(idx, "e", "weighting", SCALAR),
            Datatype::F32,
            vec![self.global_n],
        );
        let handle = engine.define_variable(&decl)?;
        let span = engine.put_span(&handle, chunk)?;
        self.fill_span(1.0, span);
        engine.end_step()?;
        self.step += 1;
        Ok(StepStatus::Ok)
    }

    pub fn steps_written(&self) -> u64 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::bp::{BpReader, BpWriter, WriterCtx};

    #[test]
    fn produces_seven_components_with_right_sizes() {
        let path = std::env::temp_dir()
            .join(format!("synth-{}.bp", std::process::id()));
        let mut p = SyntheticProducer::new(0, 100, 0, 100, 1);
        assert_eq!(p.bytes_per_step(), 100 * 28);
        let mut w =
            BpWriter::create(&path, WriterCtx::default()).unwrap();
        p.write_step(&mut w).unwrap();
        w.close().unwrap();

        let mut r = BpReader::open(&path).unwrap();
        r.begin_step().unwrap();
        let vars = r.available_variables();
        assert_eq!(vars.len(), 7);
        assert!(vars.iter().all(|v| v.shape == vec![100]));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sizing_by_bytes() {
        let p = SyntheticProducer::with_bytes_per_step(0, 28_000, 4, 2);
        assert_eq!(p.n, 1000);
        assert_eq!(p.global_n, 4000);
        assert_eq!(p.bytes_per_step(), 28_000);
    }

    #[test]
    fn deterministic_per_seed() {
        let path1 = std::env::temp_dir()
            .join(format!("synth-d1-{}.bp", std::process::id()));
        let path2 = std::env::temp_dir()
            .join(format!("synth-d2-{}.bp", std::process::id()));
        for p in [&path1, &path2] {
            let mut prod = SyntheticProducer::new(3, 50, 0, 50, 99);
            let mut w =
                BpWriter::create(p, WriterCtx::default()).unwrap();
            prod.write_step(&mut w).unwrap();
            w.close().unwrap();
        }
        assert_eq!(std::fs::read(&path1).unwrap(),
                   std::fs::read(&path2).unwrap());
        std::fs::remove_file(&path1).ok();
        std::fs::remove_file(&path2).ok();
    }
}
