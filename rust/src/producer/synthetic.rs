//! Synthetic producer: the data *shape* of PIConGPU without the physics.
//!
//! IO benchmarks (micro_transport, fig_compression, the real-engine
//! parts of the examples) need realistic openPMD step structure at
//! arbitrary sizes without paying for particle pushes. The synthetic
//! producer emits the same species layout (`position`/`momentum`/
//! `weighting`, one chunk per rank) with deterministic payloads —
//! serialized straight into the engine's staging buffer via `put_span`,
//! so the hot path performs zero intermediate copies.
//!
//! The payloads model the *statistics* of real PIC output, which is
//! what makes the operator benchmarks honest rather than flattering:
//!
//! * `position` — a quantized ramp with a per-step phase (particles are
//!   initialized on a lattice and stay spatially ordered per rank);
//! * `momentum` — quantized pseudo-random values (thermal spread;
//!   15 significant bits, the effective precision of real single-
//!   precision particle data);
//! * `weighting` — constant (macroparticle weight is uniform in the
//!   paper's KH setup).

use anyhow::Result;

use crate::adios::engine::{Engine, StepStatus, VarDecl};
use crate::adios::ops::OpChain;
use crate::openpmd::chunk::Chunk;
use crate::openpmd::series::var_name;
use crate::openpmd::types::Datatype;
use crate::openpmd::record::SCALAR;
use crate::openpmd::Attribute;
use crate::util::rng::Rng;

/// Synthetic producer for one rank.
pub struct SyntheticProducer {
    pub rank: usize,
    /// Particles this rank contributes per step.
    pub n: usize,
    pub global_offset: u64,
    pub global_n: u64,
    /// Operator chain declared for every emitted variable.
    pub ops: OpChain,
    rng: Rng,
    step: u64,
}

impl SyntheticProducer {
    pub fn new(rank: usize, n: usize, global_offset: u64, global_n: u64,
               seed: u64) -> Self {
        SyntheticProducer {
            rank,
            n,
            global_offset,
            global_n,
            ops: OpChain::identity(),
            rng: Rng::new(seed ^ rank as u64),
            step: 0,
        }
    }

    /// Producer sized by bytes per step (7 f32 components per particle:
    /// 3 position + 3 momentum + 1 weighting).
    pub fn with_bytes_per_step(rank: usize, bytes: u64, ranks: usize,
                               seed: u64) -> Self {
        let n = (bytes / (7 * 4)).max(1) as usize;
        let global_n = (n * ranks) as u64;
        Self::new(rank, n, (rank * n) as u64, global_n, seed)
    }

    /// Attach an operator chain to every variable this producer
    /// declares (builder style).
    pub fn with_ops(mut self, ops: OpChain) -> Self {
        self.ops = ops;
        self
    }

    /// Bytes this producer writes per step.
    pub fn bytes_per_step(&self) -> u64 {
        self.n as u64 * 7 * 4
    }

    /// Quantized lattice ramp: monotone across the rank's chunk with a
    /// per-step phase, 15 significant bits per value.
    fn fill_ramp(span: &mut [u8], offset: u64, global_n: u64, step: u64,
                 scale: f32) {
        let n = global_n.max(1);
        let phase = (step * 131) & 0x7fff;
        for (j, slot) in span.chunks_exact_mut(4).enumerate() {
            let g = offset + j as u64;
            let t = ((g * 0x7fff / n) + phase) & 0x7fff;
            let v = (t as f32 / 32768.0) * scale;
            slot.copy_from_slice(&v.to_le_bytes());
        }
    }

    /// Quantized pseudo-random values: 15 significant bits per value.
    fn fill_quantized(&mut self, span: &mut [u8], scale: f32) {
        for slot in span.chunks_exact_mut(4) {
            let q = (self.rng.next_u64() & 0x7fff) as f32;
            let v = q / 32768.0 * scale;
            slot.copy_from_slice(&v.to_le_bytes());
        }
    }

    fn fill_constant(span: &mut [u8], v: f32) {
        for slot in span.chunks_exact_mut(4) {
            slot.copy_from_slice(&v.to_le_bytes());
        }
    }

    fn fill_component(&mut self, record: &str, span: &mut [u8],
                      step: u64) {
        match record {
            "position" => Self::fill_ramp(span, self.global_offset,
                                          self.global_n, step, 64.0),
            "momentum" => self.fill_quantized(span, 8.0),
            _ => Self::fill_constant(span, 1.0),
        }
    }

    /// Write one step of openPMD-shaped particle data through the
    /// two-phase API: every component is declared (with this producer's
    /// operator chain), serialized into a `put_span` staging buffer,
    /// and the whole step is performed by `end_step` as one batch.
    /// Returns the step status from the engine (discards propagate).
    pub fn write_step(&mut self, engine: &mut dyn Engine)
        -> Result<StepStatus>
    {
        match engine.begin_step()? {
            StepStatus::Ok => {}
            other => {
                if other == StepStatus::Discarded {
                    self.step += 1;
                }
                return Ok(other);
            }
        }
        let idx = self.step;
        engine.put_attribute(
            &format!("/data/{idx}/time"),
            Attribute::F64(idx as f64),
        )?;
        let chunk = Chunk::new(vec![self.global_offset],
                               vec![self.n as u64]);
        for record in ["position", "momentum"] {
            for comp in ["x", "y", "z"] {
                let decl = VarDecl::new(
                    var_name(idx, "e", record, comp),
                    Datatype::F32,
                    vec![self.global_n],
                )
                .with_ops(self.ops.clone());
                let handle = engine.define_variable(&decl)?;
                let span = engine.put_span(&handle, chunk.clone())?;
                self.fill_component(record, span, idx);
            }
        }
        let decl = VarDecl::new(
            var_name(idx, "e", "weighting", SCALAR),
            Datatype::F32,
            vec![self.global_n],
        )
        .with_ops(self.ops.clone());
        let handle = engine.define_variable(&decl)?;
        let span = engine.put_span(&handle, chunk)?;
        Self::fill_constant(span, 1.0);
        engine.end_step()?;
        self.step += 1;
        Ok(StepStatus::Ok)
    }

    /// One step's per-component payloads without an engine — exactly
    /// the bytes `write_step` would serialize, for codec benchmarks and
    /// compression-ratio tests. Advances the step counter like
    /// `write_step`.
    pub fn component_payloads(&mut self) -> Vec<(String, Vec<u8>)> {
        let idx = self.step;
        let mut out = Vec::with_capacity(7);
        for record in ["position", "momentum"] {
            for comp in ["x", "y", "z"] {
                let mut buf = vec![0u8; self.n * 4];
                self.fill_component(record, &mut buf, idx);
                out.push((var_name(idx, "e", record, comp), buf));
            }
        }
        let mut buf = vec![0u8; self.n * 4];
        Self::fill_constant(&mut buf, 1.0);
        out.push((var_name(idx, "e", "weighting", SCALAR), buf));
        self.step += 1;
        out
    }

    pub fn steps_written(&self) -> u64 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::bp::{BpReader, BpWriter, WriterCtx};
    use crate::adios::ops::{self, OpCtx, OpsReport};

    #[test]
    fn produces_seven_components_with_right_sizes() {
        let path = std::env::temp_dir()
            .join(format!("synth-{}.bp", std::process::id()));
        let mut p = SyntheticProducer::new(0, 100, 0, 100, 1);
        assert_eq!(p.bytes_per_step(), 100 * 28);
        let mut w =
            BpWriter::create(&path, WriterCtx::default()).unwrap();
        p.write_step(&mut w).unwrap();
        w.close().unwrap();

        let mut r = BpReader::open(&path).unwrap();
        r.begin_step().unwrap();
        let vars = r.available_variables();
        assert_eq!(vars.len(), 7);
        assert!(vars.iter().all(|v| v.shape == vec![100]));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sizing_by_bytes() {
        let p = SyntheticProducer::with_bytes_per_step(0, 28_000, 4, 2);
        assert_eq!(p.n, 1000);
        assert_eq!(p.global_n, 4000);
        assert_eq!(p.bytes_per_step(), 28_000);
    }

    #[test]
    fn deterministic_per_seed() {
        let path1 = std::env::temp_dir()
            .join(format!("synth-d1-{}.bp", std::process::id()));
        let path2 = std::env::temp_dir()
            .join(format!("synth-d2-{}.bp", std::process::id()));
        for p in [&path1, &path2] {
            let mut prod = SyntheticProducer::new(3, 50, 0, 50, 99);
            let mut w =
                BpWriter::create(p, WriterCtx::default()).unwrap();
            prod.write_step(&mut w).unwrap();
            w.close().unwrap();
        }
        assert_eq!(std::fs::read(&path1).unwrap(),
                   std::fs::read(&path2).unwrap());
        std::fs::remove_file(&path1).ok();
        std::fs::remove_file(&path2).ok();
    }

    #[test]
    fn payload_helper_matches_write_step_shape() {
        let mut p = SyntheticProducer::new(0, 64, 0, 64, 7);
        let payloads = p.component_payloads();
        assert_eq!(payloads.len(), 7);
        assert!(payloads.iter().all(|(_, b)| b.len() == 64 * 4));
        assert_eq!(p.steps_written(), 1);
        // Component names follow the openPMD layout.
        assert!(payloads[0].0.contains("/position/x"));
        assert!(payloads[6].0.contains("/weighting"));
    }

    /// The acceptance bar for the operator subsystem: `shuffle|rle`
    /// over the synthetic producer's fields reduces the step by more
    /// than 1.5x (the fig_compression bench measures the same thing
    /// over a real SST-TCP stream).
    #[test]
    fn shuffle_rle_beats_1_5x_on_producer_fields() {
        let chain = OpChain::parse("shuffle|rle").unwrap();
        let mut p = SyntheticProducer::new(0, 20_000, 0, 20_000, 42);
        let payloads = p.component_payloads();
        let mut report = OpsReport::default();
        for (name, raw) in &payloads {
            let octx = OpCtx {
                dtype: Datatype::F32,
                extent: &[raw.len() as u64 / 4],
            };
            let framed =
                ops::encode_bytes(&chain, &octx, raw, &mut report)
                    .unwrap();
            // Lossless: decodes back to the exact input.
            let mut dec_report = OpsReport::default();
            let back = ops::decode_bytes(&chain, &octx, &framed,
                                         raw.len(), &mut dec_report)
                .unwrap();
            assert_eq!(*back, *raw, "{name}");
        }
        assert!(report.ratio() > 1.5,
                "shuffle|rle ratio {:.2} <= 1.5 over {} raw bytes",
                report.ratio(), report.raw_bytes_in);
    }
}
